//! Engine-simulator throughput — the planner's inner loop and therefore
//! the dominant term of "extra time". Compares per-token stepping with
//! the aggregated fast-step path (bit-identical results, fewer loop
//! iterations) and reports simulated tokens/sec as the trajectory metric.
//!
//! Emits `BENCH_simulator.json` (schema documented in
//! `docs/SIMULATOR_PERF.md`): per request-set size the median fast-step
//! and per-token sim times, `sim_tokens_per_sec` for the fast path, and
//! `fast_step_ratio` (per-token / fast-step — the speedup). The largest
//! set runs the fast path only; per-token stepping there is what the
//! fast path exists to avoid. Run with:
//!
//! ```text
//! cargo bench --bench bench_simulator
//! ```

use samullm::cluster::ClusterSpec;
use samullm::costmodel::{CostModel, HardwareModel};
use samullm::engine::sim::{EngineConfig, EngineSim};
use samullm::engine::EngineRequest;
use samullm::models::Registry;
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;
use samullm::util::rng::Rng;

fn requests(n: usize, seed: u64) -> Vec<EngineRequest> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|i| {
            let out = samullm::workload::lengths::true_output_len(
                "vicuna-13b-v1.5",
                0.0,
                30,
                512,
                4096,
                &mut rng,
            );
            EngineRequest::fresh(i, 30, out)
        })
        .collect()
}

fn main() {
    // --smoke: tiny CI configuration (one small request set, 3 samples).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let spec = registry.get("vicuna-13b-v1.5").unwrap().clone();
    let hw = HardwareModel::new(cluster.clone());
    let cm = CostModel::calibrated(&cluster, 1);

    let mut g = BenchGroup::new("simulator");
    g.sample_size(if smoke { 3 } else { 5 });
    // The fast path makes a 10x larger set than the old per-token ceiling
    // (10k) cheap enough to bench; per-token stepping stops at 10k.
    let sizes: &[usize] = if smoke { &[200] } else { &[1000, 10_000, 100_000] };
    let per_token_max = if smoke { 200 } else { 10_000 };
    let mut rows: Vec<Json> = vec![];
    for &n in sizes {
        let reqs = requests(n, 3);
        let tokens: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let fast_median = g
            .bench(&format!("fast_step_{n}"), || {
                let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
                let mut sim = EngineSim::new(&spec, 1, &hw, cfg, reqs.clone(), 0.0, 0);
                sim.run(None)
            })
            .median;
        let per_token_median = (n <= per_token_max).then(|| {
            g.bench(&format!("per_token_{n}"), || {
                let mut cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
                cfg.fast_step = false;
                let mut sim = EngineSim::new(&spec, 1, &hw, cfg, reqs.clone(), 0.0, 0);
                sim.run(None)
            })
            .median
        });
        g.bench(&format!("linear_model_{n}"), || {
            let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
            let mut sim = EngineSim::new(&spec, 1, &cm.iter_model, cfg, reqs.clone(), 0.0, 0);
            sim.run(None)
        });
        rows.push(Json::obj(vec![
            ("n_requests", Json::Num(n as f64)),
            ("tokens", Json::Num(tokens as f64)),
            ("fast_step_s", Json::Num(fast_median)),
            (
                "per_token_s",
                match per_token_median {
                    Some(t) => Json::Num(t),
                    None => Json::Null,
                },
            ),
            (
                "sim_tokens_per_sec",
                Json::Num(tokens as f64 / fast_median.max(1e-12)),
            ),
            (
                "fast_step_ratio",
                match per_token_median {
                    Some(t) => Json::Num(t / fast_median.max(1e-12)),
                    None => Json::Null,
                },
            ),
        ]));
    }
    g.finish();

    let doc = Json::obj(vec![
        ("bench", Json::Str("simulator".to_string())),
        ("model", Json::Str(spec.name.clone())),
        ("smoke", Json::Bool(smoke)),
        ("sets", Json::Arr(rows)),
    ])
    .to_string();
    std::fs::write("BENCH_simulator.json", format!("{doc}\n"))
        .expect("write BENCH_simulator.json");
    println!("wrote BENCH_simulator.json");
}
