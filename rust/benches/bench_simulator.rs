//! Engine-simulator throughput — the planner's inner loop and therefore
//! the dominant term of "extra time". Compares the exact per-iteration
//! path with the fast-forward event-jump path.

use samullm::cluster::ClusterSpec;
use samullm::costmodel::{CostModel, HardwareModel};
use samullm::engine::sim::{EngineConfig, EngineSim};
use samullm::engine::EngineRequest;
use samullm::models::Registry;
use samullm::util::bench::BenchGroup;
use samullm::util::rng::Rng;

fn requests(n: usize, seed: u64) -> Vec<EngineRequest> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|i| {
            let out = samullm::workload::lengths::true_output_len(
                "vicuna-13b-v1.5",
                0.0,
                30,
                512,
                4096,
                &mut rng,
            );
            EngineRequest::fresh(i, 30, out)
        })
        .collect()
}

fn main() {
    // --smoke: tiny CI configuration (one small request set, 3 samples).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let spec = registry.get("vicuna-13b-v1.5").unwrap().clone();
    let hw = HardwareModel::new(cluster.clone());
    let cm = CostModel::calibrated(&cluster, 1);

    let mut g = BenchGroup::new("simulator");
    if smoke {
        g.sample_size(3);
    }
    let sizes: &[usize] = if smoke { &[200] } else { &[1000, 10000] };
    let exact_at = sizes[0];
    for &n in sizes {
        let reqs = requests(n, 3);
        g.bench(&format!("fast_forward_{n}"), || {
            let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
            let mut sim = EngineSim::new(&spec, 1, &hw, cfg, reqs.clone(), 0.0, 0);
            sim.run(None)
        });
        if n == exact_at {
            g.bench(&format!("exact_{n}"), || {
                let mut cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
                cfg.fast_forward = false;
                let mut sim = EngineSim::new(&spec, 1, &hw, cfg, reqs.clone(), 0.0, 0);
                sim.run(None)
            });
        }
        g.bench(&format!("linear_model_{n}"), || {
            let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
            let mut sim = EngineSim::new(&spec, 1, &cm.iter_model, cfg, reqs.clone(), 0.0, 0);
            sim.run(None)
        });
    }
    g.finish();
}
