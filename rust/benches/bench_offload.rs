//! Model-residency benchmarks: the same multi-model batch is run three
//! ways — (a) oversubscribed on a deliberately too-small cluster (packed
//! stages time-slice the GPUs, loads overlap decode tails), (b) naively
//! sequential on the same cluster (one model at a time, every cold load
//! on the critical path), and (c) on a cluster big enough to hold every
//! model at once (the no-swap reference). Reports per-arm makespan and
//! the oversubscribed arm's swap counters; the headline bit is
//! `packed_beats_sequential`. Writes `BENCH_offload.json`; `--smoke`
//! shrinks the batch to CI size.

use samullm::cluster::ClusterSpec;
use samullm::graph::AppGraph;
use samullm::metrics::RunReport;
use samullm::runner::{run_policy, AppRequest, RunOpts, Scenario};
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

const SEED: u64 = 42;

/// `n_models` independent chatglm3-6b nodes, `n_reqs` requests each, with
/// deterministic mixed lengths. `n_models = 1` carves the single-model
/// slice the sequential arm runs one at a time.
fn scenario(n_models: usize, n_reqs: usize) -> Scenario {
    let mut graph = AppGraph::default();
    let mut workloads = vec![];
    for i in 0..n_models {
        graph.add_node("chatglm3-6b", &format!("m{i}"), 256);
        workloads.push(
            (0..n_reqs as u64)
                .map(|id| AppRequest::simple(id, 24, 30 + (id * 13 % 90) as u32))
                .collect::<Vec<_>>(),
        );
    }
    Scenario { name: "offload-batch".into(), graph, workloads }
}

fn completions(r: &RunReport) -> u64 {
    r.timeline.iter().map(|s| s.events.completions).sum()
}

struct Arm {
    makespan: f64,
    wall: f64,
    report: Option<RunReport>,
}

fn bench_arm(
    label: &str,
    g: &mut BenchGroup,
    mut run: impl FnMut() -> (f64, Option<RunReport>),
) -> Arm {
    let mut result: Option<(f64, Option<RunReport>)> = None;
    let wall = g
        .bench(label, || {
            result = Some(run());
        })
        .median;
    let (makespan, report) = result.expect("bench ran at least one sample");
    Arm { makespan, wall, report }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_models, n_reqs) = if smoke { (3, 12) } else { (4, 48) };
    let total = (n_models * n_reqs) as u64;
    let tiny = ClusterSpec::a100_node(2);
    // Four GPUs hold every model of either batch size at once (three-GPU
    // nodes would break the power-of-two placement alignment).
    let big = ClusterSpec::a100_node(4);

    let mut g = BenchGroup::new("offload");
    g.sample_size(if smoke { 2 } else { 3 });

    // (a) Oversubscribed: all models planned together on two GPUs.
    let over = bench_arm("oversubscribed/2gpu", &mut g, || {
        let s = scenario(n_models, n_reqs);
        let opts = RunOpts { seed: SEED, oversubscribe: true, ..RunOpts::default() };
        let r = run_policy("ours", &s, &tiny, &opts);
        assert_eq!(completions(&r), total, "oversubscribed arm lost requests");
        (r.inference_time, Some(r))
    });

    // (b) Naive sequential: one model at a time on the same two GPUs;
    // every cold load sits on the critical path and nothing overlaps.
    let seq = bench_arm("sequential/2gpu", &mut g, || {
        let mut makespan = 0.0;
        let mut done = 0u64;
        for _model in 0..n_models {
            let s = scenario(1, n_reqs);
            let r = run_policy("ours", &s, &tiny, &RunOpts { seed: SEED, ..RunOpts::default() });
            done += completions(&r);
            makespan += r.inference_time;
        }
        assert_eq!(done, total, "sequential arm lost requests");
        (makespan, None)
    });

    // (c) Fits-in-HBM reference: enough GPUs for everything at once.
    let fits = bench_arm("fits/4gpu", &mut g, || {
        let s = scenario(n_models, n_reqs);
        let r = run_policy("ours", &s, &big, &RunOpts { seed: SEED, ..RunOpts::default() });
        assert_eq!(completions(&r), total, "fits arm lost requests");
        (r.inference_time, Some(r))
    });
    g.finish();

    let or = over.report.as_ref().expect("oversubscribed report");
    let res = or.residency;
    let packed_beats_sequential = over.makespan < seq.makespan;
    println!(
        "makespan: oversubscribed {:.1}s vs sequential {:.1}s vs fits {:.1}s ({})",
        over.makespan,
        seq.makespan,
        fits.makespan,
        if packed_beats_sequential { "packing wins" } else { "sequential wins" }
    );
    println!(
        "swaps: in={} out={} moved={:.1}GB stalled={:.1}s overlapped={:.1}s",
        res.swaps_in,
        res.swaps_out,
        (res.bytes_in + res.bytes_out) as f64 / 1e9,
        res.stall_seconds,
        res.overlapped_seconds
    );
    if let Some(fr) = &fits.report {
        assert_eq!(fr.residency.swaps_in + fr.residency.swaps_out, 0, "fits arm swapped");
    }

    let arm_json = |label: &str, a: &Arm| {
        Json::obj(vec![
            ("arm", Json::Str(label.to_string())),
            ("makespan_s", Json::Num(a.makespan)),
            ("throughput_rps", Json::Num(total as f64 / a.makespan)),
            ("wall_s", Json::Num(a.wall)),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::Str("offload".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("n_models", Json::Num(n_models as f64)),
        ("n_requests_per_model", Json::Num(n_reqs as f64)),
        (
            "arms",
            Json::Arr(vec![
                arm_json("oversubscribed", &over),
                arm_json("sequential", &seq),
                arm_json("fits_in_hbm", &fits),
            ]),
        ),
        (
            "residency",
            Json::obj(vec![
                ("swaps_in", Json::Num(res.swaps_in as f64)),
                ("swaps_out", Json::Num(res.swaps_out as f64)),
                ("bytes_in", Json::Num(res.bytes_in as f64)),
                ("bytes_out", Json::Num(res.bytes_out as f64)),
                ("stall_seconds", Json::Num(res.stall_seconds)),
                ("overlapped_seconds", Json::Num(res.overlapped_seconds)),
            ]),
        ),
        ("packed_beats_sequential", Json::Bool(packed_beats_sequential)),
    ])
    .to_string();
    std::fs::write("BENCH_offload.json", format!("{doc}\n")).expect("write BENCH_offload.json");
    println!("wrote BENCH_offload.json");
}
