//! Serving-discipline benchmark: the retired static-bucket loop (kept
//! here as an inline reference implementation) vs the unified
//! continuous-batching `PjrtBackend` on the same synthetic requests.
//!
//! Static buckets drain a whole batch before admitting the next one, so
//! mixed output lengths leave seats idle; continuous batching refills a
//! seat the moment its request completes. The gap shows up directly in
//! wall time and decode-step counts.
//!
//! Requires `make artifacts`; skipped gracefully (and records the skip in
//! `BENCH_serve.json`) when they are absent.

use samullm::exec::pjrt::PjrtBackend;
use samullm::runtime::{default_artifacts_dir, TinyGpt};
use samullm::serve::{serve_requests, synthetic_requests};
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

/// The old `ServeEngine::serve` static-bucket loop, preserved verbatim in
/// spirit as the comparison baseline: fill a bucket of up to `batch()`
/// prompts, prefill once, decode until every request in the bucket hits
/// its budget, then move to the next bucket.
fn serve_static_buckets(
    model: &TinyGpt,
    requests: &[(u64, Vec<i32>, usize)],
) -> anyhow::Result<(u64, u64, u64)> {
    let b = model.batch();
    let s = model.max_seq();
    let mut prefills = 0u64;
    let mut decode_steps = 0u64;
    let mut total_tokens = 0u64;
    for bucket in requests.chunks(b) {
        let mut tokens = vec![0i32; b * s];
        let mut lengths = vec![1i32; b];
        let mut budgets = vec![0usize; b];
        for (row, (_, prompt, max_new)) in bucket.iter().enumerate() {
            let plen = prompt.len().min(s - max_new.min(s - 1) - 1).max(1);
            tokens[row * s..row * s + plen].copy_from_slice(&prompt[..plen]);
            lengths[row] = plen as i32;
            budgets[row] = max_new.min(s - plen - 1);
        }
        let out = model.prefill(&tokens, &lengths)?;
        prefills += 1;
        let mut state = out.state;
        let mut next = model.argmax(&out.logits);
        let mut pos: Vec<i32> = lengths.clone();
        let mut produced = vec![0usize; b];
        for row in 0..bucket.len() {
            if budgets[row] > 0 {
                produced[row] = 1;
                total_tokens += 1;
            }
        }
        let max_budget = budgets.iter().copied().max().unwrap_or(0);
        for _step in 1..max_budget {
            if (0..bucket.len()).all(|r| produced[r] >= budgets[r]) {
                break;
            }
            let out = model.decode(&next, state, &pos)?;
            decode_steps += 1;
            state = out.state;
            let sampled = model.argmax(&out.logits);
            for row in 0..bucket.len() {
                if produced[row] >= budgets[row] {
                    continue;
                }
                pos[row] += 1;
                next[row] = sampled[row];
                produced[row] += 1;
                total_tokens += 1;
            }
        }
    }
    Ok((prefills, decode_steps, total_tokens))
}

fn main() {
    // --smoke: tiny CI configuration (fewer requests + samples).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let dir = default_artifacts_dir();
    if !dir.join("model_meta.json").exists() {
        eprintln!("bench_serve skipped: run `make artifacts` first");
        let doc = Json::obj(vec![
            ("bench", Json::Str("serve".to_string())),
            ("skipped", Json::Bool(true)),
            ("reason", Json::Str("artifacts missing (make artifacts)".to_string())),
        ])
        .to_string();
        std::fs::write("BENCH_serve.json", format!("{doc}\n")).expect("write BENCH_serve.json");
        return;
    }

    // Mixed-length workload: the regime where static buckets waste seats.
    let n = if smoke { 16 } else { 48 };
    let (requests, prompts) = synthetic_requests(n, 12, 4, 11);
    let mut mixed = requests.clone();
    for (i, r) in mixed.iter_mut().enumerate() {
        r.output_len = 4 + (i as u32 % 5) * 6; // 4..28 tokens
    }
    let bucket_reqs: Vec<(u64, Vec<i32>, usize)> = mixed
        .iter()
        .map(|r| (r.id, prompts[&r.id].clone(), r.output_len as usize))
        .collect();

    let model = TinyGpt::load(&dir).expect("load artifacts");
    let mut g = BenchGroup::new("serve");
    g.sample_size(if smoke { 3 } else { 5 });

    let static_median = g
        .bench("static_buckets", || serve_static_buckets(&model, &bucket_reqs).unwrap())
        .median;
    let (s_prefills, s_decodes, s_tokens) = serve_static_buckets(&model, &bucket_reqs).unwrap();

    let mut backend = PjrtBackend::load(&dir).unwrap();
    let continuous_median = g
        .bench("continuous_batching", || {
            serve_requests(&mut backend, &mixed, &prompts).unwrap()
        })
        .median;
    let (results, metrics) = serve_requests(&mut backend, &mixed, &prompts).unwrap();
    assert_eq!(results.len(), n, "continuous batching must complete everything");
    g.finish();

    let doc = Json::obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("skipped", Json::Bool(false)),
        ("n_requests", Json::Num(n as f64)),
        ("static_buckets_s", Json::Num(static_median)),
        ("continuous_batching_s", Json::Num(continuous_median)),
        ("speedup", Json::Num(static_median / continuous_median.max(1e-12))),
        ("static_prefills", Json::Num(s_prefills as f64)),
        ("static_decode_steps", Json::Num(s_decodes as f64)),
        ("static_tokens", Json::Num(s_tokens as f64)),
        ("continuous_prefills", Json::Num(metrics.prefills as f64)),
        ("continuous_decode_steps", Json::Num(metrics.decode_steps as f64)),
        ("continuous_tokens", Json::Num(metrics.total_tokens as f64)),
        ("continuous_p99_latency_s", Json::Num(metrics.p99_latency)),
    ])
    .to_string();
    std::fs::write("BENCH_serve.json", format!("{doc}\n")).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
