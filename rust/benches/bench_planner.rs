//! Planner search time ("extra time" in §5): Algorithm 1 over the four
//! paper applications, sequential vs parallel + memoized evaluation.
//!
//! Emits `BENCH_planner.json` (schema documented in
//! `docs/PLANNER_PERF.md` and `docs/SIMULATOR_PERF.md`): per app the
//! median sequential and parallel+cached search times, the speedup, the
//! cache counters, a plan-parity bit asserting the two searches committed
//! identical stages and `est_total`, and a time-boxed arm (quarter of the
//! sequential median) with its `budget_exhausted` flag. Run with:
//!
//! ```text
//! cargo bench --bench bench_planner
//! ```

use std::sync::Arc;

use samullm::cluster::ClusterSpec;
use samullm::costmodel::CostModel;
use samullm::models::Registry;
use samullm::planner::{GreedyPlanner, SimCache};
use samullm::runner::Scenario;
use samullm::spec::AppSpec;
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

fn planner(cost: &CostModel, cluster: &ClusterSpec) -> GreedyPlanner {
    GreedyPlanner::new(cost.clone(), Registry::paper(), cluster.clone())
}

fn main() {
    // --smoke: tiny CI configuration (small apps, 3 samples).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cluster = ClusterSpec::a100_node(8);
    let cost = CostModel::calibrated(&cluster, 1);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8);

    let apps: Vec<(&str, Scenario)> = if smoke {
        vec![
            ("ensembling", AppSpec::ensembling(120, 256).build(42).expect("spec")),
            ("mixed", AppSpec::mixed(10, 120, 500, 256, 2).build(7).expect("spec")),
        ]
    } else {
        vec![
            ("ensembling", AppSpec::ensembling(1000, 256).build(42).expect("spec")),
            ("routing", AppSpec::routing(4096, false).build(7).expect("spec")),
            ("chain_summary", AppSpec::chain_summary(100, 2, 500).build(7).expect("spec")),
            ("mixed", AppSpec::mixed(100, 1000, 900, 256, 4).build(7).expect("spec")),
        ]
    };

    let mut g = BenchGroup::new("planner");
    g.sample_size(if smoke { 3 } else { 5 });
    let mut rows: Vec<Json> = vec![];
    for (name, s) in &apps {
        // Sequential reference: one thread, private per-search memo only
        // (the pre-evaluator behavior).
        let mut seq = planner(&cost, &cluster);
        seq.threads = 1;
        let seq_median = g
            .bench(&format!("{name}_sequential"), || {
                seq.plan(&s.graph, &s.workloads, false, 7)
            })
            .median;

        // Parallel + cached: worker threads plus a cache shared across
        // samples — the warm repeated-search scenario.
        let cache = Arc::new(SimCache::new());
        let mut par = planner(&cost, &cluster);
        par.threads = threads;
        par.cache = Some(cache.clone());
        let par_median = g
            .bench(&format!("{name}_parallel_cached"), || {
                par.plan(&s.graph, &s.workloads, false, 7)
            })
            .median;

        // Parity: both searches must commit identical plans + estimates.
        let a = seq.plan(&s.graph, &s.workloads, false, 7);
        let b = par.plan(&s.graph, &s.workloads, false, 7);
        let identical = a.stages == b.stages && a.est_total.to_bits() == b.est_total.to_bits();
        assert!(identical, "{name}: parallel+cached plan diverged from sequential");

        // Anytime arm: time-box a cold sequential search to a quarter of
        // the unbudgeted median and report whether it had to stop early
        // (best-so-far plans are still complete and executable).
        let mut boxed = planner(&cost, &cluster);
        boxed.threads = 1;
        boxed.search_budget = Some(seq_median / 4.0);
        let budgeted = boxed.plan(&s.graph, &s.workloads, false, 7);
        assert!(!budgeted.stages.is_empty(), "{name}: budgeted search returned no plan");

        rows.push(Json::obj(vec![
            ("app", Json::Str(name.to_string())),
            ("sequential_s", Json::Num(seq_median)),
            ("parallel_cached_s", Json::Num(par_median)),
            ("speedup", Json::Num(seq_median / par_median.max(1e-12))),
            ("cache_hits", Json::Num(cache.hits() as f64)),
            ("cache_misses", Json::Num(cache.misses() as f64)),
            ("identical_plans", Json::Bool(identical)),
            ("est_total_s", Json::Num(a.est_total)),
            ("n_stages", Json::Num(a.stages.len() as f64)),
            ("budget_s", Json::Num(seq_median / 4.0)),
            ("budgeted_search_s", Json::Num(budgeted.search_time)),
            ("budget_exhausted", Json::Bool(budgeted.eval.budget_exhausted)),
        ]));
    }
    g.finish();

    let doc = Json::obj(vec![
        ("bench", Json::Str("planner".to_string())),
        ("threads", Json::Num(threads as f64)),
        ("apps", Json::Arr(rows)),
    ])
    .to_string();
    std::fs::write("BENCH_planner.json", format!("{doc}\n")).expect("write BENCH_planner.json");
    println!("wrote BENCH_planner.json ({threads} threads)");
}
