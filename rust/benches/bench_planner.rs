//! Planner search time ("extra time" in §5): Algorithm 1 over the paper's
//! applications. The paper reports 22–69 s on its testbed for ensembling;
//! our target is to keep search a small fraction of end-to-end time.

use samullm::cluster::ClusterSpec;
use samullm::costmodel::CostModel;
use samullm::models::Registry;
use samullm::planner::GreedyPlanner;
use samullm::spec::AppSpec;
use samullm::util::bench::BenchGroup;

fn main() {
    let cluster = ClusterSpec::a100_node(8);
    let cost = CostModel::calibrated(&cluster, 1);
    let planner = GreedyPlanner::new(cost, Registry::paper(), cluster);

    let mut g = BenchGroup::new("planner");
    g.sample_size(5);
    for n in [1000usize, 4000] {
        let s = AppSpec::ensembling(n, 256).build(42).expect("spec");
        g.bench(&format!("ensembling_{n}"), || {
            planner.plan(&s.graph, &s.workloads, false, 7)
        });
    }
    let s = AppSpec::routing(4096, false).build(7).expect("spec");
    g.bench("routing", || planner.plan(&s.graph, &s.workloads, false, 7));
    let s = AppSpec::chain_summary(100, 2, 500).build(7).expect("spec");
    g.bench("chain_summary", || planner.plan(&s.graph, &s.workloads, false, 7));
    g.finish();
}
