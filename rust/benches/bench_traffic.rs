//! Open-loop traffic benchmarks: (1) open-loop serving vs submitting the
//! same request population as one closed-loop batch — the queueing-delay
//! price of arrival pacing and the admission queue; (2) weighted fair
//! share at 2:1 vs unweighted on two identical overloaded streams — the
//! weight must measurably shift p99 latency between the apps. Writes
//! `BENCH_traffic.json`; `--smoke` shrinks windows and sample counts to
//! CI size.

use samullm::cluster::ClusterSpec;
use samullm::harness::poisson_pair_traffic;
use samullm::metrics::RunReport;
use samullm::runner::{run_traffic, run_workload, RunOpts};
use samullm::spec::{AppSpec, ArrivalSpec, TrafficEntry, TrafficSpec, WorkloadEntry, WorkloadSpec};
use samullm::traffic::QueuePolicy;
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

const SEED: u64 = 42;

fn opts() -> RunOpts {
    RunOpts { seed: SEED, ..RunOpts::default() }
}

/// Open-loop: the paced streams through the admission queue. Closed-loop:
/// the same two apps as a batch workload, everything present at t = 0.
/// The contrast prices the serving dynamics (queueing + pacing) against
/// pure batch throughput on identical hardware.
fn open_vs_closed(smoke: bool, cluster: &ClusterSpec, g: &mut BenchGroup) -> Json {
    let duration = if smoke { 12.0 } else { 60.0 };
    let spec = poisson_pair_traffic(1.5, 1.0, 2.0, duration);
    let ts = spec.build(SEED).expect("valid traffic mix");
    let mut open: Option<RunReport> = None;
    let open_wall = g
        .bench("open_vs_closed/open_loop", || {
            open = Some(run_traffic("ours", &ts, cluster, &opts()));
        })
        .median;
    let wl = WorkloadSpec {
        name: "closed-pair".into(),
        entries: spec
            .entries
            .iter()
            .map(|e| WorkloadEntry::new(e.app.clone()))
            .collect(),
    };
    let ws = wl.build(SEED).expect("valid workload");
    let mut closed: Option<RunReport> = None;
    let closed_wall = g
        .bench("open_vs_closed/closed_loop", || {
            closed = Some(run_workload("ours", &ws, cluster, &opts()));
        })
        .median;
    let open = open.expect("bench ran at least one sample");
    let closed = closed.expect("bench ran at least one sample");
    let t = open.traffic.as_ref().expect("traffic section");
    println!(
        "open vs closed: open-loop served {} jobs in {:.1}s, closed-loop batch {:.1}s",
        t.admitted, open.inference_time, closed.inference_time
    );
    let per_app: Vec<Json> = t
        .per_app
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("name", Json::Str(a.name.clone())),
                ("admitted", Json::Num(a.admitted as f64)),
                ("ttft_mean_s", opt_num(a.ttft_mean)),
                ("latency_p50_s", opt_num(a.latency_p50)),
                ("latency_p99_s", opt_num(a.latency_p99)),
                ("slo_attainment", opt_num(a.slo_attainment)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("window_s", Json::Num(duration)),
        ("open_inference_s", Json::Num(open.inference_time)),
        ("closed_inference_s", Json::Num(closed.inference_time)),
        ("offered", Json::Num(t.offered as f64)),
        ("admitted", Json::Num(t.admitted as f64)),
        ("queue_depth_mean", Json::Num(t.queue_depth_mean)),
        ("per_app", Json::Arr(per_app)),
        ("open_wall_s", Json::Num(open_wall)),
        ("closed_wall_s", Json::Num(closed_wall)),
    ])
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

/// Two identical overloaded streams; one run gives app 0 weight 2, the
/// control run keeps both at weight 1. The weighted run must shift p99
/// latency toward the favoured app.
fn weighted_vs_unweighted(smoke: bool, cluster: &ClusterSpec, g: &mut BenchGroup) -> Json {
    let duration = if smoke { 10.0 } else { 45.0 };
    let mix = |weight_a: f64| {
        let entry = |weight: f64| TrafficEntry {
            app: AppSpec::ensembling(24, 96),
            process: ArrivalSpec::Poisson { rate: 2.5 },
            weight,
            slo: Some(30.0),
            seed: Some(7),
        };
        TrafficSpec {
            name: format!("fairness-w{weight_a:.0}"),
            entries: vec![entry(weight_a), entry(1.0)],
            duration,
            warmup: 0.0,
            queue_capacity: 2,
            queue_policy: QueuePolicy::Defer,
            admit_quantum: 1,
        }
    };
    let run = |label: &str, weight_a: f64, g: &mut BenchGroup| {
        let ts = mix(weight_a).build(SEED).expect("valid traffic mix");
        let mut report: Option<RunReport> = None;
        let wall = g
            .bench(&format!("fairness/{label}"), || {
                report = Some(run_traffic("round-robin", &ts, cluster, &opts()));
            })
            .median;
        (report.expect("bench ran at least one sample"), wall)
    };
    let (weighted, weighted_wall) = run("weighted_2to1", 2.0, g);
    let (flat, flat_wall) = run("unweighted", 1.0, g);
    let wt = weighted.traffic.as_ref().expect("traffic section");
    let ft = flat.traffic.as_ref().expect("traffic section");
    let p99 = |t: &samullm::metrics::latency::TrafficReport, app: usize| {
        t.per_app[app].latency_p99.unwrap_or(f64::NAN)
    };
    let weighted_gap = p99(wt, 1) - p99(wt, 0);
    let flat_gap = p99(ft, 1) - p99(ft, 0);
    println!(
        "fairness: weighted p99 app0 {:.2}s / app1 {:.2}s (gap {:.2}s), \
         unweighted gap {:.2}s",
        p99(wt, 0),
        p99(wt, 1),
        weighted_gap,
        flat_gap
    );
    Json::obj(vec![
        ("window_s", Json::Num(duration)),
        ("weighted_p99_app0_s", Json::Num(p99(wt, 0))),
        ("weighted_p99_app1_s", Json::Num(p99(wt, 1))),
        ("unweighted_p99_app0_s", Json::Num(p99(ft, 0))),
        ("unweighted_p99_app1_s", Json::Num(p99(ft, 1))),
        ("weighted_p99_gap_s", Json::Num(weighted_gap)),
        ("unweighted_p99_gap_s", Json::Num(flat_gap)),
        (
            "weight_shifts_p99",
            Json::Bool(weighted_gap > flat_gap && p99(wt, 0) < p99(wt, 1)),
        ),
        ("weighted_deferred", Json::Num(wt.deferred as f64)),
        ("weighted_wall_s", Json::Num(weighted_wall)),
        ("unweighted_wall_s", Json::Num(flat_wall)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cluster = ClusterSpec::a100_node(8);
    let mut g = BenchGroup::new("traffic");
    g.sample_size(if smoke { 3 } else { 5 });

    let open_closed = open_vs_closed(smoke, &cluster, &mut g);
    let fairness = weighted_vs_unweighted(smoke, &cluster, &mut g);
    g.finish();

    let doc = Json::obj(vec![
        ("bench", Json::Str("traffic".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("open_vs_closed", open_closed),
        ("fairness", fairness),
    ])
    .to_string();
    std::fs::write("BENCH_traffic.json", format!("{doc}\n"))
        .expect("write BENCH_traffic.json");
    println!("wrote BENCH_traffic.json");
}
