//! Admission-policy benchmarks on the engine scheduler: a heavy-tailed
//! closed batch (1% long jobs at the head of the FCFS queue) run under
//! every admission policy, plus an adversarially mispredicted variant
//! (long jobs predicted short and vice versa) that prices the cost of
//! trusting bad length predictions. Reports per-policy p50/p99 request
//! latency, throughput, and the admission counters; the headline bit is
//! `spjf_beats_fcfs_p99` on the heavy-tailed trace. Writes
//! `BENCH_admission.json`; `--smoke` shrinks the trace to CI size.

use samullm::cluster::ClusterSpec;
use samullm::costmodel::HardwareModel;
use samullm::engine::sim::{EngineConfig, EngineSim};
use samullm::engine::{AdmitPolicy, EngineRequest, EventKind, SimOutcome};
use samullm::models::Registry;
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

const SEED: u64 = 42;
const MAX_NUM_SEQS: usize = 8;

/// Heavy-tailed closed batch: `n_long` long jobs take the lowest ids (so
/// FCFS admits them first — worst-case head-of-line blocking) and the
/// short crowd queues behind them. Everything is ready at t = 0, so a
/// request's completion time *is* its latency.
fn heavy_tailed(n: usize, n_long: usize) -> Vec<EngineRequest> {
    let mut reqs = Vec::with_capacity(n);
    for i in 0..n as u64 {
        let (input, output) = if (i as usize) < n_long {
            (32 + (i % 3) as u32 * 8, 1200 + (i % 4) as u32 * 100)
        } else {
            (12 + (i % 7) as u32, 4 + (i % 12) as u32)
        };
        let mut r = EngineRequest::fresh(i, input, output);
        r.predicted_len = output;
        reqs.push(r);
    }
    reqs
}

/// The same trace with predictions swapped across the tail: long jobs
/// claim to be short and shorts claim to be long. Length-aware policies
/// now actively favour the long jobs.
fn mispredicted(n: usize, n_long: usize) -> Vec<EngineRequest> {
    let mut reqs = heavy_tailed(n, n_long);
    for r in reqs.iter_mut() {
        r.predicted_len = if r.output_len >= 1000 { 6 } else { 1300 };
    }
    reqs
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct PolicyRun {
    out: SimOutcome,
    p50: f64,
    p99: f64,
    wall: f64,
}

/// Run one policy over `reqs`, collecting per-request completion-time
/// latencies from the event stream.
fn run_policy(
    label: &str,
    admit: AdmitPolicy,
    reqs: &[EngineRequest],
    g: &mut BenchGroup,
) -> PolicyRun {
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let spec = registry.get("chatglm3-6b").expect("paper model");
    let hw = HardwareModel::new(cluster.clone());
    let mut result: Option<(SimOutcome, Vec<f64>)> = None;
    let wall = g
        .bench(label, || {
            let mut cfg = EngineConfig::standard(spec, 1, cluster.mem_bytes)
                .expect("engine config");
            cfg.max_num_seqs = MAX_NUM_SEQS;
            cfg.admit = admit;
            let mut sim =
                EngineSim::new(spec, 1, &hw, cfg, reqs.to_vec(), 0.0, SEED);
            sim.enable_events(0, 0);
            let out = sim.run(None);
            let mut lat: Vec<f64> = sim
                .take_events()
                .iter()
                .filter_map(|e| match e.kind {
                    EventKind::Completed { .. } => Some(e.t),
                    _ => None,
                })
                .collect();
            lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
            result = Some((out, lat));
        })
        .median;
    let (out, lat) = result.expect("bench ran at least one sample");
    assert!(out.finished == reqs.len(), "{label}: policy lost requests");
    PolicyRun { p50: quantile(&lat, 0.50), p99: quantile(&lat, 0.99), wall, out }
}

fn policy_json(name: &str, r: &PolicyRun, n: usize) -> Json {
    Json::obj(vec![
        ("policy", Json::Str(name.to_string())),
        ("latency_p50_s", Json::Num(r.p50)),
        ("latency_p99_s", Json::Num(r.p99)),
        ("makespan_s", Json::Num(r.out.clock)),
        ("throughput_rps", Json::Num(n as f64 / r.out.clock)),
        ("queue_jumps", Json::Num(r.out.admit.queue_jumps as f64)),
        ("promotions", Json::Num(r.out.admit.promotions as f64)),
        ("max_queue_wait_s", Json::Num(r.out.admit.max_queue_wait)),
        ("wall_s", Json::Num(r.wall)),
    ])
}

fn sweep(tag: &str, reqs: &[EngineRequest], g: &mut BenchGroup) -> Vec<(String, PolicyRun)> {
    let policies = [
        ("fcfs", AdmitPolicy::Fcfs),
        ("spjf", AdmitPolicy::Spjf),
        ("multi-bin:4", AdmitPolicy::MultiBin { bins: 4 }),
        ("skip-join:4:5", AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after: 5.0 }),
    ];
    policies
        .into_iter()
        .map(|(name, admit)| {
            let r = run_policy(&format!("{tag}/{name}"), admit, reqs, g);
            println!(
                "{tag}/{name}: p50 {:.2}s p99 {:.2}s makespan {:.1}s \
                 jumps {} promotions {}",
                r.p50, r.p99, r.out.clock, r.out.admit.queue_jumps, r.out.admit.promotions
            );
            (name.to_string(), r)
        })
        .collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n, n_long) = if smoke { (120, 2) } else { (400, 4) };
    let mut g = BenchGroup::new("admission");
    g.sample_size(if smoke { 2 } else { 3 });

    let heavy = sweep("heavy_tailed", &heavy_tailed(n, n_long), &mut g);
    let swapped = sweep("mispredicted", &mispredicted(n, n_long), &mut g);
    g.finish();

    let p99_of = |runs: &[(String, PolicyRun)], name: &str| {
        runs.iter().find(|(n, _)| n == name).expect("policy present").1.p99
    };
    let spjf_beats_fcfs = p99_of(&heavy, "spjf") < p99_of(&heavy, "fcfs");
    println!(
        "heavy-tailed p99: fcfs {:.2}s vs spjf {:.2}s ({})",
        p99_of(&heavy, "fcfs"),
        p99_of(&heavy, "spjf"),
        if spjf_beats_fcfs { "spjf wins" } else { "fcfs wins" }
    );

    let doc = Json::obj(vec![
        ("bench", Json::Str("admission".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("n_requests", Json::Num(n as f64)),
        ("n_long", Json::Num(n_long as f64)),
        (
            "heavy_tailed",
            Json::Arr(heavy.iter().map(|(name, r)| policy_json(name, r, n)).collect()),
        ),
        (
            "mispredicted",
            Json::Arr(swapped.iter().map(|(name, r)| policy_json(name, r, n)).collect()),
        ),
        ("spjf_beats_fcfs_p99", Json::Bool(spjf_beats_fcfs)),
    ])
    .to_string();
    std::fs::write("BENCH_admission.json", format!("{doc}\n"))
        .expect("write BENCH_admission.json");
    println!("wrote BENCH_admission.json");
}
