//! Concurrent-vs-sequential measured stage lowering: the same two-node
//! disjoint-GPU stage runs through `ExecState::run_stage_concurrent`
//! (event-loop interleaving, stage wall-clock = max over nodes) and
//! `ExecState::run_stage_measured` (chained nodes, wall-clock = sum) on
//! a `MockModel` whose every prefill/decode call sleeps, so measured
//! durations are dominated by identical per-call device time. Both arms
//! must complete the same request set; the headline bit is
//! `concurrent_beats_sequential` on the *reported* stage span. Writes
//! `BENCH_concurrent.json`; `--smoke` shrinks the workload to CI size.

use samullm::exec::pjrt::{MockModel, PjrtBackend};
use samullm::graph::AppGraph;
use samullm::models::Registry;
use samullm::plan::{ExecPlan, Stage, StageEntry};
use samullm::runner::state::ExecState;
use samullm::runner::AppRequest;
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

fn pair_scenario(n_reqs: u64, out_len: u32) -> (AppGraph, Vec<Vec<AppRequest>>) {
    let mut g = AppGraph::default();
    g.add_node("chatglm3-6b", "left", 64);
    g.add_node("mistral-7b-instruct", "right", 64);
    let w = |_node: usize| -> Vec<AppRequest> {
        (0..n_reqs)
            .map(|id| AppRequest::simple(id, 8, 2 + (id as u32 * 7 % out_len)))
            .collect()
    };
    (g, vec![w(0), w(1)])
}

fn stage_of(g: &AppGraph) -> Stage {
    Stage {
        entries: (0..g.n_nodes())
            .map(|n| StageEntry { node: n, plan: ExecPlan::new(1, 1) })
            .collect(),
    }
}

struct Arm {
    span: f64,
    completions: usize,
    wall: f64,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_reqs, out_len, delay) = if smoke { (4u64, 6u32, 0.002) } else { (12, 10, 0.003) };
    let reg = Registry::paper();

    let mut g = BenchGroup::new("concurrent");
    g.sample_size(if smoke { 2 } else { 3 });

    let mut run_arm = |label: &str, concurrent: bool| -> Arm {
        let mut result: Option<(f64, usize)> = None;
        let wall = g
            .bench(label, || {
                let (graph, w) = pair_scenario(n_reqs, out_len);
                let s = stage_of(&graph);
                let mut st = ExecState::init(&w, |_, r| r.true_output_len);
                let mut be =
                    PjrtBackend::with_model(Box::new(MockModel::new(4, 64).with_delay(delay)));
                let res = if concurrent {
                    st.run_stage_concurrent(&s, &graph, &reg, &mut be, None)
                } else {
                    st.run_stage_measured(&s, &graph, &reg, &mut be, None)
                }
                .expect("mock backend is infallible");
                assert!(st.all_done(), "{label}: stage left requests unfinished");
                result = Some((res.end - res.start, st.completed.len()));
            })
            .median;
        let (span, completions) = result.expect("bench ran at least one sample");
        Arm { span, completions, wall }
    };

    let con = run_arm("concurrent/2node", true);
    let seq = run_arm("sequential/2node", false);
    g.finish();

    assert_eq!(
        con.completions, seq.completions,
        "lowerings completed different request sets"
    );
    let concurrent_beats_sequential = con.span < seq.span;
    println!(
        "stage span: concurrent {:.3}s vs sequential {:.3}s ({}), {} completions each",
        con.span,
        seq.span,
        if concurrent_beats_sequential { "event loop wins" } else { "sequential wins" },
        con.completions
    );

    let arm_json = |label: &str, a: &Arm| {
        Json::obj(vec![
            ("arm", Json::Str(label.to_string())),
            ("stage_span_s", Json::Num(a.span)),
            ("completions", Json::Num(a.completions as f64)),
            ("wall_s", Json::Num(a.wall)),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::Str("concurrent".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("n_requests_per_node", Json::Num(n_reqs as f64)),
        ("per_call_delay_s", Json::Num(delay)),
        (
            "arms",
            Json::Arr(vec![arm_json("concurrent", &con), arm_json("sequential", &seq)]),
        ),
        ("speedup", Json::Num(seq.span / con.span.max(1e-12))),
        ("concurrent_beats_sequential", Json::Bool(concurrent_beats_sequential)),
    ])
    .to_string();
    std::fs::write("BENCH_concurrent.json", format!("{doc}\n"))
        .expect("write BENCH_concurrent.json");
    println!("wrote BENCH_concurrent.json");
}
