//! End-to-end application runs (plan + execute) for each paper experiment:
//! the meso-benchmarks behind Figs. 7, 8, 11 and 12. Wall-clock here is
//! our framework's cost to schedule+simulate the whole application —
//! the paper's "extra time" plus the runner's bookkeeping.

use samullm::cluster::ClusterSpec;
use samullm::runner::{run_policy, RunOpts};
use samullm::spec::AppSpec;
use samullm::util::bench::BenchGroup;

fn main() {
    // --smoke: tiny CI configuration (shrunken apps, 3 samples).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cluster = ClusterSpec::a100_node(8);
    let opts = RunOpts::default();
    let mut g = BenchGroup::new("e2e_apps");
    g.sample_size(if smoke { 3 } else { 4 });
    let n_reqs = if smoke { 100 } else { 1000 };
    let n_docs = if smoke { 10 } else { 100 };

    let s = AppSpec::ensembling(n_reqs, 256).build(42).expect("spec");
    g.bench("fig7_ensembling_ours", || run_policy("ours", &s, &cluster, &opts));
    g.bench("fig7_ensembling_max", || {
        run_policy("max-heuristic", &s, &cluster, &opts)
    });
    g.bench("fig7_ensembling_min", || {
        run_policy("min-heuristic", &s, &cluster, &opts)
    });

    if !smoke {
        let s = AppSpec::routing(4096, false).build(7).expect("spec");
        g.bench("fig8_routing_ours", || run_policy("ours", &s, &cluster, &opts));
    }

    let s = AppSpec::chain_summary(n_docs, 2, 500).build(7).expect("spec");
    g.bench("fig11_chain_summary_ours", || run_policy("ours", &s, &cluster, &opts));

    let s = AppSpec::mixed(n_docs, n_reqs, 900, 256, 4).build(7).expect("spec");
    g.bench("fig12_mixed_ours", || run_policy("ours", &s, &cluster, &opts));
    g.finish();
}
