//! End-to-end application runs (plan + execute) for each paper experiment:
//! the meso-benchmarks behind Figs. 7, 8, 11 and 12. Wall-clock here is
//! our framework's cost to schedule+simulate the whole application —
//! the paper's "extra time" plus the runner's bookkeeping.

use samullm::apps::{chain_summary, ensembling, mixed, routing};
use samullm::baselines::PolicyKind;
use samullm::cluster::ClusterSpec;
use samullm::runner::{run_policy, RunOpts};
use samullm::util::bench::BenchGroup;

fn main() {
    let cluster = ClusterSpec::a100_node(8);
    let opts = RunOpts::default();
    let mut g = BenchGroup::new("e2e_apps");
    g.sample_size(4);

    let s = ensembling::build(1000, 256, 42);
    g.bench("fig7_ensembling_1k_ours", || run_policy(PolicyKind::SamuLlm, &s, &cluster, &opts));
    g.bench("fig7_ensembling_1k_max", || {
        run_policy(PolicyKind::MaxHeuristic, &s, &cluster, &opts)
    });
    g.bench("fig7_ensembling_1k_min", || {
        run_policy(PolicyKind::MinHeuristic, &s, &cluster, &opts)
    });

    let s = routing::build(4096, 7);
    g.bench("fig8_routing_ours", || run_policy(PolicyKind::SamuLlm, &s, &cluster, &opts));

    let s = chain_summary::build(100, 2, 500, 7);
    g.bench("fig11_chain_summary_ours", || run_policy(PolicyKind::SamuLlm, &s, &cluster, &opts));

    let s = mixed::build(100, 1000, 900, 256, 4, 7);
    g.bench("fig12_mixed_ours", || run_policy(PolicyKind::SamuLlm, &s, &cluster, &opts));
    g.finish();
}
