//! Multi-app workload benchmarks: joint planning of N concurrent apps on
//! one cluster vs running the same apps sequentially (each with the whole
//! cluster to itself), on a 2-app and a 4-app workload — the §5.4
//! "mixed application" argument generalised to the workload layer — plus
//! a staggered-arrival scenario exercising the arrival→forced-replan
//! path. Writes `BENCH_workload.json`; `--smoke` shrinks workloads and
//! sample counts to CI size.

use samullm::cluster::ClusterSpec;
use samullm::harness::staggered_pair_workload;
use samullm::metrics::RunReport;
use samullm::runner::{run_policy, run_workload, RunOpts, WorkloadScenario};
use samullm::spec::{AppSpec, WorkloadEntry, WorkloadSpec};
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

const SEED: u64 = 42;

fn opts() -> RunOpts {
    RunOpts { seed: SEED, ..RunOpts::default() }
}

/// Joint: the composed workload, planned and executed as one run.
/// Sequential: each entry's scenario run on its own (whole cluster,
/// same per-entry seeds), inference times summed — the "run the apps one
/// after another" baseline the paper's §5.4 compares against.
fn joint_vs_sequential(
    label: &str,
    wl: &WorkloadSpec,
    cluster: &ClusterSpec,
    g: &mut BenchGroup,
) -> Json {
    let ws: WorkloadScenario = wl.build(SEED).expect("bench workloads are valid");
    let mut joint: Option<RunReport> = None;
    let joint_wall = g
        .bench(&format!("{label}/joint"), || {
            joint = Some(run_workload("ours", &ws, cluster, &opts()));
        })
        .median;
    let scenarios: Vec<_> = wl
        .entries
        .iter()
        .enumerate()
        .map(|(i, e)| e.app.build(wl.entry_seed(i, SEED)).expect("valid entry"))
        .collect();
    let mut sequential: Vec<RunReport> = vec![];
    let seq_wall = g
        .bench(&format!("{label}/sequential"), || {
            sequential = scenarios
                .iter()
                .map(|s| run_policy("ours", s, cluster, &opts()))
                .collect();
        })
        .median;

    let joint = joint.expect("bench ran at least one sample");
    let seq_inference: f64 = sequential.iter().map(|r| r.inference_time).sum();
    let seq_e2e: f64 = sequential.iter().map(|r| r.end_to_end_time).sum();
    println!(
        "{label}: joint {:.1}s vs sequential {:.1}s ({:.2}x)",
        joint.inference_time,
        seq_inference,
        seq_inference / joint.inference_time.max(1e-12)
    );
    let per_app: Vec<Json> = joint
        .workload
        .as_ref()
        .expect("workload runs carry per-app stats")
        .per_app
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("name", Json::Str(a.name.clone())),
                ("makespan_s", Json::Num(a.makespan)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("n_apps", Json::Num(wl.entries.len() as f64)),
        ("joint_inference_s", Json::Num(joint.inference_time)),
        ("joint_e2e_s", Json::Num(joint.end_to_end_time)),
        ("sequential_inference_s", Json::Num(seq_inference)),
        ("sequential_e2e_s", Json::Num(seq_e2e)),
        (
            "joint_speedup",
            Json::Num(seq_inference / joint.inference_time.max(1e-12)),
        ),
        (
            "joint_faster",
            Json::Bool(joint.inference_time < seq_inference),
        ),
        ("per_app", Json::Arr(per_app)),
        ("joint_wall_s", Json::Num(joint_wall)),
        ("sequential_wall_s", Json::Num(seq_wall)),
    ])
}

fn staggered_bench(smoke: bool, cluster: &ClusterSpec, g: &mut BenchGroup) -> Json {
    let (docs, ens, arrival) = if smoke { (8, 80, 50.0) } else { (30, 400, 120.0) };
    let ws = staggered_pair_workload(docs, ens, arrival)
        .build(SEED)
        .expect("valid workload");
    let mut report: Option<RunReport> = None;
    let wall = g
        .bench("staggered/joint_with_arrival", || {
            report = Some(run_workload("ours", &ws, cluster, &opts()));
        })
        .median;
    let report = report.expect("bench ran at least one sample");
    let w = report.workload.as_ref().expect("per-app stats");
    let late = &w.per_app[1];
    println!(
        "staggered: arrival={arrival:.0}s replans={} late-app stretch {:.1}s, total {:.1}s",
        w.arrival_replans, late.makespan, report.inference_time
    );
    Json::obj(vec![
        ("arrival_s", Json::Num(arrival)),
        ("arrivals", Json::Num(w.arrivals as f64)),
        ("arrival_replans", Json::Num(w.arrival_replans as f64)),
        ("late_app_stretch_s", Json::Num(late.makespan)),
        ("early_app_makespan_s", Json::Num(w.per_app[0].makespan)),
        ("joint_inference_s", Json::Num(report.inference_time)),
        ("wall_s", Json::Num(wall)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cluster = ClusterSpec::a100_node(8);
    let mut g = BenchGroup::new("workload");
    g.sample_size(if smoke { 3 } else { 5 });

    let (docs, ens) = if smoke { (8, 100) } else { (30, 500) };
    let two_app = staggered_pair_workload(docs, ens, 0.0);
    let two = joint_vs_sequential("two_app", &two_app, &cluster, &mut g);

    let (d4, e4) = if smoke { (5, 60) } else { (15, 250) };
    let four_app = WorkloadSpec {
        name: "four-app".into(),
        entries: vec![
            WorkloadEntry::new(AppSpec::chain_summary(d4, 2, 300)),
            WorkloadEntry::new(AppSpec::ensembling(e4, 128)),
            WorkloadEntry::new(AppSpec::chain_summary(d4, 1, 200)),
            WorkloadEntry::new(AppSpec::ensembling(e4, 96)),
        ],
    };
    let four = joint_vs_sequential("four_app", &four_app, &cluster, &mut g);

    let staggered = staggered_bench(smoke, &cluster, &mut g);
    g.finish();

    let doc = Json::obj(vec![
        ("bench", Json::Str("workload".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("two_app", two),
        ("four_app", four),
        ("staggered", staggered),
    ])
    .to_string();
    std::fs::write("BENCH_workload.json", format!("{doc}\n"))
        .expect("write BENCH_workload.json");
    println!("wrote BENCH_workload.json");
}
