//! Real-path benchmarks: PJRT prefill/decode steps of the AOT-compiled
//! TinyGPT (requires `make artifacts`; benches are skipped otherwise).

use samullm::runtime::{default_artifacts_dir, TinyGpt};
use samullm::util::bench::BenchGroup;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("model_meta.json").exists() {
        eprintln!("bench_runtime skipped: run `make artifacts` first");
        return;
    }
    let model = TinyGpt::load(&dir).expect("load artifacts");
    let b = model.batch();
    let s = model.max_seq();
    let mut tokens = vec![0i32; b * s];
    for row in 0..b {
        for i in 0..16 {
            tokens[row * s + i] = ((row * 7 + i) % 500 + 1) as i32;
        }
    }
    let lengths = vec![16i32; b];

    let mut g = BenchGroup::new("runtime");
    g.sample_size(8);
    g.bench("prefill_b8_s128", || model.prefill(&tokens, &lengths).unwrap());

    let out = model.prefill(&tokens, &lengths).unwrap();
    let next = model.argmax(&out.logits);
    let pos = vec![16i32; b];
    g.bench("decode_step_b8", || {
        let o = model.prefill(&tokens, &lengths).unwrap();
        model.decode(&next, o.state, &pos).unwrap()
    });
    // A short generation loop: prefill + 16 decode steps.
    g.bench("generate_16_tokens_b8", || {
        let o = model.prefill(&tokens, &lengths).unwrap();
        let mut state = o.state;
        let mut nxt = model.argmax(&o.logits);
        let mut p: Vec<i32> = lengths.clone();
        for _ in 0..16 {
            let o = model.decode(&nxt, state, &p).unwrap();
            state = o.state;
            nxt = model.argmax(&o.logits);
            for x in p.iter_mut() {
                *x += 1;
            }
        }
        nxt
    });
    g.finish();
}
