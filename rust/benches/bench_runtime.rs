//! Runtime benchmarks, two parts:
//!
//! 1. **Length-feedback loop** (always runs): a workload whose true
//!    output lengths are shifted away from the offline No Robots trace —
//!    the exact regime where frozen planning-time estimates go wrong.
//!    Runs `ours` with frozen estimates vs with online refinement
//!    (conditional posterior re-estimation + drift-triggered replanning)
//!    and writes `BENCH_runtime.json` with both virtual makespans, the
//!    replan/drift counters and the wall-clock cost of each run.
//! 2. **PJRT microbenches** (requires `make artifacts`; skipped
//!    otherwise): prefill/decode steps of the AOT-compiled TinyGPT.
//!
//! `--smoke` shrinks the workload and sample counts to CI size.

use samullm::cluster::ClusterSpec;
use samullm::harness::shifted_length_scenario;
use samullm::runner::{run_policy, RunOpts};
use samullm::runtime::{default_artifacts_dir, TinyGpt};
use samullm::util::bench::BenchGroup;
use samullm::util::json::Json;

fn feedback_bench(smoke: bool) -> Json {
    let cluster = ClusterSpec::a100_node(8);
    let n_requests = if smoke { 60 } else { 250 };
    // Shared with tests/integration_online.rs, so the CI guard and these
    // published numbers measure the exact same miscalibrated workload.
    let scenario = shifted_length_scenario(n_requests, 42);

    let frozen_opts = RunOpts { seed: 42, ..RunOpts::default() };
    let online_opts = RunOpts { online_refinement: true, ..frozen_opts.clone() };

    let mut g = BenchGroup::new("runtime_feedback");
    g.sample_size(if smoke { 3 } else { 5 });
    // Runs are deterministic per seed, so the reports the timed closures
    // produce ARE the experiment results — keep the last one instead of
    // paying two extra end-to-end runs afterwards.
    let mut frozen = None;
    let frozen_wall = g
        .bench("frozen_estimates", || {
            frozen = Some(run_policy("ours", &scenario, &cluster, &frozen_opts));
        })
        .median;
    let mut online = None;
    let online_wall = g
        .bench("online_refinement", || {
            online = Some(run_policy("ours", &scenario, &cluster, &online_opts));
        })
        .median;
    g.finish();

    let frozen = frozen.expect("bench ran at least one sample");
    let online = online.expect("bench ran at least one sample");
    let stats = online.online.expect("online run must report feedback stats");
    println!(
        "shifted-length makespan: frozen {:.1}s vs online {:.1}s ({:+.1}%), \
         replans={} max-drift={:.2}",
        frozen.inference_time,
        online.inference_time,
        (online.inference_time / frozen.inference_time - 1.0) * 100.0,
        stats.replans,
        stats.drift
    );

    Json::obj(vec![
        ("scenario", Json::Str(scenario.name.clone())),
        ("n_requests_per_model", Json::Num(n_requests as f64)),
        ("frozen_inference_s", Json::Num(frozen.inference_time)),
        ("online_inference_s", Json::Num(online.inference_time)),
        (
            "online_speedup",
            Json::Num(frozen.inference_time / online.inference_time.max(1e-12)),
        ),
        ("online_faster", Json::Bool(online.inference_time < frozen.inference_time)),
        ("replans", Json::Num(stats.replans as f64)),
        ("max_drift", Json::Num(stats.drift)),
        ("pre_est_total_s", Json::Num(stats.pre_est_total)),
        ("post_est_total_s", Json::Num(stats.post_est_total)),
        ("frozen_wall_s", Json::Num(frozen_wall)),
        ("online_wall_s", Json::Num(online_wall)),
        ("frozen_estimation_error", Json::Num(frozen.estimation_error())),
        ("online_estimation_error", Json::Num(online.estimation_error())),
    ])
}

fn pjrt_bench(smoke: bool) -> Json {
    let dir = default_artifacts_dir();
    if !dir.join("model_meta.json").exists() {
        eprintln!("bench_runtime pjrt part skipped: run `make artifacts` first");
        return Json::obj(vec![
            ("skipped", Json::Bool(true)),
            ("reason", Json::Str("artifacts missing (make artifacts)".to_string())),
        ]);
    }
    let model = TinyGpt::load(&dir).expect("load artifacts");
    let b = model.batch();
    let s = model.max_seq();
    let mut tokens = vec![0i32; b * s];
    for row in 0..b {
        for i in 0..16 {
            tokens[row * s + i] = ((row * 7 + i) % 500 + 1) as i32;
        }
    }
    let lengths = vec![16i32; b];

    let mut g = BenchGroup::new("runtime");
    g.sample_size(if smoke { 3 } else { 8 });
    let prefill = g
        .bench("prefill_b8_s128", || model.prefill(&tokens, &lengths).unwrap())
        .median;

    let out = model.prefill(&tokens, &lengths).unwrap();
    let next = model.argmax(&out.logits);
    let pos = vec![16i32; b];
    let decode = g
        .bench("decode_step_b8", || {
            let o = model.prefill(&tokens, &lengths).unwrap();
            model.decode(&next, o.state, &pos).unwrap()
        })
        .median;
    // A short generation loop: prefill + 16 decode steps.
    let generate = g
        .bench("generate_16_tokens_b8", || {
            let o = model.prefill(&tokens, &lengths).unwrap();
            let mut state = o.state;
            let mut nxt = model.argmax(&o.logits);
            let mut p: Vec<i32> = lengths.clone();
            for _ in 0..16 {
                let o = model.decode(&nxt, state, &p).unwrap();
                state = o.state;
                nxt = model.argmax(&o.logits);
                for x in p.iter_mut() {
                    *x += 1;
                }
            }
            nxt
        })
        .median;
    g.finish();
    Json::obj(vec![
        ("skipped", Json::Bool(false)),
        ("prefill_s", Json::Num(prefill)),
        ("decode_step_s", Json::Num(decode)),
        ("generate_16_s", Json::Num(generate)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let feedback = feedback_bench(smoke);
    let pjrt = pjrt_bench(smoke);
    let doc = Json::obj(vec![
        ("bench", Json::Str("runtime".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("feedback", feedback),
        ("pjrt", pjrt),
    ])
    .to_string();
    std::fs::write("BENCH_runtime.json", format!("{doc}\n")).expect("write BENCH_runtime.json");
    println!("wrote BENCH_runtime.json");
}
