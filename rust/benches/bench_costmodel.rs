//! Per-iteration cost-model pricing: the innermost hot function of every
//! simulation (called once per simulated iteration segment).

use samullm::cluster::ClusterSpec;
use samullm::costmodel::{HardwareModel, IterLatency, LinearIterModel, OutputSampler};
use samullm::models::Registry;
use samullm::util::bench::BenchGroup;
use samullm::util::rng::Rng;

fn main() {
    // --smoke: tiny CI configuration (fewer inner iterations + samples).
    let smoke = std::env::args().any(|a| a == "--smoke");
    let iters = if smoke { 50usize } else { 1000 };
    let draws = if smoke { 500usize } else { 10_000 };
    let cluster = ClusterSpec::a100_node(8);
    let hw = HardwareModel::new(cluster.clone());
    let lm = LinearIterModel::fit_from_profile(&hw);
    let registry = Registry::paper();
    let spec = registry.get("vicuna-13b-v1.5").unwrap().clone();

    let mut g = BenchGroup::new("costmodel");
    if smoke {
        g.sample_size(3);
    }
    g.bench(&format!("hardware_decode_x{iters}"), || {
        let mut acc = 0.0;
        for b in 1..=iters {
            acc += hw.decode(&spec, 1, b % 256 + 1, (b as u64 % 256 + 1) * 300, 320);
        }
        acc
    });
    g.bench(&format!("linear_decode_x{iters}"), || {
        let mut acc = 0.0;
        for b in 1..=iters {
            acc += lm.decode(&spec, 1, b % 256 + 1, (b as u64 % 256 + 1) * 300, 320);
        }
        acc
    });
    let lens = vec![200u32; 64];
    g.bench(&format!("hardware_prefill_64_x{iters}"), || {
        let mut acc = 0.0;
        for _ in 0..iters {
            acc += hw.prefill(&spec, 1, &lens);
        }
        acc
    });
    g.bench("fit_from_profile", || LinearIterModel::fit_from_profile(&hw));
    g.bench("sampler_build", || OutputSampler::from_norobots_trace(1));
    let sampler = OutputSampler::from_norobots_trace(1);
    g.bench(&format!("sampler_draw_{draws}"), || {
        let mut rng = Rng::new(2);
        (0..draws)
            .map(|_| sampler.sample("vicuna-13b-v1.5", 30, 512, 4096, &mut rng))
            .sum::<u32>()
    });
    g.finish();
}
