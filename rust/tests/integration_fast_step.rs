//! End-to-end differentials for the aggregated decode stepping
//! (`fast_step`): the default-on fast path must be bit-identical to
//! per-token stepping on every paper app, under non-FCFS admission, and
//! through the residency packed-stage lowering. These run the full
//! session facade, so they also cover planner replans pricing estimated
//! states with the same flag.

use samullm::metrics::RunReport;
use samullm::session::SamuLlm;
use samullm::spec::{AppSpec, NodeSpec, WorkloadGen};

/// Bit-level equality on everything the simulator determines: virtual
/// times, stage structure, and the per-stage engine event digests.
fn assert_bit_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(
        a.inference_time.to_bits(),
        b.inference_time.to_bits(),
        "{what}: inference time differs ({} vs {})",
        a.inference_time,
        b.inference_time
    );
    let (ea, eb) = (a.estimated_inference_time, b.estimated_inference_time);
    assert!(
        (ea.is_nan() && eb.is_nan()) || ea.to_bits() == eb.to_bits(),
        "{what}: estimate differs ({ea} vs {eb})"
    );
    assert_eq!(a.n_stages, b.n_stages, "{what}: stage count differs");
    for (i, (sa, sb)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(sa.entries, sb.entries, "{what}: stage {i} entries differ");
        assert_eq!(sa.start.to_bits(), sb.start.to_bits(), "{what}: stage {i} start");
        assert_eq!(sa.end.to_bits(), sb.end.to_bits(), "{what}: stage {i} end");
        assert_eq!(sa.events, sb.events, "{what}: stage {i} event digest differs");
    }
}

fn run_pair(spec: &AppSpec, seed: u64) -> (RunReport, RunReport) {
    let fast = SamuLlm::builder().gpus(8).seed(seed).build().unwrap().run(spec).unwrap();
    let exact = SamuLlm::builder()
        .gpus(8)
        .seed(seed)
        .fast_step(false)
        .build()
        .unwrap()
        .run(spec)
        .unwrap();
    (fast, exact)
}

#[test]
fn fast_step_matches_per_token_on_ensembling() {
    let (fast, exact) = run_pair(&AppSpec::ensembling(60, 128), 7);
    assert_bit_identical(&fast, &exact, "ensembling");
    assert!(fast.inference_time > 0.0);
}

#[test]
fn fast_step_matches_per_token_on_routing() {
    let (fast, exact) = run_pair(&AppSpec::routing(512, false), 11);
    assert_bit_identical(&fast, &exact, "routing");
}

#[test]
fn fast_step_matches_per_token_on_chain_summary() {
    let (fast, exact) = run_pair(&AppSpec::chain_summary(6, 1, 200), 13);
    assert_bit_identical(&fast, &exact, "chain-summary");
}

#[test]
fn fast_step_matches_per_token_on_mixed() {
    let (fast, exact) = run_pair(&AppSpec::mixed(4, 40, 160, 96, 1), 17);
    assert_bit_identical(&fast, &exact, "mixed");
}

#[test]
fn fast_step_matches_per_token_under_non_fcfs_admission() {
    // Non-FCFS policies reorder the waiting queue, which changes which
    // composition windows are stable; the aggregation must still land on
    // the same outcomes.
    let spec = AppSpec::ensembling(50, 128);
    for admit in ["spjf", "multi-bin:4", "skip-join:4:5"] {
        let fast = SamuLlm::builder()
            .gpus(8)
            .seed(19)
            .admit_policy(admit)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        let exact = SamuLlm::builder()
            .gpus(8)
            .seed(19)
            .admit_policy(admit)
            .fast_step(false)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_bit_identical(&fast, &exact, admit);
    }
}

#[test]
fn fast_step_matches_per_token_through_packed_stages() {
    // Three single-GPU models cannot be co-resident on 2 A100s, so the
    // residency subsystem lowers the plan into time-sliced sub-stages
    // with deadline replays — the hardest path for window aggregation
    // (deadlines cut windows short mid-flight).
    let spec = AppSpec::Custom {
        name: "packed-triple".into(),
        nodes: (0..3)
            .map(|i| NodeSpec {
                model: "chatglm3-6b".into(),
                label: format!("m{i}"),
                max_out: 256,
                workload: WorkloadGen::Synthetic { n_requests: 40, input_min: 10, input_max: 60 },
            })
            .collect(),
        edges: vec![],
    };
    let build = |fast_step: bool| {
        SamuLlm::builder()
            .gpus(2)
            .seed(23)
            .oversubscribe(true)
            .fast_step(fast_step)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap()
    };
    let (fast, exact) = (build(true), build(false));
    assert_bit_identical(&fast, &exact, "packed");
    assert_eq!(fast.residency, exact.residency, "packed: swap counters differ");
    assert!(fast.residency.any(), "packed lowering never triggered: {:?}", fast.residency);
    let completions: u64 = fast.timeline.iter().map(|s| s.events.completions).sum();
    assert_eq!(completions, 3 * 40, "all requests drained through sub-stages");
}
