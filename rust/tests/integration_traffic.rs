//! End-to-end tests of the open-loop traffic layer: seeded determinism
//! of every arrival process, Poisson statistics as properties, bounded
//! admission-queue behaviour, weighted fair share as a real priority,
//! and the zero-traffic parity contract (batch paths untouched).

use samullm::cluster::ClusterSpec;
use samullm::harness::{poisson_pair_traffic, staggered_pair_workload};
use samullm::prop_assert;
use samullm::runner::{run_policy, run_traffic, run_workload, RunOpts};
use samullm::session::SamuLlm;
use samullm::spec::{AppSpec, ArrivalSpec, TrafficEntry, TrafficSpec};
use samullm::traffic::{arrivals, AdmissionQueue, QueuePolicy, QueuedJob};
use samullm::util::quickprop;

fn cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

#[test]
fn every_arrival_process_is_seed_deterministic() {
    let dir = std::env::temp_dir().join("samullm_it_trace.txt");
    std::fs::write(&dir, "0.5\n1.25\n# comment\n3.0\n7.5\n").unwrap();
    let procs = vec![
        ArrivalSpec::Poisson { rate: 3.0 },
        ArrivalSpec::OnOff { rate_on: 6.0, rate_off: 0.2, mean_on: 4.0, mean_off: 9.0 },
        ArrivalSpec::Trace { path: dir.display().to_string() },
    ];
    for p in &procs {
        let a = arrivals::generate(p, 99, 50.0).unwrap();
        let b = arrivals::generate(p, 99, 50.0).unwrap();
        assert_eq!(a, b, "{p:?}: same seed must replay the same stream");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "{p:?}: sorted");
        assert!(a.iter().all(|&t| (0.0..50.0).contains(&t)), "{p:?}: in horizon");
    }
    // Different seeds decorrelate the random processes (trace replay is
    // seed-independent by construction).
    for p in &procs[..2] {
        let a = arrivals::generate(p, 99, 50.0).unwrap();
        let c = arrivals::generate(p, 100, 50.0).unwrap();
        assert_ne!(a, c, "{p:?}: seed must matter");
    }
    std::fs::remove_file(&dir).ok();
}

#[test]
fn poisson_interarrival_mean_matches_rate_as_a_property() {
    // Property: over random rates and seeds, the empirical mean gap of a
    // generated Poisson stream is within 15% of 1/rate (the horizon is
    // scaled so every case sees ~600 arrivals).
    quickprop::run(25, 0xA121, |rng| {
        let rate = 0.5 + rng.uniform() * 7.5;
        let horizon = 600.0 / rate;
        let ts = arrivals::generate(&ArrivalSpec::Poisson { rate }, rng.next_u64(), horizon)
            .map_err(|e| e.to_string())?;
        prop_assert!(ts.len() >= 300, "rate {rate:.2}: only {} arrivals", ts.len());
        let gaps: Vec<f64> = std::iter::once(ts[0])
            .chain(ts.windows(2).map(|w| w[1] - w[0]))
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let expect = 1.0 / rate;
        prop_assert!(
            (mean - expect).abs() / expect < 0.15,
            "rate {rate:.2}: mean gap {mean:.4} vs expected {expect:.4}"
        );
        Ok(())
    });
}

#[test]
fn bounded_queue_reject_and_defer_boundaries() {
    let job = |app_id: usize, seq: u64| QueuedJob { app_id, seq, arrival: seq as f64 };
    // Reject: exactly `capacity` jobs fit; the next offer is dropped and
    // counted, and draining one slot reopens the queue.
    let mut q = AdmissionQueue::new(&[1.0], 2, QueuePolicy::Reject);
    assert!(q.offer(job(0, 0)) && q.offer(job(0, 1)));
    assert!(!q.offer(job(0, 2)), "offer past capacity must be rejected");
    assert_eq!(q.counters()[0].rejected, 1);
    assert_eq!(q.pop_fair().unwrap().seq, 0);
    assert!(q.offer(job(0, 3)), "draining reopens the queue");
    // Defer: the overflow parks in the backlog instead, preserving FIFO
    // order through promotion.
    let mut q = AdmissionQueue::new(&[1.0], 2, QueuePolicy::Defer);
    for seq in 0..5 {
        assert!(q.offer(job(0, seq)), "defer never drops");
    }
    assert_eq!(q.counters()[0].deferred, 3);
    assert_eq!(q.len(), 5);
    let order: Vec<u64> = std::iter::from_fn(|| q.pop_fair()).map(|j| j.seq).collect();
    assert_eq!(order, vec![0, 1, 2, 3, 4]);
    assert_eq!(q.counters()[0].admitted, 5);
}

#[test]
fn weighted_fair_share_is_a_real_admission_priority() {
    // Two identical app streams (same spec, same per-entry seed, so the
    // same arrival timestamps and the same request templates) differing
    // only in weight, over an overloaded narrow queue. The weight-2 app
    // must take 2/3 of the admission slots whenever both queues are
    // backlogged, which shows up as strictly better queueing delay.
    let entry = |weight: f64| TrafficEntry {
        app: AppSpec::ensembling(24, 96),
        process: ArrivalSpec::Poisson { rate: 2.5 },
        weight,
        slo: Some(30.0),
        seed: Some(7),
    };
    let spec = TrafficSpec {
        name: "fairness-pair".into(),
        entries: vec![entry(2.0), entry(1.0)],
        duration: 10.0,
        warmup: 0.0,
        queue_capacity: 2,
        queue_policy: QueuePolicy::Defer,
        admit_quantum: 1,
    };
    let ts = spec.build(11).unwrap();
    assert_eq!(ts.apps[0].arrivals, ts.apps[1].arrivals, "paired streams");
    let opts = RunOpts { seed: 11, ..RunOpts::default() };
    let r = run_traffic("round-robin", &ts, &cluster(), &opts);
    let t = r.traffic.expect("traffic section");
    assert!(t.deferred > 0, "the mix must actually overload the queue: {t:?}");
    let (a, b) = (&t.per_app[0], &t.per_app[1]);
    assert_eq!(a.offered, b.offered, "identical streams offer identically");
    let (ttft_a, ttft_b) = (a.ttft_mean.unwrap(), b.ttft_mean.unwrap());
    assert!(
        ttft_a < ttft_b,
        "weight 2 must buy shorter queueing delay: ttft {ttft_a:.3} vs {ttft_b:.3}"
    );
    let (p99_a, p99_b) = (a.latency_p99.unwrap(), b.latency_p99.unwrap());
    assert!(
        p99_a <= p99_b,
        "weight 2 must not worsen tail latency: p99 {p99_a:.3} vs {p99_b:.3}"
    );
}

#[test]
fn traffic_runs_are_deterministic_end_to_end() {
    let ts = poisson_pair_traffic(2.0, 1.0, 2.0, 15.0).build(5).unwrap();
    let opts = RunOpts { seed: 5, ..RunOpts::default() };
    let a = run_traffic("ours", &ts, &cluster(), &opts);
    let b = run_traffic("ours", &ts, &cluster(), &opts);
    assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
    assert_eq!(a.traffic, b.traffic);
    assert_eq!(a.to_json(), b.to_json());
}

#[test]
fn zero_traffic_runs_stay_on_the_batch_path_bit_for_bit() {
    // The parity contract: `run` and `run_workload` know nothing about
    // traffic — their reports carry no serving section, their JSON pins
    // `"traffic":null`, and repeated runs stay bit-identical.
    let opts = RunOpts { seed: 42, ..RunOpts::default() };
    let scenario = AppSpec::ensembling(60, 128).build(42).unwrap();
    let r1 = run_policy("ours", &scenario, &cluster(), &opts);
    let r2 = run_policy("ours", &scenario, &cluster(), &opts);
    assert!(r1.traffic.is_none());
    assert!(r1.to_json().contains("\"traffic\":null"), "{}", r1.to_json());
    assert_eq!(r1.inference_time.to_bits(), r2.inference_time.to_bits());
    assert_eq!(r1.to_json(), r2.to_json());

    let ws = staggered_pair_workload(8, 80, 40.0).build(42).unwrap();
    let w1 = run_workload("ours", &ws, &cluster(), &opts);
    let w2 = run_workload("ours", &ws, &cluster(), &opts);
    assert!(w1.traffic.is_none());
    assert!(w1.to_json().contains("\"traffic\":null"));
    assert_eq!(w1.inference_time.to_bits(), w2.inference_time.to_bits());
    assert_eq!(w1.to_json(), w2.to_json());
}

#[test]
fn session_traffic_round_trips_through_config_json() {
    // A traffic mix survives the ExperimentConfig JSON round-trip and the
    // rebuilt spec reproduces the run bit-for-bit.
    let spec = poisson_pair_traffic(1.5, 1.0, 2.0, 10.0);
    let cfg_json = format!(
        r#"{{"traffic":{},"policy":"ours","n_gpus":8,"seed":9}}"#,
        spec.to_json_string()
    );
    let cfg = samullm::config::ExperimentConfig::from_json(&cfg_json).unwrap();
    let back = cfg.traffic.expect("traffic mode");
    assert_eq!(back, spec);
    let session = SamuLlm::builder().gpus(8).seed(9).build().unwrap();
    let a = session.run_traffic(&spec).unwrap();
    let b = session.run_traffic(&back).unwrap();
    assert_eq!(a.to_json(), b.to_json());
    let t = a.traffic.expect("traffic section");
    assert_eq!(t.per_app.len(), 2);
    // Every reported metric field is present in the JSON contract.
    let json = b.to_json();
    for key in [
        "\"ttft_mean\"",
        "\"ttft_p99\"",
        "\"tpot_mean\"",
        "\"latency_p50\"",
        "\"latency_p99\"",
        "\"slo_attainment\"",
        "\"queue_depth_mean\"",
    ] {
        assert!(json.contains(key), "missing {key} in {json}");
    }
}
