//! Differential-testing layer for the admission-policy work: the FCFS
//! default is pinned bit-identical across every surface (the four paper
//! apps, a multi-app workload run and an open-loop traffic run), each
//! length-aware policy is exercised end-to-end on both the simulated and
//! the real (mock-PJRT) scheduler, and the misprediction-correction loop
//! is regression-tested on the shifted-length scenario.

use samullm::cluster::ClusterSpec;
use samullm::engine::sim::EngineConfig;
use samullm::engine::{AdmitPolicy, AdmitStats, EngineRequest, EngineSim, EventKind};
use samullm::exec::pjrt::{MockModel, PjrtBackend};
use samullm::exec::{ExecBackend, NodeRun};
use samullm::harness::{poisson_pair_traffic, shifted_length_scenario, staggered_pair_workload};
use samullm::metrics::RunReport;
use samullm::models::Registry;
use samullm::plan::ExecPlan;
use samullm::runner::{run_policy, run_traffic, run_workload, RunOpts};
use samullm::spec::AppSpec;

fn cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

fn opts(admit: AdmitPolicy) -> RunOpts {
    RunOpts { seed: 42, admit, ..RunOpts::default() }
}

const NON_FCFS: [AdmitPolicy; 3] = [
    AdmitPolicy::Spjf,
    AdmitPolicy::MultiBin { bins: 4 },
    AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after: 5.0 },
];

/// The bit-level pin: every virtual-time number of `a` and `b` agrees
/// exactly (wall-clock fields like search time are excluded by design).
fn assert_bit_identical(label: &str, a: &RunReport, b: &RunReport) {
    assert_eq!(
        a.inference_time.to_bits(),
        b.inference_time.to_bits(),
        "{label}: inference_time diverged ({} vs {})",
        a.inference_time,
        b.inference_time
    );
    assert_eq!(
        a.estimated_inference_time.to_bits(),
        b.estimated_inference_time.to_bits(),
        "{label}: estimate diverged"
    );
    assert_eq!(a.n_stages, b.n_stages, "{label}: stage count diverged");
    assert_eq!(a.admission, b.admission, "{label}: admission counters diverged");
    for (sa, sb) in a.timeline.iter().zip(&b.timeline) {
        assert_eq!(sa.start.to_bits(), sb.start.to_bits(), "{label}: stage start diverged");
        assert_eq!(sa.end.to_bits(), sb.end.to_bits(), "{label}: stage end diverged");
        assert_eq!(sa.entries, sb.entries, "{label}: stage entries diverged");
    }
}

fn completions(r: &RunReport) -> u64 {
    r.timeline.iter().map(|s| s.events.completions).sum()
}

/// The four paper apps in small configurations, with their total request
/// counts (first-node admissions differ; completions cover all nodes).
fn paper_apps() -> Vec<(&'static str, AppSpec)> {
    vec![
        ("ensembling", AppSpec::ensembling(60, 128)),
        ("routing", AppSpec::routing(512, false)),
        ("chain-summary", AppSpec::chain_summary(15, 1, 200)),
        ("mixed", AppSpec::mixed(10, 120, 300, 96, 2)),
    ]
}

#[test]
fn fcfs_default_is_pinned_bit_identical_across_paper_apps() {
    // The admission layer is strictly opt-in: a default build and an
    // explicit --admit fcfs build must agree on every virtual-time bit,
    // and the counters must stay at their zero defaults.
    let c = cluster();
    for (name, spec) in paper_apps() {
        let s = spec.build(42).expect("valid spec");
        let default_run = run_policy("ours", &s, &c, &RunOpts { seed: 42, ..RunOpts::default() });
        let explicit = run_policy("ours", &s, &c, &opts(AdmitPolicy::Fcfs));
        let again = run_policy("ours", &s, &c, &opts(AdmitPolicy::Fcfs));
        assert_bit_identical(name, &default_run, &explicit);
        assert_bit_identical(name, &explicit, &again);
        assert_eq!(default_run.admit_policy, "fcfs", "{name}");
        assert_eq!(default_run.admission, AdmitStats::default(), "{name}: FCFS touched stats");
        assert!(completions(&default_run) > 0, "{name}: no completions recorded");
    }
}

#[test]
fn fcfs_workload_and_traffic_runs_are_pinned() {
    let c = cluster();
    let ws = staggered_pair_workload(8, 60, 20.0).build(42).expect("valid workload");
    let wa = run_workload("ours", &ws, &c, &RunOpts { seed: 42, ..RunOpts::default() });
    let wb = run_workload("ours", &ws, &c, &opts(AdmitPolicy::Fcfs));
    assert_bit_identical("workload", &wa, &wb);
    assert_eq!(wa.admission, AdmitStats::default());

    let ts = poisson_pair_traffic(1.0, 1.0, 2.0, 10.0).build(42).expect("valid traffic mix");
    let ta = run_traffic("ours", &ts, &c, &RunOpts { seed: 42, ..RunOpts::default() });
    let tb = run_traffic("ours", &ts, &c, &opts(AdmitPolicy::Fcfs));
    assert_bit_identical("traffic", &ta, &tb);
    assert_eq!(ta.admission, AdmitStats::default());
    let sa = ta.traffic.as_ref().expect("traffic section");
    let sb = tb.traffic.as_ref().expect("traffic section");
    assert_eq!((sa.offered, sa.admitted, sa.rejected), (sb.offered, sb.admitted, sb.rejected));
}

#[test]
fn fcfs_engine_ignores_length_predictions_bit_for_bit() {
    // The deepest pin: even with adversarial garbage in `predicted_len`,
    // the FCFS arm must not read it — the outcome is bit-identical to a
    // prediction-free run. This is what keeps the default path byte-equal
    // to the pre-policy engine no matter what the runner installs.
    let reg = Registry::paper();
    let spec = reg.get("chatglm3-6b").unwrap().clone();
    let c = cluster();
    let hw = samullm::costmodel::HardwareModel::new(c.clone());
    let plain: Vec<EngineRequest> = (0..80)
        .map(|i| EngineRequest::fresh(i, 20 + (i % 40) as u32, 8 + (i * 7 % 300) as u32))
        .collect();
    let mut poisoned = plain.clone();
    for r in poisoned.iter_mut() {
        // Anti-correlated predictions: shorts predicted huge, longs tiny.
        r.predicted_len = if r.output_len > 100 { 1 } else { 4096 };
    }
    let cfg = EngineConfig::standard(&spec, 1, c.mem_bytes).unwrap();
    let a = EngineSim::new(&spec, 1, &hw, cfg.clone(), plain, 0.0, 0).run(None);
    let b = EngineSim::new(&spec, 1, &hw, cfg, poisoned, 0.0, 0).run(None);
    assert_eq!(a.clock.to_bits(), b.clock.to_bits(), "FCFS consumed predictions");
    assert_eq!(a, b);
    assert_eq!(a.admit, AdmitStats::default());
}

#[test]
fn policies_are_deterministic_on_the_sim_backend() {
    // Same seed, same policy -> same report, bit for bit, and the
    // non-FCFS policies actually engage (counters move somewhere).
    let c = cluster();
    let s = AppSpec::ensembling(60, 128).build(42).expect("valid spec");
    let mut any_jumps = 0u64;
    for admit in NON_FCFS {
        let a = run_policy("ours", &s, &c, &opts(admit));
        let b = run_policy("ours", &s, &c, &opts(admit));
        assert_bit_identical(&admit.name(), &a, &b);
        assert_eq!(a.admit_policy, admit.name());
        assert!(completions(&a) >= 60, "{}: lost requests", admit.name());
        any_jumps += a.admission.queue_jumps;
    }
    assert!(any_jumps > 0, "no policy ever reordered the queue");
}

/// A `NodeRun` for the mock-PJRT scheduler over `reqs`.
fn node_run<'a>(
    spec: &'a samullm::models::ModelSpec,
    reqs: &'a [EngineRequest],
    admit: AdmitPolicy,
) -> NodeRun<'a> {
    NodeRun {
        node: 0,
        model: "tinygpt",
        spec,
        plan: ExecPlan::new(1, 1),
        requests: reqs,
        start_time: 0.0,
        deadline: None,
        noise_sigma: None,
        noise_seed: 0,
        collect_events: true,
        admit,
        fast_step: true,
    }
}

fn admitted_order(events: &[samullm::engine::EngineEvent]) -> Vec<u64> {
    events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::Admitted { req } => Some(req),
            _ => None,
        })
        .collect()
}

#[test]
fn policies_are_deterministic_on_the_real_scheduler() {
    // The same SchedCore drives the real backend; per-policy admission
    // *order* and generations must be reproducible run-to-run. (Measured
    // wall-clock durations are excluded — they are real time.) Skip-join
    // uses an unreachable promotion clock here so its order cannot depend
    // on measured waits.
    let reg = Registry::paper();
    let spec = reg.get("chatglm3-6b").unwrap().clone();
    let mut reqs: Vec<EngineRequest> =
        (0..16).map(|i| EngineRequest::fresh(i, 6, 4 + (i * 5 % 23) as u32)).collect();
    for r in reqs.iter_mut() {
        r.predicted_len = r.output_len; // perfect predictions
    }
    for admit in [
        AdmitPolicy::Fcfs,
        AdmitPolicy::Spjf,
        AdmitPolicy::MultiBin { bins: 4 },
        AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after: 1e9 },
    ] {
        let run_once = || {
            let mut b = PjrtBackend::with_model(Box::new(MockModel::new(4, 64)));
            let mut out = b.run_node(&node_run(&spec, &reqs, admit)).unwrap();
            out.generations.sort_by_key(|(id, _)| *id);
            (admitted_order(&out.events), out.generations, out.completions.len())
        };
        let (order_a, gens_a, done_a) = run_once();
        let (order_b, gens_b, done_b) = run_once();
        assert_eq!(order_a, order_b, "{}: admission order not reproducible", admit.name());
        assert_eq!(gens_a, gens_b, "{}: generations not reproducible", admit.name());
        assert_eq!(done_a, reqs.len(), "{}: lost requests", admit.name());
        assert_eq!(done_b, reqs.len());
    }
}

#[test]
fn spjf_overtakes_long_jobs_on_the_real_scheduler() {
    // One long prompt enqueued first, shorts behind, four seats: FCFS
    // admits id 0 first; SPJF admits four shorts first and reports the
    // queue jumps. Exercises the policy end-to-end on the real engine.
    let reg = Registry::paper();
    let spec = reg.get("chatglm3-6b").unwrap().clone();
    let mut reqs = vec![EngineRequest::fresh(0, 8, 60)];
    for i in 1..10u64 {
        reqs.push(EngineRequest::fresh(i, 6, 3));
    }
    for r in reqs.iter_mut() {
        r.predicted_len = r.output_len;
    }
    let run = |admit: AdmitPolicy| {
        let mut b = PjrtBackend::with_model(Box::new(MockModel::new(4, 128)));
        b.run_node(&node_run(&spec, &reqs, admit)).unwrap()
    };
    let fcfs = run(AdmitPolicy::Fcfs);
    let spjf = run(AdmitPolicy::Spjf);
    assert_eq!(admitted_order(&fcfs.events)[0], 0, "FCFS must admit arrival order");
    assert_ne!(admitted_order(&spjf.events)[0], 0, "SPJF must overtake the long job");
    assert_eq!(fcfs.replicas[0].admit, AdmitStats::default());
    assert!(spjf.replicas[0].admit.queue_jumps > 0, "{:?}", spjf.replicas[0].admit);
    assert_eq!(fcfs.completions.len(), reqs.len());
    assert_eq!(spjf.completions.len(), reqs.len());
}

#[test]
fn refined_predictions_keep_length_aware_policies_honest() {
    // Misprediction-correction regression (§4.3 feedback loop meets the
    // admission layer): on the deliberately miscalibrated shifted-length
    // scenario, running SPJF/multi-bin with online refinement must
    // complete everything, report policy activity, and not be meaningfully
    // slower than the frozen-prediction variant (it is typically faster;
    // the lenient bound keeps a pathological seed from flaking CI).
    let c = cluster();
    let s = shifted_length_scenario(120, 42);
    let total: u64 = s.workloads.iter().map(|w| w.len() as u64).sum();
    for admit in [AdmitPolicy::Spjf, AdmitPolicy::MultiBin { bins: 4 }] {
        let frozen = run_policy("ours", &s, &c, &opts(admit));
        let refined = run_policy(
            "ours",
            &s,
            &c,
            &RunOpts { online_refinement: true, ..opts(admit) },
        );
        for (label, r) in [("frozen", &frozen), ("refined", &refined)] {
            assert!(
                completions(r) >= total,
                "{label} {} lost requests: {} < {total}",
                admit.name(),
                completions(r)
            );
            assert!(r.inference_time > 0.0, "{label} {} wedged", admit.name());
        }
        assert!(refined.online.is_some(), "{}: refinement stats missing", admit.name());
        assert!(
            refined.inference_time <= frozen.inference_time * 1.10,
            "{}: refined {:.1}s much slower than frozen {:.1}s",
            admit.name(),
            refined.inference_time,
            frozen.inference_time
        );
    }
}
