//! End-to-end tests of the multi-app workload layer: legacy-Mixed
//! bit-compatibility, mid-run arrivals through the forced-replan path,
//! per-app reporting, and every policy running workloads unchanged.

use samullm::apps;
use samullm::cluster::ClusterSpec;
use samullm::harness::staggered_pair_workload;
use samullm::policy;
use samullm::runner::{run_policy, run_workload, RunOpts};
use samullm::session::SamuLlm;
use samullm::spec::{AppSpec, WorkloadEntry, WorkloadSpec};

fn cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

#[test]
fn two_entry_workload_reproduces_legacy_mixed_bit_for_bit() {
    // The compat contract: a 2-entry workload of (chain-summary,
    // ensembling) at arrival 0, seeded exactly like the legacy builder
    // (entry 1 = seed ^ ENSEMBLE_SEED_SALT), must produce the same
    // numbers as `AppSpec::Mixed` on seed 42 — same composed graph, same
    // workloads, same stage sequence, bit-equal clocks.
    let seed = 42u64;
    let wl = WorkloadSpec::new(vec![
        WorkloadEntry {
            app: AppSpec::chain_summary(12, 4, 300),
            arrival: 0.0,
            weight: 1.0,
            seed: Some(seed),
        },
        WorkloadEntry {
            app: AppSpec::ensembling(100, 128),
            arrival: 0.0,
            weight: 1.0,
            seed: Some(seed ^ apps::mixed::ENSEMBLE_SEED_SALT),
        },
    ]);
    let ws = wl.build(seed).unwrap();
    let legacy = AppSpec::mixed(12, 100, 300, 128, 4).build(seed).unwrap();

    // Structural identity of the composition.
    assert_eq!(ws.scenario.graph.n_nodes(), legacy.graph.n_nodes());
    assert_eq!(ws.scenario.graph.edges, legacy.graph.edges);
    for (a, b) in ws.scenario.workloads.iter().zip(&legacy.workloads) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(
                (x.id, x.input_len, x.true_output_len),
                (y.id, y.input_len, y.true_output_len)
            );
            assert_eq!(x.dep, y.dep);
        }
    }

    // Numerical identity of the run.
    let opts = RunOpts { seed, ..RunOpts::default() };
    let joint = run_workload("ours", &ws, &cluster(), &opts);
    let mixed = run_policy("ours", &legacy, &cluster(), &opts);
    assert_eq!(joint.inference_time.to_bits(), mixed.inference_time.to_bits());
    assert_eq!(
        joint.estimated_inference_time.to_bits(),
        mixed.estimated_inference_time.to_bits()
    );
    assert_eq!(joint.n_stages, mixed.n_stages);
    for (a, b) in joint.timeline.iter().zip(&mixed.timeline) {
        assert_eq!(a.start.to_bits(), b.start.to_bits());
        assert_eq!(a.end.to_bits(), b.end.to_bits());
        assert_eq!(a.entries, b.entries);
    }
    // Only the workload run carries the per-app section.
    assert!(mixed.workload.is_none());
    let w = joint.workload.expect("workload section");
    assert_eq!(w.arrivals, 0);
    assert_eq!(w.arrival_replans, 0);
    assert_eq!(w.per_app.len(), 2);
}

#[test]
fn arrival_triggers_forced_replan_and_per_app_report() {
    let wl = staggered_pair_workload(10, 120, 60.0);
    let ws = wl.build(42).unwrap();
    let opts = RunOpts { seed: 42, ..RunOpts::default() };
    let r = run_workload("ours", &ws, &cluster(), &opts);
    let w = r.workload.expect("workload section");
    assert_eq!(w.arrivals, 1, "the ensembling app arrived mid-run");
    assert!(w.arrival_replans >= 1, "arrival must force a re-plan");
    assert_eq!(w.per_app.len(), 2);
    let late = &w.per_app[1];
    assert_eq!(late.arrival, 60.0);
    assert_eq!(late.completed, late.n_requests, "late app ran to completion");
    assert!(late.finish > late.arrival, "work happens only after arrival");
    assert!((late.makespan - (late.finish - late.arrival)).abs() < 1e-12);
    let early = &w.per_app[0];
    assert_eq!(early.completed, early.n_requests);
    assert!(early.makespan > 0.0);
    // No completion of the late app predates its arrival: its stretch is
    // bounded by the global makespan measured from its arrival.
    assert!(late.finish <= r.inference_time + 1e-9);
    // The run is deterministic.
    let again = run_workload("ours", &ws, &cluster(), &opts);
    assert_eq!(r.inference_time.to_bits(), again.inference_time.to_bits());
    assert_eq!(
        again.workload.unwrap().arrival_replans,
        w.arrival_replans
    );
}

#[test]
fn arrival_replans_surface_in_online_stats_when_refinement_is_on() {
    let wl = staggered_pair_workload(8, 80, 50.0);
    let ws = wl.build(7).unwrap();
    let opts = RunOpts { seed: 7, online_refinement: true, ..RunOpts::default() };
    let r = run_workload("ours", &ws, &cluster(), &opts);
    let w = r.workload.as_ref().expect("workload section");
    assert_eq!(w.arrivals, 1);
    assert!(w.arrival_replans >= 1);
    let online = r.online.expect("online stats with refinement on");
    assert!(
        online.replans >= w.arrival_replans,
        "forced arrival replans count into the replan total: {online:?}"
    );
}

#[test]
fn all_policies_run_staggered_workloads_unchanged() {
    let wl = staggered_pair_workload(6, 60, 40.0);
    let ws = wl.build(3).unwrap();
    let opts = RunOpts { seed: 3, ..RunOpts::default() };
    for p in policy::names() {
        let r = run_workload(p, &ws, &cluster(), &opts);
        assert!(r.inference_time > 0.0, "{p}");
        let w = r.workload.expect("workload section");
        assert_eq!(w.arrivals, 1, "{p}");
        assert_eq!(w.per_app.len(), 2, "{p}");
        for a in &w.per_app {
            assert_eq!(a.completed, a.n_requests, "{p}: app {} incomplete", a.app_id);
        }
        if p != "ours" {
            assert_eq!(w.arrival_replans, 0, "{p}: baselines never replan");
        }
        for s in &r.timeline {
            assert!(s.gpus_used() <= 8, "{p} stage over budget");
        }
    }
}

#[test]
fn session_workload_gantt_labels_lanes_by_app() {
    let session = SamuLlm::builder().gpus(8).seed(5).build().unwrap();
    let wl = staggered_pair_workload(5, 40, 0.0);
    let r = session.run_workload(&wl).unwrap();
    let g = samullm::metrics::gantt::render(&r, 60);
    assert!(g.contains("a0 n"), "{g}");
    assert!(g.contains("a1 n"), "{g}");
    assert!(g.contains("workload: arrivals=0"), "{g}");
    assert!(g.contains("makespan="), "{g}");
}
