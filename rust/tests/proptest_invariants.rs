//! Property-based tests on coordinator invariants (routing, batching,
//! placement, planning, state) via the in-tree `quickprop` harness.

use std::collections::HashMap;

use samullm::cluster::{ClusterSpec, Placement};
use samullm::costmodel::{CostModel, Ecdf, HardwareModel, OutputSampler};
use samullm::engine::sim::{EngineConfig, EngineSim};
use samullm::engine::{AdmitPolicy, AdmitStats, EngineRequest, EventKind};
use samullm::exec::SimBackend;
use samullm::graph::AppGraph;
use samullm::models::Registry;
use samullm::plan::ExecPlan;
use samullm::planner::GreedyPlanner;
use samullm::prop_assert;
use samullm::runner::state::{AppRequest, ExecState};
use samullm::util::quickprop;
use samullm::util::rng::Rng;

fn random_requests(rng: &mut Rng, n: usize) -> Vec<EngineRequest> {
    (0..n as u64)
        .map(|i| {
            EngineRequest::fresh(
                i,
                rng.range_u64(1, 600) as u32,
                rng.range_u64(1, 700) as u32,
            )
        })
        .collect()
}

#[test]
fn engine_conserves_requests_and_tokens() {
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let hw = HardwareModel::new(cluster.clone());
    quickprop::run(25, 0xE11, |rng| {
        let name = *rng.choice(&["chatglm3-6b", "vicuna-13b-v1.5", "mistral-7b-instruct"]);
        let spec = registry.get(name).unwrap();
        let tp = *rng.choice(&[1u32, 2]);
        let n = rng.range_usize(1, 400);
        let reqs = random_requests(rng, n);
        let want_tokens: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let cfg = EngineConfig::standard(spec, tp, cluster.mem_bytes).unwrap();
        let mut sim = EngineSim::new(spec, tp, &hw, cfg, reqs, 0.0, rng.next_u64());
        let out = sim.run(None);
        prop_assert!(out.finished == n, "finished {} != {}", out.finished, n);
        prop_assert!(
            out.tokens_generated == want_tokens,
            "tokens {} != {}",
            out.tokens_generated,
            want_tokens
        );
        prop_assert!(sim.is_done(), "sim not done");
        prop_assert!(sim.free_blocks() <= sim.blocks_total(), "block leak");
        prop_assert!(
            sim.free_blocks() == sim.blocks_total(),
            "blocks not all freed: {}/{}",
            sim.free_blocks(),
            sim.blocks_total()
        );
        prop_assert!(out.clock.is_finite() && out.clock > 0.0, "bad clock {}", out.clock);
        Ok(())
    });
}

#[test]
fn every_admission_policy_conserves_requests_and_tokens() {
    // Work conservation is policy-independent: whatever order the waiting
    // queue is drained in, every request finishes, every token is
    // produced, and every KV block comes back. Predictions of arbitrary
    // quality (including absent) must not break any of it.
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let hw = HardwareModel::new(cluster.clone());
    let spec = registry.get("chatglm3-6b").unwrap();
    quickprop::run(16, 0xAD317, |rng| {
        let admit = match rng.range_u64(0, 4) {
            0 => AdmitPolicy::Fcfs,
            1 => AdmitPolicy::Spjf,
            2 => AdmitPolicy::MultiBin { bins: rng.range_u64(1, 7) as u32 },
            _ => AdmitPolicy::SkipJoinMlfq {
                queues: rng.range_u64(1, 7) as u32,
                promote_after: rng.range_f64(0.2, 20.0),
            },
        };
        let n = rng.range_usize(1, 250);
        let mut reqs = random_requests(rng, n);
        for r in reqs.iter_mut() {
            r.predicted_len = rng.range_u64(0, 900) as u32;
            if rng.range_u64(0, 3) == 0 {
                r.ready_time = rng.range_f64(0.0, 20.0);
            }
        }
        let want_tokens: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let mut cfg = EngineConfig::standard(spec, 1, cluster.mem_bytes).unwrap();
        cfg.max_num_seqs = rng.range_usize(2, 64);
        cfg.admit = admit;
        let mut sim = EngineSim::new(spec, 1, &hw, cfg, reqs, 0.0, rng.next_u64());
        let out = sim.run(None);
        prop_assert!(out.finished == n, "{admit:?} finished {} != {n}", out.finished);
        prop_assert!(
            out.tokens_generated == want_tokens,
            "{admit:?} tokens {} != {want_tokens}",
            out.tokens_generated
        );
        prop_assert!(
            sim.free_blocks() == sim.blocks_total(),
            "{admit:?} leaked blocks: {}/{}",
            sim.free_blocks(),
            sim.blocks_total()
        );
        if admit == AdmitPolicy::Fcfs {
            prop_assert!(out.admit == AdmitStats::default(), "FCFS touched counters");
        }
        Ok(())
    });
}

#[test]
fn multi_bin_assignment_is_monotone_and_clamped() {
    // The geometric bin index shared by multi-bin and the skip-join queue
    // levels: monotone non-decreasing in the predicted length, always
    // inside [0, bins), and zero-length lands in the shortest bin.
    quickprop::run(200, 0xB195, |rng| {
        let bins = rng.range_u64(1, 9) as u32;
        let a = rng.range_u64(0, 5000) as u32;
        let b = a + rng.range_u64(0, 5000) as u32;
        let ba = AdmitPolicy::bin_index(a, bins);
        let bb = AdmitPolicy::bin_index(b, bins);
        prop_assert!(ba <= bb, "bin regressed: {a}->{ba} vs {b}->{bb} ({bins} bins)");
        prop_assert!(bb < bins, "bin {bb} out of range for {bins} bins");
        prop_assert!(AdmitPolicy::bin_index(0, bins) == 0, "zero length must be bin 0");
        Ok(())
    });
}

#[test]
fn skip_join_promotion_bounds_starvation_on_heavy_tails() {
    // Randomized heavy-tailed trace, single seat: SPJF starves the long
    // job until the short crowd drains; the skip-join promotion clock —
    // set relative to the measured SPJF starvation so the property is
    // independent of absolute iteration latencies — must cut that wait at
    // least in half, via at least one recorded promotion.
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let hw = HardwareModel::new(cluster.clone());
    let spec = registry.get("chatglm3-6b").unwrap();
    quickprop::run(10, 0x57A2F, |rng| {
        let n_short = rng.range_usize(40, 80);
        let mut reqs = vec![EngineRequest::fresh(
            0,
            16 + rng.range_u64(0, 32) as u32,
            300 + rng.range_u64(0, 200) as u32,
        )];
        for i in 1..=n_short as u64 {
            reqs.push(EngineRequest::fresh(
                i,
                8 + rng.range_u64(0, 12) as u32,
                4 + rng.range_u64(0, 8) as u32,
            ));
        }
        let run = |admit: AdmitPolicy| {
            let mut cfg = EngineConfig::standard(spec, 1, cluster.mem_bytes).unwrap();
            cfg.max_num_seqs = 1;
            cfg.admit = admit;
            let mut sim = EngineSim::new(spec, 1, &hw, cfg, reqs.clone(), 0.0, 7);
            sim.enable_events(0, 0);
            let out = sim.run(None);
            let evs = sim.take_events();
            (out, evs)
        };
        let (spjf_out, spjf_ev) = run(AdmitPolicy::Spjf);
        let long_admit = |evs: &[samullm::engine::EngineEvent]| {
            evs.iter().find_map(|e| match e.kind {
                EventKind::Admitted { req: 0 } => Some(e.t),
                _ => None,
            })
        };
        let starved = long_admit(&spjf_ev).ok_or("long job never admitted under SPJF")?;
        prop_assert!(starved > 0.0, "SPJF admitted the long job before any short");
        let promote_after = starved / 4.0;
        let (skip_out, skip_ev) =
            run(AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after });
        prop_assert!(spjf_out.finished == reqs.len(), "SPJF lost requests");
        prop_assert!(skip_out.finished == reqs.len(), "skip-join lost requests");
        prop_assert!(
            skip_out.admit.promotions >= 1,
            "no promotion despite starvation: {:?}",
            skip_out.admit
        );
        let promoted = long_admit(&skip_ev).ok_or("long job never admitted under skip-join")?;
        prop_assert!(
            promoted <= starved / 2.0,
            "promotion did not bound starvation: {promoted:.2}s vs SPJF {starved:.2}s"
        );
        Ok(())
    });
}

#[test]
fn engine_clock_monotone_and_busy_bounded() {
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let hw = HardwareModel::new(cluster.clone());
    let spec = registry.get("chatglm3-6b").unwrap();
    quickprop::run(20, 0xC10C, |rng| {
        let n = rng.range_usize(5, 150);
        let mut reqs = random_requests(rng, n);
        for r in reqs.iter_mut() {
            r.ready_time = rng.range_f64(0.0, 30.0);
        }
        let cfg = EngineConfig::standard(spec, 1, cluster.mem_bytes).unwrap();
        let mut sim = EngineSim::new(spec, 1, &hw, cfg, reqs, 0.0, 1);
        let mut prev = sim.clock();
        while sim.step() || sim.idle_until_ready() {
            prop_assert!(sim.clock() >= prev, "clock went backwards");
            prev = sim.clock();
            if sim.is_done() {
                break;
            }
        }
        let out = sim.outcome();
        prop_assert!(out.busy_time <= sim.clock() + 1e-9, "busy > wall");
        Ok(())
    });
}

#[test]
fn fast_step_is_bit_identical_to_per_token_stepping() {
    // The aggregated decode stepping is exact, not approximate: on random
    // workloads, admission policies, seat limits, ready times, and jitter
    // streams, the fast path must reproduce the per-token outcome bit for
    // bit (the retired approximate fast-forward mode only promised < 3%
    // clock error here).
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let hw = HardwareModel::new(cluster.clone());
    quickprop::run(16, 0xFA57, |rng| {
        let name = *rng.choice(&["chatglm3-6b", "mistral-7b-instruct", "vicuna-13b-v1.5"]);
        let spec = registry.get(name).unwrap();
        let admit = match rng.range_u64(0, 4) {
            0 => AdmitPolicy::Fcfs,
            1 => AdmitPolicy::Spjf,
            2 => AdmitPolicy::MultiBin { bins: rng.range_u64(1, 7) as u32 },
            _ => AdmitPolicy::SkipJoinMlfq {
                queues: rng.range_u64(1, 7) as u32,
                promote_after: rng.range_f64(0.2, 20.0),
            },
        };
        let n = rng.range_usize(10, 250);
        let mut reqs = random_requests(rng, n);
        for r in reqs.iter_mut() {
            r.predicted_len = rng.range_u64(0, 900) as u32;
            if rng.range_u64(0, 3) == 0 {
                r.ready_time = rng.range_f64(0.0, 20.0);
            }
        }
        let mut cfg = EngineConfig::standard(spec, 1, cluster.mem_bytes).unwrap();
        cfg.max_num_seqs = rng.range_usize(2, 64);
        cfg.admit = admit;
        if rng.range_u64(0, 2) == 0 {
            cfg.noise_sigma = Some(rng.range_f64(0.01, 0.1));
        }
        let seed = rng.next_u64();
        cfg.fast_step = false;
        let exact = EngineSim::new(spec, 1, &hw, cfg.clone(), reqs.clone(), 0.0, seed).run(None);
        cfg.fast_step = true;
        let fast = EngineSim::new(spec, 1, &hw, cfg, reqs, 0.0, seed).run(None);
        prop_assert!(
            fast.clock.to_bits() == exact.clock.to_bits(),
            "{admit:?} clock diverged: {} vs {}",
            fast.clock,
            exact.clock
        );
        prop_assert!(
            fast.busy_time.to_bits() == exact.busy_time.to_bits(),
            "{admit:?} busy time diverged"
        );
        prop_assert!(fast.tokens_generated == exact.tokens_generated, "token mismatch");
        prop_assert!(fast.finished == exact.finished, "finished mismatch");
        Ok(())
    });
}

#[test]
fn placement_transitions_always_valid_and_minimal() {
    let cluster = ClusterSpec::a100_node(8);
    quickprop::run(60, 0x97AC, |rng| {
        let loader = |_o: u64, tp: u32| 10.0 + tp as f64;
        let mut placement = Placement::empty(8);
        for _ in 0..rng.range_usize(1, 6) {
            // Random feasible stage: owners 0..5, dp*tp <= 8 total.
            let mut needs: Vec<(u64, u32, u32)> = vec![];
            let mut budget = 8u32;
            for owner in 0..rng.range_u64(1, 5) {
                let tp = *rng.choice(&[1u32, 2, 4]);
                if tp > budget {
                    continue;
                }
                let dp = rng.range_u64(1, (budget / tp) as u64 + 1) as u32;
                needs.push((owner, dp, tp));
                budget -= dp * tp;
            }
            if needs.is_empty() {
                continue;
            }
            let plan = Placement::transition(&placement, &needs, &cluster, &loader)
                .ok_or("transition failed for feasible needs")?;
            prop_assert!(plan.placement.is_valid(&cluster), "invalid placement");
            // All needs satisfied.
            for (owner, dp, tp) in &needs {
                let got = plan
                    .placement
                    .groups
                    .iter()
                    .filter(|g| g.owner == *owner && g.tp == *tp)
                    .count();
                prop_assert!(got == *dp as usize, "owner {owner} got {got} != dp {dp}");
            }
            // Min-reload: unchanged (owner, tp) pairs from the previous
            // placement are never in new_groups when capacity allows zero
            // moves (checked via identity transition).
            let again = Placement::transition(&plan.placement, &needs, &cluster, &loader)
                .ok_or("identity transition failed")?;
            prop_assert!(again.new_groups.is_empty(), "identity transition reloaded");
            placement = plan.placement;
        }
        Ok(())
    });
}

#[test]
fn ecdf_quantile_cdf_inverse() {
    quickprop::run(50, 0xECDF, |rng| {
        let n = rng.range_usize(1, 500);
        let samples: Vec<u32> = (0..n).map(|_| rng.range_u64(0, 2000) as u32).collect();
        let e = Ecdf::from_samples(samples.clone());
        let q = rng.uniform();
        let x = e.quantile(q);
        prop_assert!(e.cdf(x) + 1e-12 >= q, "cdf(quantile(q)) < q");
        prop_assert!(x >= e.min() && x <= e.max(), "quantile out of support");
        // CDF is monotone.
        let a = rng.range_u64(0, 2000) as u32;
        let b = a + rng.range_u64(0, 100) as u32;
        prop_assert!(e.cdf(a) <= e.cdf(b), "cdf not monotone");
        Ok(())
    });
}

#[test]
fn conditional_ecdf_quantiles_dominate_unconditional() {
    // The feedback loop's conditional view `X | X > d`: for every
    // quantile level and every progress point, the conditional quantile
    // must dominate the unconditional one and exceed the conditioning
    // point — re-estimating an in-flight request can only push its total
    // length up, never below what it already generated.
    quickprop::run(50, 0xC0ND, |rng| {
        let n = rng.range_usize(1, 400);
        let samples: Vec<u32> = (0..n).map(|_| rng.range_u64(0, 1500) as u32).collect();
        let e = Ecdf::from_samples(samples);
        let q = rng.uniform();
        let d = rng.range_u64(0, 1600) as u32;
        match e.quantile_given_gt(q, d) {
            None => prop_assert!(e.tail_count(d) == 0, "None with non-empty tail"),
            Some(x) => {
                prop_assert!(x > d, "conditional quantile {x} <= condition {d}");
                prop_assert!(
                    x >= e.quantile(q),
                    "conditional quantile {x} below unconditional {}",
                    e.quantile(q)
                );
                // Round-trip: the conditional CDF at the conditional
                // quantile covers the requested level.
                prop_assert!(
                    e.cdf_given_gt(x, d) + 1e-12 >= q,
                    "cdf|gt(quantile|gt(q)) < q"
                );
                // Conditional CDF is monotone in x.
                let x2 = x + rng.range_u64(0, 50) as u32;
                prop_assert!(
                    e.cdf_given_gt(x, d) <= e.cdf_given_gt(x2, d) + 1e-12,
                    "conditional cdf not monotone"
                );
            }
        }
        // Conditioning below the support is a no-op: same quantiles.
        if e.min() > 0 {
            prop_assert!(
                e.quantile_given_gt(q, e.min() - 1) == Some(e.quantile(q)),
                "vacuous conditioning changed the quantile"
            );
        }
        Ok(())
    });
}

#[test]
fn online_posterior_with_zero_observations_is_the_offline_ecdf() {
    use samullm::costmodel::OnlineSampler;
    quickprop::run(6, 0x0B5E, |rng| {
        let offline = OutputSampler::from_norobots_trace(rng.next_u64());
        let weight = rng.range_f64(0.0, 128.0);
        let mut online = OnlineSampler::new(offline.clone(), weight);
        let models: Vec<String> = offline.models().map(|m| m.to_string()).collect();
        for m in &models {
            let prior = offline.ecdf(m).unwrap();
            let xs: Vec<u32> = (0..60).map(|i| i * 20).collect();
            prop_assert!(
                online.posterior(m).curve(&xs) == prior.curve(&xs)
                    && online.posterior(m).len() == prior.len(),
                "posterior != prior for {m} before any observation"
            );
            // And sampling consumes the same stream as the offline path.
            let mut r1 = Rng::new(9);
            let mut r2 = Rng::new(9);
            for _ in 0..32 {
                let a = online.sample_total(m, 20, 512, 4096, 0, &mut r1);
                let b = offline.sample(m, 20, 512, 4096, &mut r2);
                prop_assert!(a == b, "zero-observation sample diverged: {a} vs {b}");
            }
        }
        // One observation with positive weight must change the posterior.
        let m = &models[0];
        online.record(m, 5000);
        if weight >= 0.5 {
            prop_assert!(
                online.posterior(m).len() > offline.ecdf(m).unwrap().len(),
                "observation ignored at weight {weight}"
            );
        }
        Ok(())
    });
}

#[test]
fn planner_stages_always_valid() {
    let cluster = ClusterSpec::a100_node(8);
    let cost = CostModel::calibrated(&cluster, 5);
    let registry = Registry::paper();
    let planner = GreedyPlanner::new(cost, registry.clone(), cluster.clone());
    let models = Registry::ensembling_models();
    quickprop::run(8, 0x91A0, |rng| {
        let k = rng.range_usize(2, 6);
        let mut graph = AppGraph::default();
        let mut workloads = vec![];
        for i in 0..k {
            let m = models[rng.range_usize(0, models.len())];
            graph.add_node(m, &format!("m{i}"), 256);
            let n = rng.range_usize(20, 150);
            workloads.push(
                (0..n as u64)
                    .map(|id| {
                        let input = rng.range_u64(5, 127) as u32;
                        AppRequest::simple(id, input, rng.range_u64(5, 256) as u32)
                    })
                    .collect::<Vec<_>>(),
            );
        }
        let plan = planner.plan(&graph, &workloads, false, rng.next_u64());
        prop_assert!(!plan.stages.is_empty(), "empty plan");
        let mut finished: std::collections::HashSet<usize> = Default::default();
        for s in &plan.stages {
            prop_assert!(s.n_gpus() <= 8, "stage over budget: {:?}", s);
            for e in &s.entries {
                let spec = registry.get(&graph.nodes[e.node].model).unwrap();
                prop_assert!(
                    e.plan.is_valid_for(spec, &cluster),
                    "invalid plan {:?} for {}",
                    e.plan,
                    spec.name
                );
                prop_assert!(!finished.contains(&e.node), "finished node rescheduled");
            }
            // Estimated windows are ordered.
            let _ = &mut finished;
        }
        // Every node appears somewhere.
        for nid in 0..k {
            prop_assert!(
                plan.stages.iter().any(|s| s.nodes().contains(&nid)),
                "node {nid} never scheduled"
            );
        }
        Ok(())
    });
}

#[test]
fn exec_state_progress_is_monotone() {
    let cluster = ClusterSpec::a100_node(8);
    let registry = Registry::paper();
    let hw = HardwareModel::new(cluster.clone());
    quickprop::run(10, 0x57A7E, |rng| {
        let mut graph = AppGraph::default();
        graph.add_node("chatglm3-6b", "a", 256);
        graph.add_node("alpaca-13b", "b", 256);
        let w: Vec<Vec<AppRequest>> = (0..2)
            .map(|_| {
                (0..rng.range_u64(20, 200))
                    .map(|id| AppRequest::simple(id, 20, rng.range_u64(10, 300) as u32))
                    .collect()
            })
            .collect();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let stage = samullm::plan::Stage {
            entries: vec![
                samullm::plan::StageEntry { node: 0, plan: ExecPlan::new(4, 1) },
                samullm::plan::StageEntry { node: 1, plan: ExecPlan::new(4, 1) },
            ],
        };
        let mut prev_done = 0usize;
        let mut prev_clock = 0.0f64;
        let mut guard = 0;
        while !st.all_done() {
            guard += 1;
            prop_assert!(guard < 64, "state machine diverged");
            let mut s2 = stage.clone();
            s2.entries.retain(|e| !st.finished_nodes.contains(&e.node));
            let mut backend = SimBackend::new(&hw, cluster.mem_bytes);
            let res = st.run_stage(
                &s2,
                &graph,
                &registry,
                &mut backend,
                &HashMap::new(),
                false,
                false,
                None,
            );
            prop_assert!(res.end + 1e-12 >= res.start, "negative stage duration");
            prop_assert!(st.clock + 1e-12 >= prev_clock, "clock regressed");
            prop_assert!(st.completed.len() >= prev_done, "completions regressed");
            prev_done = st.completed.len();
            prev_clock = st.clock;
        }
        let total: usize = w.iter().map(|x| x.len()).sum();
        prop_assert!(st.completed.len() == total, "lost requests: {}", st.completed.len());
        Ok(())
    });
}

#[test]
fn compose_preserves_counts_edges_acyclicity_and_provenance() {
    // AppGraph::compose is a disjoint union: node/edge counts add up,
    // acyclicity survives, part order is preserved, and the
    // (app, local_id) provenance stamped on every node round-trips back
    // to the exact part node it came from.
    let registry = Registry::paper();
    let models: Vec<&str> = registry.names();
    quickprop::run(40, 0xC0A7, |rng| {
        let n_apps = rng.range_usize(1, 5);
        let mut parts: Vec<AppGraph> = vec![];
        for _ in 0..n_apps {
            let n = rng.range_usize(1, 7);
            let mut g = AppGraph::default();
            for i in 0..n {
                let m = *rng.choice(&models);
                g.add_node(m, &format!("n{i}"), 32 + rng.range_u64(0, 200) as u32);
            }
            // Forward-only random edges: acyclic by construction.
            for t in 1..n {
                if rng.range_u64(0, 2) == 1 {
                    let f = rng.range_usize(0, t);
                    g.add_edge(f, t);
                }
            }
            parts.push(g);
        }
        let refs: Vec<&AppGraph> = parts.iter().collect();
        let g = AppGraph::compose(&refs);
        let want_nodes: usize = parts.iter().map(|p| p.n_nodes()).sum();
        let want_edges: usize = parts.iter().map(|p| p.edges.len()).sum();
        prop_assert!(g.n_nodes() == want_nodes, "nodes {} != {want_nodes}", g.n_nodes());
        prop_assert!(g.edges.len() == want_edges, "edges {} != {want_edges}", g.edges.len());
        prop_assert!(g.is_acyclic(), "composition introduced a cycle");
        // Provenance round-trip, walking parts in order.
        let mut offset = 0usize;
        for (app, part) in parts.iter().enumerate() {
            for (i, local) in part.nodes.iter().enumerate() {
                let n = &g.nodes[offset + i];
                prop_assert!(n.app == app, "node {}: app {} != {app}", n.id, n.app);
                prop_assert!(
                    n.local_id == i,
                    "node {}: local_id {} != {i}",
                    n.id,
                    n.local_id
                );
                prop_assert!(
                    n.model == local.model && n.label == local.label
                        && n.max_out == local.max_out,
                    "node {}: payload mismatch",
                    n.id
                );
            }
            offset += part.n_nodes();
        }
        // Every edge stays inside its own app (disjoint union).
        for &(f, t) in &g.edges {
            prop_assert!(
                g.nodes[f].app == g.nodes[t].app,
                "edge ({f},{t}) crosses apps"
            );
        }
        // nodes_by_app partitions the node set in id order.
        let groups = g.nodes_by_app();
        prop_assert!(groups.len() == n_apps, "groups {} != {n_apps}", groups.len());
        let mut seen: Vec<usize> = groups.concat();
        seen.sort_unstable();
        prop_assert!(
            seen == (0..want_nodes).collect::<Vec<_>>(),
            "nodes_by_app is not a partition"
        );
        Ok(())
    });
}
