//! Integration tests for the unified API: `AppSpec` round-trips, the
//! `SamuLlm` session facade, and policy-object equivalence with the
//! by-name runner path (the pre-trait `PolicyKind` numbers).

use samullm::cluster::ClusterSpec;
use samullm::config::ExperimentConfig;
use samullm::metrics::RunReport;
use samullm::policy;
use samullm::runner::{run_policy, RunOpts};
use samullm::session::SamuLlm;
use samullm::spec::{AppSpec, NodeSpec, RequestSpec, WorkloadGen};

fn small_custom_spec() -> AppSpec {
    AppSpec::Custom {
        name: "triad".into(),
        nodes: vec![
            NodeSpec {
                model: "chatglm3-6b".into(),
                label: "draft".into(),
                max_out: 128,
                workload: WorkloadGen::Synthetic {
                    n_requests: 60,
                    input_min: 10,
                    input_max: 100,
                },
            },
            NodeSpec {
                model: "alpaca-13b".into(),
                label: "expand".into(),
                max_out: 160,
                workload: WorkloadGen::Synthetic {
                    n_requests: 40,
                    input_min: 20,
                    input_max: 80,
                },
            },
            NodeSpec {
                model: "mistral-7b-instruct".into(),
                label: "judge".into(),
                max_out: 96,
                workload: WorkloadGen::Explicit {
                    requests: (0..30)
                        .map(|i| RequestSpec { input_len: 15 + i, output_len: 40 + i })
                        .collect(),
                },
            },
        ],
        edges: vec![(0, 2), (1, 2)],
    }
}

/// The deterministic parts of two reports must agree exactly (wall-clock
/// fields — extra_time, end_to_end_time — are measured, not simulated).
fn assert_same_run(a: &RunReport, b: &RunReport) {
    assert_eq!(a.policy, b.policy);
    assert_eq!(a.scenario, b.scenario);
    assert_eq!(a.n_stages, b.n_stages, "{}: stage count differs", a.policy);
    assert_eq!(
        a.inference_time.to_bits(),
        b.inference_time.to_bits(),
        "{}: inference time differs ({} vs {})",
        a.policy,
        a.inference_time,
        b.inference_time
    );
    let (ea, eb) = (a.estimated_inference_time, b.estimated_inference_time);
    assert!(
        (ea.is_nan() && eb.is_nan()) || ea.to_bits() == eb.to_bits(),
        "{}: estimate differs ({ea} vs {eb})",
        a.policy
    );
    for (sa, sb) in a.timeline.iter().zip(&b.timeline) {
        assert_eq!(sa.entries, sb.entries, "{}: stage entries differ", a.policy);
        assert_eq!(sa.start.to_bits(), sb.start.to_bits());
        assert_eq!(sa.end.to_bits(), sb.end.to_bits());
    }
}

#[test]
fn session_reproduces_runner_numbers_for_every_policy() {
    // The session facade and the classic by-name runner path must produce
    // identical schedules and virtual times for a fixed seed — i.e. every
    // Policy impl reproduces the pre-trait enum-dispatch numbers.
    let seed = 11;
    let spec = small_custom_spec();
    let cluster = ClusterSpec::a100_node(8);
    let scenario = spec.build(seed).expect("spec builds");
    let opts = RunOpts { seed, ..Default::default() };
    for name in policy::names() {
        let direct = run_policy(name, &scenario, &cluster, &opts);
        let session = SamuLlm::builder()
            .cluster(cluster.clone())
            .policy(name)
            .seed(seed)
            .build()
            .unwrap();
        let via_session = session.run(&spec).unwrap();
        assert_same_run(&via_session, &direct);
        assert!(via_session.inference_time > 0.0);
    }
}

#[test]
fn session_runs_are_reproducible() {
    let session = SamuLlm::builder().policy("ours").seed(4).build().unwrap();
    let spec = small_custom_spec();
    let a = session.run(&spec).unwrap();
    let b = session.run(&spec).unwrap();
    assert_same_run(&a, &b);
}

#[test]
fn spec_round_trips_through_config_json_and_runs() {
    // A full experiment config carrying a custom graph: parse -> to_json
    // -> parse equality, then run it end to end.
    let cfg = ExperimentConfig {
        app: Some(small_custom_spec()),
        workload: None,
        traffic: None,
        policy: "round-robin".to_string(),
        backend: "sim".to_string(),
        artifacts: None,
        n_gpus: 8,
        seed: 9,
        no_preemption: false,
        known_output_lengths: false,
        threads: 0,
        sim_cache: true,
        online_refinement: false,
        replan_threshold: samullm::costmodel::online::DEFAULT_REPLAN_THRESHOLD,
        online_weight: samullm::costmodel::online::DEFAULT_OBS_WEIGHT,
        admit: "fcfs".to_string(),
        oversubscribe: false,
        h2d_bw: None,
        fast_step: true,
        search_budget: None,
        sequential_measured: false,
    };
    let text = cfg.to_json();
    let back = ExperimentConfig::from_json(&text).unwrap();
    assert_eq!(back.app, cfg.app);
    assert_eq!(back.policy, cfg.policy);
    assert_eq!(back.to_json(), text, "serialisation is stable");

    let session = SamuLlm::builder()
        .cluster(ClusterSpec::a100_node(back.n_gpus))
        .policy(&back.policy)
        .seed(back.seed)
        .build()
        .unwrap();
    let report = session.run(back.app.as_ref().unwrap()).unwrap();
    assert_eq!(report.policy, "round-robin");
    assert_eq!(report.scenario, "triad");
    assert!(report.inference_time > 0.0);
    assert!(report.n_stages >= 1);
    // Dependent node (2) must finish last-or-equal: its stages cannot
    // start before some producer stage exists.
    let first_judge_stage = report
        .timeline
        .iter()
        .position(|s| s.entries.iter().any(|(n, _)| *n == 2))
        .expect("judge node scheduled");
    let producers_done_by = report
        .timeline
        .iter()
        .position(|s| s.entries.iter().any(|(n, _)| *n == 0 || *n == 1))
        .expect("producers scheduled");
    assert!(producers_done_by <= first_judge_stage);
}

#[test]
fn routing_known_lengths_field_is_honoured() {
    // The seed CLI discarded the routing spec's known_lengths field; the
    // session must honour it (known lengths -> exact cost-model inputs,
    // so the estimate tracks reality more closely on average).
    let spec_known = AppSpec::routing(1024, true);
    let spec_unknown = AppSpec::routing(1024, false);
    assert!(spec_known.wants_known_lengths());
    let session = SamuLlm::builder().policy("ours").seed(13).build().unwrap();
    let known = session.run(&spec_known).unwrap();
    let unknown = session.run(&spec_unknown).unwrap();
    // Same workload either way; the flag changes the planner's view.
    assert_eq!(known.scenario, unknown.scenario);
    assert!(known.estimation_error() <= unknown.estimation_error() + 0.05);
}

#[test]
fn paper_spec_defaults_run_under_all_paper_policies() {
    let session = SamuLlm::builder().seed(42).build().unwrap();
    let reports = session.compare(&AppSpec::ensembling(300, 128), &policy::PAPER).unwrap();
    let names: Vec<&str> = reports.iter().map(|r| r.policy.as_str()).collect();
    assert_eq!(names, vec!["ours", "max-heuristic", "min-heuristic"]);
    for r in &reports {
        assert!(r.inference_time > 0.0, "{} did not run", r.policy);
    }
}
