//! Integration tests: whole applications, all policies, paper-shape
//! assertions (who wins, roughly by how much) — the §5 claims as tests.

use samullm::cluster::ClusterSpec;
use samullm::policy;
use samullm::runner::{run_policy, RunOpts, Scenario};
use samullm::spec::AppSpec;

fn cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

fn scenario(spec: AppSpec, seed: u64) -> Scenario {
    spec.build(seed).expect("valid spec")
}

#[test]
fn ensembling_small_workload_ours_beats_max() {
    // Fig. 7 shape at the small end: Max wastes GPUs on underfilled
    // models; Ours should win clearly (paper: 1.1-2.4x).
    let s = scenario(AppSpec::ensembling(1000, 256), 42);
    let opts = RunOpts::default();
    let ours = run_policy("ours", &s, &cluster(), &opts);
    let max = run_policy("max-heuristic", &s, &cluster(), &opts);
    let min = run_policy("min-heuristic", &s, &cluster(), &opts);
    let speedup_max = max.end_to_end_time / ours.end_to_end_time;
    let speedup_min = min.end_to_end_time / ours.end_to_end_time;
    assert!(speedup_max > 1.05, "vs max: {speedup_max:.2}x (paper 1.1-2.4x)");
    assert!(speedup_max < 4.0, "vs max absurdly large: {speedup_max:.2}x");
    assert!(speedup_min > 0.9, "vs min: {speedup_min:.2}x (paper 1.0-1.6x)");
}

#[test]
fn ensembling_advantage_shrinks_with_scale() {
    // Fig. 7 shape: as #requests grows, Ours' edge over Max narrows.
    let opts = RunOpts::default();
    let small = scenario(AppSpec::ensembling(800, 256), 1);
    let large = scenario(AppSpec::ensembling(6000, 256), 1);
    let edge = |s: &Scenario| {
        let ours = run_policy("ours", s, &cluster(), &opts);
        let max = run_policy("max-heuristic", s, &cluster(), &opts);
        max.inference_time / ours.inference_time
    };
    let e_small = edge(&small);
    let e_large = edge(&large);
    assert!(
        e_large < e_small + 0.15,
        "advantage should shrink: small {e_small:.2}x -> large {e_large:.2}x"
    );
}

#[test]
fn routing_skewed_workloads_ours_beats_max() {
    // Fig. 8 shape (paper: 1.4-1.8x vs Max, ~1.0-1.1x vs Min).
    let s = scenario(AppSpec::routing(4096, false), 7);
    let opts = RunOpts::default();
    let ours = run_policy("ours", &s, &cluster(), &opts);
    let max = run_policy("max-heuristic", &s, &cluster(), &opts);
    let speedup = max.end_to_end_time / ours.end_to_end_time;
    assert!(speedup > 1.1, "vs max: {speedup:.2}x (paper 1.4-1.8x)");
}

#[test]
fn chain_summary_idle_time_ordering() {
    // §5.3: Min wastes the most GPU time, Ours the least (ratios ~1.2/1.5).
    let s = scenario(AppSpec::chain_summary(100, 2, 500), 24);
    let opts = RunOpts::default();
    let ours = run_policy("ours", &s, &cluster(), &opts);
    let min = run_policy("min-heuristic", &s, &cluster(), &opts);
    assert!(
        min.end_to_end_time > ours.end_to_end_time * 0.95,
        "ours {:.0}s vs min {:.0}s",
        ours.end_to_end_time,
        min.end_to_end_time
    );
    // Both complete everything; idle time exists for both but ours isn't
    // dramatically worse.
    assert!(ours.gpu_idle_time() < min.gpu_idle_time() * 1.6 + 1.0);
}

#[test]
fn mixed_whole_app_roughly_matches_sequential() {
    // §5.4: the paper reports whole-app scheduling 1.0-1.2x better than
    // sequential. On our substrate the two land at parity (0.95-1.01x
    // across workload ratios — see EXPERIMENTS.md §Fig12 for why: the
    // greedy's first-GPU-per-model bias starves the chain-summary
    // critical path early at small doc counts). Assert the parity band.
    let opts = RunOpts::default();
    let whole = scenario(AppSpec::mixed(100, 3000, 900, 256, 4), 33);
    let r_whole = run_policy("ours", &whole, &cluster(), &opts);
    let cs = scenario(AppSpec::chain_summary(100, 4, 900), 33);
    let en = scenario(AppSpec::ensembling(3000, 256), 33 ^ 0x4D49_58);
    let r_cs = run_policy("ours", &cs, &cluster(), &opts);
    let r_en = run_policy("ours", &en, &cluster(), &opts);
    let sequential = r_cs.end_to_end_time + r_en.end_to_end_time;
    let ratio = r_whole.end_to_end_time / sequential;
    assert!(
        (0.80..=1.10).contains(&ratio),
        "whole {:.0}s vs sequential {:.0}s (ratio {ratio:.2})",
        r_whole.end_to_end_time,
        sequential
    );
}

#[test]
fn preemption_ablation_shapes() {
    // §5.5 Fig. 14: no-preemption hurts Min more than Ours.
    let s = scenario(AppSpec::mixed(60, 600, 900, 512, 2), 55);
    let c = cluster();
    let base = RunOpts::default();
    let np = RunOpts { no_preemption: true, ..base.clone() };
    let ours = run_policy("ours", &s, &c, &base);
    let ours_np = run_policy("ours", &s, &c, &np);
    let min = run_policy("min-heuristic", &s, &c, &base);
    let min_np = run_policy("min-heuristic", &s, &c, &np);
    let ours_cost = ours_np.inference_time / ours.inference_time;
    let min_cost = min_np.inference_time / min.inference_time;
    assert!(ours_cost > 0.85, "ours np cost {ours_cost:.2} (paper 1.0-1.2x)");
    assert!(min_cost > 0.95, "min np cost {min_cost:.2} (paper 1.3-1.4x)");
}

#[test]
fn extra_time_stays_small_fraction() {
    // §5.1: search time is 4.5-10.5% of end-to-end on the paper's
    // testbed; ours must stay well below that (virtual inference time is
    // hundreds of seconds, search is sub-second).
    let s = scenario(AppSpec::ensembling(2000, 256), 3);
    let r = run_policy("ours", &s, &cluster(), &RunOpts::default());
    assert!(r.extra_time_ratio() < 0.11, "extra ratio {:.3}", r.extra_time_ratio());
}

#[test]
fn estimation_error_within_paper_band() {
    // §5.5: 6.5-38.7% unknown lengths; known lengths tighter on average.
    let s = scenario(AppSpec::ensembling(1500, 256), 9);
    let c = cluster();
    let unk = run_policy("ours", &s, &c, &RunOpts::default());
    assert!(
        unk.estimation_error() < 0.5,
        "unknown-lengths error {:.2}",
        unk.estimation_error()
    );
    let known = run_policy(
        "ours",
        &s,
        &c,
        &RunOpts { known_lengths: true, ..Default::default() },
    );
    assert!(known.estimation_error() < 0.4, "known-lengths error {:.2}", known.estimation_error());
}

#[test]
fn reports_are_consistent() {
    let s = scenario(AppSpec::routing(2048, false), 11);
    for p in policy::names() {
        let r = run_policy(p, &s, &cluster(), &RunOpts::default());
        assert!((r.end_to_end_time - r.extra_time - r.inference_time).abs() < 1e-9);
        assert_eq!(r.n_stages, r.timeline.len());
        // Timeline is contiguous and monotone.
        for w in r.timeline.windows(2) {
            assert!(w[0].end <= w[1].start + 1e-6, "{p} timeline overlap");
        }
        assert!(r.timeline.last().unwrap().end <= r.inference_time + 1e-6);
        // JSON renders and reparses.
        let j = samullm::util::json::Json::parse(&r.to_json()).unwrap();
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(), r.policy);
    }
}
