//! Differential tests for the concurrent measured lowering: the event
//! loop that interleaves a stage's nodes through the backend's stepping
//! interface must complete exactly the same requests with exactly the
//! same generated tokens as the sequential lowering, while reporting a
//! strictly smaller stage wall-clock (max over nodes instead of sum).
//! The `sequential_measured` escape hatch must be inert on the virtual
//! substrate: sim runs are pinned bit-identical with the flag on or off
//! across all four paper applications.

use std::collections::{HashMap, HashSet};

use samullm::exec::pjrt::{MockModel, PjrtBackend};
use samullm::graph::AppGraph;
use samullm::metrics::RunReport;
use samullm::models::Registry;
use samullm::plan::{ExecPlan, Stage, StageEntry};
use samullm::runner::state::ExecState;
use samullm::runner::AppRequest;
use samullm::session::SamuLlm;
use samullm::spec::AppSpec;

fn stage(entries: Vec<(usize, u32, u32)>) -> Stage {
    Stage {
        entries: entries
            .into_iter()
            .map(|(n, dp, tp)| StageEntry { node: n, plan: ExecPlan::new(dp, tp) })
            .collect(),
    }
}

/// Producer -> consumer pair: node `b`'s requests each depend on the
/// matching request of node `a`, so the concurrent lowering must forward
/// completions mid-flight (and start `b` lazily on its first injection).
fn dep_pair() -> (AppGraph, Vec<Vec<AppRequest>>, usize, usize) {
    let mut g = AppGraph::default();
    let a = g.add_node("chatglm3-6b", "prod", 64);
    let b = g.add_node("mistral-7b-instruct", "cons", 64);
    g.add_edge(a, b);
    let wa: Vec<AppRequest> = (0..6).map(|i| AppRequest::simple(i, 8, 5)).collect();
    let wb: Vec<AppRequest> = (0..6)
        .map(|i| AppRequest { dep: Some((a, i)), ..AppRequest::simple(i, 8, 4) })
        .collect();
    (g, vec![wa, wb], a, b)
}

/// Two independent nodes on disjoint GPU subsets: nothing to forward,
/// pure interleaving — the stage wall-clock should drop from the sum of
/// node times to the max.
fn disjoint_pair() -> (AppGraph, Vec<Vec<AppRequest>>, usize, usize) {
    let mut g = AppGraph::default();
    let a = g.add_node("chatglm3-6b", "left", 64);
    let b = g.add_node("mistral-7b-instruct", "right", 64);
    let wa: Vec<AppRequest> = (0..4).map(|i| AppRequest::simple(i, 8, 6)).collect();
    let wb: Vec<AppRequest> = (0..4).map(|i| AppRequest::simple(i, 8, 6)).collect();
    (g, vec![wa, wb], a, b)
}

#[test]
fn concurrent_matches_sequential_on_dependent_stage() {
    let reg = Registry::paper();
    let (g, w, a, b) = dep_pair();
    let s = stage(vec![(a, 1, 1), (b, 1, 1)]);

    let mut st_seq = ExecState::init(&w, |_, r| r.true_output_len);
    let mut be_seq = PjrtBackend::with_model(Box::new(MockModel::new(4, 64)));
    let mut ev_seq = vec![];
    let seq = st_seq
        .run_stage_measured(&s, &g, &reg, &mut be_seq, Some(&mut ev_seq))
        .unwrap();

    let mut st_con = ExecState::init(&w, |_, r| r.true_output_len);
    let mut be_con = PjrtBackend::with_model(Box::new(MockModel::new(4, 64)));
    let mut ev_con = vec![];
    let con = st_con
        .run_stage_concurrent(&s, &g, &reg, &mut be_con, Some(&mut ev_con))
        .unwrap();

    // Same completion sets (order-independent), everything drained.
    assert!(st_seq.all_done() && st_con.all_done());
    let keys = |st: &ExecState| -> HashSet<(usize, u64)> {
        st.completed.keys().copied().collect()
    };
    assert_eq!(keys(&st_seq), keys(&st_con));
    assert_eq!(st_con.completed.len(), 12);

    // Same generations, token for token: MockModel tokens are a pure
    // function of (last token, position), so interleaving must not change
    // any request's history.
    for node in [a, b] {
        for id in 0..6u64 {
            assert_eq!(
                be_seq.history(node, id),
                be_con.history(node, id),
                "node {node} req {id}: generations diverged between lowerings"
            );
        }
    }

    // Each lowering produced a unified stream covering both nodes; the
    // concurrent merge is time-ordered.
    let nodes: HashSet<usize> = ev_con.iter().map(|e| e.node).collect();
    assert_eq!(nodes, [a, b].into_iter().collect());
    for pair in ev_con.windows(2) {
        assert!(pair[0].t <= pair[1].t + 1e-12, "merged events out of order");
    }

    // Consumers still finish at or after their producer.
    for i in 0..6u64 {
        assert!(st_con.completed[&(b, i)] >= st_con.completed[&(a, i)] - 1e-12);
    }
    assert!(seq.end >= seq.start && con.end >= con.start);
}

#[test]
fn concurrent_stage_wall_clock_beats_sequential_on_disjoint_nodes() {
    let reg = Registry::paper();
    let (g, w, a, b) = disjoint_pair();
    let s = stage(vec![(a, 1, 1), (b, 1, 1)]);
    // Every prefill/decode call sleeps, so each node's measured duration
    // is dominated by its own call count and the two lowerings differ
    // cleanly: sequential chains the nodes (span = durA + durB) while
    // concurrent starts both at the stage clock (span = max).
    let delay = 0.002;

    let mut st_seq = ExecState::init(&w, |_, r| r.true_output_len);
    let mut be_seq =
        PjrtBackend::with_model(Box::new(MockModel::new(4, 64).with_delay(delay)));
    let seq = st_seq.run_stage_measured(&s, &g, &reg, &mut be_seq, None).unwrap();

    let mut st_con = ExecState::init(&w, |_, r| r.true_output_len);
    let mut be_con =
        PjrtBackend::with_model(Box::new(MockModel::new(4, 64).with_delay(delay)));
    let con = st_con.run_stage_concurrent(&s, &g, &reg, &mut be_con, None).unwrap();

    // Identical work on both paths.
    assert!(st_seq.all_done() && st_con.all_done());
    assert_eq!(st_seq.completed.len(), st_con.completed.len());
    let keys = |st: &ExecState| -> HashSet<(usize, u64)> {
        st.completed.keys().copied().collect()
    };
    assert_eq!(keys(&st_seq), keys(&st_con));

    let seq_span = seq.end - seq.start;
    let con_span = con.end - con.start;
    assert!(seq_span > 0.0 && con_span > 0.0);
    assert!(
        con_span < seq_span,
        "concurrent stage must beat sequential: {con_span}s vs {seq_span}s"
    );

    // The concurrent stage overlapped real node time: per-node walls sum
    // past the stage span (the sequential lowering sums to it exactly).
    let walls = |r: &samullm::runner::state::StageResult| -> f64 {
        r.nodes.iter().map(|n| n.wall).sum()
    };
    assert!(walls(&con) > con_span + 1e-9, "no overlap measured");
    assert!((walls(&seq) - seq_span).abs() < 1e-9, "sequential walls must chain");
}

/// Bit-level equality on everything the simulator determines (mirrors
/// the fast-step differential): the `sequential_measured` knob picks a
/// measured lowering and must not touch virtual runs.
fn assert_bit_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(
        a.inference_time.to_bits(),
        b.inference_time.to_bits(),
        "{what}: inference time differs ({} vs {})",
        a.inference_time,
        b.inference_time
    );
    let (ea, eb) = (a.estimated_inference_time, b.estimated_inference_time);
    assert!(
        (ea.is_nan() && eb.is_nan()) || ea.to_bits() == eb.to_bits(),
        "{what}: estimate differs ({ea} vs {eb})"
    );
    assert_eq!(a.n_stages, b.n_stages, "{what}: stage count differs");
    for (i, (sa, sb)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(sa.entries, sb.entries, "{what}: stage {i} entries differ");
        assert_eq!(sa.start.to_bits(), sb.start.to_bits(), "{what}: stage {i} start");
        assert_eq!(sa.end.to_bits(), sb.end.to_bits(), "{what}: stage {i} end");
        assert_eq!(sa.events, sb.events, "{what}: stage {i} event digest differs");
    }
}

#[test]
fn sequential_measured_flag_is_inert_on_virtual_runs() {
    let apps: Vec<(&str, AppSpec)> = vec![
        ("ensembling", AppSpec::ensembling(40, 96)),
        ("routing", AppSpec::routing(512, false)),
        ("chain-summary", AppSpec::chain_summary(6, 1, 200)),
        ("mixed", AppSpec::mixed(4, 40, 160, 96, 1)),
    ];
    for (name, spec) in &apps {
        let run = |sequential: bool| {
            SamuLlm::builder()
                .gpus(8)
                .seed(21)
                .sequential_measured(sequential)
                .build()
                .unwrap()
                .run(spec)
                .unwrap()
        };
        let (default, forced) = (run(false), run(true));
        assert_bit_identical(&default, &forced, name);
        assert!(default.measured.is_none(), "{name}: sim runs report no measured stats");
        // And the flag round-trips determinism: same flag, same bits.
        assert_bit_identical(&run(true), &forced, &format!("{name} (repeat)"));
    }
}

#[test]
fn concurrent_falls_back_to_sequential_for_single_node_stages() {
    let reg = Registry::paper();
    let mut g = AppGraph::default();
    let a = g.add_node("chatglm3-6b", "solo", 64);
    let w = vec![(0..4).map(|i| AppRequest::simple(i, 8, 5)).collect::<Vec<_>>()];
    let s = stage(vec![(a, 1, 1)]);
    let mut st = ExecState::init(&w, |_, r| r.true_output_len);
    let mut be = PjrtBackend::with_model(Box::new(MockModel::new(4, 64)));
    // One involved node -> delegates to the sequential lowering, which
    // must drain the node exactly as a direct call would.
    let res = st.run_stage_concurrent(&s, &g, &reg, &mut be, None).unwrap();
    assert!(st.all_done());
    assert_eq!(st.completed.len(), 4);
    assert_eq!(res.nodes.len(), 1);
    let walls: HashMap<usize, f64> = res.nodes.iter().map(|n| (n.node, n.wall)).collect();
    assert!((walls[&a] - (res.end - res.start)).abs() < 1e-9);
}
