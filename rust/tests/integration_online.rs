//! End-to-end tests of the runtime length-feedback loop: online eCDF
//! refinement, drift scoring and drift-triggered replanning (the §4.3
//! "adjust scheduling based on runtime information" path).

use samullm::cluster::ClusterSpec;
use samullm::config::ExperimentConfig;
use samullm::harness::shifted_length_scenario;
use samullm::runner::{run_policy, RunOpts};
use samullm::session::SamuLlm;
use samullm::spec::AppSpec;

#[test]
fn shifted_workload_triggers_replanning() {
    let cluster = ClusterSpec::a100_node(8);
    let scenario = shifted_length_scenario(120, 42);
    let frozen_opts = RunOpts { seed: 42, ..RunOpts::default() };
    let online_opts = RunOpts { online_refinement: true, ..frozen_opts.clone() };

    let frozen = run_policy("ours", &scenario, &cluster, &frozen_opts);
    let online = run_policy("ours", &scenario, &cluster, &online_opts);

    assert!(frozen.online.is_none(), "frozen run must not report feedback stats");
    let stats = online.online.expect("online run must report feedback stats");
    assert!(stats.replans >= 1, "drift this large must trigger a replan: {stats:?}");
    assert!(
        stats.drift > online_opts.replan_threshold,
        "reported drift {} below threshold",
        stats.drift
    );
    assert!(stats.pre_est_total > 0.0);
    assert!(stats.post_est_total > 0.0);
    // Both paths complete the same workload; refinement must not lose
    // requests or wedge the runner.
    assert!(online.inference_time > 0.0 && frozen.inference_time > 0.0);
    // The point of the loop: on a miscalibrated workload the refined run
    // must not be meaningfully slower (it is typically faster — the
    // bench records the actual gap; this bound is deliberately lenient
    // so a pathological seed can't flake CI).
    assert!(
        online.inference_time <= frozen.inference_time * 1.10,
        "online {:.1}s much slower than frozen {:.1}s",
        online.inference_time,
        frozen.inference_time
    );
}

#[test]
fn replan_threshold_infinity_disables_replanning_but_keeps_refinement() {
    let cluster = ClusterSpec::a100_node(8);
    let scenario = shifted_length_scenario(80, 7);
    let opts = RunOpts {
        seed: 7,
        online_refinement: true,
        replan_threshold: f64::INFINITY,
        ..RunOpts::default()
    };
    let r = run_policy("ours", &scenario, &cluster, &opts);
    let stats = r.online.expect("stats present even without replans");
    assert_eq!(stats.replans, 0, "infinite threshold must never replan");
    assert_eq!(stats.replan_time, 0.0);
    assert_eq!(
        stats.pre_est_total.to_bits(),
        stats.post_est_total.to_bits(),
        "estimate must be untouched without replans"
    );
    assert!(stats.drift > 0.0, "drift is still measured and reported");
    assert!(r.inference_time > 0.0);
}

#[test]
fn baseline_policies_run_under_refinement_without_stats() {
    // Baselines consume the refreshed estimate (their stages see the
    // posterior lengths) but do not participate in drift/replanning, so
    // the report carries no online section.
    let cluster = ClusterSpec::a100_node(8);
    let scenario = shifted_length_scenario(60, 3);
    let opts = RunOpts { seed: 3, online_refinement: true, ..RunOpts::default() };
    for p in ["min-heuristic", "max-heuristic", "round-robin"] {
        let r = run_policy(p, &scenario, &cluster, &opts);
        assert!(r.inference_time > 0.0, "{p}");
        assert!(r.online.is_none(), "{p} must not report feedback stats");
    }
}

#[test]
fn online_knobs_flow_from_config_json_to_the_report() {
    let json = r#"{
        "app": {"kind": "ensembling", "n_requests": 50, "max_out": 128},
        "policy": "ours",
        "n_gpus": 8,
        "seed": 5,
        "online_refinement": true,
        "replan_threshold": 0.5,
        "online_weight": 16.0
    }"#;
    let cfg = ExperimentConfig::from_json(json).unwrap();
    assert!(cfg.online_refinement);
    assert_eq!(cfg.replan_threshold, 0.5);
    assert_eq!(cfg.online_weight, 16.0);

    let session = SamuLlm::builder()
        .gpus(cfg.n_gpus)
        .policy(&cfg.policy)
        .seed(cfg.seed)
        .online_refinement(cfg.online_refinement)
        .replan_threshold(cfg.replan_threshold)
        .online_weight(cfg.online_weight)
        .build()
        .unwrap();
    let report = session.run(cfg.app.as_ref().unwrap()).unwrap();
    let j = report.to_json();
    assert!(j.contains("\"online\":{"), "{j}");
    assert!(j.contains("\"replans\":"), "{j}");
    assert!(report.online.is_some());
}

#[test]
fn no_preemption_pins_plans_even_across_replans() {
    // Locked plans are a hard constraint: even when drift triggers a
    // replan, a started node must keep its original plan.
    let cluster = ClusterSpec::a100_node(8);
    let scenario = shifted_length_scenario(80, 11);
    let opts = RunOpts {
        seed: 11,
        online_refinement: true,
        no_preemption: true,
        ..RunOpts::default()
    };
    let r = run_policy("ours", &scenario, &cluster, &opts);
    let mut seen: std::collections::HashMap<usize, samullm::plan::ExecPlan> =
        std::collections::HashMap::new();
    for s in &r.timeline {
        assert!(s.gpus_used() <= 8, "stage over budget");
        for (n, plan) in &s.entries {
            if let Some(prev) = seen.get(n) {
                assert_eq!(prev, plan, "node {n} changed plan under no-preemption");
            }
            seen.insert(*n, *plan);
        }
    }
    assert!(r.inference_time > 0.0);
}

#[test]
fn session_knob_works_on_stock_specs() {
    // The session facade exposes the same loop on the paper's stock
    // applications: the knob must not disturb completion guarantees even
    // when the workload is well-calibrated.
    let spec = AppSpec::ensembling(60, 128);
    let r = SamuLlm::builder()
        .gpus(8)
        .seed(9)
        .online_refinement(true)
        .build()
        .unwrap()
        .run(&spec)
        .unwrap();
    assert!(r.inference_time > 0.0);
    assert!(r.online.is_some());
}
