//! Real-path integration: AOT artifacts -> PJRT -> batched serving.
//! These tests are skipped (with a notice) until `make artifacts` has run.

use samullm::runtime::{default_artifacts_dir, TinyGpt};
use samullm::serve::{synthetic_requests, ServeEngine};

fn ready() -> bool {
    let ok = default_artifacts_dir().join("model_meta.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

#[test]
fn artifacts_meta_matches_weights() {
    if !ready() {
        return;
    }
    let meta = samullm::runtime::ModelMeta::parse(
        &std::fs::read_to_string(default_artifacts_dir().join("model_meta.json")).unwrap(),
    )
    .unwrap();
    let blob_len = std::fs::metadata(default_artifacts_dir().join("weights.bin")).unwrap().len();
    let declared: usize = meta.params.iter().map(|p| p.bytes).sum();
    assert_eq!(declared as u64, blob_len, "weights.bin size mismatch");
    // Shapes are consistent with dims.
    let c = &meta.config;
    assert_eq!(meta.params[0].shape, vec![c.vocab, c.d_model]); // embed
    assert_eq!(c.d_model / c.n_heads, c.d_head);
}

#[test]
fn greedy_generation_is_reproducible() {
    if !ready() {
        return;
    }
    let engine = ServeEngine::load(&default_artifacts_dir()).unwrap();
    let reqs = synthetic_requests(8, 10, 8, 5);
    let (a, _) = engine.serve(&reqs).unwrap();
    let (b, _) = engine.serve(&reqs).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.generated, y.generated, "nondeterministic generation");
    }
}

#[test]
fn decode_continues_prefill_distribution() {
    // The decode path must consume the prefill KV cache coherently:
    // feeding the argmax token back must produce finite, varying logits.
    if !ready() {
        return;
    }
    let m = TinyGpt::load(&default_artifacts_dir()).unwrap();
    let b = m.batch();
    let s = m.max_seq();
    let mut tokens = vec![0i32; b * s];
    for row in 0..b {
        for i in 0..12 {
            tokens[row * s + i] = ((row * 31 + i * 7) % 500 + 1) as i32;
        }
    }
    let lengths = vec![12i32; b];
    let out = m.prefill(&tokens, &lengths).unwrap();
    let mut next = m.argmax(&out.logits);
    let mut state = out.state;
    let mut pos = lengths.clone();
    let mut history: Vec<Vec<i32>> = vec![vec![]; b];
    for _ in 0..6 {
        let o = m.decode(&next, state, &pos).unwrap();
        assert!(o.logits.iter().all(|x| x.is_finite()));
        state = o.state;
        next = m.argmax(&o.logits);
        for (row, h) in history.iter_mut().enumerate() {
            h.push(next[row]);
            pos[row] += 1;
        }
    }
    // Different prompts should not all generate the same stream.
    let distinct: std::collections::HashSet<_> = history.iter().collect();
    assert!(distinct.len() > 1, "all rows generated identical streams");
}

#[test]
fn serving_metrics_are_coherent() {
    if !ready() {
        return;
    }
    let engine = ServeEngine::load(&default_artifacts_dir()).unwrap();
    let reqs = synthetic_requests(20, 8, 5, 9);
    let (results, m) = engine.serve(&reqs).unwrap();
    assert_eq!(m.n_requests, 20);
    assert_eq!(m.total_tokens, 20 * 5);
    assert!(m.wall_time > 0.0);
    assert!(m.mean_latency <= m.p99_latency + 1e-9);
    assert!(m.prefills == 3, "20 reqs / batch 8 = 3 prefills, got {}", m.prefills);
    for r in &results {
        assert!(r.latency <= m.wall_time + 1e-9);
    }
}
