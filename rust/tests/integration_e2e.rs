//! Real-path integration: AOT artifacts -> PJRT -> the unified execution
//! API (continuous-batching serving + full scheduler runs).
//! These tests are skipped (with a notice) until `make artifacts` has run.

use samullm::exec::pjrt::PjrtBackend;
use samullm::prelude::*;
use samullm::runtime::{default_artifacts_dir, TinyGpt};
use samullm::serve::{serve_requests, synthetic_requests};

fn ready() -> bool {
    let ok = default_artifacts_dir().join("model_meta.json").exists();
    if !ok {
        eprintln!("skipping e2e test: run `make artifacts` first");
    }
    ok
}

#[test]
fn artifacts_meta_matches_weights() {
    if !ready() {
        return;
    }
    let meta = samullm::runtime::ModelMeta::parse(
        &std::fs::read_to_string(default_artifacts_dir().join("model_meta.json")).unwrap(),
    )
    .unwrap();
    let blob_len = std::fs::metadata(default_artifacts_dir().join("weights.bin")).unwrap().len();
    let declared: usize = meta.params.iter().map(|p| p.bytes).sum();
    assert_eq!(declared as u64, blob_len, "weights.bin size mismatch");
    // Shapes are consistent with dims.
    let c = &meta.config;
    assert_eq!(meta.params[0].shape, vec![c.vocab, c.d_model]); // embed
    assert_eq!(c.d_model / c.n_heads, c.d_head);
}

#[test]
fn greedy_generation_is_reproducible() {
    if !ready() {
        return;
    }
    let (reqs, prompts) = synthetic_requests(8, 10, 8, 5);
    let mut run = || {
        let mut backend = PjrtBackend::load(&default_artifacts_dir()).unwrap();
        serve_requests(&mut backend, &reqs, &prompts).unwrap().0
    };
    let a = run();
    let b = run();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.tokens, y.tokens, "nondeterministic generation");
    }
}

#[test]
fn decode_continues_prefill_distribution() {
    // The decode path must consume the prefill KV cache coherently:
    // feeding the argmax token back must produce finite, varying logits.
    if !ready() {
        return;
    }
    let m = TinyGpt::load(&default_artifacts_dir()).unwrap();
    let b = m.batch();
    let s = m.max_seq();
    let mut tokens = vec![0i32; b * s];
    for row in 0..b {
        for i in 0..12 {
            tokens[row * s + i] = ((row * 31 + i * 7) % 500 + 1) as i32;
        }
    }
    let lengths = vec![12i32; b];
    let out = m.prefill(&tokens, &lengths).unwrap();
    let mut next = m.argmax(&out.logits);
    let mut state = out.state;
    let mut pos = lengths.clone();
    let mut history: Vec<Vec<i32>> = vec![vec![]; b];
    for _ in 0..6 {
        let o = m.decode(&next, state, &pos).unwrap();
        assert!(o.logits.iter().all(|x| x.is_finite()));
        state = o.state;
        next = m.argmax(&o.logits);
        for (row, h) in history.iter_mut().enumerate() {
            h.push(next[row]);
            pos[row] += 1;
        }
    }
    // Different prompts should not all generate the same stream.
    let distinct: std::collections::HashSet<_> = history.iter().collect();
    assert!(distinct.len() > 1, "all rows generated identical streams");
}

#[test]
fn serving_metrics_are_coherent() {
    if !ready() {
        return;
    }
    let mut backend = PjrtBackend::load(&default_artifacts_dir()).unwrap();
    let (reqs, prompts) = synthetic_requests(20, 8, 5, 9);
    let (results, m) = serve_requests(&mut backend, &reqs, &prompts).unwrap();
    assert_eq!(m.n_requests, 20);
    assert_eq!(m.total_tokens, 20 * 5);
    assert!(m.wall_time > 0.0);
    assert!(m.mean_latency <= m.p99_latency + 1e-9);
    // Continuous batching: 20 requests through 8 seats need at least 3
    // admission prefills (possibly more as seats free one by one).
    assert!(m.prefills >= 3, "20 reqs / batch 8: prefills {}", m.prefills);
    for r in &results {
        assert!(r.latency <= m.wall_time + 1e-9);
    }
}

#[test]
fn session_runs_an_app_spec_on_the_pjrt_backend() {
    // The acceptance path: the same AppSpec runs end-to-end through the
    // one `SamuLlm` code path on the real runtime, producing a RunReport
    // with measured iteration stats from the unified event stream.
    if !ready() {
        return;
    }
    let session = SamuLlm::builder()
        .gpus(8)
        .policy("ours")
        .backend("pjrt")
        .seed(11)
        .build()
        .unwrap();
    let spec = AppSpec::ensembling(12, 16);
    let report = session.run(&spec).unwrap();
    assert_eq!(report.backend, "pjrt");
    assert!(report.inference_time > 0.0, "measured wall time must be positive");
    assert!(report.n_stages >= 1);
    // Every request of every node completed on the real engine.
    let completions: u64 = report.timeline.iter().map(|s| s.events.completions).sum();
    assert!(completions > 0);
    let measured = report.measured.expect("pjrt runs must report measured stats");
    assert!(measured.decode_iters > 0);
    assert!(measured.decode_mean > 0.0);
    assert!(measured.tokens > 0);
    // The measured-vs-predicted hook exists (prediction may be wildly off
    // for the tiny CPU model — it just has to be present and finite).
    assert!(measured.predicted_decode_mean.is_finite());
}

#[test]
fn sim_and_pjrt_run_the_same_spec_through_one_code_path() {
    if !ready() {
        return;
    }
    let spec = AppSpec::ensembling(10, 12);
    let run = |backend: &str| {
        SamuLlm::builder()
            .gpus(8)
            .backend(backend)
            .seed(4)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap()
    };
    let sim = run("sim");
    let real = run("pjrt");
    assert_eq!(sim.backend, "sim");
    assert_eq!(real.backend, "pjrt");
    // Identical applications: both backends complete the same request
    // count (the unified event stream counts completions identically).
    let done = |r: &samullm::metrics::RunReport| -> u64 {
        r.timeline.iter().map(|s| s.events.completions).sum()
    };
    assert_eq!(done(&sim), done(&real));
    assert!(sim.measured.is_none());
    assert!(real.measured.is_some());
}
