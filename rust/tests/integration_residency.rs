//! Differential-testing layer for the model-residency subsystem: the
//! default (no `--oversubscribe`) path is pinned bit-identical across
//! every surface, oversubscription on a *fitting* workload is inert by
//! construction, and the packed-stage lowering is exercised end-to-end on
//! a deliberately too-small cluster — on both the simulated and the real
//! (mock-PJRT) scheduler — including the displacement (swap-vs-wait) and
//! proactive-offload (load/decode overlap) rules.

use samullm::cluster::ClusterSpec;
use samullm::costmodel::{HardwareModel, SwapCost};
use samullm::engine::EventKind;
use samullm::exec::pjrt::{MockModel, PjrtBackend};
use samullm::exec::SimBackend;
use samullm::graph::AppGraph;
use samullm::harness::{poisson_pair_traffic, staggered_pair_workload};
use samullm::metrics::RunReport;
use samullm::models::Registry;
use samullm::plan::{ExecPlan, Stage, StageEntry};
use samullm::prop_assert;
use samullm::residency::{run_packed_stage, ResidencyManager, ResidencyStats};
use samullm::runner::state::ExecState;
use samullm::runner::{run_policy, run_traffic, run_workload, AppRequest, RunOpts, Scenario};
use samullm::spec::AppSpec;
use samullm::util::quickprop;

fn big_cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

/// Two A100s: any three single-GPU models overcommit it, so this is the
/// smallest cluster that forces packed stages.
fn tiny_cluster() -> ClusterSpec {
    ClusterSpec::a100_node(2)
}

fn over_opts() -> RunOpts {
    RunOpts { seed: 42, oversubscribe: true, ..RunOpts::default() }
}

/// The bit-level pin: every virtual-time number of `a` and `b` agrees
/// exactly (wall-clock fields like search time are excluded by design).
fn assert_bit_identical(label: &str, a: &RunReport, b: &RunReport) {
    assert_eq!(
        a.inference_time.to_bits(),
        b.inference_time.to_bits(),
        "{label}: inference_time diverged ({} vs {})",
        a.inference_time,
        b.inference_time
    );
    assert_eq!(
        a.estimated_inference_time.to_bits(),
        b.estimated_inference_time.to_bits(),
        "{label}: estimate diverged"
    );
    assert_eq!(a.n_stages, b.n_stages, "{label}: stage count diverged");
    assert_eq!(a.residency, b.residency, "{label}: residency counters diverged");
    for (sa, sb) in a.timeline.iter().zip(&b.timeline) {
        assert_eq!(sa.start.to_bits(), sb.start.to_bits(), "{label}: stage start diverged");
        assert_eq!(sa.end.to_bits(), sb.end.to_bits(), "{label}: stage end diverged");
        assert_eq!(sa.entries, sb.entries, "{label}: stage entries diverged");
        assert_eq!(
            sa.swap_stall.to_bits(),
            sb.swap_stall.to_bits(),
            "{label}: swap stall diverged"
        );
    }
}

fn completions(r: &RunReport) -> u64 {
    r.timeline.iter().map(|s| s.events.completions).sum()
}

/// The four paper apps in small configurations.
fn paper_apps() -> Vec<(&'static str, AppSpec)> {
    vec![
        ("ensembling", AppSpec::ensembling(60, 128)),
        ("routing", AppSpec::routing(512, false)),
        ("chain-summary", AppSpec::chain_summary(15, 1, 200)),
        ("mixed", AppSpec::mixed(10, 120, 300, 96, 2)),
    ]
}

/// `n` independent chatglm3-6b nodes with the given per-node request
/// counts — three or more of these overcommit [`tiny_cluster`].
fn multi_model_scenario(reqs_per_node: &[usize]) -> Scenario {
    let mut graph = AppGraph::default();
    let mut workloads = vec![];
    for (i, &n) in reqs_per_node.iter().enumerate() {
        graph.add_node("chatglm3-6b", &format!("m{i}"), 256);
        workloads.push(
            (0..n as u64)
                .map(|id| AppRequest::simple(id, 24, 30 + (id * 13 % 90) as u32))
                .collect(),
        );
    }
    Scenario { name: "multi-model".into(), graph, workloads }
}

#[test]
fn residency_off_is_the_default_and_oversubscribe_on_fits_is_inert() {
    // Two pins in one: (a) a default build and an explicit
    // oversubscribe=false build agree on every virtual-time bit; (b) with
    // oversubscription *enabled* but every stage fitting the 8-GPU
    // cluster, the packed path never engages, the counters stay zero, and
    // the run is still bit-identical. The paper suite must never
    // overcommit eight GPUs (that is the `overcommitted` gate's contract).
    let c = big_cluster();
    for (name, spec) in paper_apps() {
        let s = spec.build(42).expect("valid spec");
        let default_run = run_policy("ours", &s, &c, &RunOpts { seed: 42, ..RunOpts::default() });
        let explicit_off = run_policy(
            "ours",
            &s,
            &c,
            &RunOpts { seed: 42, oversubscribe: false, ..RunOpts::default() },
        );
        let enabled_but_fits = run_policy("ours", &s, &c, &over_opts());
        assert_bit_identical(name, &default_run, &explicit_off);
        assert_bit_identical(name, &default_run, &enabled_but_fits);
        assert_eq!(
            default_run.residency,
            ResidencyStats::default(),
            "{name}: default run counted swaps"
        );
        assert_eq!(
            enabled_but_fits.residency,
            ResidencyStats::default(),
            "{name}: fitting workload swapped"
        );
        assert!(completions(&default_run) > 0, "{name}: no completions recorded");
    }
}

#[test]
fn residency_workload_and_traffic_runs_are_pinned() {
    let c = big_cluster();
    let ws = staggered_pair_workload(8, 60, 20.0).build(42).expect("valid workload");
    let wa = run_workload("ours", &ws, &c, &RunOpts { seed: 42, ..RunOpts::default() });
    let wb = run_workload("ours", &ws, &c, &over_opts());
    assert_bit_identical("workload", &wa, &wb);
    assert_eq!(wa.residency, ResidencyStats::default());

    // Traffic runs reject oversubscription outright (unit-tested in the
    // runner); a custom h2d bandwidth alone prices transfers that never
    // happen, so it must not move a bit either.
    let ts = poisson_pair_traffic(1.0, 1.0, 2.0, 10.0).build(42).expect("valid traffic mix");
    let ta = run_traffic("ours", &ts, &c, &RunOpts { seed: 42, ..RunOpts::default() });
    let tb = run_traffic(
        "ours",
        &ts,
        &c,
        &RunOpts { seed: 42, h2d_bw: Some(20.0e9), ..RunOpts::default() },
    );
    assert_bit_identical("traffic", &ta, &tb);
    assert_eq!(ta.residency, ResidencyStats::default());
}

#[test]
fn oversubscribed_three_models_on_two_gpus_run_end_to_end() {
    // Three single-GPU models on two GPUs: planning must emit a packed
    // stage, the lowering must time-slice the GPUs (every *executed*
    // sub-stage fits the cluster), every request must complete, and the
    // drain boundaries must show up as swap-outs in the report. The
    // packed run pays modeled swap latency, so it may trail the strict
    // (fit-only) schedule somewhat — but not collapse.
    let c = tiny_cluster();
    let s = multi_model_scenario(&[60, 60, 60]);
    let total = 180u64;

    let strict = run_policy("ours", &s, &c, &RunOpts { seed: 42, ..RunOpts::default() });
    let over = run_policy("ours", &s, &c, &over_opts());

    for (label, r) in [("strict", &strict), ("oversubscribed", &over)] {
        assert_eq!(completions(r), total, "{label}: lost requests");
        assert!(r.inference_time > 0.0, "{label}: wedged");
        for st in &r.timeline {
            assert!(
                st.gpus_used() <= c.n_gpus,
                "{label}: executed stage used {} GPUs on a {}-GPU cluster",
                st.gpus_used(),
                c.n_gpus
            );
            assert!(st.swap_stall >= 0.0, "{label}: negative swap stall");
        }
    }
    assert_eq!(strict.residency, ResidencyStats::default(), "strict run swapped");
    assert!(
        over.residency.swaps_out >= 1,
        "packed run reported no swap-outs: {:?}",
        over.residency
    );
    assert!(
        over.inference_time <= strict.inference_time * 1.5 + 10.0,
        "packed run collapsed: {:.1}s vs strict {:.1}s",
        over.inference_time,
        strict.inference_time
    );
    let json = over.to_json();
    assert!(json.contains("\"residency\":{"), "report JSON lost the residency block");
}

#[test]
fn proactive_offload_overlaps_the_joiners_load_with_the_decode_tail() {
    // One node drains far earlier than its peer, with a third model
    // waiting: the drain boundary must discard the finished weights and
    // credit the joiner's transfer against the previous sub-stage's
    // decode tail — visible as overlapped (hidden) seconds in the report.
    let c = tiny_cluster();
    let s = multi_model_scenario(&[200, 8, 120]);
    let over = run_policy("ours", &s, &c, &over_opts());
    assert_eq!(completions(&over), 328, "lost requests");
    assert!(
        over.residency.swaps_out >= 1,
        "no drain-boundary swap-outs: {:?}",
        over.residency
    );
    assert!(
        over.residency.overlapped_seconds > 0.0,
        "joiner load never overlapped the decode tail: {:?}",
        over.residency
    );
}

/// Scan a lowering's event stream and check the residency lifecycle:
/// a `SwapIn` (warm reload) of a node is only legal after some `SwapOut`
/// released that node's weights earlier in the run.
fn assert_swap_lifecycle(label: &str, events: &[(usize, EventKind)]) {
    let mut swapped_out: std::collections::HashSet<usize> = Default::default();
    let mut ins = 0u64;
    for (node, kind) in events {
        match kind {
            EventKind::SwapOut { .. } => {
                swapped_out.insert(*node);
            }
            EventKind::SwapIn { .. } => {
                ins += 1;
                assert!(
                    swapped_out.contains(node),
                    "{label}: node {node} swapped in without a prior swap-out"
                );
            }
            _ => {}
        }
    }
    assert!(ins > 0, "{label}: expected at least one warm swap-in");
}

/// A packed stage engineered to displace: two narrow models hold the
/// GPUs, a wide (2-GPU) model waits behind them. The short one drains
/// fast; the long one is displaced (swap-vs-wait fires), the wide model
/// runs, and the long one rejoins warm.
fn displacement_fixture() -> (AppGraph, Vec<Vec<AppRequest>>, Stage) {
    let mut graph = AppGraph::default();
    graph.add_node("chatglm3-6b", "long", 512);
    graph.add_node("chatglm3-6b", "short", 512);
    graph.add_node("chatglm3-6b", "wide", 512);
    let lens = [(400usize, 180u32), (6, 20), (30, 60)];
    let workloads: Vec<Vec<AppRequest>> = lens
        .iter()
        .map(|&(n, out)| {
            (0..n as u64)
                .map(|id| AppRequest::simple(id, 24, out + (id * 7 % 40) as u32))
                .collect()
        })
        .collect();
    let stage = Stage {
        entries: vec![
            StageEntry { node: 0, plan: ExecPlan::new(1, 1) },
            StageEntry { node: 1, plan: ExecPlan::new(1, 1) },
            StageEntry { node: 2, plan: ExecPlan::new(2, 1) },
        ],
    };
    (graph, workloads, stage)
}

#[test]
fn packed_lowering_displaces_and_reloads_warm_on_the_sim_backend() {
    let c = tiny_cluster();
    let reg = Registry::paper();
    let hw = HardwareModel::new(c.clone());
    let swap = SwapCost::new(&c);
    let (graph, workloads, stage) = displacement_fixture();
    let total: usize = workloads.iter().map(|w| w.len()).sum();

    let mut state = ExecState::init(&workloads, |_, r| r.true_output_len);
    let mut mgr = ResidencyManager::new();
    let mut backend = SimBackend::new(&hw, c.mem_bytes);
    let out = run_packed_stage(
        &stage, &mut state, &graph, &reg, &c, &swap, &mut mgr, &mut backend, false,
    )
    .expect("lowering runs");

    assert!(out.subs.len() >= 3, "expected several sub-stages, got {}", out.subs.len());
    for sub in &out.subs {
        let used: u32 = sub.stage.entries.iter().map(|e| e.plan.n_gpus()).sum();
        assert!(used <= c.n_gpus, "sub-stage used {used} GPUs on {} available", c.n_gpus);
        assert!(sub.swap_stall >= 0.0);
    }
    assert_eq!(state.completed.len(), total, "lowering lost requests");
    assert!(state.clock > 0.0);

    // The long model must have been displaced (d2h swap-out) and later
    // rejoined over the h2d link (warm swap-in) — and never while pinned.
    let events: Vec<(usize, EventKind)> = out
        .subs
        .iter()
        .flat_map(|s| s.events.iter().map(|e| (e.node, e.kind)))
        .collect();
    assert_swap_lifecycle("displacement", &events);
    assert!(mgr.stats.swaps_out >= 2, "evict + drain discard expected: {:?}", mgr.stats);
    assert!(mgr.stats.swaps_in >= 1, "warm rejoin expected: {:?}", mgr.stats);
    assert!(mgr.stats.bytes_in > 0 && mgr.stats.bytes_out > 0);
    assert!(mgr.stats.stall_seconds > 0.0, "displacement must cost stall time");
}

#[test]
fn packed_lowering_completes_on_the_real_scheduler() {
    // The measured arm: the same lowering drives the mock-PJRT backend;
    // swap stalls advance the measured clock directly. Small workloads —
    // this exercises wiring, not throughput.
    let c = tiny_cluster();
    let reg = Registry::paper();
    let swap = SwapCost::new(&c);
    let mut graph = AppGraph::default();
    let mut workloads = vec![];
    for i in 0..3 {
        graph.add_node("chatglm3-6b", &format!("m{i}"), 64);
        workloads.push(
            (0..5u64).map(|id| AppRequest::simple(id, 6, 3 + (id % 5) as u32)).collect(),
        );
    }
    let stage = Stage {
        entries: (0..3)
            .map(|node| StageEntry { node, plan: ExecPlan::new(1, 1) })
            .collect(),
    };
    let mut state = ExecState::init(&workloads, |_, r| r.true_output_len);
    let mut mgr = ResidencyManager::new();
    let mut backend = PjrtBackend::with_model(Box::new(MockModel::new(4, 64)));
    let out = run_packed_stage(
        &stage, &mut state, &graph, &reg, &c, &swap, &mut mgr, &mut backend, true,
    )
    .expect("measured lowering runs");

    assert!(out.subs.len() >= 2, "three models cannot fit one sub-stage on two GPUs");
    for sub in &out.subs {
        let used: u32 = sub.stage.entries.iter().map(|e| e.plan.n_gpus()).sum();
        assert!(used <= c.n_gpus);
    }
    assert_eq!(state.completed.len(), 15, "measured lowering lost requests");
    assert!(state.clock > 0.0, "measured clock never advanced");
}

#[test]
fn packed_lowering_invariants_hold_under_random_workloads() {
    // Property sweep over the lowering: random per-node request counts on
    // the two-GPU cluster must always (a) complete everything, (b) keep
    // every executed sub-stage within the cluster, (c) keep resident
    // weights within total HBM at rest, and (d) respect the residency
    // lifecycle (warm swap-ins only after a swap-out).
    let c = tiny_cluster();
    let reg = Registry::paper();
    let hw = HardwareModel::new(c.clone());
    let swap = SwapCost::new(&c);
    let total_hbm = c.mem_bytes * c.n_gpus as u64;
    quickprop::run(12, 0x0FF10AD, |rng| {
        let n_models = rng.range_usize(3, 5);
        let mut graph = AppGraph::default();
        let mut workloads = vec![];
        for i in 0..n_models {
            graph.add_node("chatglm3-6b", &format!("m{i}"), 256);
            let n = rng.range_usize(4, 80);
            workloads.push(
                (0..n as u64)
                    .map(|id| {
                        AppRequest::simple(
                            id,
                            rng.range_u64(4, 60) as u32,
                            rng.range_u64(2, 120) as u32,
                        )
                    })
                    .collect::<Vec<_>>(),
            );
        }
        let total: usize = workloads.iter().map(|w| w.len()).sum();
        let mut state = ExecState::init(&workloads, |_, r| r.true_output_len);
        let mut mgr = ResidencyManager::new();
        let mut backend = SimBackend::new(&hw, c.mem_bytes);
        // The lowering hands control back once every packed entry got
        // scheduled at least once; the runner's outer loop re-plans the
        // remainder. Emulate that here: re-lower the unfinished set until
        // it drains (each call completes at least one node).
        let mut subs = vec![];
        for _pass in 0..(2 * n_models + 4) {
            let unfinished = state.unfinished_nodes();
            if unfinished.is_empty() {
                break;
            }
            let stage = Stage {
                entries: unfinished
                    .iter()
                    .map(|&node| StageEntry { node, plan: ExecPlan::new(1, 1) })
                    .collect(),
            };
            let out = run_packed_stage(
                &stage, &mut state, &graph, &reg, &c, &swap, &mut mgr, &mut backend, false,
            )
            .expect("lowering runs");
            subs.extend(out.subs);
        }
        prop_assert!(
            state.completed.len() == total,
            "lost requests: {} != {}",
            state.completed.len(),
            total
        );
        for sub in &subs {
            let used: u32 = sub.stage.entries.iter().map(|e| e.plan.n_gpus()).sum();
            prop_assert!(used <= c.n_gpus, "sub-stage used {} GPUs", used);
        }
        prop_assert!(
            mgr.resident_weight_bytes() <= total_hbm,
            "resident weights exceed HBM: {} > {}",
            mgr.resident_weight_bytes(),
            total_hbm
        );
        let mut swapped_out: std::collections::HashSet<usize> = Default::default();
        for e in subs.iter().flat_map(|s| &s.events) {
            match e.kind {
                EventKind::SwapOut { .. } => {
                    swapped_out.insert(e.node);
                }
                EventKind::SwapIn { .. } => {
                    prop_assert!(
                        swapped_out.contains(&e.node),
                        "node {} swapped in before any swap-out",
                        e.node
                    );
                }
                _ => {}
            }
        }
        Ok(())
    });
}
