//! The paper's linear per-iteration cost model (Eq. 5, Fig. 4).
//!
//! `t = t_comp + t_prep + t_samp`, each of the form
//! `a_phase[B] · x_phase + b_phase[B]` with `x_comp = FLOPs`,
//! `x_prep = B·s` (padded tokens) and `x_samp = S` (total tokens).
//!
//! The coefficients are *fit* per batch-size bucket against profiled
//! iterations — here profiles of [`super::HardwareModel`], mirroring how
//! the paper profiles vLLM on A100s. Crucially the fit only sees the three
//! modeled components; the engine's fixed overhead and TP communication are
//! invisible to it, so the model inherits the paper's estimation error.

use std::collections::BTreeMap;

use super::hardware::HardwareModel;
use super::{flops, IterLatency};
use crate::models::ModelSpec;
use crate::util::linfit::{self, LinFit};

/// Batch-size buckets the paper's `a[B]`, `b[B]` constants are keyed by.
pub const B_BUCKETS: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Linear pieces for one (phase, bucket).
#[derive(Debug, Clone, Copy)]
struct Piece {
    comp: LinFit,
    prep: LinFit,
    samp: LinFit,
}

/// The fitted Eq. 5 model. One coefficient set per batch bucket, shared
/// across models (the inputs — FLOPs, B·s, S — carry the model identity,
/// exactly as in the paper where the same functional form fits Llama-7B).
#[derive(Debug, Clone)]
pub struct LinearIterModel {
    pieces: BTreeMap<usize, Piece>,
    /// TP degrees divide FLOPs; efficiency differences are folded into the
    /// per-bucket slopes at fit time using a tp=1 profile, so the planner
    /// sees TP through the FLOPs argument alone (plus this comm surcharge
    /// table fit per tp).
    comm_per_layer_token: BTreeMap<u32, f64>,
}

fn bucket_of(b: usize) -> usize {
    *B_BUCKETS
        .iter()
        .min_by_key(|&&c| (c as i64 - b as i64).abs())
        .unwrap()
}

impl LinearIterModel {
    /// Profile the hardware model over a workload sweep and fit the three
    /// linear pieces per batch bucket (the paper's Fig. 4 procedure).
    pub fn fit_from_profile(hw: &HardwareModel) -> Self {
        // A mid-size dense model is the profiling vehicle (paper: Llama-7B).
        let probe = crate::models::Registry::paper()
            .get("mistral-7b-instruct")
            .unwrap()
            .clone();
        let mut pieces = BTreeMap::new();
        for &b in &B_BUCKETS {
            let mut xs_comp = vec![];
            let mut ys_comp = vec![];
            let mut xs_prep = vec![];
            let mut ys_prep = vec![];
            let mut xs_samp = vec![];
            let mut ys_samp = vec![];
            // Sweep context lengths to vary FLOPs at fixed B. Include both
            // decode and prefill points so one line prices both phases (the
            // paper fits latency-vs-FLOPs lines per #seq).
            for ctx in [32u32, 64, 128, 256, 512, 1024, 2048] {
                let total_ctx = b as u64 * ctx as u64;
                let c = hw.decode_components(&probe, 1, b, total_ctx, ctx);
                xs_comp.push(flops::decode_flops(&probe, b, total_ctx));
                ys_comp.push(c.comp);
                xs_prep.push(b as f64 * ctx as f64);
                ys_prep.push(c.prep);
                xs_samp.push(total_ctx as f64);
                ys_samp.push(c.samp);

                let lens = vec![ctx; b];
                let p = hw.prefill_components(&probe, 1, &lens);
                xs_comp.push(flops::prefill_flops(&probe, &lens));
                ys_comp.push(p.comp);
            }
            let piece = Piece {
                comp: linfit::fit(&xs_comp, &ys_comp).expect("comp fit"),
                prep: linfit::fit(&xs_prep, &ys_prep).expect("prep fit"),
                samp: linfit::fit(&xs_samp, &ys_samp).expect("samp fit"),
            };
            pieces.insert(b, piece);
        }

        // TP comm surcharge per (layer, token): fit from two probe points.
        let mut comm = BTreeMap::new();
        for tp in [1u32, 2, 4, 8] {
            let c = hw.decode_components(&probe, tp, 64, 64 * 256, 256);
            let per = c.comm / (probe.n_layers as f64 * 64.0);
            comm.insert(tp, per);
        }
        LinearIterModel { pieces, comm_per_layer_token: comm }
    }

    fn piece(&self, b: usize) -> &Piece {
        &self.pieces[&bucket_of(b)]
    }

    fn comm(&self, spec: &ModelSpec, tp: u32, tokens: f64) -> f64 {
        self.comm_per_layer_token.get(&tp).copied().unwrap_or(0.0)
            * spec.n_layers as f64
            * tokens
    }

    /// Goodness-of-fit report for Fig. 4 (r² per phase at a bucket).
    pub fn fit_quality(&self, b: usize) -> (f64, f64, f64) {
        let p = self.piece(b);
        (p.comp.r2, p.prep.r2, p.samp.r2)
    }
}

impl IterLatency for LinearIterModel {
    fn prefill(&self, spec: &ModelSpec, tp: u32, prompt_lens: &[u32]) -> f64 {
        let b = prompt_lens.len();
        let p = self.piece(b);
        let tokens: u64 = prompt_lens.iter().map(|&l| l as u64).sum();
        let max_len = prompt_lens.iter().copied().max().unwrap_or(0);
        let fl = flops::prefill_flops(spec, prompt_lens) / tp as f64;
        (p.comp.predict(fl) + p.prep.predict(b as f64 * max_len as f64)
            + p.samp.predict(tokens as f64)
            + self.comm(spec, tp, tokens as f64))
            .max(1e-5)
    }

    fn decode(
        &self,
        spec: &ModelSpec,
        tp: u32,
        batch: usize,
        total_context: u64,
        max_context: u32,
    ) -> f64 {
        let p = self.piece(batch);
        let fl = flops::decode_flops(spec, batch, total_context) / tp as f64;
        (p.comp.predict(fl) + p.prep.predict(batch as f64 * max_context as f64)
            + p.samp.predict(total_context as f64)
            + self.comm(spec, tp, batch as f64))
            .max(1e-5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::models::Registry;

    fn fitted() -> (LinearIterModel, HardwareModel) {
        let hw = HardwareModel::new(ClusterSpec::a100_node(8));
        (LinearIterModel::fit_from_profile(&hw), hw)
    }

    #[test]
    fn fits_are_tight() {
        let (m, _) = fitted();
        for &b in &[1usize, 16, 256] {
            let (rc, rp, rs) = m.fit_quality(b);
            assert!(rc > 0.95, "comp r2 at B={b}: {rc}");
            assert!(rp > 0.95, "prep r2 at B={b}: {rp}");
            assert!(rs > 0.95, "samp r2 at B={b}: {rs}");
        }
    }

    #[test]
    fn estimate_close_but_below_truth() {
        // The linear model misses base overhead + comm => systematic
        // underestimate, within ~5–40% (the paper's observed error band).
        let (m, hw) = fitted();
        let spec = Registry::paper().get("vicuna-13b-v1.5").unwrap().clone();
        for (b, ctx) in [(256usize, 200u32), (64, 400), (8, 150)] {
            let total = b as u64 * ctx as u64;
            let est = m.decode(&spec, 1, b, total, ctx);
            let truth = hw.decode(&spec, 1, b, total, ctx);
            assert!(est < truth, "B={b}: est {est} >= truth {truth}");
            assert!(est > truth * 0.5, "B={b}: est {est} too far below {truth}");
        }
    }

    #[test]
    fn bucket_interpolation_is_monotoneish() {
        let (m, _) = fitted();
        let spec = Registry::paper().get("chatglm3-6b").unwrap().clone();
        let t64 = m.decode(&spec, 1, 64, 64 * 200, 210);
        let t256 = m.decode(&spec, 1, 256, 256 * 200, 210);
        assert!(t256 > t64);
    }

    #[test]
    fn generalizes_across_models() {
        // Fit on 7B, price a 70B: per-iteration time must scale up ~with c.
        let (m, hw) = fitted();
        let big = Registry::paper().get("llama-2-70b-chat").unwrap().clone();
        let est = m.decode(&big, 8, 128, 128 * 300, 310);
        let truth = hw.decode(&big, 8, 128, 128 * 300, 310);
        let err = (est - truth).abs() / truth;
        assert!(err < 0.5, "err={err} est={est} truth={truth}");
    }
}
