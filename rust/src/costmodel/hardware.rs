//! Analytic A100 ground-truth latency model.
//!
//! Substitutes the paper's physical testbed (DESIGN.md): a roofline
//! (compute vs HBM) with a batch-dependent efficiency curve, explicit
//! input-preparation and sampling costs (the paper's three components,
//! Fig. 4), a fixed scheduler/launch overhead, and tensor-parallel
//! all-reduce time over NVLink/PCIe.
//!
//! Calibration anchors (§5.1 of the paper, reproduced by unit tests):
//! * chatglm3-6b, 1 000 requests (in≈21, out≈180, limit 512):
//!   ≈37–48 s on 1 GPU; ≈5× less on 8 GPUs (paper: 2.3–3×; sublinear).
//! * chatglm3-6b, 10 000 requests: ≈356 s on 1 GPU, ≈6.6× better on 8.
//! * vicuna-13b, 1 000 SharedGPT requests ≈ 92 s inference on one plan.

use super::{flops, IterLatency};
use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;

/// Latency decomposition of one iteration. `comp`/`prep`/`samp` are the
/// paper's three modeled components; `base` (engine/scheduler overhead) and
/// `comm` (TP all-reduce) exist in reality but are *not* captured by the
/// linear cost model — the gap is the paper's residual estimation error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterComponents {
    /// Matmul/attention compute time.
    pub comp: f64,
    /// Input-preparation time.
    pub prep: f64,
    /// Token-sampling time.
    pub samp: f64,
    /// Fixed engine/scheduler overhead.
    pub base: f64,
    /// Tensor-parallel all-reduce time.
    pub comm: f64,
}

impl IterComponents {
    /// Sum of all five components.
    pub fn total(&self) -> f64 {
        self.comp + self.prep + self.samp + self.base + self.comm
    }
}

/// Ground-truth per-iteration latency model (see module docs).
#[derive(Debug, Clone)]
pub struct HardwareModel {
    /// The hardware being modeled.
    pub cluster: ClusterSpec,
    /// Peak decode MXU/tensor-core efficiency at infinite batch.
    pub eff_dec_max: f64,
    /// Batch size at which decode efficiency reaches half its max.
    pub eff_dec_knee: f64,
    /// Peak prefill efficiency at infinite batched tokens.
    pub eff_pref_max: f64,
    /// Batched-token count at which prefill efficiency reaches half max.
    pub eff_pref_knee: f64,
    /// Fixed per-iteration engine overhead (seconds).
    pub base_overhead: f64,
    /// Input-preparation constant (seconds per iteration).
    pub prep_const: f64,
    /// Input-preparation cost per padded token (seconds).
    pub prep_per_padded_token: f64,
    /// Sampling constant (seconds per iteration).
    pub samp_const: f64,
    /// Sampling cost per running sequence (seconds).
    pub samp_per_token: f64,
}

impl HardwareModel {
    /// The calibrated A100 ground-truth model for `cluster`.
    pub fn new(cluster: ClusterSpec) -> Self {
        HardwareModel {
            cluster,
            eff_dec_max: 0.35,
            eff_dec_knee: 90.0,
            eff_pref_max: 0.55,
            eff_pref_knee: 512.0,
            base_overhead: 6.0e-3,
            prep_const: 2.0e-3,
            prep_per_padded_token: 3.0e-8,
            samp_const: 2.5e-3,
            samp_per_token: 1.5e-7,
        }
    }

    fn eff_decode(&self, batch: f64) -> f64 {
        self.eff_dec_max * batch / (batch + self.eff_dec_knee)
    }

    fn eff_prefill(&self, tokens: f64) -> f64 {
        self.eff_pref_max * tokens / (tokens + self.eff_pref_knee)
    }

    /// All-reduce time per iteration for a TP group (2 all-reduces per
    /// layer, ring cost `2·(tp-1)/tp · bytes / bw`).
    fn comm_time(&self, spec: &ModelSpec, tp: u32, tokens: f64) -> f64 {
        if tp <= 1 {
            return 0.0;
        }
        let bytes = tokens * spec.hidden as f64 * spec.dtype_bytes as f64;
        let bw = self.cluster.tp_group_bw(tp);
        let per_ar = 2.0 * (tp as f64 - 1.0) / tp as f64 * bytes / bw;
        // 2 all-reduces per layer + a small per-launch latency.
        2.0 * spec.n_layers as f64 * (per_ar + 6.0e-6)
    }

    /// Component breakdown of a prefill iteration.
    pub fn prefill_components(
        &self,
        spec: &ModelSpec,
        tp: u32,
        prompt_lens: &[u32],
    ) -> IterComponents {
        let tokens: u64 = prompt_lens.iter().map(|&l| l as u64).sum();
        let batch = prompt_lens.len() as f64;
        let max_len = prompt_lens.iter().copied().max().unwrap_or(0) as f64;
        let fl = flops::prefill_flops(spec, prompt_lens);
        let t_flops = fl / (tp as f64 * self.cluster.peak_flops * self.eff_prefill(tokens as f64));
        let t_mem = spec.weight_bytes_per_gpu(tp) as f64 / self.cluster.hbm_bw;
        IterComponents {
            comp: t_flops.max(t_mem),
            prep: self.prep_const + self.prep_per_padded_token * batch * max_len,
            samp: self.samp_const + self.samp_per_token * tokens as f64,
            base: self.base_overhead,
            comm: self.comm_time(spec, tp, tokens as f64),
        }
    }

    /// Component breakdown of a decode iteration.
    pub fn decode_components(
        &self,
        spec: &ModelSpec,
        tp: u32,
        batch: usize,
        total_context: u64,
        max_context: u32,
    ) -> IterComponents {
        let fl = flops::decode_flops(spec, batch, total_context);
        let t_flops = fl / (tp as f64 * self.cluster.peak_flops * self.eff_decode(batch as f64));
        let kv_bytes = total_context as f64 * spec.kv_bytes_per_token(tp) as f64;
        let t_mem = (spec.weight_bytes_per_gpu(tp) as f64 + kv_bytes) / self.cluster.hbm_bw;
        IterComponents {
            comp: t_flops.max(t_mem),
            prep: self.prep_const + self.prep_per_padded_token * batch as f64 * max_context as f64,
            samp: self.samp_const + self.samp_per_token * total_context as f64,
            base: self.base_overhead,
            comm: self.comm_time(spec, tp, batch as f64),
        }
    }
}

/// Weight swap-cost estimator for the model-residency subsystem: what a
/// host-cached model costs to bring back onto (or proactively evict off)
/// its GPUs over the host link, per model × TP degree. Cold first loads
/// (disk + engine init) stay priced by [`ModelSpec::load_time`]; this
/// estimator prices the *warm* path, where the weights already sit in
/// pinned host memory and only the h2d/d2h transfer plus a fixed
/// runtime-rebind overhead remains.
#[derive(Debug, Clone, Copy)]
pub struct SwapCost {
    /// Host-to-device transfer bandwidth (bytes/s, per GPU).
    pub h2d_bw: f64,
    /// Device-to-host offload bandwidth (bytes/s, per GPU).
    pub d2h_bw: f64,
}

/// Fixed per-swap overhead (allocator rebind, cache re-warm) in seconds,
/// paid on top of the h2d transfer for a warm load.
pub const SWAP_FIXED_OVERHEAD: f64 = 0.5;

impl SwapCost {
    /// The estimator for `cluster`'s host links.
    pub fn new(cluster: &ClusterSpec) -> Self {
        SwapCost { h2d_bw: cluster.h2d_bw, d2h_bw: cluster.d2h_bw }
    }

    /// The estimator with an overridden h2d bandwidth (the `--h2d-bw`
    /// CLI knob); `d2h` scales by the cluster's d2h/h2d ratio.
    pub fn with_h2d(cluster: &ClusterSpec, h2d_bw: f64) -> Self {
        let ratio = if cluster.h2d_bw > 0.0 { cluster.d2h_bw / cluster.h2d_bw } else { 1.0 };
        SwapCost { h2d_bw, d2h_bw: h2d_bw * ratio }
    }

    /// Bytes one replica group moves per GPU when swapping under `tp`.
    pub fn bytes_per_gpu(spec: &ModelSpec, tp: u32) -> u64 {
        spec.weight_bytes_per_gpu(tp)
    }

    /// Total weight bytes a `(dp, tp)` deployment moves across all its
    /// GPUs (`dp` replicas × full weights each).
    pub fn bytes_total(spec: &ModelSpec, dp: u32, tp: u32) -> u64 {
        Self::bytes_per_gpu(spec, tp) * (dp * tp) as u64
    }

    /// Seconds to swap a host-cached model *in* under `tp`: the per-GPU
    /// shard transfer (shards move concurrently over independent links)
    /// plus the fixed rebind overhead. Far cheaper than the cold
    /// [`ModelSpec::load_time`] — that is the whole point of keeping
    /// evicted weights in host memory.
    pub fn load_secs(&self, spec: &ModelSpec, tp: u32) -> f64 {
        Self::bytes_per_gpu(spec, tp) as f64 / self.h2d_bw + SWAP_FIXED_OVERHEAD
    }

    /// Seconds to proactively evict a model's weights to host under `tp`.
    pub fn evict_secs(&self, spec: &ModelSpec, tp: u32) -> f64 {
        Self::bytes_per_gpu(spec, tp) as f64 / self.d2h_bw
    }
}

impl IterLatency for HardwareModel {
    fn prefill(&self, spec: &ModelSpec, tp: u32, prompt_lens: &[u32]) -> f64 {
        self.prefill_components(spec, tp, prompt_lens).total()
    }

    fn decode(
        &self,
        spec: &ModelSpec,
        tp: u32,
        batch: usize,
        total_context: u64,
        max_context: u32,
    ) -> f64 {
        self.decode_components(spec, tp, batch, total_context, max_context).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn hw() -> HardwareModel {
        HardwareModel::new(ClusterSpec::a100_node(8))
    }

    fn glm() -> ModelSpec {
        Registry::paper().get("chatglm3-6b").unwrap().clone()
    }

    #[test]
    fn decode_iteration_magnitude() {
        // chatglm3-6b, saturated batch: ~40–80 ms/iter -> ~4–6 k tok/s,
        // consistent with the paper's 1.8 M tokens in 356 s on 1 GPU.
        let t = hw().decode(&glm(), 1, 256, 256 * 200, 230);
        assert!((0.03..0.09).contains(&t), "t={t}");
        let toks_per_s = 256.0 / t;
        assert!((3000.0..7000.0).contains(&toks_per_s), "{toks_per_s}");
    }

    #[test]
    fn decode_has_memory_floor_at_tiny_batch() {
        // B=1 must still pay the full weight read: >= 12 GB / 2 TB/s = 6 ms.
        let c = hw().decode_components(&glm(), 1, 1, 200, 200);
        assert!(c.comp >= 5.5e-3, "comp={}", c.comp);
    }

    #[test]
    fn decode_efficiency_rises_with_batch() {
        // Per-token cost must fall as batch grows (the paper's key
        // sublinearity driver).
        let h = hw();
        let t32 = h.decode(&glm(), 1, 32, 32 * 200, 210) / 32.0;
        let t256 = h.decode(&glm(), 1, 256, 256 * 200, 210) / 256.0;
        assert!(t256 < t32 * 0.5, "t32/token={t32} t256/token={t256}");
    }

    #[test]
    fn tp_helps_large_model_more_than_small() {
        let reg = Registry::paper();
        let big = reg.get("llama-2-70b-chat").unwrap();
        let h = hw();
        let t1 = h.decode(big, 2, 128, 128 * 400, 420);
        let t8 = h.decode(big, 8, 128, 128 * 400, 420);
        assert!(t8 < t1, "t1={t1} t8={t8}");
        // But not 4x better: comm + overheads bite.
        assert!(t8 > t1 / 4.0);
    }

    #[test]
    fn tp_across_pairs_pays_pcie() {
        let h = hw();
        let s = glm();
        let c2 = h.decode_components(&s, 2, 256, 256 * 200, 210);
        let c4 = h.decode_components(&s, 4, 256, 256 * 200, 210);
        assert!(c4.comm > c2.comm * 2.0, "nvlink {} vs pcie {}", c2.comm, c4.comm);
    }

    #[test]
    fn prefill_throughput_reasonable() {
        // 64 prompts x 310 tokens on a 7B model @ tp=1: tens of ms.
        let reg = Registry::paper();
        let spec = reg.get("mistral-7b-instruct").unwrap();
        let lens = vec![310u32; 64];
        let t = hw().prefill(spec, 1, &lens);
        let toks_per_s = (64.0 * 310.0) / t;
        assert!((5.0e3..100.0e3).contains(&toks_per_s), "{toks_per_s}");
    }

    #[test]
    fn warm_swap_is_much_cheaper_than_cold_load() {
        // chatglm3-6b: ~12 GB of weights. Warm swap-in at ~26 GB/s is
        // under a second plus overhead; the cold load is 10+ seconds.
        let c = ClusterSpec::a100_node(8);
        let swap = SwapCost::new(&c);
        let s = glm();
        for tp in [1u32, 2] {
            let warm = swap.load_secs(&s, tp);
            let cold = s.load_time(tp);
            assert!(warm < cold * 0.5, "tp={tp} warm={warm} cold={cold}");
            assert!(warm > SWAP_FIXED_OVERHEAD, "transfer must cost something");
        }
        // Evict is pure d2h transfer, no rebind overhead.
        assert!(swap.evict_secs(&s, 1) < swap.load_secs(&s, 1));
        // TP splits the per-GPU shard, so per-GPU swap time shrinks.
        assert!(swap.load_secs(&s, 2) < swap.load_secs(&s, 1));
    }

    #[test]
    fn h2d_override_scales_both_directions() {
        let c = ClusterSpec::a100_node(8);
        let fast = SwapCost::with_h2d(&c, c.h2d_bw * 2.0);
        let base = SwapCost::new(&c);
        let s = glm();
        assert!(fast.load_secs(&s, 1) < base.load_secs(&s, 1));
        assert!(fast.evict_secs(&s, 1) < base.evict_secs(&s, 1));
        let ratio = fast.d2h_bw / fast.h2d_bw;
        assert!((ratio - c.d2h_bw / c.h2d_bw).abs() < 1e-12);
    }

    #[test]
    fn swap_bytes_account_all_replicas() {
        let s = glm();
        let per_gpu = SwapCost::bytes_per_gpu(&s, 2);
        assert_eq!(SwapCost::bytes_total(&s, 3, 2), per_gpu * 6);
    }

    #[test]
    fn anchor_one_gpu_vs_eight_sublinear() {
        // Reproduce the paper's §5.1 observation qualitatively: for a small
        // workload, 8 GPUs of data parallelism yield far less than 8x.
        // (Full end-to-end check lives in the engine tests; here we check
        // the per-iteration shape: batch 256 is much more efficient than
        // batch 32 per token.)
        let h = hw();
        let s = glm();
        let full = h.decode(&s, 1, 256, 256 * 110, 130);
        let split = h.decode(&s, 1, 32, 32 * 110, 130);
        // Per-GPU token throughput at B=256 vs B=32: the big batch must be
        // far more efficient, which is exactly why dp=8 over a small
        // workload disappoints.
        let tput_full = 256.0 / full;
        let tput_split = 32.0 / split;
        assert!(tput_full / tput_split > 2.0, "{}", tput_full / tput_split);
    }
}
