//! Output-length sampler (§4.1): per-model eCDFs built from the No Robots
//! trace, sampled at planning time.
//!
//! Given an input of length `l_in`, model max sequence `l_max` and an
//! explicit output limit `y`:  `l_out = min(X, y, l_max - l_in)`,
//! `X ~ F_out` — exactly the paper's formula.

use std::collections::BTreeMap;

use super::ecdf::Ecdf;
use crate::models::Registry;
use crate::util::rng::Rng;
use crate::workload::norobots;

/// The paper's §4.1 output-length clamp, shared by the offline sampler
/// and the online posterior so the two estimate paths can never
/// desynchronize: `l_out = min(x, max_out, max_seq - input_len)` with a
/// *saturating* subtraction (a prompt at/over the context window clamps
/// to 1 instead of wrapping the u32) and a floor of 1.
pub fn clamp_output_len(x: u32, input_len: u32, max_out: u32, max_seq: u32) -> u32 {
    let window = max_seq.saturating_sub(input_len).max(1);
    x.min(max_out).min(window).max(1)
}

/// Per-model output-length eCDFs, built offline (§2).
#[derive(Debug, Clone)]
pub struct OutputSampler {
    ecdfs: BTreeMap<String, Ecdf>,
}

/// Trace size used to build each model's eCDF (paper: 10 000 requests).
pub const TRACE_SIZE: usize = 10_000;

impl OutputSampler {
    /// Build eCDFs for every model in the paper registry by "running" the
    /// No Robots trace through each (see `workload::norobots`).
    pub fn from_norobots_trace(seed: u64) -> Self {
        let reg = Registry::paper();
        let mut ecdfs = BTreeMap::new();
        for name in reg.names() {
            let t = norobots::trace(name, TRACE_SIZE, seed ^ 0xECDF);
            let lens = t.into_iter().map(|r| r.output_len).collect();
            ecdfs.insert(name.to_string(), Ecdf::from_samples(lens));
        }
        OutputSampler { ecdfs }
    }

    /// Build a sampler from explicit per-model observation sets (tests,
    /// custom calibrations, and the online posterior construction).
    pub fn from_samples_map(samples: BTreeMap<String, Vec<u32>>) -> Self {
        OutputSampler {
            ecdfs: samples.into_iter().map(|(m, s)| (m, Ecdf::from_samples(s))).collect(),
        }
    }

    /// The eCDF built for `model`, if registered.
    pub fn ecdf(&self, model: &str) -> Option<&Ecdf> {
        self.ecdfs.get(model)
    }

    /// Registered model names, ascending.
    pub fn models(&self) -> impl Iterator<Item = &str> {
        self.ecdfs.keys().map(|s| s.as_str())
    }

    /// Sample one output length for a request (the paper's §4.1 formula).
    pub fn sample(
        &self,
        model: &str,
        input_len: u32,
        max_out: u32,
        max_seq: u32,
        rng: &mut Rng,
    ) -> u32 {
        let x = self
            .ecdfs
            .get(model)
            .unwrap_or_else(|| panic!("no eCDF for model {model}"))
            .sample(rng);
        clamp_output_len(x, input_len, max_out, max_seq)
    }

    /// Sample output lengths for a whole request batch.
    pub fn sample_many(
        &self,
        model: &str,
        inputs: &[u32],
        max_out: u32,
        max_seq: u32,
        rng: &mut Rng,
    ) -> Vec<u32> {
        inputs.iter().map(|&l| self.sample(model, l, max_out, max_seq, rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::lengths::model_style;

    #[test]
    fn ecdf_exists_for_all_models() {
        let s = OutputSampler::from_norobots_trace(1);
        for m in Registry::paper().names() {
            assert!(s.ecdf(m).is_some(), "{m}");
        }
    }

    #[test]
    fn sampler_tracks_true_distribution() {
        // The eCDF is built from the model's true style, so sampled means
        // must land near the true mean (finite-sample error only).
        let s = OutputSampler::from_norobots_trace(2);
        let mut rng = Rng::new(3);
        for m in ["vicuna-13b-v1.5", "chatglm3-6b", "mistral-7b-instruct"] {
            let n = 5000;
            let mean: f64 = (0..n)
                .map(|_| s.sample(m, 20, 100_000, 100_000, &mut rng) as f64)
                .sum::<f64>()
                / n as f64;
            let truth = model_style(m).approx_mean();
            let err = (mean - truth).abs() / truth;
            assert!(err < 0.25, "{m}: sampled {mean} vs true {truth}");
        }
    }

    #[test]
    fn limits_are_enforced() {
        let s = OutputSampler::from_norobots_trace(4);
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let l = s.sample("alpaca-13b", 30, 256, 2048, &mut rng);
            assert!((1..=256).contains(&l));
            // Context-window clamp: input eats almost the whole window.
            let l2 = s.sample("alpaca-13b", 2040, 512, 2048, &mut rng);
            assert!(l2 <= 8);
        }
    }

    #[test]
    fn prompt_at_or_over_context_never_underflows() {
        // Regression: `l_out = min(X, y, l_max - l_in)` must use a
        // saturating subtraction — a prompt at or past the model context
        // (`l_in >= l_max`) clamps the window to 1 instead of wrapping a
        // u32 (which in release builds produced a ~4-billion-token
        // "window" and in debug builds panicked).
        let s = OutputSampler::from_norobots_trace(6);
        let mut rng = Rng::new(7);
        for input_len in [2048u32, 2049, 10_000, u32::MAX] {
            let l = s.sample("alpaca-13b", input_len, 512, 2048, &mut rng);
            assert_eq!(l, 1, "input_len={input_len}");
        }
        let batch = s.sample_many("alpaca-13b", &[2048, 4096, u32::MAX], 512, 2048, &mut rng);
        assert!(batch.iter().all(|&l| l == 1), "{batch:?}");
    }

    #[test]
    fn explicit_samples_map_round_trips() {
        let mut map = BTreeMap::new();
        map.insert("m".to_string(), vec![5u32, 10, 15]);
        let s = OutputSampler::from_samples_map(map);
        assert_eq!(s.models().collect::<Vec<_>>(), vec!["m"]);
        let e = s.ecdf("m").unwrap();
        assert_eq!((e.min(), e.max(), e.len()), (5, 15, 3));
    }
}
