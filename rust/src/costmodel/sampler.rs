//! Output-length sampler (§4.1): per-model eCDFs built from the No Robots
//! trace, sampled at planning time.
//!
//! Given an input of length `l_in`, model max sequence `l_max` and an
//! explicit output limit `y`:  `l_out = min(X, y, l_max - l_in)`,
//! `X ~ F_out` — exactly the paper's formula.

use std::collections::BTreeMap;

use super::ecdf::Ecdf;
use crate::models::Registry;
use crate::util::rng::Rng;
use crate::workload::norobots;

/// Per-model output-length eCDFs, built offline (§2).
#[derive(Debug, Clone)]
pub struct OutputSampler {
    ecdfs: BTreeMap<String, Ecdf>,
}

/// Trace size used to build each model's eCDF (paper: 10 000 requests).
pub const TRACE_SIZE: usize = 10_000;

impl OutputSampler {
    /// Build eCDFs for every model in the paper registry by "running" the
    /// No Robots trace through each (see `workload::norobots`).
    pub fn from_norobots_trace(seed: u64) -> Self {
        let reg = Registry::paper();
        let mut ecdfs = BTreeMap::new();
        for name in reg.names() {
            let t = norobots::trace(name, TRACE_SIZE, seed ^ 0xECDF);
            let lens = t.into_iter().map(|r| r.output_len).collect();
            ecdfs.insert(name.to_string(), Ecdf::from_samples(lens));
        }
        OutputSampler { ecdfs }
    }

    /// The eCDF built for `model`, if registered.
    pub fn ecdf(&self, model: &str) -> Option<&Ecdf> {
        self.ecdfs.get(model)
    }

    /// Sample one output length for a request (the paper's §4.1 formula).
    pub fn sample(
        &self,
        model: &str,
        input_len: u32,
        max_out: u32,
        max_seq: u32,
        rng: &mut Rng,
    ) -> u32 {
        let x = self
            .ecdfs
            .get(model)
            .unwrap_or_else(|| panic!("no eCDF for model {model}"))
            .sample(rng);
        let window = max_seq.saturating_sub(input_len).max(1);
        x.min(max_out).min(window).max(1)
    }

    /// Sample output lengths for a whole request batch.
    pub fn sample_many(
        &self,
        model: &str,
        inputs: &[u32],
        max_out: u32,
        max_seq: u32,
        rng: &mut Rng,
    ) -> Vec<u32> {
        inputs.iter().map(|&l| self.sample(model, l, max_out, max_seq, rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::lengths::model_style;

    #[test]
    fn ecdf_exists_for_all_models() {
        let s = OutputSampler::from_norobots_trace(1);
        for m in Registry::paper().names() {
            assert!(s.ecdf(m).is_some(), "{m}");
        }
    }

    #[test]
    fn sampler_tracks_true_distribution() {
        // The eCDF is built from the model's true style, so sampled means
        // must land near the true mean (finite-sample error only).
        let s = OutputSampler::from_norobots_trace(2);
        let mut rng = Rng::new(3);
        for m in ["vicuna-13b-v1.5", "chatglm3-6b", "mistral-7b-instruct"] {
            let n = 5000;
            let mean: f64 = (0..n)
                .map(|_| s.sample(m, 20, 100_000, 100_000, &mut rng) as f64)
                .sum::<f64>()
                / n as f64;
            let truth = model_style(m).approx_mean();
            let err = (mean - truth).abs() / truth;
            assert!(err < 0.25, "{m}: sampled {mean} vs true {truth}");
        }
    }

    #[test]
    fn limits_are_enforced() {
        let s = OutputSampler::from_norobots_trace(4);
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let l = s.sample("alpaca-13b", 30, 256, 2048, &mut rng);
            assert!((1..=256).contains(&l));
            // Context-window clamp: input eats almost the whole window.
            let l2 = s.sample("alpaca-13b", 2040, 512, 2048, &mut rng);
            assert!(l2 <= 8);
        }
    }
}
