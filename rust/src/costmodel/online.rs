//! Runtime length-feedback: online refinement of the offline eCDFs.
//!
//! The offline cost model freezes its output-length estimates at planning
//! time: one eCDF per model, built from the No Robots trace, sampled once
//! per request ([`super::sampler::OutputSampler`]). When the application's
//! true answers come from a different distribution (a dataset shift, a
//! different prompt style), every simulation the plan rests on is
//! miscalibrated — and stays miscalibrated for the whole run.
//!
//! [`OnlineSampler`] closes the loop during the running phase:
//!
//! * **Conditional sampling** — an in-flight request that already decoded
//!   `d` tokens without finishing is, by definition, in the tail of the
//!   distribution: re-estimating its total length must sample from
//!   `X | X > d`, not from the unconditional eCDF
//!   ([`super::Ecdf::sample_given_gt`]). The frozen path instead clamps an
//!   unconditional draw up to `d + 1`, which systematically underestimates
//!   every long request still running.
//! * **Posterior mixing** — each completed request contributes its
//!   *observed* ground-truth length. The per-model posterior is the eCDF
//!   over the offline trace plus every observation replicated
//!   `obs_weight` times, so evidence gradually outweighs the prior as
//!   completions accumulate. With zero observations the posterior *is*
//!   the offline eCDF, bit for bit.
//!
//! Everything here is deterministic under the session seed: observations
//! arrive in stage-commit order, the posterior is a pure function of
//! (offline trace, observations, weight), and sampling consumes exactly
//! one uniform draw per request.
//!
//! [`OnlineStats`] carries the drift/replan accounting the policy layer
//! (see [`crate::policy::SamuLlmPolicy`]) reports through
//! [`crate::metrics::RunReport`].

use std::collections::BTreeMap;

use super::ecdf::Ecdf;
use super::sampler::OutputSampler;
use crate::util::rng::Rng;

/// Default weight of one observed completion, in offline-trace-sample
/// equivalents (the builder's `.online_weight(..)` knob).
pub const DEFAULT_OBS_WEIGHT: f64 = 64.0;

/// Upper bound on the observation weight. The posterior materializes
/// each observation `weight` times, so an unbounded knob would turn a
/// typo (`--online-weight 1e6`) into a gigabyte-scale allocation
/// mid-run; past this cap a few dozen completions already dominate the
/// 10 000-sample offline trace anyway.
pub const MAX_OBS_WEIGHT: f64 = 1024.0;

/// Default drift score above which the remaining application is
/// replanned (the builder's `.replan_threshold(..)` knob). Set above the
/// typical makespan error of a well-calibrated run, so healthy runs keep
/// repairing stages instead of paying search time at every boundary.
pub const DEFAULT_REPLAN_THRESHOLD: f64 = 0.35;

/// Minimum completed observations before a model's mean-length drift
/// counts toward the replan trigger (below this the sample mean is too
/// noisy to act on).
pub const MIN_DRIFT_OBS: usize = 8;

/// Per-model observation set plus its lazily rebuilt posterior.
#[derive(Debug, Clone, Default)]
struct ModelObs {
    /// Observed ground-truth output lengths, in completion order.
    lens: Vec<u32>,
    /// Running sum of `lens` (mean bookkeeping).
    sum: f64,
    /// Posterior eCDF over offline trace + weighted observations;
    /// `None` marks it dirty (rebuilt on next use).
    posterior: Option<Ecdf>,
}

/// Per-model posterior over output lengths: the offline eCDF refined with
/// observed completions, plus conditional sampling for in-flight
/// requests. One instance lives per run (owned by the running-phase loop
/// in [`crate::runner::run_with_backend`]).
#[derive(Debug, Clone)]
pub struct OnlineSampler {
    offline: OutputSampler,
    obs_weight: f64,
    observed: BTreeMap<String, ModelObs>,
}

impl OnlineSampler {
    /// Wrap the run's offline sampler. `obs_weight` is how many
    /// offline-trace samples one observed completion is worth. It is
    /// normalized up front to the *effective* replication count — rounded
    /// to the nearest integer and clamped to `[0, MAX_OBS_WEIGHT]` — so
    /// the sampled posterior and [`OnlineSampler::posterior_mean`] always
    /// agree, and an oversized knob can't balloon the posterior rebuild.
    /// `0` (anything below 0.5) makes the posterior permanently equal to
    /// the prior.
    pub fn new(offline: OutputSampler, obs_weight: f64) -> Self {
        let obs_weight = obs_weight.clamp(0.0, MAX_OBS_WEIGHT).round();
        OnlineSampler { offline, obs_weight, observed: BTreeMap::new() }
    }

    /// The offline sampler this instance refines.
    pub fn offline(&self) -> &OutputSampler {
        &self.offline
    }

    /// The effective observation replication weight (integer-valued
    /// after construction-time normalization).
    pub fn obs_weight(&self) -> f64 {
        self.obs_weight
    }

    /// Fold one completed request's ground-truth output length into the
    /// model's posterior.
    pub fn record(&mut self, model: &str, observed_len: u32) {
        let obs = self.observed.entry(model.to_string()).or_default();
        obs.lens.push(observed_len);
        obs.sum += observed_len as f64;
        obs.posterior = None;
    }

    /// Completed observations recorded for `model`.
    pub fn observations(&self, model: &str) -> usize {
        self.observed.get(model).map(|o| o.lens.len()).unwrap_or(0)
    }

    /// Mean of the observed completions for `model` (`None` before the
    /// first completion).
    pub fn observed_mean(&self, model: &str) -> Option<f64> {
        let obs = self.observed.get(model)?;
        if obs.lens.is_empty() {
            return None;
        }
        Some(obs.sum / obs.lens.len() as f64)
    }

    /// Mean of the offline (prior) eCDF for `model`.
    pub fn offline_mean(&self, model: &str) -> Option<f64> {
        self.offline.ecdf(model).map(|e| e.mean())
    }

    /// Mean of the posterior: the weighted blend of the offline trace and
    /// the observations (pure arithmetic — no eCDF rebuild).
    pub fn posterior_mean(&self, model: &str) -> Option<f64> {
        let e = self.offline.ecdf(model)?;
        let n_off = e.len() as f64;
        match self.observed.get(model) {
            None => Some(e.mean()),
            Some(obs) => {
                let w = self.obs_weight * obs.lens.len() as f64;
                Some((n_off * e.mean() + self.obs_weight * obs.sum) / (n_off + w).max(1.0))
            }
        }
    }

    /// Relative mean-length drift of `model`: how far the observed mean
    /// has moved from `reference`, discounted by observation count so a
    /// handful of completions cannot trigger on noise
    /// (`|obs - ref| / ref · n/(n + MIN_DRIFT_OBS)`). `None` below
    /// [`MIN_DRIFT_OBS`] observations or for an unknown model.
    pub fn mean_drift(&self, model: &str, reference: f64) -> Option<f64> {
        let n = self.observations(model);
        if n < MIN_DRIFT_OBS || reference <= 0.0 {
            return None;
        }
        let obs = self.observed_mean(model)?;
        let confidence = n as f64 / (n + MIN_DRIFT_OBS) as f64;
        Some((obs - reference).abs() / reference * confidence)
    }

    /// The posterior eCDF for `model`, rebuilding it if observations
    /// arrived since the last call. Panics on a model the offline sampler
    /// doesn't know (same contract as [`OutputSampler::sample`]).
    pub fn posterior(&mut self, model: &str) -> &Ecdf {
        let offline = self
            .offline
            .ecdf(model)
            .unwrap_or_else(|| panic!("no offline eCDF for model {model}"));
        match self.observed.get_mut(model) {
            // No observations yet: the posterior IS the prior.
            None => offline,
            Some(obs) => {
                if obs.posterior.is_none() {
                    obs.posterior = Some(blend(offline, &obs.lens, self.obs_weight));
                }
                obs.posterior.as_ref().unwrap()
            }
        }
    }

    /// Sample one *total* output length for a request that has already
    /// generated `generated` tokens: conditional posterior draw from
    /// `X | X > generated` (plain posterior draw when `generated == 0`),
    /// clamped exactly like the offline path —
    /// `min(X, max_out, max_seq - input_len)` with saturating subtraction
    /// and a floor of 1. Callers wanting a strictly consistent estimate
    /// additionally floor at `generated + 1`, as the frozen path does.
    pub fn sample_total(
        &mut self,
        model: &str,
        input_len: u32,
        max_out: u32,
        max_seq: u32,
        generated: u32,
        rng: &mut Rng,
    ) -> u32 {
        let e = self.posterior(model);
        let x = if generated == 0 {
            e.sample(rng)
        } else {
            // An exhausted tail (progress past every posterior sample)
            // still consumes its draw, keeping the stream aligned.
            e.sample_given_gt(rng, generated).unwrap_or(generated.saturating_add(1))
        };
        super::sampler::clamp_output_len(x, input_len, max_out, max_seq)
    }
}

/// Build the posterior eCDF: offline samples plus each observation
/// replicated `weight` times (already integer-valued and capped by
/// construction), concatenated and re-sorted by [`Ecdf::from_samples`].
/// Rebuilds are O(n log n) but only happen once per (stage, dirtied
/// model), on at most `offline + capped-weight × completions` entries —
/// milliseconds at the workloads this repo runs.
fn blend(offline: &Ecdf, observed: &[u32], weight: f64) -> Ecdf {
    let rep = weight as usize;
    if rep == 0 || observed.is_empty() {
        return offline.clone();
    }
    let mut all: Vec<u32> = Vec::with_capacity(offline.len() + observed.len() * rep);
    all.extend_from_slice(offline.samples());
    for &o in observed {
        all.extend(std::iter::repeat_n(o, rep));
    }
    Ecdf::from_samples(all)
}

/// Drift/replan accounting of one run's length-feedback loop, reported
/// through [`crate::metrics::RunReport`] (`"online"` in the JSON).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OnlineStats {
    /// Full re-plans of the remaining application triggered by drift.
    pub replans: u64,
    /// Largest drift score observed across the run (`max` of the
    /// per-model mean-length drift and the stage-makespan drift).
    pub drift: f64,
    /// Wall-clock seconds spent inside drift-triggered re-plan searches
    /// (billed into the report's `extra_time` by the runner).
    pub replan_time: f64,
    /// The offline plan's estimated total inference time.
    pub pre_est_total: f64,
    /// The estimate after the last re-plan (equals `pre_est_total` when
    /// no re-plan fired). Absolute virtual seconds, same clock as
    /// `inference_time`.
    pub post_est_total: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn offline(samples: Vec<u32>) -> OutputSampler {
        let mut map = BTreeMap::new();
        map.insert("m".to_string(), samples);
        OutputSampler::from_samples_map(map)
    }

    #[test]
    fn zero_observations_posterior_is_the_offline_ecdf() {
        let mut os = OnlineSampler::new(offline(vec![10, 20, 30, 40]), 16.0);
        let xs: Vec<u32> = (0..=50).collect();
        let prior = os.offline().ecdf("m").unwrap().curve(&xs);
        assert_eq!(os.posterior("m").curve(&xs), prior);
        assert_eq!(os.posterior("m").len(), 4);
        assert_eq!(os.posterior_mean("m"), Some(25.0));
        assert_eq!(os.observations("m"), 0);
        assert_eq!(os.observed_mean("m"), None);
    }

    #[test]
    fn observations_pull_the_posterior_toward_the_evidence() {
        let mut os = OnlineSampler::new(offline(vec![10, 20, 30, 40]), 2.0);
        os.record("m", 100);
        os.record("m", 100);
        // 4 offline samples (mean 25) + 2 obs × weight 2 (mean 100):
        // posterior mean = (4·25 + 4·100) / 8 = 62.5.
        assert_eq!(os.posterior_mean("m"), Some(62.5));
        assert_eq!(os.posterior("m").len(), 8);
        assert_eq!(os.posterior("m").max(), 100);
        assert_eq!(os.observed_mean("m"), Some(100.0));
        // More evidence keeps shifting it.
        os.record("m", 100);
        assert!(os.posterior_mean("m").unwrap() > 62.5);
    }

    #[test]
    fn zero_weight_ignores_observations() {
        let mut os = OnlineSampler::new(offline(vec![10, 20]), 0.0);
        os.record("m", 500);
        assert_eq!(os.posterior("m").max(), 20);
        assert_eq!(os.posterior_mean("m"), Some(15.0));
    }

    #[test]
    fn weight_is_normalized_so_mean_and_samples_agree() {
        // Fractional weights round to the effective replication count up
        // front: the reported posterior mean and the sampled posterior
        // describe the same distribution.
        let mut os = OnlineSampler::new(offline(vec![10, 20]), 0.4);
        assert_eq!(os.obs_weight(), 0.0);
        os.record("m", 5000);
        assert_eq!(os.posterior("m").max(), 20, "rep 0: prior unchanged");
        assert_eq!(os.posterior_mean("m"), Some(15.0), "mean must match the sampler");
        // Oversized knobs are capped instead of ballooning the rebuild.
        let os = OnlineSampler::new(offline(vec![10, 20]), 1.0e9);
        assert_eq!(os.obs_weight(), MAX_OBS_WEIGHT);
        // Negative weights clamp to 0.
        assert_eq!(OnlineSampler::new(offline(vec![1]), -3.0).obs_weight(), 0.0);
    }

    #[test]
    fn conditional_sampling_respects_progress_and_clamps() {
        let mut os = OnlineSampler::new(offline(vec![10, 20, 30, 40]), 8.0);
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            // Conditioned on 20 generated tokens: draws land in {30, 40}.
            let x = os.sample_total("m", 5, 512, 4096, 20, &mut rng);
            assert!(x == 30 || x == 40, "x={x}");
            // Progress past the whole posterior: floor at generated + 1.
            assert_eq!(os.sample_total("m", 5, 512, 4096, 40, &mut rng), 41);
            // The offline clamp formula applies unchanged (over-long
            // prompt saturates the window to 1 — the regression case).
            assert_eq!(os.sample_total("m", 4096, 512, 4096, 20, &mut rng), 1);
        }
    }

    #[test]
    fn sampling_is_deterministic_under_a_seed() {
        let mk = || {
            let mut os = OnlineSampler::new(offline((1..=200).collect()), 16.0);
            os.record("m", 900);
            os.record("m", 950);
            let mut rng = Rng::new(42);
            (0u32..64)
                .map(|i| os.sample_total("m", 10, 4096, 8192, i % 7, &mut rng))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn mean_drift_needs_evidence_and_discounts_small_samples() {
        let mut os = OnlineSampler::new(offline(vec![100; 10]), 16.0);
        for _ in 0..MIN_DRIFT_OBS - 1 {
            os.record("m", 200);
        }
        assert_eq!(os.mean_drift("m", 100.0), None, "below the floor");
        os.record("m", 200);
        let d = os.mean_drift("m", 100.0).unwrap();
        // Raw drift 1.0 discounted by n/(n+MIN): 8/16 = 0.5.
        assert!((d - 0.5).abs() < 1e-12, "d={d}");
        for _ in 0..56 {
            os.record("m", 200);
        }
        let d = os.mean_drift("m", 100.0).unwrap();
        assert!(d > 0.85, "confidence should approach 1: {d}");
        assert_eq!(os.mean_drift("nope", 100.0), None);
    }
}
