//! Empirical cumulative distribution functions over output lengths (§2).

use crate::util::rng::Rng;

/// An eCDF over non-negative integer lengths, stored as a sorted sample.
#[derive(Debug, Clone)]
pub struct Ecdf {
    sorted: Vec<u32>,
}

impl Ecdf {
    /// Build from raw observations (at least one required).
    pub fn from_samples(mut samples: Vec<u32>) -> Self {
        assert!(!samples.is_empty(), "eCDF needs at least one sample");
        samples.sort_unstable();
        Ecdf { sorted: samples }
    }

    /// Number of observations.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// The underlying observations, ascending (posterior blending reads
    /// these back instead of round-tripping through quantiles).
    pub fn samples(&self) -> &[u32] {
        &self.sorted
    }

    /// Whether the eCDF holds no observations (never true by construction).
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// `P(X <= x)`.
    pub fn cdf(&self, x: u32) -> f64 {
        // partition_point = number of elements <= x.
        let cnt = self.sorted.partition_point(|&v| v <= x);
        cnt as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF: smallest observed value with `cdf >= q`.
    pub fn quantile(&self, q: f64) -> u32 {
        let q = q.clamp(0.0, 1.0);
        let idx = ((q * self.sorted.len() as f64).ceil() as usize).max(1) - 1;
        self.sorted[idx.min(self.sorted.len() - 1)]
    }

    /// Draw one value by inverse-transform sampling.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        self.quantile(rng.uniform())
    }

    /// Number of observations strictly greater than `d` — the support of
    /// the conditional distribution `X | X > d`.
    pub fn tail_count(&self, d: u32) -> usize {
        self.sorted.len() - self.sorted.partition_point(|&v| v <= d)
    }

    /// Conditional CDF `P(X <= x | X > d)`. Returns 1.0 when no
    /// observation exceeds `d` (the conditional distribution is empty and
    /// every probe is vacuously past it).
    pub fn cdf_given_gt(&self, x: u32, d: u32) -> f64 {
        let below_d = self.sorted.partition_point(|&v| v <= d);
        let tail = self.sorted.len() - below_d;
        if tail == 0 {
            return 1.0;
        }
        let below_x = self.sorted.partition_point(|&v| v <= x);
        below_x.saturating_sub(below_d) as f64 / tail as f64
    }

    /// Conditional inverse CDF: smallest observed value `> d` with
    /// `cdf_given_gt >= q`, or `None` when no observation exceeds `d`.
    ///
    /// Dominance invariant: for every `q` and `d`,
    /// `quantile_given_gt(q, d) >= quantile(q)` — conditioning on having
    /// already generated `d` tokens can only push the estimate up.
    pub fn quantile_given_gt(&self, q: f64, d: u32) -> Option<u32> {
        let start = self.sorted.partition_point(|&v| v <= d);
        let tail = self.sorted.len() - start;
        if tail == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let idx = ((q * tail as f64).ceil() as usize).max(1) - 1;
        Some(self.sorted[start + idx.min(tail - 1)])
    }

    /// Draw one value from `X | X > d` by inverse-transform sampling
    /// (`None` when no observation exceeds `d`). Consumes exactly one
    /// uniform draw either way, so deciding to condition never desyncs a
    /// deterministic stream.
    pub fn sample_given_gt(&self, rng: &mut Rng, d: u32) -> Option<u32> {
        let q = rng.uniform();
        self.quantile_given_gt(q, d)
    }

    /// Mean of the conditional distribution `X | X > d` (`None` when no
    /// observation exceeds `d`).
    pub fn mean_given_gt(&self, d: u32) -> Option<f64> {
        let start = self.sorted.partition_point(|&v| v <= d);
        let tail = &self.sorted[start..];
        if tail.is_empty() {
            return None;
        }
        Some(tail.iter().map(|&v| v as f64).sum::<f64>() / tail.len() as f64)
    }

    /// Mean of the observations.
    pub fn mean(&self) -> f64 {
        self.sorted.iter().map(|&v| v as f64).sum::<f64>() / self.sorted.len() as f64
    }

    /// Smallest observation.
    pub fn min(&self) -> u32 {
        self.sorted[0]
    }

    /// Largest observation.
    pub fn max(&self) -> u32 {
        *self.sorted.last().unwrap()
    }

    /// Evaluate the eCDF on a fixed grid — used to print Fig. 2 series.
    pub fn curve(&self, xs: &[u32]) -> Vec<(u32, f64)> {
        xs.iter().map(|&x| (x, self.cdf(x))).collect()
    }

    /// Kolmogorov–Smirnov distance to another eCDF (used to validate the
    /// "category-invariance" insight of Fig. 2).
    pub fn ks_distance(&self, other: &Ecdf) -> f64 {
        let mut xs: Vec<u32> = self.sorted.iter().chain(other.sorted.iter()).copied().collect();
        xs.sort_unstable();
        xs.dedup();
        xs.iter()
            .map(|&x| (self.cdf(x) - other.cdf(x)).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_and_quantile_roundtrip() {
        let e = Ecdf::from_samples(vec![10, 20, 30, 40]);
        assert_eq!(e.cdf(9), 0.0);
        assert_eq!(e.cdf(10), 0.25);
        assert_eq!(e.cdf(40), 1.0);
        assert_eq!(e.quantile(0.0), 10);
        assert_eq!(e.quantile(0.5), 20);
        assert_eq!(e.quantile(1.0), 40);
    }

    #[test]
    fn sampling_recovers_distribution() {
        let e = Ecdf::from_samples((1..=100).collect());
        let mut rng = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| e.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - e.mean()).abs() < 2.0, "mean={mean} want≈{}", e.mean());
    }

    #[test]
    fn ks_distance_self_is_zero() {
        let e = Ecdf::from_samples(vec![5, 6, 7, 8, 9]);
        assert_eq!(e.ks_distance(&e), 0.0);
        let f = Ecdf::from_samples(vec![50, 60, 70]);
        assert!(e.ks_distance(&f) > 0.9);
    }

    #[test]
    #[should_panic]
    fn empty_rejected() {
        Ecdf::from_samples(vec![]);
    }

    #[test]
    fn conditional_quantiles_condition_on_the_tail() {
        let e = Ecdf::from_samples(vec![10, 20, 30, 40]);
        assert_eq!(e.tail_count(0), 4);
        assert_eq!(e.tail_count(10), 3);
        assert_eq!(e.tail_count(40), 0);
        // X | X > 20 is uniform over {30, 40}.
        assert_eq!(e.quantile_given_gt(0.0, 20), Some(30));
        assert_eq!(e.quantile_given_gt(0.5, 20), Some(30));
        assert_eq!(e.quantile_given_gt(0.75, 20), Some(40));
        assert_eq!(e.quantile_given_gt(1.0, 20), Some(40));
        // No mass above the max: the conditional distribution is empty.
        assert_eq!(e.quantile_given_gt(0.5, 40), None);
        let mut rng = Rng::new(1);
        assert_eq!(e.sample_given_gt(&mut rng, 40), None);
    }

    #[test]
    fn conditional_cdf_matches_tail_fractions() {
        let e = Ecdf::from_samples(vec![10, 20, 30, 40]);
        assert_eq!(e.cdf_given_gt(30, 10), 2.0 / 3.0);
        assert_eq!(e.cdf_given_gt(9, 10), 0.0);
        assert_eq!(e.cdf_given_gt(40, 10), 1.0);
        // Empty tail: vacuously 1.
        assert_eq!(e.cdf_given_gt(0, 100), 1.0);
        // Conditioning on nothing reproduces the plain CDF.
        for x in [0, 10, 25, 40, 50] {
            assert_eq!(e.cdf_given_gt(x, 0), e.cdf(x));
        }
    }

    #[test]
    fn conditional_mean_dominates_unconditional() {
        let e = Ecdf::from_samples((1..=100).collect());
        let m0 = e.mean();
        for d in [0u32, 10, 50, 99] {
            let md = e.mean_given_gt(d).unwrap();
            assert!(md >= m0, "mean|X>{d} = {md} < {m0}");
            assert!(md > d as f64);
        }
        assert_eq!(e.mean_given_gt(100), None);
    }
}
