//! Empirical cumulative distribution functions over output lengths (§2).

use crate::util::rng::Rng;

/// An eCDF over non-negative integer lengths, stored as a sorted sample.
#[derive(Debug, Clone)]
pub struct Ecdf {
    sorted: Vec<u32>,
}

impl Ecdf {
    /// Build from raw observations (at least one required).
    pub fn from_samples(mut samples: Vec<u32>) -> Self {
        assert!(!samples.is_empty(), "eCDF needs at least one sample");
        samples.sort_unstable();
        Ecdf { sorted: samples }
    }

    /// Number of observations.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// Whether the eCDF holds no observations (never true by construction).
    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// `P(X <= x)`.
    pub fn cdf(&self, x: u32) -> f64 {
        // partition_point = number of elements <= x.
        let cnt = self.sorted.partition_point(|&v| v <= x);
        cnt as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF: smallest observed value with `cdf >= q`.
    pub fn quantile(&self, q: f64) -> u32 {
        let q = q.clamp(0.0, 1.0);
        let idx = ((q * self.sorted.len() as f64).ceil() as usize).max(1) - 1;
        self.sorted[idx.min(self.sorted.len() - 1)]
    }

    /// Draw one value by inverse-transform sampling.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        self.quantile(rng.uniform())
    }

    /// Mean of the observations.
    pub fn mean(&self) -> f64 {
        self.sorted.iter().map(|&v| v as f64).sum::<f64>() / self.sorted.len() as f64
    }

    /// Smallest observation.
    pub fn min(&self) -> u32 {
        self.sorted[0]
    }

    /// Largest observation.
    pub fn max(&self) -> u32 {
        *self.sorted.last().unwrap()
    }

    /// Evaluate the eCDF on a fixed grid — used to print Fig. 2 series.
    pub fn curve(&self, xs: &[u32]) -> Vec<(u32, f64)> {
        xs.iter().map(|&x| (x, self.cdf(x))).collect()
    }

    /// Kolmogorov–Smirnov distance to another eCDF (used to validate the
    /// "category-invariance" insight of Fig. 2).
    pub fn ks_distance(&self, other: &Ecdf) -> f64 {
        let mut xs: Vec<u32> = self.sorted.iter().chain(other.sorted.iter()).copied().collect();
        xs.sort_unstable();
        xs.dedup();
        xs.iter()
            .map(|&x| (self.cdf(x) - other.cdf(x)).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_and_quantile_roundtrip() {
        let e = Ecdf::from_samples(vec![10, 20, 30, 40]);
        assert_eq!(e.cdf(9), 0.0);
        assert_eq!(e.cdf(10), 0.25);
        assert_eq!(e.cdf(40), 1.0);
        assert_eq!(e.quantile(0.0), 10);
        assert_eq!(e.quantile(0.5), 20);
        assert_eq!(e.quantile(1.0), 40);
    }

    #[test]
    fn sampling_recovers_distribution() {
        let e = Ecdf::from_samples((1..=100).collect());
        let mut rng = Rng::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| e.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - e.mean()).abs() < 2.0, "mean={mean} want≈{}", e.mean());
    }

    #[test]
    fn ks_distance_self_is_zero() {
        let e = Ecdf::from_samples(vec![5, 6, 7, 8, 9]);
        assert_eq!(e.ks_distance(&e), 0.0);
        let f = Ecdf::from_samples(vec![50, 60, 70]);
        assert!(e.ks_distance(&f) > 0.9);
    }

    #[test]
    #[should_panic]
    fn empty_rejected() {
        Ecdf::from_samples(vec![]);
    }
}
