//! The sampling-then-simulation cost model (paper §2 and §4.1).
//!
//! Estimating "how long will model `M` take to finish request set `R`
//! under execution plan `P`" decomposes into:
//!
//! 1. **Output-length sampling** ([`sampler`]) — request output lengths are
//!    unknown before running; sample them from a per-model empirical CDF
//!    ([`ecdf`]) built offline from a large trace (§2, Fig. 2).
//! 2. **Request-scheduling simulation** ([`crate::engine`]) — replay the
//!    engine's FCFS continuous-batching policy over the sampled lengths to
//!    recover the batch composition of every iteration (§2, Fig. 3).
//! 3. **Per-iteration pricing** ([`linear`], Eq. 5) — three linear pieces
//!    (`comp` vs FLOPs, `prep` vs B·s, `samp` vs S) with per-batch-size
//!    coefficients, fit against profiled iterations (§2, Fig. 4). FLOPs
//!    come from Eqs. 1–2 ([`flops`]).
//! 4. **Model loading** — a profiled cost table
//!    ([`crate::models::ModelSpec::load_time`]).
//! 5. **Online refinement** ([`online`]) — during the running phase the
//!    per-model eCDFs are refined with observed completions and in-flight
//!    requests are re-estimated conditionally (`X | X > d`), feeding the
//!    drift-triggered replanning loop.
//!
//! The *ground truth* the paper measures on real A100s is substituted by
//! [`hardware::HardwareModel`] — an analytic roofline + overhead model of
//! the same testbed (see DESIGN.md). The linear model is fit against
//! profiles of the hardware model, so the planner's estimate and the
//! runner's "reality" disagree exactly the way the paper's do.

pub mod ecdf;
pub mod flops;
pub mod hardware;
pub mod linear;
pub mod online;
pub mod sampler;

pub use ecdf::Ecdf;
pub use hardware::{HardwareModel, SwapCost};
pub use linear::LinearIterModel;
pub use online::{OnlineSampler, OnlineStats};
pub use sampler::OutputSampler;

use crate::cluster::ClusterSpec;
use crate::models::ModelSpec;

/// Per-iteration latency oracle consumed by the engine simulator.
///
/// Two implementations: [`HardwareModel`] (ground truth, used by the
/// running phase) and [`LinearIterModel`] (the paper's fitted Eq. 5 model,
/// used by the planner).
pub trait IterLatency {
    /// Latency of a prefill iteration processing `prompt_lens` new prompts.
    fn prefill(&self, spec: &ModelSpec, tp: u32, prompt_lens: &[u32]) -> f64;

    /// Latency of a decode iteration over `batch` running requests with
    /// `total_context` tokens of KV across them and `max_context` the
    /// longest (padded) context.
    fn decode(
        &self,
        spec: &ModelSpec,
        tp: u32,
        batch: usize,
        total_context: u64,
        max_context: u32,
    ) -> f64;
}

/// The full planner-side cost model: sampler + linear pricing, bundled with
/// the cluster description it was calibrated for.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Per-model output-length eCDF sampler.
    pub sampler: OutputSampler,
    /// The fitted Eq. 5 per-iteration latency model.
    pub iter_model: LinearIterModel,
    /// The cluster the model was calibrated for.
    pub cluster: ClusterSpec,
}

impl CostModel {
    /// Build the standard cost model: eCDFs from a `No Robots`-style trace
    /// and linear coefficients fit against the hardware profile (§2).
    pub fn calibrated(cluster: &ClusterSpec, seed: u64) -> Self {
        let sampler = OutputSampler::from_norobots_trace(seed);
        let hw = HardwareModel::new(cluster.clone());
        let iter_model = LinearIterModel::fit_from_profile(&hw);
        CostModel { sampler, iter_model, cluster: cluster.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_model_builds() {
        let cluster = ClusterSpec::a100_node(8);
        let cm = CostModel::calibrated(&cluster, 1);
        let reg = crate::models::Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap();
        // A decode iteration must cost a sane, positive, sub-second time.
        let t = cm.iter_model.decode(spec, 1, 64, 64 * 200, 220);
        assert!(t > 1e-4 && t < 1.0, "t={t}");
    }
}
