//! Iteration FLOPs accounting — the paper's Eqs. (1) and (2).
//!
//! * Eq. (1)  `FLOPs_prefill = L (c·B·s + 2·B·h·s²)`   (weights + attention)
//! * Eq. (2)  `FLOPs_decode  = L (c·B + 2·h·S)`
//!
//! with `L` layers, `B` running requests, `s` request length, `h` hidden
//! size, `S` total context tokens and `c` the summed matmul-weight size.
//! We use the 2-FLOPs-per-MAC convention explicitly (the paper folds it
//! into `c`): weight GEMMs cost `2·c` per token, attention costs `4·h`
//! per (token, context-token) pair (QKᵀ and PV).

use crate::models::ModelSpec;

/// FLOPs of a prefill iteration over the given prompt lengths (Eq. 1,
/// summed per request instead of padding to `B·s_max`).
pub fn prefill_flops(spec: &ModelSpec, prompt_lens: &[u32]) -> f64 {
    let l = spec.n_layers as f64;
    let h = spec.hidden as f64;
    let c = spec.c_matmul();
    let mut total = 0.0;
    for &s in prompt_lens {
        let s = s as f64;
        total += 2.0 * c * s + 4.0 * h * s * s;
    }
    l * total
}

/// FLOPs of a decode iteration (Eq. 2): one new token per running request,
/// attention over `total_context` cached tokens.
pub fn decode_flops(spec: &ModelSpec, batch: usize, total_context: u64) -> f64 {
    let l = spec.n_layers as f64;
    let h = spec.hidden as f64;
    let c = spec.c_matmul();
    l * (2.0 * c * batch as f64 + 4.0 * h * total_context as f64)
}

/// Total FLOPs for a request processed start-to-finish (prefill + all
/// decode steps). Used for stage-throughput accounting (`T_E` in §3).
pub fn request_total_flops(spec: &ModelSpec, input_len: u32, output_len: u32) -> f64 {
    let mut total = prefill_flops(spec, &[input_len]);
    let l = spec.n_layers as f64;
    let h = spec.hidden as f64;
    let c = spec.c_matmul();
    for i in 0..output_len as u64 {
        let ctx = input_len as u64 + i + 1;
        total += l * (2.0 * c + 4.0 * h * ctx as f64);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn spec() -> ModelSpec {
        Registry::paper().get("mistral-7b-instruct").unwrap().clone()
    }

    #[test]
    fn prefill_scales_superlinearly_in_length() {
        let s = spec();
        let f1 = prefill_flops(&s, &[128]);
        let f2 = prefill_flops(&s, &[256]);
        assert!(f2 > 2.0 * f1); // quadratic attention term
        assert!(f2 < 4.5 * f1);
    }

    #[test]
    fn prefill_additive_over_requests() {
        let s = spec();
        let lhs = prefill_flops(&s, &[100, 200]);
        let rhs = prefill_flops(&s, &[100]) + prefill_flops(&s, &[200]);
        assert!((lhs - rhs).abs() / rhs < 1e-12);
    }

    #[test]
    fn decode_linear_in_batch_at_fixed_context_per_req() {
        let s = spec();
        let f1 = decode_flops(&s, 10, 10 * 300);
        let f2 = decode_flops(&s, 20, 20 * 300);
        assert!((f2 / f1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_dominated_by_weights_at_small_context() {
        // For a 7B model, 2·c·B >> 4·h·S when S is small: weight reads rule.
        let s = spec();
        let with_ctx = decode_flops(&s, 1, 10);
        let weights_only = s.n_layers as f64 * 2.0 * s.c_matmul();
        assert!((with_ctx - weights_only) / with_ctx < 0.01);
    }

    #[test]
    fn request_total_is_sum_of_parts() {
        let s = spec();
        let total = request_total_flops(&s, 50, 3);
        let prefill = prefill_flops(&s, &[50]);
        assert!(total > prefill);
        // 3 decode steps, each ≳ the weight GEMM cost.
        let min_decode = 3.0 * s.n_layers as f64 * 2.0 * s.c_matmul();
        assert!(total - prefill >= min_decode * 0.99);
    }
}
