//! Byte-level tokenizer for the TinyGPT vocabulary (512 entries).
//!
//! Layout: id 0 = PAD/BOS, ids 1–256 = raw bytes 0–255, ids 257–511 =
//! the most common English bigrams (a fixed table — no training data is
//! shipped, and greedy longest-match over a static merge table is enough
//! to exercise a realistic text→ids→text path in the serving examples).

/// Fixed bigram merge table filling ids 257.. (order matters: greedy
/// longest-match prefers these over single bytes).
const BIGRAMS: [&str; 64] = [
    "th", "he", "in", "er", "an", "re", "on", "at", "en", "nd", "ti", "es", "or", "te", "of",
    "ed", "is", "it", "al", "ar", "st", "to", "nt", "ng", "se", "ha", "as", "ou", "io", "le",
    "ve", "co", "me", "de", "hi", "ri", "ro", "ic", "ne", "ea", "ra", "ce", "li", "ch", "ll",
    "be", "ma", "si", "om", "ur", " a", " t", " s", " w", " o", "e ", "s ", "d ", "t ", "n ",
    "r ", "y ", ", ", ". ",
];

/// Padding / beginning-of-sequence token id.
pub const PAD: i32 = 0;
const BYTE_BASE: i32 = 1;
const BIGRAM_BASE: i32 = 257;

/// Vocabulary size this tokenizer targets (matches TinyGPT's config).
pub const VOCAB: usize = 512;

/// Encode text into token ids (greedy bigram-then-byte).
pub fn encode(text: &str) -> Vec<i32> {
    let bytes = text.as_bytes();
    let mut out = vec![];
    let mut i = 0;
    'outer: while i < bytes.len() {
        if i + 1 < bytes.len() {
            let pair = &bytes[i..i + 2];
            for (j, bg) in BIGRAMS.iter().enumerate() {
                if bg.as_bytes() == pair {
                    out.push(BIGRAM_BASE + j as i32);
                    i += 2;
                    continue 'outer;
                }
            }
        }
        out.push(BYTE_BASE + bytes[i] as i32);
        i += 1;
    }
    out
}

/// Decode token ids back into text (lossy for ids outside the map).
pub fn decode(ids: &[i32]) -> String {
    let mut bytes = vec![];
    for &id in ids {
        if id >= BYTE_BASE && id < BYTE_BASE + 256 {
            bytes.push((id - BYTE_BASE) as u8);
        } else if id >= BIGRAM_BASE && ((id - BIGRAM_BASE) as usize) < BIGRAMS.len() {
            bytes.extend_from_slice(BIGRAMS[(id - BIGRAM_BASE) as usize].as_bytes());
        }
        // PAD and unknown ids decode to nothing.
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        for text in ["hello world", "the rain in spain", "a", "", "schedule LLMs, fast."] {
            assert_eq!(decode(&encode(text)), text);
        }
    }

    #[test]
    fn roundtrip_utf8() {
        let text = "héllo ✓";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn ids_stay_in_vocab() {
        let ids = encode("The quick brown fox jumps over the lazy dog! 0123456789");
        assert!(ids.iter().all(|&id| (0..VOCAB as i32).contains(&id)));
    }

    #[test]
    fn bigrams_compress() {
        let text = "the theme there";
        let ids = encode(text);
        assert!(ids.len() < text.len(), "{} !< {}", ids.len(), text.len());
    }

    #[test]
    fn pad_decodes_to_nothing() {
        assert_eq!(decode(&[PAD, PAD]), "");
    }
}
