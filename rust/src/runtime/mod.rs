//! PJRT runtime: load the AOT-compiled TinyGPT artifacts and execute them
//! on the CPU PJRT client — the "real" inference path proving the
//! three-layer stack (Pallas kernel → JAX model → HLO text → rust)
//! composes. Python never runs here.
//!
//! Artifacts (built by `make artifacts`):
//! * `prefill.hlo.txt`, `decode.hlo.txt` — HLO text (NOT serialized
//!   protos; xla_extension 0.5.1 rejects jax ≥0.5's 64-bit ids);
//! * `weights.bin` + `model_meta.json` — parameters as runtime inputs so
//!   the HLO stays small and rust owns every buffer.

pub mod tokenizer;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// `model_meta.json` schema (see `python/compile/aot.py`).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Model dimensions the executables were compiled for.
    pub config: ModelDims,
    /// Parameter directory into `weights.bin`.
    pub params: Vec<ParamEntry>,
    /// Seed the weights were initialized with.
    pub seed: u64,
}

impl ModelMeta {
    /// Parse the artifact contract produced by `python/compile/aot.py`.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("model_meta.json: {e}"))?;
        let cfg = v.get("config").ok_or_else(|| anyhow!("config missing"))?;
        let dim = |k: &str| -> Result<usize> {
            cfg.get(k).and_then(|x| x.as_usize()).ok_or_else(|| anyhow!("config.{k} missing"))
        };
        let config = ModelDims {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_heads: dim("n_heads")?,
            n_layers: dim("n_layers")?,
            max_seq: dim("max_seq")?,
            batch: dim("batch")?,
            d_ff: dim("d_ff")?,
            d_head: dim("d_head")?,
        };
        let params = v
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow!("params missing"))?
            .iter()
            .map(|p| -> Result<ParamEntry> {
                Ok(ParamEntry {
                    name: p
                        .get("name")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("param name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|x| x.as_arr())
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").and_then(|x| x.as_usize()).unwrap_or(0),
                    bytes: p.get("bytes").and_then(|x| x.as_usize()).unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelMeta { config, params, seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(0) })
    }
}

/// TinyGPT dimensions baked into the compiled HLO.
#[derive(Debug, Clone)]
#[allow(missing_docs)] // standard transformer dimension names
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub d_ff: usize,
    pub d_head: usize,
}

/// One parameter tensor's location inside `weights.bin`.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    /// Parameter name (canonical order matters).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Byte offset into the blob.
    pub offset: usize,
    /// Byte length in the blob.
    pub bytes: usize,
}

/// A loaded TinyGPT: compiled prefill/decode executables + weights.
///
/// Weights live on the device as `PjRtBuffer`s (uploaded once at load);
/// the KV caches returned by prefill/decode stay device-resident too, so
/// the per-token hot path moves only the tiny token/pos/logits arrays
/// across the host boundary (§Perf runtime optimization).
pub struct TinyGpt {
    /// The artifact contract the executables were loaded under.
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    prefill: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
}

/// Output of one prefill/decode call.
///
/// The model state (logits prefix + both KV caches) is one flat f32 device
/// buffer; only the `[batch, vocab]` logits prefix is copied to the host.
pub struct StepOutput {
    /// `[batch, vocab]` next-token logits, row-major.
    pub logits: Vec<f32>,
    /// Device-resident packed state `[B·V logits | k | v]` — feed it back
    /// into the next `decode` call untouched.
    pub state: xla::PjRtBuffer,
}

impl TinyGpt {
    /// Load artifacts from `dir` and compile both entry points.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta = ModelMeta::parse(
            &std::fs::read_to_string(dir.join("model_meta.json"))
                .context("read model_meta.json (run `make artifacts`)")?,
        )?;
        let client = xla::PjRtClient::cpu()?;

        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(dir.join(name).to_str().unwrap())?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill = compile("prefill.hlo.txt")?;
        let decode = compile("decode.hlo.txt")?;

        // Weights: uploaded to the device once, in canonical order.
        let blob = std::fs::read(dir.join("weights.bin")).context("read weights.bin")?;
        let mut weights = vec![];
        for p in &meta.params {
            let bytes = &blob[p.offset..p.offset + p.bytes];
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let buf = client.buffer_from_host_buffer(&floats, &p.shape, None)?;
            weights.push(buf);
        }
        Ok(TinyGpt { meta, client, prefill, decode, weights })
    }

    /// Compiled batch size.
    pub fn batch(&self) -> usize {
        self.meta.config.batch
    }

    /// Compiled maximum sequence length.
    pub fn max_seq(&self) -> usize {
        self.meta.config.max_seq
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.meta.config.vocab
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload an i32 host array.
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        extra: Vec<xla::PjRtBuffer>,
    ) -> Result<StepOutput> {
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        for e in &extra {
            args.push(e);
        }
        let mut outputs = exe.execute_b(&args)?;
        let mut outs = outputs.swap_remove(0);
        anyhow::ensure!(outs.len() == 1, "expected packed state, got {} outputs", outs.len());
        let state = outs.pop().unwrap();
        // Read the logits prefix. (CopyRawToHost is unimplemented in the
        // CPU plugin, so we sync the state literal — on CPU this is a
        // memcpy — and truncate; the device buffer itself is NOT consumed
        // and feeds the next step without re-upload.)
        let mut logits = state.to_literal_sync()?.to_vec::<f32>()?;
        logits.truncate(self.batch() * self.vocab());
        Ok(StepOutput { logits, state })
    }

    /// Run the prompt phase. `tokens` is `[batch * max_seq]` (padded),
    /// `lengths` the valid prompt length per row.
    pub fn prefill(&self, tokens: &[i32], lengths: &[i32]) -> Result<StepOutput> {
        let b = self.batch() as i64;
        let s = self.max_seq() as i64;
        anyhow::ensure!(tokens.len() as i64 == b * s, "tokens must be [B,S]");
        anyhow::ensure!(lengths.len() as i64 == b, "lengths must be [B]");
        let toks = self.upload_i32(tokens, &[b as usize, s as usize])?;
        let lens = self.upload_i32(lengths, &[lengths.len()])?;
        self.run(&self.prefill, vec![toks, lens])
    }

    /// One decode step: `token[b]` at cache position `pos[b]`. The packed
    /// state stays on-device throughout a generation.
    pub fn decode(
        &self,
        token: &[i32],
        state: xla::PjRtBuffer,
        pos: &[i32],
    ) -> Result<StepOutput> {
        let b = self.batch();
        anyhow::ensure!(token.len() == b && pos.len() == b);
        let tok = self.upload_i32(token, &[b])?;
        let p = self.upload_i32(pos, &[b])?;
        self.run(&self.decode, vec![tok, state, p])
    }

    /// Greedy next tokens from `[batch, vocab]` logits.
    pub fn argmax(&self, logits: &[f32]) -> Vec<i32> {
        let v = self.vocab();
        logits
            .chunks_exact(v)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Default artifacts directory (repo-root relative).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        default_artifacts_dir().join("model_meta.json").exists()
    }

    #[test]
    fn load_and_prefill_decode_roundtrip() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = TinyGpt::load(&default_artifacts_dir()).unwrap();
        let b = m.batch();
        let s = m.max_seq();
        // Simple prompts: tokens 1..=8, length 8 each.
        let mut tokens = vec![0i32; b * s];
        for row in 0..b {
            for i in 0..8 {
                tokens[row * s + i] = (i + 1) as i32;
            }
        }
        let lengths = vec![8i32; b];
        let out = m.prefill(&tokens, &lengths).unwrap();
        assert_eq!(out.logits.len(), b * m.vocab());
        assert!(out.logits.iter().all(|x| x.is_finite()));

        let next = m.argmax(&out.logits);
        let pos = vec![8i32; b];
        let out2 = m.decode(&next, out.state, &pos).unwrap();
        assert_eq!(out2.logits.len(), b * m.vocab());
        assert!(out2.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_is_deterministic() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = TinyGpt::load(&default_artifacts_dir()).unwrap();
        let b = m.batch();
        let s = m.max_seq();
        let mut tokens = vec![0i32; b * s];
        for row in 0..b {
            for i in 0..5 {
                tokens[row * s + i] = ((row + i) % 32 + 1) as i32;
            }
        }
        let lengths = vec![5i32; b];
        let a = m.prefill(&tokens, &lengths).unwrap();
        let b_ = m.prefill(&tokens, &lengths).unwrap();
        assert_eq!(a.logits, b_.logits);
    }
}
