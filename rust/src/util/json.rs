//! Minimal JSON parser/serializer (offline build: no serde available).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! f64 (plus integer accessors). Used for experiment configs, run-report
//! output and the `model_meta.json` artifact contract.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64; integers round-trip below 2⁵³).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object (key order normalized by the BTreeMap).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (`None` for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric value truncated to u64, if this is a number.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The string slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    write!(out, "{}", *x as i64).unwrap();
                } else {
                    write!(out, "{x}").unwrap();
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32).unwrap(),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u digits")?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        // Serialize + reparse is stable.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn strings_escape_correctly() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{oops}").is_err());
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_real_meta_shape() {
        let src = r#"{"config":{"vocab":512,"batch":8},
                      "params":[{"name":"embed","shape":[512,256],"offset":0,"bytes":524288}],
                      "seed":0}"#;
        let v = Json::parse(src).unwrap();
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("embed"));
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap()[1].as_usize(), Some(256));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
