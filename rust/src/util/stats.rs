//! Summary statistics used by the metrics/report modules.

/// Summary of a sample: count, mean, min/max, percentiles.
#[derive(Debug, Clone, PartialEq)]
#[allow(missing_docs)] // field names are the statistics themselves
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub std: f64,
}

/// Compute a [`Summary`] of `xs`. Returns `None` for empty input.
pub fn summarize(xs: &[f64]) -> Option<Summary> {
    if xs.is_empty() {
        return None;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Some(Summary {
        n,
        mean,
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
        std: var.sqrt(),
    })
}

/// Percentile (nearest-rank with linear interpolation) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Relative error `|est - truth| / truth` (the paper's "error ratio").
pub fn error_ratio(est: f64, truth: f64) -> f64 {
    if truth == 0.0 {
        if est == 0.0 { 0.0 } else { f64::INFINITY }
    } else {
        (est - truth).abs() / truth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_is_none() {
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn error_ratio_cases() {
        assert!((error_ratio(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(error_ratio(0.0, 0.0), 0.0);
        assert!(error_ratio(1.0, 0.0).is_infinite());
    }
}
