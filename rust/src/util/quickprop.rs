//! Tiny property-testing harness (offline build: proptest unavailable).
//!
//! `quickprop::run(cases, seed, |rng| { ... })` executes the closure over
//! many independently-seeded RNGs; on failure it retries with progressively
//! "smaller" derived seeds (shrinking-lite) and reports the minimal seed so
//! the case is reproducible with a unit test.

use crate::util::rng::Rng;

/// Run `prop` over `cases` random cases. `prop` returns `Err(reason)` on a
/// property violation; panics are treated as failures too.
pub fn run<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(reason) = prop(&mut rng) {
            panic!("property failed (case {case}, seed {case_seed:#x}): {reason}");
        }
    }
}

/// Assert-style helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($msg:tt)*) => {
        if !$cond {
            return Err(format!($($msg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run(50, 1, |rng| {
            count += 1;
            let x = rng.range_u64(0, 100);
            prop_assert!(x < 100, "range violated: {x}");
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run(100, 2, |rng| {
            let x = rng.range_u64(0, 10);
            prop_assert!(x != 7, "hit the bad value");
            Ok(())
        });
    }
}
