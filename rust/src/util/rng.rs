//! Deterministic PRNG (splitmix64 + xoshiro256**) — no external crates, so
//! every experiment in the repo is exactly reproducible from its seed.

/// A small, fast, seedable PRNG. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-model / per-phase seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[1].wrapping_mul(5)).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform integer in `[lo, hi)` as usize. Panics if `lo >= hi`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index according to non-negative weights.
    pub fn weighted_idx(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_idx_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_idx(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
