//! Small shared utilities: deterministic RNG, statistics, least squares,
//! plus offline-build substrates for JSON, benchmarking and property
//! testing (the usual crates are unavailable without a network).

pub mod bench;
pub mod json;
pub mod linfit;
pub mod quickprop;
pub mod rng;
pub mod stats;
