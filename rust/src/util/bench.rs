//! Mini benchmark harness (offline build: criterion unavailable).
//!
//! Criterion-flavoured API subset: named groups, warmup + timed samples,
//! mean/median/stddev reporting, and baseline save/compare under
//! `target/bench-results/` so before/after deltas survive across runs
//! (used by the §Perf pass in EXPERIMENTS.md).

use std::time::Instant;

/// One benchmark's statistics (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// `group/name` label.
    pub name: String,
    /// Number of timed samples.
    pub samples: usize,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Median seconds per iteration (the baseline-comparison statistic).
    pub median: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Fastest sample.
    pub min: f64,
}

/// A group of related benchmarks, criterion-style.
pub struct BenchGroup {
    group: String,
    warmup_iters: usize,
    sample_count: usize,
    results: Vec<BenchStats>,
}

impl BenchGroup {
    /// A named group with default warmup and sample counts.
    pub fn new(group: &str) -> Self {
        BenchGroup {
            group: group.to_string(),
            warmup_iters: 2,
            sample_count: 12,
            results: vec![],
        }
    }

    /// Lower sample counts for expensive benches (criterion's
    /// `sample_size`).
    pub fn sample_size(&mut self, n: usize) -> &mut Self {
        self.sample_count = n.max(3);
        self
    }

    /// Time `f`, which performs one complete iteration per call.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let stats = BenchStats {
            name: format!("{}/{}", self.group, name),
            samples: n,
            mean,
            median: samples[n / 2],
            stddev: var.sqrt(),
            min: samples[0],
        };
        let delta = compare_to_baseline(&stats);
        println!(
            "{:<44} mean {:>12} median {:>12} ±{:>10} (n={}){}",
            stats.name,
            fmt_time(stats.mean),
            fmt_time(stats.median),
            fmt_time(stats.stddev),
            n,
            delta
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Persist results as the new baseline for later comparisons.
    pub fn finish(&self) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        for s in &self.results {
            let path = dir.join(format!("{}.txt", sanitize(&s.name)));
            let _ = std::fs::write(path, format!("{}\n", s.median));
        }
    }
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_alphanumeric() { c } else { '_' }).collect()
}

fn compare_to_baseline(s: &BenchStats) -> String {
    let path =
        std::path::Path::new("target/bench-results").join(format!("{}.txt", sanitize(&s.name)));
    match std::fs::read_to_string(&path).ok().and_then(|t| t.trim().parse::<f64>().ok()) {
        Some(old) if old > 0.0 => {
            let pct = (s.median - old) / old * 100.0;
            format!("  [{:+.1}% vs baseline]", pct)
        }
        _ => String::new(),
    }
}

/// Human-readable seconds.
pub fn fmt_time(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3} s")
    } else if t >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else if t >= 1e-6 {
        format!("{:.3} µs", t * 1e6)
    } else {
        format!("{:.1} ns", t * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let mut g = BenchGroup::new("test");
        g.sample_size(5);
        let s = g.bench("sleepless", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean > 0.0);
        assert!(s.min <= s.median && s.median <= s.mean + s.stddev * 3.0);
        assert_eq!(s.samples, 5);
    }

    #[test]
    fn formats_time_scales() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
