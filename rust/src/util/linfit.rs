//! Ordinary least squares for the paper's Eq. (5) linear latency pieces
//! (`a[B] * x + b[B]`) fit against profiled iteration latencies.

/// Result of a 1-D least squares fit `y ~ a*x + b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinFit {
    /// Slope.
    pub a: f64,
    /// Intercept.
    pub b: f64,
    /// Coefficient of determination on the fitting data.
    pub r2: f64,
}

impl LinFit {
    /// Evaluate the fitted line at `x`.
    pub fn predict(&self, x: f64) -> f64 {
        self.a * x + self.b
    }
}

/// Fit `y ~ a*x + b` by OLS. Returns `None` for fewer than 2 points or a
/// degenerate (constant-x) design.
pub fn fit(xs: &[f64], ys: &[f64]) -> Option<LinFit> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx < 1e-30 {
        return None;
    }
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let a = sxy / sxx;
    let b = my - a * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a * x + b);
            e * e
        })
        .sum();
    let r2 = if ss_tot < 1e-30 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Some(LinFit { a, b, r2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let f = fit(&xs, &ys).unwrap();
        assert!((f.a - 2.0).abs() < 1e-12);
        assert!((f.b - 1.0).abs() < 1e-12);
        assert!(f.r2 > 0.999999);
    }

    #[test]
    fn noisy_line_reasonable() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x + 2.0 + ((x * 7.3).sin() * 0.1)).collect();
        let f = fit(&xs, &ys).unwrap();
        assert!((f.a - 0.5).abs() < 0.01);
        assert!(f.r2 > 0.99);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(fit(&[1.0], &[2.0]).is_none());
        assert!(fit(&[2.0, 2.0], &[1.0, 3.0]).is_none());
        assert!(fit(&[1.0, 2.0], &[1.0]).is_none());
    }
}
