//! Open-loop traffic: arrival processes, bounded admission, and the
//! materialised [`TrafficScenario`] the runner executes.
//!
//! This layer generalises the batch workload layer (`runner::workload`)
//! from "N apps, each a fixed job arriving once" to *streams*: each app
//! has an arrival process ([`arrivals`]) generating request-level
//! arrivals over a warmup+measurement window, a bounded admission queue
//! with a reject/defer overflow policy ([`queue`]), and a weighted fair
//! share that decides which app's jobs enter the scheduling core at each
//! stage boundary. The run is measured with serving metrics — per-app
//! TTFT, TPOT, p50/p99 latency and SLO attainment
//! ([`crate::metrics::latency`]) — instead of makespan.
//!
//! Data flow: [`crate::spec::traffic::TrafficSpec::build`] →
//! [`TrafficScenario`] →
//! [`crate::runner::traffic::run_traffic_with_backend`].

pub mod arrivals;
pub mod queue;

pub use queue::{AdmissionQueue, QueueCounters, QueuePolicy, QueuedJob};

use crate::runner::{AppRequest, Scenario};

/// Cap on the per-app sampled arrival window handed to the planner: the
/// steady-state placement is priced by simulating at most this many jobs
/// per app (§4's sampling idea applied to a rate — enough to expose the
/// per-model load mix without simulating the whole horizon).
pub const PLANNING_WINDOW_JOBS: usize = 512;

/// One application stream of a materialised traffic mix.
#[derive(Debug, Clone)]
pub struct TrafficApp {
    /// Index of this app in the mix (== graph provenance `app` stamp).
    pub app_id: usize,
    /// The app's own scenario name ("ensembling-1000", …).
    pub name: String,
    /// Weighted-fair-share admission weight (a real scheduling priority).
    pub weight: f64,
    /// Optional per-request latency SLO in seconds (arrival →
    /// completion).
    pub slo: Option<f64>,
    /// Global node ids of this app in the composed graph.
    pub nodes: Vec<usize>,
    /// Per-node request-template pools (parallel to `nodes`): arrival
    /// `seq` replays template `seq % pool.len()` on each node. Templates
    /// are independent requests — chain/dependency structure is not
    /// replayed per arrival.
    pub pools: Vec<Vec<AppRequest>>,
    /// Pre-generated arrival timestamps, sorted ascending, within
    /// `[0, warmup + duration)`.
    pub arrivals: Vec<f64>,
}

/// Run-window and admission-queue configuration of a traffic run.
#[derive(Debug, Clone, Copy)]
pub struct TrafficCfg {
    /// Measurement-window length in seconds.
    pub duration: f64,
    /// Warmup seconds before the window opens.
    pub warmup: f64,
    /// Per-app bounded queue capacity.
    pub queue_capacity: usize,
    /// Overflow policy.
    pub queue_policy: QueuePolicy,
    /// Maximum jobs admitted per stage boundary across all apps
    /// (resolved: always ≥ 1).
    pub admit_quantum: usize,
}

/// A materialised open-loop traffic mix: the composed graph (with empty
/// initial workloads — requests enter only through admission), per-app
/// streams, and the run-window configuration.
#[derive(Debug, Clone)]
pub struct TrafficScenario {
    /// Mix name (becomes `RunReport::scenario`).
    pub name: String,
    /// The composed joint scenario. `workloads` are all empty: the
    /// open-loop run starts idle and fills through the admission queue.
    pub scenario: Scenario,
    /// Per-app streams, indexed by `app_id`.
    pub apps: Vec<TrafficApp>,
    /// Window and queue configuration.
    pub cfg: TrafficCfg,
}

impl TrafficScenario {
    /// Arrival horizon: `warmup + duration`.
    pub fn horizon(&self) -> f64 {
        self.cfg.warmup + self.cfg.duration
    }

    /// Total arrivals across all apps over the horizon.
    pub fn total_jobs(&self) -> u64 {
        self.apps.iter().map(|a| a.arrivals.len() as u64).sum()
    }

    /// The sampled arrival window the planner prices: per app, the first
    /// `min(arrivals, `[`PLANNING_WINDOW_JOBS`]`)` jobs (at least one, so
    /// a plan exists even for a silent stream), each replaying its
    /// per-node templates. This is "planning against a rate": the
    /// steady-state placement is chosen by simulating a finite sample of
    /// the stream the run will actually see.
    pub fn planning_workloads(&self) -> Vec<Vec<AppRequest>> {
        let mut out: Vec<Vec<AppRequest>> = vec![vec![]; self.scenario.graph.n_nodes()];
        for app in &self.apps {
            let n = app.arrivals.len().clamp(1, PLANNING_WINDOW_JOBS) as u64;
            for (&node, pool) in app.nodes.iter().zip(&app.pools) {
                out[node] = (0..n)
                    .map(|seq| {
                        let t = pool[(seq % pool.len() as u64) as usize];
                        AppRequest::simple(seq, t.input_len, t.true_output_len)
                    })
                    .collect();
            }
        }
        out
    }
}
