//! Arrival-stream generation: materialise an
//! [`ArrivalSpec`](crate::spec::traffic::ArrivalSpec) into a sorted vector
//! of arrival timestamps over a finite horizon.
//!
//! All generators are deterministic: the same `(spec, seed, horizon)`
//! triple always produces the bit-identical stream, which is what makes
//! open-loop experiments reproducible and lets the planner price a
//! *sampled* arrival window that exactly matches what the run will see.

use anyhow::{anyhow, Result};

use crate::spec::traffic::ArrivalSpec;
use crate::util::rng::Rng;

/// One exponential inter-arrival gap with mean `1/rate` (inverse-CDF
/// sampling; `1 - uniform()` keeps the argument strictly positive).
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.uniform()).ln() / rate
}

/// Generate the arrival timestamps of `spec` in `[0, horizon)`, sorted
/// ascending. Deterministic in `(spec, seed, horizon)`.
pub fn generate(spec: &ArrivalSpec, seed: u64, horizon: f64) -> Result<Vec<f64>> {
    spec.validate()?;
    if !horizon.is_finite() || horizon <= 0.0 {
        return Err(anyhow!("arrival horizon must be finite and > 0, got {horizon}"));
    }
    let mut rng = Rng::new(seed);
    match spec {
        ArrivalSpec::Poisson { rate } => {
            let mut out = vec![];
            let mut t = 0.0;
            loop {
                t += exp_gap(&mut rng, *rate);
                if t >= horizon {
                    return Ok(out);
                }
                out.push(t);
            }
        }
        ArrivalSpec::OnOff { rate_on, rate_off, mean_on, mean_off } => {
            let mut out = vec![];
            let mut t = 0.0;
            let mut on = true; // the chain starts in the on-phase
            while t < horizon {
                let (rate, mean) = if on { (*rate_on, *mean_on) } else { (*rate_off, *mean_off) };
                let dwell = exp_gap(&mut rng, 1.0 / mean);
                let phase_end = (t + dwell).min(horizon);
                if rate > 0.0 {
                    let mut s = t;
                    loop {
                        s += exp_gap(&mut rng, rate);
                        if s >= phase_end {
                            break;
                        }
                        out.push(s);
                    }
                }
                t += dwell;
                on = !on;
            }
            Ok(out)
        }
        ArrivalSpec::Trace { path } => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("trace {path}: {e}"))?;
            let mut out = vec![];
            let mut prev = 0.0f64;
            for (lineno, line) in text.lines().enumerate() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                let t: f64 = line.parse().map_err(|e| {
                    anyhow!("trace {path}:{}: bad timestamp {line:?}: {e}", lineno + 1)
                })?;
                if !t.is_finite() || t < 0.0 {
                    return Err(anyhow!(
                        "trace {path}:{}: timestamp must be finite and >= 0, got {t}",
                        lineno + 1
                    ));
                }
                if t < prev {
                    return Err(anyhow!(
                        "trace {path}:{}: timestamps must be non-decreasing ({t} after {prev})",
                        lineno + 1
                    ));
                }
                prev = t;
                if t < horizon {
                    out.push(t);
                }
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(rate: f64) -> ArrivalSpec {
        ArrivalSpec::Poisson { rate }
    }

    #[test]
    fn poisson_is_seed_deterministic_and_sorted() {
        let a = generate(&poisson(5.0), 42, 100.0).unwrap();
        let b = generate(&poisson(5.0), 42, 100.0).unwrap();
        assert_eq!(a, b, "same seed, same stream");
        let c = generate(&poisson(5.0), 43, 100.0).unwrap();
        assert_ne!(a, c, "different seed, different stream");
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert!(a.iter().all(|&t| (0.0..100.0).contains(&t)));
    }

    #[test]
    fn poisson_rate_matches_count_and_gap_mean() {
        // 5/s over 200 s: ~1000 arrivals; mean gap ~0.2 s. Deterministic
        // seed, so the tolerances can be tight-ish without flakiness.
        let xs = generate(&poisson(5.0), 7, 200.0).unwrap();
        let n = xs.len() as f64;
        assert!((n - 1000.0).abs() < 120.0, "count {n}");
        let gaps: Vec<f64> =
            std::iter::once(xs[0]).chain(xs.windows(2).map(|w| w[1] - w[0])).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!((mean - 0.2).abs() < 0.03, "mean gap {mean}");
    }

    #[test]
    fn on_off_bursts_and_silences() {
        let spec = ArrivalSpec::OnOff {
            rate_on: 20.0,
            rate_off: 0.0,
            mean_on: 5.0,
            mean_off: 5.0,
        };
        let xs = generate(&spec, 11, 400.0).unwrap();
        assert_eq!(xs, generate(&spec, 11, 400.0).unwrap(), "deterministic");
        assert!(xs.windows(2).all(|w| w[0] <= w[1]));
        // On half the time at 20/s → roughly 400/2*20 = 4000 arrivals.
        assert!((2500..6000).contains(&xs.len()), "{}", xs.len());
        // Bursty: some inter-arrival gap spans an off-phase (≫ the 0.05 s
        // on-phase mean gap).
        let max_gap =
            xs.windows(2).map(|w| w[1] - w[0]).fold(0.0f64, f64::max);
        assert!(max_gap > 1.0, "expected an off-phase silence, max gap {max_gap}");
        // rate_off > 0 keeps a trickle flowing instead of silence.
        let trickle = ArrivalSpec::OnOff {
            rate_on: 20.0,
            rate_off: 2.0,
            mean_on: 5.0,
            mean_off: 5.0,
        };
        let ys = generate(&trickle, 11, 400.0).unwrap();
        assert!(ys.len() > xs.len());
    }

    #[test]
    fn trace_replay_parses_validates_and_clips() {
        let dir = std::env::temp_dir();
        let path = dir.join("samullm_test_trace.txt");
        std::fs::write(&path, "# comment\n0.5\n1.0\n\n1.0\n7.25\n99.0\n").unwrap();
        let p = path.to_str().unwrap().to_string();
        let xs = generate(&ArrivalSpec::Trace { path: p.clone() }, 0, 50.0).unwrap();
        assert_eq!(xs, vec![0.5, 1.0, 1.0, 7.25], "clips at the horizon");
        // Decreasing timestamps and garbage lines are errors.
        std::fs::write(&path, "2.0\n1.0\n").unwrap();
        assert!(generate(&ArrivalSpec::Trace { path: p.clone() }, 0, 50.0).is_err());
        std::fs::write(&path, "abc\n").unwrap();
        assert!(generate(&ArrivalSpec::Trace { path: p.clone() }, 0, 50.0).is_err());
        std::fs::write(&path, "-1.0\n").unwrap();
        assert!(generate(&ArrivalSpec::Trace { path: p.clone() }, 0, 50.0).is_err());
        let _ = std::fs::remove_file(&path);
        assert!(
            generate(&ArrivalSpec::Trace { path: "/nonexistent/x.txt".into() }, 0, 1.0)
                .is_err()
        );
    }

    #[test]
    fn bad_parameters_are_rejected() {
        assert!(generate(&poisson(0.0), 1, 10.0).is_err());
        assert!(generate(&poisson(f64::NAN), 1, 10.0).is_err());
        assert!(generate(&poisson(1.0), 1, 0.0).is_err());
        let bad = ArrivalSpec::OnOff {
            rate_on: 1.0,
            rate_off: -1.0,
            mean_on: 1.0,
            mean_off: 1.0,
        };
        assert!(generate(&bad, 1, 10.0).is_err());
    }
}
