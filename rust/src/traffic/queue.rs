//! Bounded admission queues with weighted-fair-share draining.
//!
//! The [`AdmissionQueue`] sits between the arrival streams and the
//! scheduling core: each app has a bounded FIFO of pending jobs
//! (configurable capacity, [`QueuePolicy`] deciding what happens on
//! overflow), and jobs are *drained* by virtual-time weighted round-robin
//! — an app's admission share under backlog is proportional to its
//! weight, which is what turns `weight=` from reporting metadata into a
//! real scheduling priority.
//!
//! The virtual-time rule is classic WFQ: admitting a job from app *j*
//! advances that app's virtual time by `1 / weight_j`, and the next
//! admission goes to the non-empty queue with the smallest virtual time
//! (ties broken by app id, so draining is fully deterministic). A queue
//! that goes idle has its virtual time floored to the last admission's
//! level when it reactivates, so idleness doesn't bank catch-up credit.

use anyhow::{anyhow, Result};
use std::collections::VecDeque;

/// What happens to an arrival that finds its app's queue full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Drop the job and count it (it never runs; rejected jobs count
    /// against SLO attainment).
    Reject,
    /// Park the job in an unbounded backlog and count the deferral; it is
    /// promoted into the bounded queue as admissions drain it.
    Defer,
}

impl QueuePolicy {
    /// The policy's JSON/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            QueuePolicy::Reject => "reject",
            QueuePolicy::Defer => "defer",
        }
    }

    /// Parse a policy name (`reject` | `defer`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "reject" => Ok(QueuePolicy::Reject),
            "defer" => Ok(QueuePolicy::Defer),
            other => Err(anyhow!("unknown queue policy {other:?} (known: reject, defer)")),
        }
    }
}

/// One queued job: the `seq`-th arrival of app `app_id`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedJob {
    /// Owning app (index into the traffic mix).
    pub app_id: usize,
    /// Per-app arrival sequence number (selects request templates).
    pub seq: u64,
    /// Wall-clock arrival time in seconds.
    pub arrival: f64,
}

/// Per-app queue-depth and overflow statistics, reported per run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QueueCounters {
    /// Jobs the arrival stream offered.
    pub offered: u64,
    /// Jobs admitted into execution (popped by the fair-share drain).
    pub admitted: u64,
    /// Jobs dropped by [`QueuePolicy::Reject`] overflow.
    pub rejected: u64,
    /// Jobs parked by [`QueuePolicy::Defer`] overflow (they still run,
    /// later).
    pub deferred: u64,
}

/// Bounded per-app admission queues drained by weighted fair share.
#[derive(Debug, Clone)]
pub struct AdmissionQueue {
    capacity: usize,
    policy: QueuePolicy,
    weights: Vec<f64>,
    queues: Vec<VecDeque<QueuedJob>>,
    backlog: Vec<VecDeque<QueuedJob>>,
    vtime: Vec<f64>,
    /// Virtual-time floor: the level of the most recent admission.
    vfloor: f64,
    counters: Vec<QueueCounters>,
    depth_sum: f64,
    depth_samples: u64,
    depth_max: usize,
}

impl AdmissionQueue {
    /// An empty queue set for `weights.len()` apps. `capacity` bounds
    /// each app's queue (≥ 1); weights must be finite and positive.
    pub fn new(weights: &[f64], capacity: usize, policy: QueuePolicy) -> Self {
        assert!(capacity >= 1, "queue capacity must be >= 1");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "weights must be finite and > 0"
        );
        let n = weights.len();
        AdmissionQueue {
            capacity,
            policy,
            weights: weights.to_vec(),
            queues: vec![VecDeque::new(); n],
            backlog: vec![VecDeque::new(); n],
            vtime: vec![0.0; n],
            vfloor: 0.0,
            counters: vec![QueueCounters::default(); n],
            depth_sum: 0.0,
            depth_samples: 0,
            depth_max: 0,
        }
    }

    /// Offer an arriving job. Returns `false` iff the job was dropped
    /// ([`QueuePolicy::Reject`] with a full queue); deferred jobs return
    /// `true` — they run eventually.
    pub fn offer(&mut self, job: QueuedJob) -> bool {
        let a = job.app_id;
        self.counters[a].offered += 1;
        if self.queues[a].len() < self.capacity {
            if self.queues[a].is_empty() && self.backlog[a].is_empty() {
                // Reactivating after idle: no banked catch-up credit.
                self.vtime[a] = self.vtime[a].max(self.vfloor);
            }
            self.queues[a].push_back(job);
            return true;
        }
        match self.policy {
            QueuePolicy::Reject => {
                self.counters[a].rejected += 1;
                false
            }
            QueuePolicy::Defer => {
                self.counters[a].deferred += 1;
                self.backlog[a].push_back(job);
                true
            }
        }
    }

    /// Admit the next job by weighted fair share: the non-empty queue
    /// with the smallest virtual time wins (ties by app id), and its
    /// virtual time advances by `1 / weight`. Deferred backlog jobs are
    /// promoted into the freed slot. `None` when everything is empty.
    pub fn pop_fair(&mut self) -> Option<QueuedJob> {
        let a = (0..self.queues.len())
            .filter(|&a| !self.queues[a].is_empty())
            .min_by(|&x, &y| {
                self.vtime[x]
                    .partial_cmp(&self.vtime[y])
                    .expect("virtual times are finite")
                    .then(x.cmp(&y))
            })?;
        let job = self.queues[a].pop_front().expect("queue is non-empty");
        self.vfloor = self.vtime[a];
        self.vtime[a] += 1.0 / self.weights[a];
        self.counters[a].admitted += 1;
        if let Some(parked) = self.backlog[a].pop_front() {
            self.queues[a].push_back(parked);
        }
        Some(job)
    }

    /// Record the current total depth (queues + backlog) into the
    /// depth statistics; call once per stage boundary.
    pub fn record_depth(&mut self) {
        let d = self.len();
        self.depth_sum += d as f64;
        self.depth_samples += 1;
        self.depth_max = self.depth_max.max(d);
    }

    /// Total jobs currently waiting (bounded queues plus defer backlog).
    pub fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum::<usize>()
            + self.backlog.iter().map(|q| q.len()).sum::<usize>()
    }

    /// Whether no job is waiting anywhere.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mean recorded depth (0 when never recorded).
    pub fn depth_mean(&self) -> f64 {
        if self.depth_samples == 0 {
            0.0
        } else {
            self.depth_sum / self.depth_samples as f64
        }
    }

    /// Maximum recorded depth.
    pub fn depth_max(&self) -> usize {
        self.depth_max
    }

    /// Per-app offered/admitted/rejected/deferred counters.
    pub fn counters(&self) -> &[QueueCounters] {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(app_id: usize, seq: u64) -> QueuedJob {
        QueuedJob { app_id, seq, arrival: seq as f64 }
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [QueuePolicy::Reject, QueuePolicy::Defer] {
            assert_eq!(QueuePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(QueuePolicy::parse("drop-oldest").is_err());
    }

    #[test]
    fn reject_boundary_at_capacity() {
        let mut q = AdmissionQueue::new(&[1.0], 3, QueuePolicy::Reject);
        for i in 0..3 {
            assert!(q.offer(job(0, i)), "slot {i} fits");
        }
        assert!(!q.offer(job(0, 3)), "capacity+1 is dropped");
        assert_eq!(q.len(), 3);
        let c = q.counters()[0];
        assert_eq!((c.offered, c.rejected, c.deferred), (4, 1, 0));
        // Draining one slot makes room again.
        assert_eq!(q.pop_fair().unwrap().seq, 0);
        assert!(q.offer(job(0, 4)));
    }

    #[test]
    fn defer_boundary_parks_and_promotes() {
        let mut q = AdmissionQueue::new(&[1.0], 2, QueuePolicy::Defer);
        for i in 0..5 {
            assert!(q.offer(job(0, i)), "defer never drops");
        }
        assert_eq!(q.len(), 5, "2 queued + 3 parked");
        let c = q.counters()[0];
        assert_eq!((c.offered, c.rejected, c.deferred), (5, 0, 3));
        // FIFO order is preserved across the backlog promotion.
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop_fair()).map(|j| j.seq).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        assert_eq!(q.counters()[0].admitted, 5);
    }

    #[test]
    fn fair_share_follows_weights_exactly() {
        // Weight 2:1 under saturation → admissions interleave 2:1
        // deterministically (virtual-time increments 0.5 vs 1.0).
        let mut q = AdmissionQueue::new(&[2.0, 1.0], 64, QueuePolicy::Reject);
        for i in 0..40 {
            q.offer(job(0, i));
            q.offer(job(1, i));
        }
        let drained: Vec<usize> =
            (0..30).map(|_| q.pop_fair().unwrap().app_id).collect();
        let heavy = drained.iter().filter(|&&a| a == 0).count();
        assert_eq!(heavy, 20, "weight-2 app gets exactly 2/3 of 30 slots");
        // Per-app FIFO still holds.
        assert_eq!(q.counters()[0].admitted, 20);
        assert_eq!(q.counters()[1].admitted, 10);
    }

    #[test]
    fn unweighted_is_round_robin() {
        let mut q = AdmissionQueue::new(&[1.0, 1.0], 64, QueuePolicy::Reject);
        for i in 0..10 {
            q.offer(job(0, i));
            q.offer(job(1, i));
        }
        let drained: Vec<usize> =
            (0..10).map(|_| q.pop_fair().unwrap().app_id).collect();
        assert_eq!(drained, vec![0, 1, 0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn idle_app_banks_no_credit() {
        let mut q = AdmissionQueue::new(&[1.0, 1.0], 64, QueuePolicy::Reject);
        // App 0 alone admits 10 jobs while app 1 idles.
        for i in 0..20 {
            q.offer(job(0, i));
        }
        for _ in 0..10 {
            assert_eq!(q.pop_fair().unwrap().app_id, 0);
        }
        // App 1 wakes up: it must NOT win the next 10 slots in a row.
        for i in 0..20 {
            q.offer(job(1, i));
        }
        let next: Vec<usize> = (0..6).map(|_| q.pop_fair().unwrap().app_id).collect();
        assert!(
            next.iter().filter(|&&a| a == 0).count() >= 2,
            "reactivated app must share, got {next:?}"
        );
    }

    #[test]
    fn depth_stats_track_mean_and_max() {
        let mut q = AdmissionQueue::new(&[1.0], 8, QueuePolicy::Reject);
        q.record_depth(); // 0
        q.offer(job(0, 0));
        q.offer(job(0, 1));
        q.record_depth(); // 2
        q.pop_fair();
        q.record_depth(); // 1
        assert_eq!(q.depth_max(), 2);
        assert!((q.depth_mean() - 1.0).abs() < 1e-12);
    }
}
