//! Typed experiment configuration with JSON (de)serialisation (via the
//! in-tree `util::json` — no serde offline).
//!
//! Every experiment is fully described by an [`ExperimentConfig`]: a
//! declarative [`AppSpec`] (one of the paper's applications *or* an
//! arbitrary custom graph), a policy name from the [`crate::policy`]
//! registry, and the run switches. The CLI (`samullm config file.json`)
//! replays any of them from a small JSON file.

use anyhow::{anyhow, Result};

use crate::costmodel::online;
use crate::engine::AdmitPolicy;
use crate::exec;
use crate::policy;
use crate::spec::{AppSpec, TrafficSpec, WorkloadSpec};
use crate::util::json::Json;

/// A complete, replayable experiment description. Exactly one of `app`
/// (a single application), `workload` (a multi-app batch workload with
/// per-entry arrivals/weights/seeds) or `traffic` (an open-loop serving
/// mix with per-app arrival processes) is set.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Single-app run: one of the paper's apps or a custom graph
    /// (`None` when `workload` or `traffic` is set).
    pub app: Option<AppSpec>,
    /// Multi-app batch run: a declarative workload (`None` when `app` or
    /// `traffic` is set).
    pub workload: Option<WorkloadSpec>,
    /// Open-loop serving run: per-app arrival streams through the bounded
    /// admission queue (`None` when `app` or `workload` is set).
    pub traffic: Option<TrafficSpec>,
    /// Canonical policy name (aliases accepted on parse).
    pub policy: String,
    /// Canonical execution backend name (`"sim"` or `"pjrt"`; aliases
    /// accepted on parse).
    pub backend: String,
    /// Artifacts directory for the `pjrt` backend (`None` = default).
    pub artifacts: Option<String>,
    /// Cluster GPU count (an A100 node).
    pub n_gpus: u32,
    /// Seed for workload generation, calibration and planning.
    pub seed: u64,
    /// Disable preemption (§5.5 ablation).
    pub no_preemption: bool,
    /// Let every policy see the true output lengths (§5.5 ablation).
    pub known_output_lengths: bool,
    /// Planner candidate-evaluation worker threads (`0` = auto); search
    /// speed only, never results.
    pub threads: usize,
    /// Memoize planner simulations across searches (default on).
    pub sim_cache: bool,
    /// Runtime length-feedback loop: online posterior refinement +
    /// drift-triggered replanning (default off).
    pub online_refinement: bool,
    /// Drift score that triggers a re-plan of the remaining app (only
    /// with `online_refinement`).
    pub replan_threshold: f64,
    /// Weight of one observed completion in offline-trace-sample
    /// equivalents (only with `online_refinement`).
    pub online_weight: f64,
    /// Canonical engine admission-policy name
    /// (`fcfs | spjf | multi-bin:K | skip-join:Q:P`; spellings accepted on
    /// parse — see [`AdmitPolicy::parse`]).
    pub admit: String,
    /// Let plans oversubscribe cluster HBM: packed stages time-slice the
    /// GPUs via the residency subsystem, paying modeled swap latency
    /// (default off; batch runs only — traffic runs reject it).
    pub oversubscribe: bool,
    /// Host-to-device bandwidth override in bytes/s for swap-cost pricing
    /// (`None` = the cluster spec's own link).
    pub h2d_bw: Option<f64>,
    /// Aggregated decode stepping in the simulator (default on). Exact —
    /// turning it off changes simulation wall-clock only, never results
    /// (see [`crate::engine::sim::EngineConfig::fast_step`]).
    pub fast_step: bool,
    /// Wall-clock budget in seconds for each planner search (`None` =
    /// unbudgeted). The search is anytime: on expiry it keeps the best
    /// complete plan found so far and flags
    /// [`crate::planner::EvalStats::budget_exhausted`].
    pub search_budget: Option<f64>,
    /// Force the sequential measured lowering: stage nodes run one after
    /// another on the device instead of interleaving through the
    /// backend's stepping interface (default off; inert for `sim` runs).
    pub sequential_measured: bool,
}

impl ExperimentConfig {
    /// Serialize to a compact JSON document.
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            (
                "app",
                match &self.app {
                    Some(app) => app.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "workload",
                match &self.workload {
                    Some(w) => w.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "traffic",
                match &self.traffic {
                    Some(t) => t.to_json(),
                    None => Json::Null,
                },
            ),
            ("policy", Json::Str(self.policy.clone())),
            ("backend", Json::Str(self.backend.clone())),
            (
                "artifacts",
                match &self.artifacts {
                    Some(dir) => Json::Str(dir.clone()),
                    None => Json::Null,
                },
            ),
            ("n_gpus", Json::Num(self.n_gpus as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("no_preemption", Json::Bool(self.no_preemption)),
            ("known_output_lengths", Json::Bool(self.known_output_lengths)),
            ("threads", Json::Num(self.threads as f64)),
            ("sim_cache", Json::Bool(self.sim_cache)),
            ("online_refinement", Json::Bool(self.online_refinement)),
            ("replan_threshold", Json::Num(self.replan_threshold)),
            ("online_weight", Json::Num(self.online_weight)),
            ("admit", Json::Str(self.admit.clone())),
            ("oversubscribe", Json::Bool(self.oversubscribe)),
            (
                "h2d_bw",
                match self.h2d_bw {
                    Some(bw) => Json::Num(bw),
                    None => Json::Null,
                },
            ),
            ("fast_step", Json::Bool(self.fast_step)),
            (
                "search_budget",
                match self.search_budget {
                    Some(b) => Json::Num(b),
                    None => Json::Null,
                },
            ),
            ("sequential_measured", Json::Bool(self.sequential_measured)),
        ])
        .to_string()
    }

    /// Parse a config document; missing switches keep the seed defaults.
    /// Exactly one of `app` / `workload` / `traffic` must be present (the
    /// workload/traffic values may be `{"name", "entries", ...}` objects
    /// or bare entry arrays).
    pub fn from_json(s: &str) -> Result<Self> {
        let v = Json::parse(s).map_err(|e| anyhow!("bad config json: {e}"))?;
        let app = match v.get("app") {
            Some(Json::Null) | None => None,
            Some(a) => Some(AppSpec::from_json(a)?),
        };
        let workload = match v.get("workload") {
            Some(Json::Null) | None => None,
            Some(w) => Some(WorkloadSpec::from_json(w)?),
        };
        let traffic = match v.get("traffic") {
            Some(Json::Null) | None => None,
            Some(t) => Some(TrafficSpec::from_json(t)?),
        };
        match app.is_some() as u8 + workload.is_some() as u8 + traffic.is_some() as u8 {
            0 => return Err(anyhow!("config needs an app, a workload or a traffic mix")),
            1 => {}
            _ => {
                return Err(anyhow!(
                    "config must set exactly one of app / workload / traffic"
                ))
            }
        }
        Ok(ExperimentConfig {
            app,
            workload,
            traffic,
            policy: policy::canonical(
                v.get("policy").and_then(|p| p.as_str()).unwrap_or("samullm"),
            )?
            .to_string(),
            backend: exec::canonical(
                v.get("backend").and_then(|b| b.as_str()).unwrap_or("sim"),
            )?
            .to_string(),
            artifacts: v
                .get("artifacts")
                .and_then(|a| a.as_str())
                .map(|s| s.to_string()),
            n_gpus: v.get("n_gpus").and_then(|x| x.as_u64()).unwrap_or(8) as u32,
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(42),
            no_preemption: v.get("no_preemption").and_then(|x| x.as_bool()).unwrap_or(false),
            known_output_lengths: v
                .get("known_output_lengths")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            threads: v.get("threads").and_then(|x| x.as_usize()).unwrap_or(0),
            sim_cache: v.get("sim_cache").and_then(|x| x.as_bool()).unwrap_or(true),
            online_refinement: v
                .get("online_refinement")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            replan_threshold: v
                .get("replan_threshold")
                .and_then(|x| x.as_f64())
                .unwrap_or(online::DEFAULT_REPLAN_THRESHOLD),
            online_weight: v
                .get("online_weight")
                .and_then(|x| x.as_f64())
                .unwrap_or(online::DEFAULT_OBS_WEIGHT),
            admit: AdmitPolicy::parse(
                v.get("admit").and_then(|a| a.as_str()).unwrap_or("fcfs"),
            )?
            .name(),
            oversubscribe: v
                .get("oversubscribe")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            h2d_bw: v.get("h2d_bw").and_then(|x| x.as_f64()),
            fast_step: v.get("fast_step").and_then(|x| x.as_bool()).unwrap_or(true),
            search_budget: v.get("search_budget").and_then(|x| x.as_f64()),
            sequential_measured: v
                .get("sequential_measured")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = ExperimentConfig {
            app: Some(AppSpec::ensembling(1000, 256)),
            workload: None,
            traffic: None,
            policy: "ours".to_string(),
            backend: "pjrt".to_string(),
            artifacts: Some("custom/artifacts".to_string()),
            n_gpus: 8,
            seed: 42,
            no_preemption: false,
            known_output_lengths: false,
            threads: 4,
            sim_cache: false,
            online_refinement: true,
            replan_threshold: 0.2,
            online_weight: 16.0,
            admit: "multi-bin:4".to_string(),
            oversubscribe: true,
            h2d_bw: Some(20.0e9),
            fast_step: false,
            search_budget: Some(0.5),
            sequential_measured: true,
        };
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.app, c.app);
        assert_eq!(back.policy, c.policy);
        assert_eq!(back.backend, "pjrt");
        assert_eq!(back.artifacts.as_deref(), Some("custom/artifacts"));
        assert_eq!(back.seed, 42);
        assert_eq!(back.threads, 4);
        assert!(!back.sim_cache);
        assert!(back.online_refinement);
        assert_eq!(back.replan_threshold, 0.2);
        assert_eq!(back.online_weight, 16.0);
        assert_eq!(back.admit, "multi-bin:4");
        assert!(back.oversubscribe);
        assert_eq!(back.h2d_bw, Some(20.0e9));
        assert!(!back.fast_step);
        assert_eq!(back.search_budget, Some(0.5));
        assert!(back.sequential_measured);
    }

    #[test]
    fn defaults_for_missing_flags() {
        let j = r#"{"app":{"kind":"routing","max_out":4096},
                     "policy":"max_heuristic","n_gpus":8,"seed":1}"#;
        let c = ExperimentConfig::from_json(j).unwrap();
        assert!(!c.no_preemption);
        assert!(!c.known_output_lengths);
        assert_eq!(c.policy, "max-heuristic");
        // Planner knobs default to auto threads + caching on.
        assert_eq!(c.threads, 0);
        assert!(c.sim_cache);
        // The length-feedback loop defaults off with the stock knobs.
        assert!(!c.online_refinement);
        assert_eq!(c.replan_threshold, online::DEFAULT_REPLAN_THRESHOLD);
        assert_eq!(c.online_weight, online::DEFAULT_OBS_WEIGHT);
        // Backend defaults to the simulated substrate, admission to FCFS.
        assert_eq!(c.backend, "sim");
        assert!(c.artifacts.is_none());
        assert_eq!(c.admit, "fcfs");
        // Residency defaults off with the cluster's own host link.
        assert!(!c.oversubscribe);
        assert!(c.h2d_bw.is_none());
        // Fast stepping defaults on; planner searches are unbudgeted;
        // measured stages take the concurrent lowering.
        assert!(c.fast_step);
        assert!(c.search_budget.is_none());
        assert!(!c.sequential_measured);
    }

    #[test]
    fn backend_aliases_and_rejection() {
        let j = r#"{"app":{"kind":"ensembling"},"backend":"real"}"#;
        assert_eq!(ExperimentConfig::from_json(j).unwrap().backend, "pjrt");
        let j = r#"{"app":{"kind":"ensembling"},"backend":"cuda"}"#;
        assert!(ExperimentConfig::from_json(j).is_err());
    }

    #[test]
    fn legacy_policy_aliases_accepted() {
        // Seed config files used "samullm"/"max_heuristic"/"min_heuristic".
        for (alias, canonical) in [
            ("samullm", "ours"),
            ("max_heuristic", "max-heuristic"),
            ("min_heuristic", "min-heuristic"),
        ] {
            let j = format!(r#"{{"app":{{"kind":"ensembling"}},"policy":"{alias}"}}"#);
            assert_eq!(ExperimentConfig::from_json(&j).unwrap().policy, canonical);
        }
    }

    #[test]
    fn all_app_kinds_roundtrip() {
        for app in [
            AppSpec::routing(4096, true),
            AppSpec::chain_summary(100, 4, 900),
            AppSpec::mixed(400, 5000, 900, 256, 4),
        ] {
            let c = ExperimentConfig {
                app: Some(app.clone()),
                workload: None,
                traffic: None,
                policy: "min-heuristic".to_string(),
                backend: "sim".to_string(),
                artifacts: None,
                n_gpus: 8,
                seed: 7,
                no_preemption: true,
                known_output_lengths: true,
                threads: 0,
                sim_cache: true,
                online_refinement: false,
                replan_threshold: online::DEFAULT_REPLAN_THRESHOLD,
                online_weight: online::DEFAULT_OBS_WEIGHT,
                admit: "fcfs".to_string(),
                oversubscribe: false,
                h2d_bw: None,
                fast_step: true,
                search_budget: None,
                sequential_measured: false,
            };
            let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(back.app, Some(app));
            assert!(back.no_preemption && back.known_output_lengths);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(ExperimentConfig::from_json("{not json").is_err());
        assert!(ExperimentConfig::from_json(r#"{"app":{"kind":"nope"}}"#).is_err());
        assert!(
            ExperimentConfig::from_json(r#"{"app":{"kind":"ensembling"},"policy":"fifo"}"#)
                .is_err()
        );
        assert!(
            ExperimentConfig::from_json(r#"{"app":{"kind":"ensembling"},"admit":"nope"}"#)
                .is_err()
        );
        // Admission spellings canonicalise on parse.
        let j = r#"{"app":{"kind":"ensembling"},"admit":"mlfq"}"#;
        assert!(ExperimentConfig::from_json(j).unwrap().admit.starts_with("skip-join:"));
        // None of app/workload/traffic, or more than one at once, errors.
        assert!(ExperimentConfig::from_json(r#"{"policy":"ours"}"#).is_err());
        let both = r#"{"app":{"kind":"ensembling"},
                       "workload":[{"app":{"kind":"ensembling"}}]}"#;
        assert!(ExperimentConfig::from_json(both).is_err());
        let both = r#"{"app":{"kind":"ensembling"},
                       "traffic":[{"app":{"kind":"ensembling"},
                                   "process":{"kind":"poisson","rate":2}}]}"#;
        assert!(ExperimentConfig::from_json(both).is_err());
    }

    #[test]
    fn traffic_config_roundtrips_and_replaces_app() {
        use crate::spec::{ArrivalSpec, TrafficEntry, TrafficSpec};
        let c = ExperimentConfig {
            app: None,
            workload: None,
            traffic: Some(TrafficSpec {
                name: "mix".into(),
                entries: vec![
                    TrafficEntry::poisson(AppSpec::ensembling(40, 96), 4.0),
                    TrafficEntry {
                        app: AppSpec::chain_summary(8, 1, 200),
                        process: ArrivalSpec::OnOff {
                            rate_on: 6.0,
                            rate_off: 0.5,
                            mean_on: 10.0,
                            mean_off: 20.0,
                        },
                        weight: 2.0,
                        slo: Some(45.0),
                        seed: Some(11),
                    },
                ],
                duration: 90.0,
                warmup: 10.0,
                queue_capacity: 16,
                queue_policy: crate::traffic::QueuePolicy::Defer,
                admit_quantum: 4,
            }),
            policy: "ours".to_string(),
            backend: "sim".to_string(),
            artifacts: None,
            n_gpus: 8,
            seed: 42,
            no_preemption: false,
            known_output_lengths: false,
            threads: 0,
            sim_cache: true,
            online_refinement: false,
            replan_threshold: online::DEFAULT_REPLAN_THRESHOLD,
            online_weight: online::DEFAULT_OBS_WEIGHT,
            admit: "fcfs".to_string(),
            oversubscribe: false,
            h2d_bw: None,
            fast_step: true,
            search_budget: None,
            sequential_measured: false,
        };
        let text = c.to_json();
        let back = ExperimentConfig::from_json(&text).unwrap();
        assert!(back.app.is_none() && back.workload.is_none());
        assert_eq!(back.traffic, c.traffic);
        assert_eq!(back.to_json(), text, "serialisation is stable");
        // The bare-array shorthand parses with default window/queue knobs.
        let j = r#"{"traffic":[{"app":{"kind":"ensembling"},
                                "process":{"kind":"poisson","rate":5}}],
                    "policy":"min"}"#;
        let cfg = ExperimentConfig::from_json(j).unwrap();
        let t = cfg.traffic.unwrap();
        assert_eq!(t.entries.len(), 1);
        assert_eq!(t.duration, 120.0);
        assert_eq!(cfg.policy, "min-heuristic");
    }

    #[test]
    fn workload_config_roundtrips_and_replaces_app() {
        use crate::spec::WorkloadEntry;
        let c = ExperimentConfig {
            app: None,
            workload: Some(WorkloadSpec {
                name: "pair".into(),
                entries: vec![
                    WorkloadEntry::new(AppSpec::chain_summary(50, 2, 300)),
                    WorkloadEntry {
                        app: AppSpec::ensembling(500, 256),
                        arrival: 30.0,
                        weight: 2.0,
                        seed: Some(7),
                    },
                ],
            }),
            traffic: None,
            policy: "ours".to_string(),
            backend: "sim".to_string(),
            artifacts: None,
            n_gpus: 8,
            seed: 42,
            no_preemption: false,
            known_output_lengths: false,
            threads: 0,
            sim_cache: true,
            online_refinement: false,
            replan_threshold: online::DEFAULT_REPLAN_THRESHOLD,
            online_weight: online::DEFAULT_OBS_WEIGHT,
            admit: "fcfs".to_string(),
            oversubscribe: false,
            h2d_bw: None,
            fast_step: true,
            search_budget: None,
            sequential_measured: false,
        };
        let text = c.to_json();
        let back = ExperimentConfig::from_json(&text).unwrap();
        assert!(back.app.is_none());
        assert_eq!(back.workload, c.workload);
        assert_eq!(back.to_json(), text, "serialisation is stable");
        // The bare-array shorthand parses too.
        let j = r#"{"workload":[{"app":{"kind":"ensembling"}},
                                {"app":{"kind":"chain_summary"},"arrival":60}],
                    "policy":"min"}"#;
        let cfg = ExperimentConfig::from_json(j).unwrap();
        let wl = cfg.workload.unwrap();
        assert_eq!(wl.entries.len(), 2);
        assert_eq!(wl.entries[1].arrival, 60.0);
        assert_eq!(cfg.policy, "min-heuristic");
    }
}
