//! Typed experiment configuration with JSON (de)serialisation (via the
//! in-tree `util::json` — no serde offline).
//!
//! Every experiment in the harness is fully described by an
//! [`ExperimentConfig`]; the CLI (`samullm config file.json`) and the
//! figure harness both build on it, so any paper experiment can be
//! replayed from a small JSON file.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Which application to build (paper §5, Fig. 5).
#[derive(Debug, Clone, PartialEq)]
pub enum AppConfig {
    /// §5.1: every model answers every request.
    Ensembling { n_requests: usize, max_out: u32 },
    /// §5.2: each request goes to its best model (Table 1 ratios).
    Routing { max_out: u32, known_lengths: bool },
    /// §5.3: chunked document summarization + summary evaluation.
    ChainSummary { n_docs: usize, eval_times: u32, max_out: u32 },
    /// §5.4: chain summary + ensembling run as one application.
    Mixed {
        n_docs: usize,
        n_ensemble_requests: usize,
        summary_max_out: u32,
        ensemble_max_out: u32,
    },
}

/// Scheduling policy selection (ours + competitors, §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyConfig {
    SamuLlm,
    MaxHeuristic,
    MinHeuristic,
}

/// A complete, replayable experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub app: AppConfig,
    pub policy: PolicyConfig,
    pub n_gpus: u32,
    pub seed: u64,
    /// Disable preemption (§5.5 ablation).
    pub no_preemption: bool,
    /// Let every policy see the true output lengths (§5.5 ablation).
    pub known_output_lengths: bool,
}

impl AppConfig {
    fn to_json(&self) -> Json {
        match self {
            AppConfig::Ensembling { n_requests, max_out } => Json::obj(vec![
                ("kind", Json::Str("ensembling".into())),
                ("n_requests", Json::Num(*n_requests as f64)),
                ("max_out", Json::Num(*max_out as f64)),
            ]),
            AppConfig::Routing { max_out, known_lengths } => Json::obj(vec![
                ("kind", Json::Str("routing".into())),
                ("max_out", Json::Num(*max_out as f64)),
                ("known_lengths", Json::Bool(*known_lengths)),
            ]),
            AppConfig::ChainSummary { n_docs, eval_times, max_out } => Json::obj(vec![
                ("kind", Json::Str("chain_summary".into())),
                ("n_docs", Json::Num(*n_docs as f64)),
                ("eval_times", Json::Num(*eval_times as f64)),
                ("max_out", Json::Num(*max_out as f64)),
            ]),
            AppConfig::Mixed {
                n_docs,
                n_ensemble_requests,
                summary_max_out,
                ensemble_max_out,
            } => Json::obj(vec![
                ("kind", Json::Str("mixed".into())),
                ("n_docs", Json::Num(*n_docs as f64)),
                ("n_ensemble_requests", Json::Num(*n_ensemble_requests as f64)),
                ("summary_max_out", Json::Num(*summary_max_out as f64)),
                ("ensemble_max_out", Json::Num(*ensemble_max_out as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let kind =
            v.get("kind").and_then(|k| k.as_str()).ok_or_else(|| anyhow!("app.kind missing"))?;
        let num = |k: &str, d: u64| v.get(k).and_then(|x| x.as_u64()).unwrap_or(d);
        Ok(match kind {
            "ensembling" => AppConfig::Ensembling {
                n_requests: num("n_requests", 1000) as usize,
                max_out: num("max_out", 256) as u32,
            },
            "routing" => AppConfig::Routing {
                max_out: num("max_out", 4096) as u32,
                known_lengths: v.get("known_lengths").and_then(|x| x.as_bool()).unwrap_or(false),
            },
            "chain_summary" => AppConfig::ChainSummary {
                n_docs: num("n_docs", 100) as usize,
                eval_times: num("eval_times", 1) as u32,
                max_out: num("max_out", 500) as u32,
            },
            "mixed" => AppConfig::Mixed {
                n_docs: num("n_docs", 100) as usize,
                n_ensemble_requests: num("n_ensemble_requests", 5000) as usize,
                summary_max_out: num("summary_max_out", 900) as u32,
                ensemble_max_out: num("ensemble_max_out", 256) as u32,
            },
            other => return Err(anyhow!("unknown app kind {other}")),
        })
    }
}

impl PolicyConfig {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyConfig::SamuLlm => "samullm",
            PolicyConfig::MaxHeuristic => "max_heuristic",
            PolicyConfig::MinHeuristic => "min_heuristic",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "samullm" | "ours" => PolicyConfig::SamuLlm,
            "max_heuristic" | "max" => PolicyConfig::MaxHeuristic,
            "min_heuristic" | "min" => PolicyConfig::MinHeuristic,
            other => return Err(anyhow!("unknown policy {other}")),
        })
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("app", self.app.to_json()),
            ("policy", Json::Str(self.policy.name().into())),
            ("n_gpus", Json::Num(self.n_gpus as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("no_preemption", Json::Bool(self.no_preemption)),
            ("known_output_lengths", Json::Bool(self.known_output_lengths)),
        ])
        .to_string()
    }

    pub fn from_json(s: &str) -> Result<Self> {
        let v = Json::parse(s).map_err(|e| anyhow!("bad config json: {e}"))?;
        Ok(ExperimentConfig {
            app: AppConfig::from_json(v.get("app").ok_or_else(|| anyhow!("app missing"))?)?,
            policy: PolicyConfig::parse(
                v.get("policy").and_then(|p| p.as_str()).unwrap_or("samullm"),
            )?,
            n_gpus: v.get("n_gpus").and_then(|x| x.as_u64()).unwrap_or(8) as u32,
            seed: v.get("seed").and_then(|x| x.as_u64()).unwrap_or(42),
            no_preemption: v.get("no_preemption").and_then(|x| x.as_bool()).unwrap_or(false),
            known_output_lengths: v
                .get("known_output_lengths")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = ExperimentConfig {
            app: AppConfig::Ensembling { n_requests: 1000, max_out: 256 },
            policy: PolicyConfig::SamuLlm,
            n_gpus: 8,
            seed: 42,
            no_preemption: false,
            known_output_lengths: false,
        };
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.app, c.app);
        assert_eq!(back.policy, c.policy);
        assert_eq!(back.seed, 42);
    }

    #[test]
    fn defaults_for_missing_flags() {
        let j = r#"{"app":{"kind":"routing","max_out":4096},
                     "policy":"max_heuristic","n_gpus":8,"seed":1}"#;
        let c = ExperimentConfig::from_json(j).unwrap();
        assert!(!c.no_preemption);
        assert!(!c.known_output_lengths);
        assert_eq!(c.policy, PolicyConfig::MaxHeuristic);
    }

    #[test]
    fn all_app_kinds_roundtrip() {
        for app in [
            AppConfig::Routing { max_out: 4096, known_lengths: true },
            AppConfig::ChainSummary { n_docs: 100, eval_times: 4, max_out: 900 },
            AppConfig::Mixed {
                n_docs: 400,
                n_ensemble_requests: 5000,
                summary_max_out: 900,
                ensemble_max_out: 256,
            },
        ] {
            let c = ExperimentConfig {
                app: app.clone(),
                policy: PolicyConfig::MinHeuristic,
                n_gpus: 8,
                seed: 7,
                no_preemption: true,
                known_output_lengths: true,
            };
            let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(back.app, app);
            assert!(back.no_preemption && back.known_output_lengths);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(ExperimentConfig::from_json("{not json").is_err());
        assert!(ExperimentConfig::from_json(r#"{"app":{"kind":"nope"}}"#).is_err());
    }
}
