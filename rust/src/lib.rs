//! # SamuLLM — offline multi-LLM application scheduling
//!
//! Reproduction of *"Improving the End-to-End Efficiency of Offline
//! Inference for Multi-LLM Applications Based on Sampling and Simulation"*
//! (Fang, Shen, Wang, Chen, 2025).
//!
//! The library answers one question: given a multi-LLM application (a
//! computation graph of LLMs), a fixed set of input requests, and a
//! single node with `N` GPUs, in which order — and with which
//! data/tensor-parallel execution plans — should the models run so the
//! whole application finishes soonest?
//!
//! ## Layers
//!
//! * [`session`] — the [`session::SamuLlm`] facade: build a session once
//!   (cluster, policy, seed), then run declarative scenarios.
//! * [`spec`] — declarative [`spec::AppSpec`] scenario descriptions (the
//!   paper's four applications plus arbitrary custom graphs), JSON
//!   round-trippable, materialised by the app-builder registry; and the
//!   multi-app workload layer ([`spec::WorkloadSpec`]): N application
//!   instances with per-app arrivals/weights/seeds composed into one
//!   jointly planned run ([`session::SamuLlm::run_workload`], CLI
//!   `samullm workload`) — apps arriving mid-run enter through the
//!   drift/replan path and the report gains per-app makespans.
//! * [`traffic`] — the open-loop serving layer behind
//!   [`spec::TrafficSpec`]: seeded arrival processes (Poisson, bursty
//!   on-off, trace replay), a bounded admission queue with reject/defer
//!   policies, and virtual-time weighted fair-share admission that makes
//!   per-app `weight` a real scheduling priority
//!   ([`session::SamuLlm::run_traffic`], CLI `samullm traffic`); runs
//!   report per-app TTFT/TPOT, latency percentiles and SLO attainment
//!   ([`metrics::latency`]).
//! * [`policy`] — the pluggable [`policy::Policy`] trait and the builtin
//!   implementations (`ours`, `max-heuristic`, `min-heuristic`,
//!   `round-robin`) behind a string registry.
//! * [`costmodel`] — the paper's sampling-then-simulation cost model:
//!   output-length eCDF sampling, FLOPs accounting (Eqs. 1–2), the linear
//!   per-iteration latency model (Eq. 5) fit against a profiled hardware
//!   ground truth, model-loading cost tables, and the runtime
//!   length-feedback loop ([`costmodel::online`]: conditional eCDFs +
//!   posterior refinement from observed completions).
//! * [`engine`] — the shared vLLM-style FCFS continuous-batching
//!   scheduling core ([`engine::sched::SchedCore`]) with a paged-KV block
//!   manager, plus its virtual-time instantiation
//!   ([`engine::EngineSim`]); both the planner (with *sampled* lengths)
//!   and the runner (with *true* lengths) step it.
//! * [`exec`] — the one execution API: the [`exec::ExecBackend`] trait
//!   with a unified timestamped event stream, implemented by the
//!   simulated substrate ([`exec::SimBackend`]) and the real PJRT
//!   serving path ([`exec::pjrt::PjrtBackend`]); select with
//!   `SamuLlm::builder().backend("sim"|"pjrt")` or `--backend`.
//! * [`graph`], [`plan`], [`planner`] — the application computation graph,
//!   execution plans/stages, and the greedy stage search (Algorithm 1).
//! * [`runner`] — the running phase: a virtual-clock orchestrator with the
//!   dynamic scheduler, communicator, preemption, NVLink-constrained
//!   minimum-reload placement of §4.3, and the opt-in length-feedback
//!   loop (`.online_refinement(true)`) that escalates stage repair to
//!   drift-triggered replanning.
//! * [`residency`] — the opt-in (`--oversubscribe`) model-residency
//!   subsystem: weight swap costs over the host links, time-sliced
//!   *packed* stages whose aggregate plans exceed the cluster, proactive
//!   offload of drained models and swap-vs-wait displacement.
//! * [`baselines`] — stage-construction math behind the §5 competitors.
//! * [`apps`], [`workload`] — the paper's applications (ensembling,
//!   routing, chain summary, mixed) and synthetic dataset generators
//!   matching the published workload statistics.
//! * [`runtime`], [`serve`] — the real path: load AOT-compiled TinyGPT
//!   HLO artifacts via PJRT and serve requests end-to-end with the shared
//!   continuous-batching scheduler (through [`exec::pjrt::PjrtBackend`]).
//! * [`harness`] — regenerates every figure/table of the paper's
//!   evaluation (see DESIGN.md for the experiment index).
//!
//! ## Quickstart
//!
//! ```no_run
//! use samullm::prelude::*;
//!
//! let session = SamuLlm::builder()
//!     .cluster(ClusterSpec::a100_node(8))
//!     .policy("ours")
//!     .seed(42)
//!     .build()?;
//! let report = session.run(&AppSpec::ensembling(1000, 256))?;
//! println!("end-to-end: {:.1}s", report.end_to_end_time);
//! # Ok::<(), anyhow::Error>(())
//! ```

#![warn(missing_docs)]

pub mod apps;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod costmodel;
pub mod engine;
pub mod exec;
pub mod graph;
pub mod harness;
pub mod metrics;
pub mod models;
pub mod plan;
pub mod planner;
pub mod policy;
pub mod residency;
pub mod runner;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod spec;
pub mod traffic;
pub mod util;
pub mod workload;

/// Commonly used items, re-exported for examples and binaries.
pub mod prelude {
    pub use crate::apps;
    pub use crate::cluster::ClusterSpec;
    pub use crate::costmodel::{CostModel, HardwareModel};
    pub use crate::exec::{ExecBackend, SimBackend};
    pub use crate::graph::AppGraph;
    pub use crate::metrics::RunReport;
    pub use crate::models::{ModelSpec, Registry};
    pub use crate::plan::{ExecPlan, Stage};
    pub use crate::planner::GreedyPlanner;
    pub use crate::policy::{self, Policy};
    pub use crate::runner::{self, Scenario};
    pub use crate::session::SamuLlm;
    pub use crate::spec::{
        AppSpec, ArrivalSpec, TrafficEntry, TrafficSpec, WorkloadEntry, WorkloadSpec,
    };
    pub use crate::util::rng::Rng;
    pub use crate::workload::Request;
}
