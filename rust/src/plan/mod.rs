//! Execution plans, stages and application plans (§3).
//!
//! * `P = (dp, tp)` — a model execution plan (Eq. 3);
//! * `E = ((M₁,P₁), …, (M_k,P_k))` — an execution stage (Eq. 4);
//! * `Φ = (E₁, …, E_m)` — an application execution plan.

use std::collections::HashSet;

use crate::cluster::ClusterSpec;
use crate::graph::AppGraph;
use crate::models::ModelSpec;

/// A model execution plan: data parallelism × tensor parallelism (Eq. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecPlan {
    /// Data-parallel replica count.
    pub dp: u32,
    /// Tensor-parallel degree per replica.
    pub tp: u32,
}

/// Tokens of KV cache one sequence must fit beside the weights for a plan
/// to be admissible ([`ExecPlan::is_valid_for`]): `min(max_seq,` this
/// constant`)`. Long-context models (≥ 8k) are not required to hold a full
/// max-length sequence — a 2048-token working set suffices to admit, the
/// same conservative watermark spirit as
/// [`crate::engine::sim::EngineConfig::standard`]'s block-level check
/// (which guards the engine's own budget at run time; this constant
/// guards plan enumeration). Changing it changes which `(dp, tp)` plans
/// the planner may even consider — see the admission-boundary unit test.
pub const KV_ADMISSION_TOKENS: u64 = 2048;

impl ExecPlan {
    /// The plan `(dp, tp)`.
    pub fn new(dp: u32, tp: u32) -> Self {
        ExecPlan { dp, tp }
    }

    /// GPUs consumed: `dp · tp`.
    pub fn n_gpus(&self) -> u32 {
        self.dp * self.tp
    }

    /// §3 validity: weights plus at least one sequence's KV must fit the
    /// per-GPU memory under `tp`. This is a per-model HBM constraint and
    /// holds regardless of oversubscription: the residency subsystem
    /// ([`crate::residency`]) time-slices *stages* whose aggregate demand
    /// exceeds the cluster, but a single model whose shard does not fit
    /// one GPU's memory can never run.
    pub fn is_valid_for(&self, spec: &ModelSpec, cluster: &ClusterSpec) -> bool {
        if self.dp == 0 || self.tp == 0 {
            return false;
        }
        if !self.tp.is_power_of_two() || self.tp > cluster.n_gpus {
            return false;
        }
        if self.n_gpus() > cluster.n_gpus {
            return false;
        }
        let weights = spec.weight_bytes_per_gpu(self.tp);
        if weights >= cluster.mem_bytes {
            return false;
        }
        // One working-set sequence's KV share per GPU must fit beside the
        // weights (capped at KV_ADMISSION_TOKENS for long-context models).
        let kv_one_seq =
            spec.kv_bytes_per_token(self.tp) * (spec.max_seq as u64).min(KV_ADMISSION_TOKENS);
        weights + kv_one_seq < cluster.mem_bytes
    }

    /// The smallest-footprint valid plan for a model (fewest GPUs,
    /// breaking ties toward lower tensor parallelism): `dp = 1` at the
    /// smallest `tp` whose shard fits. `None` when the model cannot run
    /// on this cluster at all.
    pub fn minimal(spec: &ModelSpec, cluster: &ClusterSpec) -> Option<ExecPlan> {
        cluster
            .valid_tp()
            .into_iter()
            .map(|tp| ExecPlan::new(1, tp))
            .find(|p| p.is_valid_for(spec, cluster))
    }

    /// Enumerate all valid plans for a model on a cluster.
    pub fn enumerate(spec: &ModelSpec, cluster: &ClusterSpec) -> Vec<ExecPlan> {
        let mut out = vec![];
        for tp in cluster.valid_tp() {
            for dp in 1..=cluster.n_gpus {
                let p = ExecPlan::new(dp, tp);
                if p.n_gpus() <= cluster.n_gpus && p.is_valid_for(spec, cluster) {
                    out.push(p);
                }
            }
        }
        out
    }
}

/// One (node, plan) entry of a stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageEntry {
    /// Graph node (LLM) id.
    pub node: usize,
    /// Execution plan the node runs with in this stage.
    pub plan: ExecPlan,
}

/// An execution stage (Eq. 4): nodes running concurrently with fixed plans.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stage {
    /// The (node, plan) pairs running concurrently.
    pub entries: Vec<StageEntry>,
}

impl Stage {
    /// Total GPUs the stage occupies.
    pub fn n_gpus(&self) -> u32 {
        self.entries.iter().map(|e| e.plan.n_gpus()).sum()
    }

    /// The set of node ids in the stage.
    pub fn nodes(&self) -> HashSet<usize> {
        self.entries.iter().map(|e| e.node).collect()
    }

    /// The plan `node` runs with in this stage, if it is scheduled.
    pub fn plan_of(&self, node: usize) -> Option<ExecPlan> {
        self.entries.iter().find(|e| e.node == node).map(|e| e.plan)
    }

    /// §3 stage validity: GPU budget + per-plan validity + the readiness
    /// rule (inputs finished or co-scheduled).
    pub fn is_valid(
        &self,
        graph: &AppGraph,
        finished: &HashSet<usize>,
        cluster: &ClusterSpec,
        registry: &crate::models::Registry,
    ) -> bool {
        self.is_valid_with(graph, finished, cluster, registry, false)
    }

    /// [`Stage::is_valid`] with a residency mode switch: when
    /// `oversubscribe` is set the aggregate GPU budget becomes soft (a
    /// *packed* stage's plans may sum past the cluster — the residency
    /// lowering time-slices it, [`crate::residency::run_packed_stage`]),
    /// while every per-model constraint (plan validity, readiness, HBM
    /// fit of each shard) stays hard.
    pub fn is_valid_with(
        &self,
        graph: &AppGraph,
        finished: &HashSet<usize>,
        cluster: &ClusterSpec,
        registry: &crate::models::Registry,
        oversubscribe: bool,
    ) -> bool {
        if self.entries.is_empty() || (!oversubscribe && self.n_gpus() > cluster.n_gpus) {
            return false;
        }
        let in_stage = self.nodes();
        if in_stage.len() != self.entries.len() {
            return false; // duplicate node
        }
        for e in &self.entries {
            if finished.contains(&e.node) {
                return false;
            }
            let Some(spec) = registry.get(&graph.nodes[e.node].model) else {
                return false;
            };
            if !e.plan.is_valid_for(spec, cluster) {
                return false;
            }
            if !graph.is_ready(e.node, finished, &in_stage) {
                return false;
            }
        }
        true
    }
}

/// A full application execution plan Φ (ordered stages).
#[derive(Debug, Clone, Default)]
pub struct AppPlan {
    /// Ordered execution stages.
    pub stages: Vec<Stage>,
}

impl AppPlan {
    /// Number of stages in the plan.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn setup() -> (ClusterSpec, Registry) {
        (ClusterSpec::a100_node(8), Registry::paper())
    }

    #[test]
    fn small_model_has_many_plans() {
        let (c, r) = setup();
        let plans = ExecPlan::enumerate(r.get("chatglm3-6b").unwrap(), &c);
        // dp in 1..=8 at tp=1 alone gives 8 plans.
        assert!(plans.len() >= 12, "{plans:?}");
        assert!(plans.contains(&ExecPlan::new(8, 1)));
        assert!(plans.contains(&ExecPlan::new(1, 8)));
    }

    #[test]
    fn huge_model_requires_tp() {
        let (c, r) = setup();
        let plans = ExecPlan::enumerate(r.get("llama-2-70b-chat").unwrap(), &c);
        assert!(!plans.is_empty());
        assert!(plans.iter().all(|p| p.tp >= 2), "70B needs >=2 GPUs: {plans:?}");
    }

    #[test]
    fn stage_gpu_budget_enforced() {
        let (c, r) = setup();
        let mut g = AppGraph::default();
        let a = g.add_node("chatglm3-6b", "a", 256);
        let b = g.add_node("mistral-7b-instruct", "b", 256);
        let fin = HashSet::new();
        let ok = Stage {
            entries: vec![
                StageEntry { node: a, plan: ExecPlan::new(4, 1) },
                StageEntry { node: b, plan: ExecPlan::new(2, 2) },
            ],
        };
        assert!(ok.is_valid(&g, &fin, &c, &r));
        let over = Stage {
            entries: vec![
                StageEntry { node: a, plan: ExecPlan::new(8, 1) },
                StageEntry { node: b, plan: ExecPlan::new(1, 2) },
            ],
        };
        assert!(!over.is_valid(&g, &fin, &c, &r));
    }

    #[test]
    fn stage_respects_dependencies() {
        let (c, r) = setup();
        let mut g = AppGraph::default();
        let a = g.add_node("vicuna-13b-v1.5", "sum", 900);
        let b = g.add_node("llama-2-70b-chat", "eval", 256);
        g.add_edge(a, b);
        let fin = HashSet::new();
        // b alone: input a neither finished nor co-scheduled -> invalid.
        let solo = Stage { entries: vec![StageEntry { node: b, plan: ExecPlan::new(1, 2) }] };
        assert!(!solo.is_valid(&g, &fin, &c, &r));
        // a + b together: pipeline parallelism -> valid.
        let both = Stage {
            entries: vec![
                StageEntry { node: a, plan: ExecPlan::new(2, 1) },
                StageEntry { node: b, plan: ExecPlan::new(1, 2) },
            ],
        };
        assert!(both.is_valid(&g, &fin, &c, &r));
        // b alone after a finished -> valid.
        let fin: HashSet<usize> = [a].into();
        assert!(solo.is_valid(&g, &fin, &c, &r));
    }

    #[test]
    fn kv_admission_boundary_is_pinned() {
        // Pins the exact admission watermark of `is_valid_for`: a (1, 1)
        // plan is admitted iff `weights + kv_per_token ·
        // min(max_seq, KV_ADMISSION_TOKENS) < mem_bytes`. Constructed so
        // the KV working set lands exactly on the boundary, this fails if
        // the constant (or the strict `<`) ever drifts.
        let (mut c, r) = setup();
        let spec = r.get("llama-2-70b-chat").unwrap();
        assert!(
            spec.max_seq as u64 > KV_ADMISSION_TOKENS,
            "boundary test needs a long-context model to exercise the cap"
        );
        let weights = spec.weight_bytes_per_gpu(1);
        let kv_working_set = spec.kv_bytes_per_token(1) * KV_ADMISSION_TOKENS;
        let p = ExecPlan::new(1, 1);
        // Exactly at the boundary: weights + kv == mem_bytes -> rejected
        // (strict `<`).
        c.mem_bytes = weights + kv_working_set;
        assert!(!p.is_valid_for(spec, &c));
        // One byte above the boundary -> admitted.
        c.mem_bytes = weights + kv_working_set + 1;
        assert!(p.is_valid_for(spec, &c));
        // Short-context models are capped by max_seq, not the constant.
        let small = ModelSpec { max_seq: 512, ..r.get("chatglm3-6b").unwrap().clone() };
        assert!((small.max_seq as u64) < KV_ADMISSION_TOKENS);
        let need = small.weight_bytes_per_gpu(1)
            + small.kv_bytes_per_token(1) * small.max_seq as u64;
        c.mem_bytes = need;
        assert!(!p.is_valid_for(&small, &c));
        c.mem_bytes = need + 1;
        assert!(p.is_valid_for(&small, &c));
    }

    #[test]
    fn minimal_plan_is_smallest_footprint() {
        let (c, r) = setup();
        // A 6B model fits a single GPU.
        assert_eq!(
            ExecPlan::minimal(r.get("chatglm3-6b").unwrap(), &c),
            Some(ExecPlan::new(1, 1))
        );
        // A 70B model needs tensor parallelism even for dp=1.
        let m = ExecPlan::minimal(r.get("llama-2-70b-chat").unwrap(), &c).unwrap();
        assert_eq!(m.dp, 1);
        assert!(m.tp >= 2);
        // Unrunnable model -> None.
        let mut tiny = c.clone();
        tiny.mem_bytes = 1 << 20;
        assert_eq!(ExecPlan::minimal(r.get("chatglm3-6b").unwrap(), &tiny), None);
    }

    #[test]
    fn oversubscribed_validity_softens_only_the_budget() {
        let (c, r) = setup();
        let mut g = AppGraph::default();
        let a = g.add_node("chatglm3-6b", "a", 256);
        let b = g.add_node("mistral-7b-instruct", "b", 256);
        let fin = HashSet::new();
        let over = Stage {
            entries: vec![
                StageEntry { node: a, plan: ExecPlan::new(8, 1) },
                StageEntry { node: b, plan: ExecPlan::new(1, 2) },
            ],
        };
        // 10 GPUs on an 8-GPU node: invalid normally, packable when
        // oversubscription is on.
        assert!(!over.is_valid(&g, &fin, &c, &r));
        assert!(over.is_valid_with(&g, &fin, &c, &r, true));
        // Per-model constraints remain hard either way: an invalid plan
        // (tp wider than the node) is rejected in both modes.
        let bad = Stage { entries: vec![StageEntry { node: a, plan: ExecPlan::new(1, 16) }] };
        assert!(!bad.is_valid_with(&g, &fin, &c, &r, true));
        // And so does readiness.
        let mut g2 = AppGraph::default();
        let x = g2.add_node("chatglm3-6b", "x", 256);
        let y = g2.add_node("mistral-7b-instruct", "y", 256);
        g2.add_edge(x, y);
        let solo = Stage { entries: vec![StageEntry { node: y, plan: ExecPlan::new(1, 1) }] };
        assert!(!solo.is_valid_with(&g2, &fin, &c, &r, true));
    }

    #[test]
    fn finished_nodes_cannot_rerun() {
        let (c, r) = setup();
        let mut g = AppGraph::default();
        let a = g.add_node("alpaca-13b", "a", 256);
        let fin: HashSet<usize> = [a].into();
        let s = Stage { entries: vec![StageEntry { node: a, plan: ExecPlan::new(1, 1) }] };
        assert!(!s.is_valid(&g, &fin, &c, &r));
    }
}
