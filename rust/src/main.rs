//! SamuLLM CLI: plan and run multi-LLM applications on the simulated
//! cluster, or serve the real TinyGPT through PJRT.
//!
//! Commands (offline build: hand-rolled arg parsing, no clap):
//!   samullm run    [--app A] [--policy P] [--n-requests N] [--max-out M]
//!                  [--n-docs D] [--gpus G] [--seed S]
//!                  [--no-preemption] [--known-lengths] [--gantt]
//!   samullm config <file.json>
//!   samullm serve  [--n-requests N] [--prompt-len L] [--max-new T]
//!                  [--artifacts DIR]

use anyhow::{anyhow, Result};

use samullm::apps::{chain_summary, ensembling, mixed, routing};
use samullm::baselines::PolicyKind;
use samullm::cluster::ClusterSpec;
use samullm::config::{AppConfig, ExperimentConfig, PolicyConfig};
use samullm::metrics::gantt;
use samullm::runner::{run_policy, RunOpts};

/// Tiny flag parser: `--key value` and boolean `--key`.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = vec![];
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value = argv.get(i + 1).map(|n| !n.starts_with("--")).unwrap_or(false);
                if next_is_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_policy(s: &str) -> Result<PolicyKind> {
    Ok(match s {
        "ours" | "samullm" => PolicyKind::SamuLlm,
        "max" | "max-heuristic" => PolicyKind::MaxHeuristic,
        "min" | "min-heuristic" => PolicyKind::MinHeuristic,
        other => return Err(anyhow!("unknown policy {other} (ours|max|min)")),
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let app = args.get_str("app", "ensembling");
    let n_requests: usize = args.get("n-requests", 1000);
    let max_out: u32 = args.get("max-out", 256);
    let n_docs: usize = args.get("n-docs", 100);
    let gpus: u32 = args.get("gpus", 8);
    let seed: u64 = args.get("seed", 42);
    let scenario = match app.as_str() {
        "ensembling" => ensembling::build(n_requests, max_out, seed),
        "routing" => routing::build(max_out.max(512), seed),
        "chain-summary" => chain_summary::build(n_docs, 2, max_out.max(100), seed),
        "mixed" => mixed::build(n_docs, n_requests, 900, max_out, 4, seed),
        other => return Err(anyhow!("unknown app {other}")),
    };
    let cluster = ClusterSpec::a100_node(gpus);
    let opts = RunOpts {
        seed,
        no_preemption: args.has("no-preemption"),
        known_lengths: args.has("known-lengths"),
        ..Default::default()
    };
    let report = run_policy(parse_policy(&args.get_str("policy", "ours"))?, &scenario, &cluster, &opts);
    println!("{}", report.to_json());
    if args.has("gantt") {
        println!("{}", gantt::render(&report, 80));
    }
    Ok(())
}

fn cmd_config(path: &str) -> Result<()> {
    let cfg = ExperimentConfig::from_json(&std::fs::read_to_string(path)?)?;
    let scenario = match cfg.app {
        AppConfig::Ensembling { n_requests, max_out } => {
            ensembling::build(n_requests, max_out, cfg.seed)
        }
        AppConfig::Routing { max_out, .. } => routing::build(max_out, cfg.seed),
        AppConfig::ChainSummary { n_docs, eval_times, max_out } => {
            chain_summary::build(n_docs, eval_times, max_out, cfg.seed)
        }
        AppConfig::Mixed { n_docs, n_ensemble_requests, summary_max_out, ensemble_max_out } => {
            mixed::build(n_docs, n_ensemble_requests, summary_max_out, ensemble_max_out, 4, cfg.seed)
        }
    };
    let policy = match cfg.policy {
        PolicyConfig::SamuLlm => PolicyKind::SamuLlm,
        PolicyConfig::MaxHeuristic => PolicyKind::MaxHeuristic,
        PolicyConfig::MinHeuristic => PolicyKind::MinHeuristic,
    };
    let cluster = ClusterSpec::a100_node(cfg.n_gpus);
    let opts = RunOpts {
        seed: cfg.seed,
        no_preemption: cfg.no_preemption,
        known_lengths: cfg.known_output_lengths,
        ..Default::default()
    };
    let report = run_policy(policy, &scenario, &cluster, &opts);
    println!("{}", report.to_json());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get_str("artifacts", "artifacts");
    let engine = samullm::serve::ServeEngine::load(std::path::Path::new(&artifacts))?;
    println!(
        "loaded TinyGPT on {} (batch={}, max_seq={})",
        engine.model().platform(),
        engine.model().batch(),
        engine.model().max_seq()
    );
    let reqs = samullm::serve::synthetic_requests(
        args.get("n-requests", 32),
        args.get("prompt-len", 16),
        args.get("max-new", 16),
        1,
    );
    let (_, m) = engine.serve(&reqs)?;
    println!(
        "served {} requests: {} tokens in {:.2}s -> {:.1} tok/s (prefills {}, decode steps {}, mean latency {:.2}s, p99 {:.2}s)",
        m.n_requests,
        m.total_tokens,
        m.wall_time,
        m.tokens_per_second,
        m.prefills,
        m.decode_steps,
        m.mean_latency,
        m.p99_latency
    );
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "run" => cmd_run(&args),
        "config" => {
            let path = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: samullm config <file.json>"))?;
            cmd_config(path)
        }
        "serve" => cmd_serve(&args),
        _ => {
            eprintln!(
                "usage: samullm <run|config|serve> [flags]\n  see rust/src/main.rs header for flags"
            );
            Ok(())
        }
    }
}
