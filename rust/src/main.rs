//! SamuLLM CLI: plan and run multi-LLM applications on the simulated
//! cluster, or serve the real TinyGPT through PJRT.
//!
//! Commands (offline build: hand-rolled arg parsing, no clap):
//!   samullm run    [--app A] [--policy P] [--n-requests N] [--max-out M]
//!                  [--n-docs D] [--eval-times E] [--gpus G] [--seed S]
//!                  [--no-preemption] [--known-lengths] [--gantt]
//!                  [--threads T] [--no-sim-cache] [--no-fast-step]
//!                  [--search-budget S]
//!                  [--online-refinement] [--replan-threshold X]
//!                  [--online-weight W] [--admit P]
//!                  [--oversubscribe] [--h2d-bw B] [--sequential-measured]
//!   samullm traffic --app NAME[:key=value]... [--duration S] [--warmup S]
//!                  [--queue-capacity C] [--queue-policy reject|defer]
//!                  [--admit-quantum Q] [...run flags]
//!   samullm config <file.json>
//!   samullm serve  [--n-requests N] [--prompt-len L] [--max-new T]
//!                  [--artifacts DIR] [--admit P]
//!
//! `--admit` picks the engine admission policy (fcfs | spjf |
//! multi-bin[:BINS] | skip-join[:QUEUES[:PROMOTE_S]]); fcfs is the
//! default and bit-identical to the pre-policy scheduler.
//!
//! Apps and policies resolve against the `spec`/`policy` registries
//! (`samullm run --app ?` / `--policy ?` lists them). Flags that don't
//! apply to the chosen app are rejected, not ignored; unparsable flag
//! values are errors, never silent defaults. Arbitrary user-defined
//! graphs run via `samullm config` with an `{"app": {"kind": "custom",
//! ...}}` spec.

use anyhow::{anyhow, Result};

use samullm::config::ExperimentConfig;
use samullm::metrics::gantt;
use samullm::policy;
use samullm::session::SamuLlm;
use samullm::spec::{self, AppParams, TrafficEntry, TrafficSpec, WorkloadEntry, WorkloadSpec};
use samullm::traffic::QueuePolicy;

/// Tiny flag parser: `--key value` and boolean `--key`. A token after a
/// flag counts as its value unless it is itself a flag; numeric tokens
/// (including negative ones like `-5`) are always values. A repeated
/// flag accumulates every value ([`Args::get_all`], for `workload`'s
/// `--app a --app b`); single-value accessors read the last occurrence.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, Vec<String>>,
}

/// A token starts a flag iff it is `--` followed by a non-numeric name.
/// Numeric-looking `--` tokens (`--5`) are consumed verbatim as values —
/// they then fail strict parsing with a clear error instead of being
/// misread as boolean flags.
fn is_flag_token(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some(rest) => !rest.is_empty() && rest.parse::<f64>().is_err(),
        None => false,
    }
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = vec![];
        let mut flags: std::collections::HashMap<String, Vec<String>> =
            std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if is_flag_token(a) {
                let key = a.trim_start_matches("--");
                let next_is_value = argv.get(i + 1).map(|n| !is_flag_token(n)).unwrap_or(false);
                if next_is_value {
                    flags.entry(key.to_string()).or_default().push(argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.entry(key.to_string()).or_default().push("true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    /// The last value given for `--key`, if any.
    fn last(&self, key: &str) -> Option<&String> {
        self.flags.get(key).and_then(|vs| vs.last())
    }

    /// Parse `--key`'s value, falling back to `default` only when the
    /// flag is absent. An unparsable value is an error, never a silent
    /// default (`--n-requests 10k` used to quietly run 1000).
    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.last(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|e| anyhow!("invalid value {v:?} for --{key}: {e}"))
            }
        }
    }

    /// Parse `--key`'s value if present (`None` when the flag is absent).
    fn get_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.last(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow!("invalid value {v:?} for --{key}: {e}")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.last(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Every value given for a repeated `--key`, in order.
    fn get_all(&self, key: &str) -> Vec<&String> {
        self.flags.get(key).map(|vs| vs.iter().collect()).unwrap_or_default()
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Reject flags outside `known` — a typo'd flag (`--known-length`)
    /// must error, not silently change the experiment.
    fn expect_flags(&self, known: &[&str]) -> Result<()> {
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .map(|k| k.as_str())
            .filter(|k| !known.contains(k))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        unknown.sort_unstable();
        let list = |xs: &[&str]| xs.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ");
        Err(anyhow!("unknown flag(s) {}; known: {}", list(&unknown), list(known)))
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "app",
        "policy",
        "backend",
        "artifacts",
        "n-requests",
        "max-out",
        "n-docs",
        "eval-times",
        "gpus",
        "seed",
        "no-preemption",
        "known-lengths",
        "threads",
        "no-sim-cache",
        "no-fast-step",
        "search-budget",
        "online-refinement",
        "replan-threshold",
        "online-weight",
        "admit",
        "oversubscribe",
        "h2d-bw",
        "sequential-measured",
        "gantt",
    ])?;
    let app = args.get_str("app", "ensembling");
    let params = AppParams {
        n_requests: args.get_opt("n-requests")?,
        max_out: args.get_opt("max-out")?,
        n_docs: args.get_opt("n-docs")?,
        eval_times: args.get_opt("eval-times")?,
        known_lengths: args.has("known-lengths"),
    };
    let app_spec = spec::from_cli(&app, &params)?;
    let mut builder = SamuLlm::builder()
        .gpus(args.get("gpus", 8)?)
        .policy(&args.get_str("policy", "ours"))
        .backend(&args.get_str("backend", "sim"))
        .seed(args.get("seed", 42)?)
        .no_preemption(args.has("no-preemption"))
        .known_lengths(args.has("known-lengths"))
        .threads(args.get("threads", 0)?)
        .sim_cache(!args.has("no-sim-cache"))
        .fast_step(!args.has("no-fast-step"))
        .online_refinement(args.has("online-refinement"))
        .admit_policy(&args.get_str("admit", "fcfs"))
        .oversubscribe(args.has("oversubscribe"))
        .sequential_measured(args.has("sequential-measured"));
    if let Some(b) = args.get_opt("search-budget")? {
        builder = builder.search_budget(b);
    }
    if let Some(t) = args.get_opt("replan-threshold")? {
        builder = builder.replan_threshold(t);
    }
    if let Some(w) = args.get_opt("online-weight")? {
        builder = builder.online_weight(w);
    }
    if let Some(bw) = args.get_opt("h2d-bw")? {
        builder = builder.h2d_bw(bw);
    }
    if let Some(dir) = args.last("artifacts") {
        builder = builder.artifacts_dir(dir.clone());
    }
    let session = builder.build()?;
    let report = session.run(&app_spec)?;
    println!("{}", report.to_json());
    if args.has("gantt") {
        println!("{}", gantt::render(&report, 80));
    }
    Ok(())
}

fn cmd_workload(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "app",
        "name",
        "policy",
        "backend",
        "artifacts",
        "gpus",
        "seed",
        "no-preemption",
        "threads",
        "no-sim-cache",
        "no-fast-step",
        "search-budget",
        "online-refinement",
        "replan-threshold",
        "online-weight",
        "admit",
        "oversubscribe",
        "h2d-bw",
        "sequential-measured",
        "gantt",
    ])?;
    let descriptors = args.get_all("app");
    if descriptors.is_empty() {
        return Err(anyhow!(
            "workload needs at least one --app descriptor, e.g. \
             --app ensembling:n-requests=2000 --app chain-summary:n-docs=100:arrival=30"
        ));
    }
    let entries = descriptors
        .iter()
        .map(|d| WorkloadEntry::parse_cli(d.as_str()))
        .collect::<Result<Vec<_>>>()?;
    let workload = WorkloadSpec {
        name: args.get_str("name", ""),
        entries,
    };
    let mut builder = SamuLlm::builder()
        .gpus(args.get("gpus", 8)?)
        .policy(&args.get_str("policy", "ours"))
        .backend(&args.get_str("backend", "sim"))
        .seed(args.get("seed", 42)?)
        .no_preemption(args.has("no-preemption"))
        .threads(args.get("threads", 0)?)
        .sim_cache(!args.has("no-sim-cache"))
        .fast_step(!args.has("no-fast-step"))
        .online_refinement(args.has("online-refinement"))
        .admit_policy(&args.get_str("admit", "fcfs"))
        .oversubscribe(args.has("oversubscribe"))
        .sequential_measured(args.has("sequential-measured"));
    if let Some(b) = args.get_opt("search-budget")? {
        builder = builder.search_budget(b);
    }
    if let Some(t) = args.get_opt("replan-threshold")? {
        builder = builder.replan_threshold(t);
    }
    if let Some(w) = args.get_opt("online-weight")? {
        builder = builder.online_weight(w);
    }
    if let Some(bw) = args.get_opt("h2d-bw")? {
        builder = builder.h2d_bw(bw);
    }
    if let Some(dir) = args.last("artifacts") {
        builder = builder.artifacts_dir(dir.clone());
    }
    let session = builder.build()?;
    let report = session.run_workload(&workload)?;
    println!("{}", report.to_json());
    if args.has("gantt") {
        println!("{}", gantt::render(&report, 80));
    }
    Ok(())
}

fn cmd_traffic(args: &Args) -> Result<()> {
    args.expect_flags(&[
        "app",
        "name",
        "duration",
        "warmup",
        "queue-capacity",
        "queue-policy",
        "admit-quantum",
        "policy",
        "backend",
        "artifacts",
        "gpus",
        "seed",
        "no-preemption",
        "threads",
        "no-sim-cache",
        "no-fast-step",
        "search-budget",
        "online-refinement",
        "replan-threshold",
        "online-weight",
        "admit",
        "gantt",
    ])?;
    let descriptors = args.get_all("app");
    if descriptors.is_empty() {
        return Err(anyhow!(
            "traffic needs at least one --app descriptor, e.g. \
             --app ensembling:rate=5:weight=2 --app chain-summary:rate=1:slo=60"
        ));
    }
    let entries = descriptors
        .iter()
        .map(|d| TrafficEntry::parse_cli(d.as_str()))
        .collect::<Result<Vec<_>>>()?;
    let mut traffic = TrafficSpec::new(entries);
    traffic.name = args.get_str("name", "");
    traffic.duration = args.get("duration", traffic.duration)?;
    traffic.warmup = args.get("warmup", traffic.warmup)?;
    traffic.queue_capacity = args.get("queue-capacity", traffic.queue_capacity)?;
    if let Some(p) = args.last("queue-policy") {
        traffic.queue_policy = QueuePolicy::parse(p)?;
    }
    traffic.admit_quantum = args.get("admit-quantum", traffic.admit_quantum)?;
    let mut builder = SamuLlm::builder()
        .gpus(args.get("gpus", 8)?)
        .policy(&args.get_str("policy", "ours"))
        .backend(&args.get_str("backend", "sim"))
        .seed(args.get("seed", 42)?)
        .no_preemption(args.has("no-preemption"))
        .threads(args.get("threads", 0)?)
        .sim_cache(!args.has("no-sim-cache"))
        .fast_step(!args.has("no-fast-step"))
        .online_refinement(args.has("online-refinement"))
        .admit_policy(&args.get_str("admit", "fcfs"));
    if let Some(b) = args.get_opt("search-budget")? {
        builder = builder.search_budget(b);
    }
    if let Some(t) = args.get_opt("replan-threshold")? {
        builder = builder.replan_threshold(t);
    }
    if let Some(w) = args.get_opt("online-weight")? {
        builder = builder.online_weight(w);
    }
    if let Some(dir) = args.last("artifacts") {
        builder = builder.artifacts_dir(dir.clone());
    }
    let session = builder.build()?;
    let report = session.run_traffic(&traffic)?;
    println!("{}", report.to_json());
    if args.has("gantt") {
        println!("{}", gantt::render(&report, 80));
    }
    Ok(())
}

fn cmd_config(path: &str) -> Result<()> {
    let cfg = ExperimentConfig::from_json(&std::fs::read_to_string(path)?)?;
    let mut builder = SamuLlm::builder()
        .gpus(cfg.n_gpus)
        .policy(&cfg.policy)
        .backend(&cfg.backend)
        .seed(cfg.seed)
        .no_preemption(cfg.no_preemption)
        .known_lengths(cfg.known_output_lengths)
        .threads(cfg.threads)
        .sim_cache(cfg.sim_cache)
        .fast_step(cfg.fast_step)
        .online_refinement(cfg.online_refinement)
        .replan_threshold(cfg.replan_threshold)
        .online_weight(cfg.online_weight)
        .admit_policy(&cfg.admit)
        .oversubscribe(cfg.oversubscribe)
        .sequential_measured(cfg.sequential_measured);
    if let Some(b) = cfg.search_budget {
        builder = builder.search_budget(b);
    }
    if let Some(bw) = cfg.h2d_bw {
        builder = builder.h2d_bw(bw);
    }
    if let Some(dir) = &cfg.artifacts {
        builder = builder.artifacts_dir(dir.clone());
    }
    let session = builder.build()?;
    let report = match (&cfg.app, &cfg.workload, &cfg.traffic) {
        (Some(app), None, None) => session.run(app)?,
        (None, Some(workload), None) => session.run_workload(workload)?,
        (None, None, Some(traffic)) => session.run_traffic(traffic)?,
        // from_json enforces exactly-one; unreachable for parsed configs.
        _ => return Err(anyhow!("config needs exactly one of app/workload/traffic")),
    };
    println!("{}", report.to_json());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_flags(&["n-requests", "prompt-len", "max-new", "artifacts", "admit"])?;
    let admit = samullm::engine::AdmitPolicy::parse(&args.get_str("admit", "fcfs"))?;
    let artifacts = args.get_str("artifacts", "artifacts");
    let mut backend = samullm::exec::pjrt::PjrtBackend::load(std::path::Path::new(&artifacts))?;
    println!(
        "loaded TinyGPT on {} (batch={}, max_seq={})",
        backend.platform(),
        backend.batch(),
        backend.max_seq()
    );
    let (reqs, prompts) = samullm::serve::synthetic_requests(
        args.get("n-requests", 32)?,
        args.get("prompt-len", 16)?,
        args.get("max-new", 16)?,
        1,
    );
    let (_, m) = samullm::serve::serve_requests_with(&mut backend, &reqs, &prompts, admit)?;
    println!(
        "served {} requests: {} tokens in {:.2}s -> {:.1} tok/s (prefills {}, decode steps {}, mean latency {:.2}s, p99 {:.2}s)",
        m.n_requests,
        m.total_tokens,
        m.wall_time,
        m.tokens_per_second,
        m.prefills,
        m.decode_steps,
        m.mean_latency,
        m.p99_latency
    );
    Ok(())
}

fn usage() -> String {
    let apps: Vec<String> = spec::builders()
        .iter()
        .map(|b| format!("    {:<14} {}", b.name, b.about))
        .collect();
    let policies: Vec<String> = policy::builtin()
        .iter()
        .map(|p| format!("    {:<14} {}", p.name, p.about))
        .collect();
    let backends: Vec<String> = samullm::exec::builtin()
        .iter()
        .map(|b| format!("    {:<14} {}", b.name, b.about))
        .collect();
    format!(
        "usage: samullm <run|workload|traffic|config|serve> [flags]\n\
         \n  samullm run    [--app A] [--policy P] [--backend B] [--n-requests N]\n\
         \x20                [--max-out M] [--n-docs D] [--eval-times E] [--gpus G]\n\
         \x20                [--seed S] [--no-preemption] [--known-lengths] [--gantt]\n\
         \x20                [--threads T] [--no-sim-cache]   (planner search speed knobs)\n\
         \x20                [--no-fast-step]  (per-token decode stepping; bit-identical\n\
         \x20                                  results, only slower simulation)\n\
         \x20                [--search-budget SECONDS]        (anytime planner: keep the\n\
         \x20                                  best plan found within the wall-clock budget)\n\
         \x20                [--online-refinement] [--replan-threshold X] [--online-weight W]\n\
         \x20                                  (runtime length-feedback loop, default off)\n\
         \x20                [--admit fcfs|spjf|multi-bin[:BINS]|skip-join[:QUEUES[:PROMOTE_S]]]\n\
         \x20                                  (engine admission policy, default fcfs)\n\
         \x20                [--oversubscribe] [--h2d-bw BYTES_PER_S]\n\
         \x20                                  (let plans exceed cluster HBM: stages\n\
         \x20                                  time-slice GPUs, paying modeled weight-swap\n\
         \x20                                  latency over the host link; default off)\n\
         \x20                [--sequential-measured]          (measured stages run nodes\n\
         \x20                                  one after another instead of the concurrent\n\
         \x20                                  event loop; sim runs ignore it)\n\
         \x20                [--artifacts DIR]                (pjrt backend artifacts)\n\
         \x20 samullm workload --app NAME[:key=value]... [--app ...] [--name N]\n\
         \x20                [--policy P] [--gpus G] [--seed S] [--gantt] [...run flags]\n\
         \x20                  N concurrent apps jointly planned on one cluster; per-app\n\
         \x20                  keys: the app's own knobs + arrival=T, seed=S, and weight=W\n\
         \x20                  (batch runs record weight in the per-app report; `samullm\n\
         \x20                  traffic` turns it into a real admission priority),\n\
         \x20                  e.g. --app ensembling:n-requests=2000 \\\n\
         \x20                       --app chain-summary:n-docs=100:arrival=30\n\
         \x20 samullm traffic --app NAME[:key=value]... [--app ...] [--name N]\n\
         \x20                [--duration S] [--warmup S] [--queue-capacity C]\n\
         \x20                [--queue-policy reject|defer] [--admit-quantum Q]\n\
         \x20                [--policy P] [--gpus G] [--seed S] [--gantt] [...run flags]\n\
         \x20                  open-loop serving: per-app arrival processes (keys: rate=R\n\
         \x20                  poisson | rate-on/mean-on/mean-off[/rate-off] bursty on-off\n\
         \x20                  | trace=FILE replay) feed a bounded admission queue;\n\
         \x20                  weight=W sets the app's weighted fair share, slo=S its\n\
         \x20                  latency target; reports per-app TTFT/TPOT, p50/p99 latency\n\
         \x20                  and SLO attainment, e.g. --app ensembling:rate=5:weight=2 \\\n\
         \x20                       --app chain-summary:rate=1:slo=60 --duration 300\n\
         \x20 samullm config <file.json>   (custom graphs via kind=custom; multi-app\n\
         \x20                               workloads via a top-level workload: [...];\n\
         \x20                               open-loop mixes via traffic: [...])\n\
         \x20 samullm serve  [--n-requests N] [--prompt-len L] [--max-new T] [--artifacts DIR]\n\
         \x20                [--admit P]      (admission policy for the real PJRT engine)\n\
         \napps:\n{}\npolicies:\n{}\nbackends:\n{}",
        apps.join("\n"),
        policies.join("\n"),
        backends.join("\n")
    )
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "run" => cmd_run(&args),
        "workload" => cmd_workload(&args),
        "traffic" => cmd_traffic(&args),
        "config" => {
            let path = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: samullm config <file.json>"))?;
            cmd_config(path)
        }
        "serve" => cmd_serve(&args),
        _ => {
            eprintln!("{}", usage());
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(&tokens.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn unparsable_values_are_errors_not_defaults() {
        let a = parse(&["--n-requests", "10k"]);
        let r: Result<usize> = a.get("n-requests", 1000);
        let err = r.unwrap_err().to_string();
        assert!(err.contains("10k"), "{err}");
        // Absent flag still falls back.
        assert_eq!(a.get::<u32>("gpus", 8).unwrap(), 8);
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse(&["--shift", "-5", "--flag"]);
        assert_eq!(a.get::<i64>("shift", 0).unwrap(), -5);
        assert!(a.has("flag"));
        // Numeric-looking double-dash tokens are consumed as values (and
        // later fail strict parsing) rather than becoming bogus flags.
        let b = parse(&["--delta", "--3.5"]);
        assert_eq!(b.last("delta").map(|s| s.as_str()), Some("--3.5"));
        assert!(b.get::<f64>("delta", 0.0).is_err());
    }

    #[test]
    fn repeated_flags_accumulate_and_last_wins_for_scalars() {
        let a = parse(&["--app", "ensembling:arrival=0", "--app", "chain-summary:arrival=30"]);
        let all: Vec<&str> = a.get_all("app").into_iter().map(|s| s.as_str()).collect();
        assert_eq!(all, vec!["ensembling:arrival=0", "chain-summary:arrival=30"]);
        // Scalar accessors read the last occurrence (unchanged behavior).
        let b = parse(&["--seed", "1", "--seed", "2"]);
        assert_eq!(b.get::<u64>("seed", 0).unwrap(), 2);
        assert!(parse(&[]).get_all("app").is_empty());
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let a = parse(&["--known-length"]); // typo: missing 's'
        let err = a.expect_flags(&["known-lengths", "seed"]).unwrap_err().to_string();
        assert!(err.contains("--known-length"), "{err}");
        assert!(err.contains("--known-lengths"), "{err}");
        assert!(parse(&["--seed", "7"]).expect_flags(&["known-lengths", "seed"]).is_ok());
    }

    #[test]
    fn boolean_and_valued_flags_mix() {
        let a = parse(&["--app", "routing", "--gantt", "--seed", "7", "pos"]);
        assert_eq!(a.get_str("app", "x"), "routing");
        assert!(a.has("gantt"));
        assert_eq!(a.get::<u64>("seed", 0).unwrap(), 7);
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn get_opt_distinguishes_absent_from_invalid() {
        let a = parse(&["--max-out", "512"]);
        assert_eq!(a.get_opt::<u32>("max-out").unwrap(), Some(512));
        assert_eq!(a.get_opt::<u32>("n-docs").unwrap(), None);
        assert!(parse(&["--max-out", "big"]).get_opt::<u32>("max-out").is_err());
    }
}
