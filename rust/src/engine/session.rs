//! Data-parallel model sessions and completion-time estimation.
//!
//! A model running under plan `(dp, tp)` is `dp` independent engine
//! replicas, each owning a round-robin share of the request stream. The
//! planner's "time for model M to finish workload R under plan P" (§4.1
//! "put them all together") is the max over replica completion times plus
//! any loading cost the caller accounts separately.

use super::sched::EngineEvent;
use super::sim::{EngineConfig, EngineSim, SimOutcome};
use super::EngineRequest;
use crate::costmodel::{flops, IterLatency};
use crate::models::ModelSpec;

/// Split requests round-robin (in FCFS order) across `dp` replicas.
/// Chained requests (fused self-loop nodes) must stay on one replica so
/// the chain can unblock locally — they are routed by their chain root.
pub fn split_round_robin(requests: &[EngineRequest], dp: u32) -> Vec<Vec<EngineRequest>> {
    let dp = dp.max(1) as usize;
    let mut parts: Vec<Vec<EngineRequest>> = vec![vec![]; dp];
    // First pass: assign chain roots & free requests round-robin; remember
    // id -> replica for chain members.
    let mut assignment: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
    let mut rr = 0usize;
    for r in requests {
        let part = if r.ready_time.is_infinite() {
            // Chain successor: placed in pass 2.
            continue;
        } else {
            let p = rr % dp;
            rr += 1;
            p
        };
        assignment.insert(r.id, part);
        parts[part].push(*r);
    }
    // Pass 2: walk chains from their (already-placed) roots.
    let mut changed = true;
    let mut placed: std::collections::HashSet<u64> = assignment.keys().copied().collect();
    while changed {
        changed = false;
        for r in requests {
            if placed.contains(&r.id) {
                if let Some(next) = r.chain_next {
                    if !placed.contains(&next) {
                        if let Some(nr) = requests.iter().find(|x| x.id == next) {
                            let p = assignment[&r.id];
                            assignment.insert(next, p);
                            parts[p].push(*nr);
                            placed.insert(next);
                            changed = true;
                        }
                    }
                }
            }
        }
    }
    // Orphaned blocked requests (their predecessor finished in an earlier
    // stage): treat as free, round-robin them.
    for r in requests {
        if !placed.contains(&r.id) {
            let p = rr % dp;
            rr += 1;
            parts[p].push(*r);
            placed.insert(r.id);
        }
    }
    parts
}

/// Result of estimating/running a model session.
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    /// Completion time of the slowest replica (absolute virtual time).
    pub finish_time: f64,
    /// Per-replica outcomes.
    pub replicas: Vec<SimOutcome>,
    /// Completion times across replicas: (request id, time).
    pub completions: Vec<(u64, f64)>,
    /// Unfinished requests drained from the replicas (empty if run to
    /// completion).
    pub remaining: Vec<EngineRequest>,
}

/// Run a `(dp, tp)` session to completion (or `deadline`), starting at
/// `start_time`.
#[allow(clippy::too_many_arguments)] // established engine-session signature
pub fn run_session(
    spec: &ModelSpec,
    dp: u32,
    tp: u32,
    lat: &dyn IterLatency,
    cfg: &EngineConfig,
    requests: &[EngineRequest],
    start_time: f64,
    deadline: Option<f64>,
    noise_seed: u64,
) -> SessionOutcome {
    run_session_traced(
        spec, dp, tp, lat, cfg, requests, start_time, deadline, noise_seed, 0, None,
    )
}

/// [`run_session`] with an optional unified event stream: per-replica
/// [`EngineEvent`]s are appended to `trace`, labelled with `node` and the
/// replica index. Results are identical whether or not events are
/// recorded.
#[allow(clippy::too_many_arguments)] // established engine-session signature
pub fn run_session_traced(
    spec: &ModelSpec,
    dp: u32,
    tp: u32,
    lat: &dyn IterLatency,
    cfg: &EngineConfig,
    requests: &[EngineRequest],
    start_time: f64,
    deadline: Option<f64>,
    noise_seed: u64,
    node: usize,
    trace: Option<&mut Vec<EngineEvent>>,
) -> SessionOutcome {
    let parts = split_round_robin(requests, dp);
    let mut finish: f64 = start_time;
    let mut replicas = vec![];
    let mut completions = vec![];
    let mut remaining = vec![];
    let mut trace = trace;
    for (ri, part) in parts.into_iter().enumerate() {
        if part.is_empty() {
            continue;
        }
        let mut sim =
            EngineSim::new(spec, tp, lat, cfg.clone(), part, start_time, noise_seed ^ ri as u64);
        if trace.is_some() {
            sim.enable_events(node, ri);
        }
        let out = sim.run(deadline);
        finish = finish.max(out.clock);
        completions.extend(sim.completions.iter().copied());
        if let Some(t) = trace.as_mut() {
            t.extend(sim.take_events());
        }
        remaining.extend(sim.drain_unfinished());
        replicas.push(out);
    }
    SessionOutcome { finish_time: finish, replicas, completions, remaining }
}

/// Estimated time for the session to finish its workload, relative to its
/// start (the planner's `t_{M,P}` of §3, excluding loading).
pub fn estimate_completion(
    spec: &ModelSpec,
    dp: u32,
    tp: u32,
    lat: &dyn IterLatency,
    cfg: &EngineConfig,
    requests: &[EngineRequest],
    start_time: f64,
) -> f64 {
    run_session(spec, dp, tp, lat, cfg, requests, start_time, None, 0).finish_time - start_time
}

/// Remaining FLOPs in a workload (re-prefill of carried progress included),
/// used for the stage-throughput objective `T_E = FLOPs_E / t_E`.
pub fn remaining_flops(spec: &ModelSpec, requests: &[EngineRequest]) -> f64 {
    let mut total = 0.0;
    for r in requests {
        if r.is_done() {
            continue;
        }
        let prompt = r.input_len + r.generated;
        total += flops::prefill_flops(spec, &[prompt]);
        let l = spec.n_layers as f64;
        let h = spec.hidden as f64;
        let c = spec.c_matmul();
        let rem = r.remaining() as f64;
        // Decode steps from ctx=prompt+1 .. prompt+remaining.
        let avg_ctx = prompt as f64 + (rem + 1.0) / 2.0;
        total += rem * l * (2.0 * c + 4.0 * h * avg_ctx);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::costmodel::HardwareModel;
    use crate::models::Registry;

    fn fixture() -> (ModelSpec, HardwareModel, EngineConfig) {
        let spec = Registry::paper().get("chatglm3-6b").unwrap().clone();
        let cluster = ClusterSpec::a100_node(8);
        let hw = HardwareModel::new(cluster.clone());
        let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
        (spec, hw, cfg)
    }

    fn reqs(n: usize) -> Vec<EngineRequest> {
        (0..n as u64).map(|i| EngineRequest::fresh(i, 20, 50 + (i % 100) as u32)).collect()
    }

    #[test]
    fn round_robin_covers_everything() {
        let rs = reqs(101);
        let parts = split_round_robin(&rs, 4);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 101);
        // Balanced within 1.
        let max = parts.iter().map(|p| p.len()).max().unwrap();
        let min = parts.iter().map(|p| p.len()).min().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn chains_stay_on_one_replica() {
        let mut rs = reqs(10);
        rs[0].chain_next = Some(5);
        rs[5].ready_time = EngineRequest::BLOCKED;
        rs[5].chain_next = Some(7);
        rs[7].ready_time = EngineRequest::BLOCKED;
        let parts = split_round_robin(&rs, 3);
        let find = |id: u64| parts.iter().position(|p| p.iter().any(|r| r.id == id)).unwrap();
        assert_eq!(find(0), find(5));
        assert_eq!(find(5), find(7));
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn more_replicas_help_sublinearly() {
        // A small workload split 8 ways leaves every replica with a tiny
        // batch: speedup must be visibly below 8x (the paper's premise).
        let (spec, hw, cfg) = fixture();
        let rs = reqs(160);
        let t1 = estimate_completion(&spec, 1, 1, &hw, &cfg, &rs, 0.0);
        let t8 = estimate_completion(&spec, 8, 1, &hw, &cfg, &rs, 0.0);
        assert!(t8 < t1);
        assert!(t1 / t8 < 6.0, "dp=8 speedup {} should be sublinear", t1 / t8);
        assert!(t1 / t8 > 1.2, "dp=8 speedup {} should still help", t1 / t8);
    }

    #[test]
    fn session_deadline_returns_remaining() {
        let (spec, hw, cfg) = fixture();
        let rs = reqs(500);
        let out = run_session(&spec, 2, 1, &hw, &cfg, &rs, 0.0, Some(1.0), 0);
        assert!(!out.remaining.is_empty());
        let done: usize = out.replicas.iter().map(|r| r.finished).sum();
        assert_eq!(done + out.remaining.len(), 500);
    }

    #[test]
    fn remaining_flops_accounting() {
        let (spec, _, _) = fixture();
        let fresh = reqs(10);
        // Done requests contribute nothing.
        let mut done = fresh.clone();
        for r in done.iter_mut() {
            r.generated = r.output_len;
        }
        assert_eq!(remaining_flops(&spec, &done), 0.0);
        // Recompute semantics: carried progress is re-prefilled, so
        // mid-progress work stays within ~15% of fresh work (same total
        // tokens to touch), while nearly-done requests clearly cost less
        // decode work than fresh ones.
        let mut half = fresh.clone();
        for r in half.iter_mut() {
            r.generated = r.output_len / 2;
        }
        let f0 = remaining_flops(&spec, &fresh);
        let f_half = remaining_flops(&spec, &half);
        assert!(f_half > 0.0);
        assert!((f_half - f0).abs() / f0 < 0.15, "half {f_half} vs fresh {f0}");
    }

    #[test]
    fn start_time_offsets_finish_time() {
        let (spec, hw, cfg) = fixture();
        let rs = reqs(50);
        let a = run_session(&spec, 1, 1, &hw, &cfg, &rs, 0.0, None, 0).finish_time;
        let b = run_session(&spec, 1, 1, &hw, &cfg, &rs, 100.0, None, 0).finish_time;
        assert!((b - a - 100.0).abs() < 1e-9);
    }
}
