//! vLLM-style inference engine simulator (§2, Fig. 3).
//!
//! Replays the engine's request-scheduling policy — FCFS admission,
//! continuous batching, paged-KV block management with preemption-by-
//! recompute — over a set of requests with known (sampled or true) output
//! lengths, pricing every iteration with an
//! [`crate::costmodel::IterLatency`] oracle.
//!
//! The same simulator serves two masters:
//! * the **planner** steps it with eCDF-*sampled* lengths and the fitted
//!   linear latency model (the paper's cost model), and
//! * the **runner** steps it with *true* lengths and the hardware
//!   ground-truth model (+ jitter) — this is the substitute for executing
//!   on real A100s.

pub mod sched;
pub mod session;
pub mod sim;

pub use sched::{
    AdmitPolicy, AdmitStats, EngineConfig, EngineEvent, EventKind, SimOutcome, StepExec, StepReq,
};
pub use sim::EngineSim;

/// A request as fed to the engine: lengths are already resolved (the
/// planner resolves by sampling, the runner by ground truth).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineRequest {
    /// Request id, unique within its node.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Resolved output length in tokens.
    pub output_len: u32,
    /// Virtual time at which the request may be admitted. Use
    /// [`EngineRequest::BLOCKED`] for chain successors that become ready
    /// only when their predecessor (same engine) completes.
    pub ready_time: f64,
    /// Decode tokens already produced in a previous stage (preempted
    /// requests re-enter with their progress; the engine re-prefills
    /// `input_len + generated` tokens — vLLM's recompute semantics).
    pub generated: u32,
    /// Id of the next request in a fused self-loop chain (§4.1: "if we
    /// fuse two models with dependency ... we dynamically update the ready
    /// time of the input requests of the fused model during simulation").
    pub chain_next: Option<u64>,
    /// True when this request's KV cache survived the stage boundary (the
    /// model kept its plan and placement): re-admission skips the
    /// re-prefill cost. Reset by in-engine preemption (recompute).
    pub kv_resident: bool,
    /// Predicted total output length for length-aware admission policies
    /// (sampled from the offline eCDF, refined by the online posterior).
    /// `0` = no prediction: policies fall back to `output_len`, which in
    /// planner estimate-states *is* the sampled prediction. Ignored by
    /// FCFS.
    pub predicted_len: u32,
}

impl EngineRequest {
    /// Sentinel ready time for requests waiting on an in-engine chain
    /// predecessor.
    pub const BLOCKED: f64 = f64::INFINITY;

    /// A request ready at time 0 with no progress, chain or resident KV.
    pub fn fresh(id: u64, input_len: u32, output_len: u32) -> Self {
        EngineRequest {
            id,
            input_len,
            output_len,
            ready_time: 0.0,
            generated: 0,
            chain_next: None,
            kv_resident: false,
            predicted_len: 0,
        }
    }

    /// Decode tokens still to generate.
    pub fn remaining(&self) -> u32 {
        self.output_len.saturating_sub(self.generated)
    }

    /// Whether the request generated its full output.
    pub fn is_done(&self) -> bool {
        self.generated >= self.output_len
    }
}
