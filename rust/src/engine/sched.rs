//! The extracted vLLM-v0 scheduling core, shared by every execution
//! backend (§4.3's running phase meets §4.2's simulator).
//!
//! [`SchedCore`] owns the *scheduling discipline* — FCFS admission bounded
//! by `max_num_seqs`/`max_batch_tokens`, continuous batching, paged-KV
//! block accounting with preemption-by-recompute, ready times and fused
//! request chains — but not the *iteration execution*. Each iteration is
//! delegated to a [`StepExec`]:
//!
//! * [`crate::engine::sim::OracleStep`] **prices** iterations with an
//!   [`crate::costmodel::IterLatency`] oracle in virtual time (supports
//!   the fast-forward decode-span approximation) — this is the classic
//!   [`crate::engine::EngineSim`], bit-identical to the pre-extraction
//!   simulator;
//! * [`crate::exec::pjrt::PjrtStep`] **executes** iterations on the real
//!   PJRT runtime ([`crate::runtime::TinyGpt`]) and reports measured
//!   wall-clock durations, so the same scheduler drives real serving.
//!
//! The core also emits a unified stream of timestamped [`EngineEvent`]s
//! (`Admitted`/`Prefill`/`Decode`/`Preempted`/`Completed`) from which the
//! runner and metrics layers build stage records, run reports and Gantt
//! charts identically for every backend.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use anyhow::{anyhow, Result};

use super::EngineRequest;
use crate::models::ModelSpec;
use crate::util::rng::Rng;

const GIB: f64 = (1u64 << 30) as f64;

/// Engine scheduling parameters (vLLM defaults).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Maximum running requests per iteration (vLLM `max_num_seqs`).
    pub max_num_seqs: usize,
    /// Maximum prompt tokens batched into one prefill iteration.
    pub max_batch_tokens: u64,
    /// Tokens per KV block.
    pub block_tokens: u32,
    /// Blocks kept free as admission watermark.
    pub watermark_blocks: u64,
    /// Enable event-jump acceleration for uniform decode runs (only
    /// honoured when the executor can price a span — see
    /// [`StepExec::decode_span`]).
    pub fast_forward: bool,
    /// Per-iteration multiplicative jitter σ (ground-truth realism);
    /// `None` for the planner's deterministic estimates.
    pub noise_sigma: Option<f64>,
    /// GPU memory available for KV blocks (set from cluster + weights).
    pub kv_bytes_budget: u64,
}

impl EngineConfig {
    /// Standard config for a model replica under `tp`, on a cluster with
    /// `mem_bytes` per GPU.
    ///
    /// Errors (instead of silently producing a zero-block KV budget that
    /// would wedge the engine with no admissible requests) when the
    /// weights don't fit beside the per-GPU memory, or when the remaining
    /// KV budget cannot hold even one block above the admission watermark.
    pub fn standard(spec: &ModelSpec, tp: u32, mem_bytes: u64) -> Result<Self> {
        let weights = spec.weight_bytes_per_gpu(tp);
        if weights >= mem_bytes {
            return Err(anyhow!(
                "{}: weights need {:.1} GiB/GPU under tp={tp} but only {:.1} GiB are \
                 available — no KV budget remains (use a larger tp or more memory)",
                spec.name,
                weights as f64 / GIB,
                mem_bytes as f64 / GIB,
            ));
        }
        let kv_budget = (mem_bytes - weights) * tp as u64;
        let cfg = EngineConfig {
            max_num_seqs: 256,
            max_batch_tokens: 4096,
            block_tokens: 16,
            watermark_blocks: 8,
            fast_forward: true,
            noise_sigma: None,
            kv_bytes_budget: kv_budget,
        };
        let block_bytes = cfg.block_tokens as u64 * spec.kv_bytes_per_token(tp) * tp as u64;
        if kv_budget < block_bytes.saturating_mul(cfg.watermark_blocks + 1) {
            return Err(anyhow!(
                "{}: KV budget {:.2} GiB under tp={tp} cannot hold one block above the \
                 admission watermark — the engine would never admit a request",
                spec.name,
                kv_budget as f64 / GIB,
            ));
        }
        Ok(cfg)
    }

    /// A plan is infeasible if the weights don't fit or not even one
    /// max-length sequence's KV fits beside them (§3's validity rule).
    pub fn feasible(&self, spec: &ModelSpec, tp: u32, mem_bytes: u64) -> bool {
        if spec.weight_bytes_per_gpu(tp) >= mem_bytes {
            return false;
        }
        let per_seq = spec.kv_bytes_per_token(tp) * tp as u64 * spec.max_seq as u64;
        self.kv_bytes_budget >= per_seq / 4
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ReqState {
    Blocked,
    Waiting,
    Running,
    Done,
}

#[derive(Debug, Clone)]
struct Slot {
    req: EngineRequest,
    state: ReqState,
    /// Tokens currently materialised in KV (prompt + generated so far).
    ctx: u32,
    blocks: u64,
    /// Admission order, for preempt-latest-first.
    admit_seq: u64,
}

/// Aggregate result of driving a scheduling core to (partial) completion.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimOutcome {
    /// Requests that completed.
    pub finished: usize,
    /// Virtual time at the end of the run (absolute for stage replays;
    /// relative when the simulation started at a canonical origin, as in
    /// [`crate::runner::state::ExecState::simulate_node_fast`]).
    pub clock: f64,
    /// Time spent actually executing iterations (vs waiting for inputs).
    pub busy_time: f64,
    /// Decode iterations executed (fast-forwarded runs count each step).
    pub decode_iterations: u64,
    /// Prefill iterations executed.
    pub prefill_iterations: u64,
    /// Preemption-by-recompute events.
    pub preemptions: u64,
    /// Output tokens produced.
    pub tokens_generated: u64,
}

/// A scheduler-side view of one request inside an iteration, handed to the
/// [`StepExec`] that prices or executes the iteration.
#[derive(Debug, Clone, Copy)]
pub struct StepReq {
    /// Request id.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Decode tokens produced before this iteration.
    pub generated: u32,
    /// Tokens materialised in KV (prompt + generated, +1 once admitted).
    pub ctx: u32,
    /// Whether the request's KV survived a stage boundary (re-admission
    /// skips the re-prefill *price*; real executors rebuild state anyway).
    pub kv_resident: bool,
}

/// One timestamped entry of the unified engine event stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineEvent {
    /// Graph node the engine runs (0 when standalone).
    pub node: usize,
    /// Data-parallel replica index within the node.
    pub replica: usize,
    /// Clock at which the event was recorded (virtual seconds for the sim
    /// backend, measured seconds for real backends).
    pub t: f64,
    /// What happened.
    pub kind: EventKind,
}

/// Event payloads of the unified stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A waiting request joined a prefill batch.
    Admitted {
        /// Request id.
        req: u64,
    },
    /// A prefill iteration executed.
    Prefill {
        /// Requests in the batch.
        batch: usize,
        /// Prompt tokens processed (KV-resident re-admissions count 1).
        new_tokens: u64,
        /// Iteration latency in seconds (jitter included).
        dur: f64,
    },
    /// One decode iteration — or a fast-forwarded uniform run of `iters`.
    Decode {
        /// Running requests in the batch.
        batch: usize,
        /// Iterations covered by this event (1 unless fast-forwarded).
        iters: u32,
        /// Total KV context across the batch before the iteration(s).
        total_ctx: u64,
        /// Longest context in the batch before the iteration(s).
        max_ctx: u32,
        /// Total latency of the covered iterations (jitter included).
        dur: f64,
    },
    /// A running request was preempted by recompute (KV blocks reclaimed).
    Preempted {
        /// Request id.
        req: u64,
    },
    /// A request generated its full output.
    Completed {
        /// Request id.
        req: u64,
    },
}

/// How one scheduler iteration is priced or executed. See module docs.
pub trait StepExec {
    /// Execute (or price) one prefill iteration over `admitted` (in FCFS
    /// batch order); `running` is the set of already-running requests
    /// (real executors rebuild device state for them). Returns the
    /// iteration latency in seconds, before jitter.
    fn prefill(&mut self, admitted: &[StepReq], running: &[StepReq]) -> f64;

    /// Execute (or price) one decode iteration over `running`. Returns the
    /// iteration latency in seconds, before jitter.
    fn decode(&mut self, running: &[StepReq]) -> f64;

    /// Price a uniform run of `n` decode iterations at once (fast-forward
    /// acceleration, midpoint-context pricing). Return `None` when every
    /// iteration must actually execute (real hardware); the core then
    /// falls back to single-iteration decodes.
    fn decode_span(&mut self, running: &[StepReq], n: u32) -> Option<f64>;

    /// Cheap single-iteration latency estimate at the current context,
    /// used only to bound fast-forward jumps against a deadline. Never
    /// executes anything.
    fn estimate_decode(&self, running: &[StepReq]) -> f64;

    /// The first error the executor encountered, if any (real executors
    /// surface device failures here; pricing executors never fail).
    fn take_error(&mut self) -> Option<anyhow::Error> {
        None
    }
}

type ReadyKey = Reverse<(u64, u64, usize)>; // (ready_time bits, fcfs seq, slot)

/// The shared single-replica scheduling core. See module docs.
pub struct SchedCore<X: StepExec> {
    exec: X,
    cfg: EngineConfig,
    blocks_total: u64,
    free_blocks: u64,
    slots: Vec<Slot>,
    waiting: BinaryHeap<ReadyKey>,
    running: Vec<usize>,
    id_to_slot: HashMap<u64, usize>,
    clock: f64,
    outcome: SimOutcome,
    admit_counter: u64,
    fcfs_counter: u64,
    noise: Option<Rng>,
    /// Active run() deadline — bounds fast-forward jumps so a stage replay
    /// never overshoots its stage-end boundary.
    deadline: Option<f64>,
    events: Option<Vec<EngineEvent>>,
    ev_node: usize,
    ev_replica: usize,
    scratch_admit: Vec<StepReq>,
    scratch_run: Vec<StepReq>,
    /// Completion times per request id (for the communicator).
    pub completions: Vec<(u64, f64)>,
    /// Optional (clock, running-count) trace for Fig. 3.
    pub iter_trace: Option<Vec<(f64, usize)>>,
}

/// Fill `dst` with step views of the slots named by `idxs`, in order.
fn fill_step_reqs(dst: &mut Vec<StepReq>, slots: &[Slot], idxs: &[usize]) {
    dst.clear();
    dst.extend(idxs.iter().map(|&i| {
        let s = &slots[i];
        StepReq {
            id: s.req.id,
            input_len: s.req.input_len,
            generated: s.req.generated,
            ctx: s.ctx,
            kv_resident: s.req.kv_resident,
        }
    }));
}

impl<X: StepExec> SchedCore<X> {
    /// Build a scheduling core over `requests`, starting its clock at
    /// `start_time`. KV capacity is `cfg.kv_bytes_budget / block_bytes`
    /// blocks (`block_bytes` = bytes one KV block occupies — model- and
    /// tp-dependent for priced simulations, nominal for real executors).
    pub fn with_exec(
        exec: X,
        cfg: EngineConfig,
        block_bytes: u64,
        requests: Vec<EngineRequest>,
        start_time: f64,
        noise_seed: u64,
    ) -> Self {
        let blocks_total = (cfg.kv_bytes_budget / block_bytes.max(1)).max(1);
        let noise = cfg.noise_sigma.map(|_| Rng::new(noise_seed ^ 0x5EED_0E0E));
        let mut core = SchedCore {
            exec,
            cfg,
            blocks_total,
            free_blocks: blocks_total,
            slots: Vec::with_capacity(requests.len()),
            waiting: BinaryHeap::with_capacity(requests.len()),
            running: vec![],
            id_to_slot: HashMap::with_capacity(requests.len()),
            clock: start_time,
            outcome: SimOutcome::default(),
            admit_counter: 0,
            fcfs_counter: 0,
            noise,
            deadline: None,
            events: None,
            ev_node: 0,
            ev_replica: 0,
            scratch_admit: vec![],
            scratch_run: vec![],
            completions: vec![],
            iter_trace: None,
        };
        for req in requests {
            core.push_request(req);
        }
        core
    }

    fn push_request(&mut self, req: EngineRequest) {
        let idx = self.slots.len();
        let state = if req.is_done() {
            self.outcome.finished += 1;
            ReqState::Done
        } else if req.ready_time.is_infinite() {
            ReqState::Blocked
        } else {
            ReqState::Waiting
        };
        self.id_to_slot.insert(req.id, idx);
        self.slots.push(Slot { req, state, ctx: 0, blocks: 0, admit_seq: 0 });
        if state == ReqState::Waiting {
            self.enqueue_waiting(idx);
        }
    }

    fn enqueue_waiting(&mut self, idx: usize) {
        let t = self.slots[idx].req.ready_time.max(0.0);
        self.waiting.push(Reverse((t.to_bits(), self.fcfs_counter, idx)));
        self.fcfs_counter += 1;
    }

    /// Current virtual (or measured) time.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Total KV blocks the replica owns.
    pub fn blocks_total(&self) -> u64 {
        self.blocks_total
    }

    /// KV blocks currently free.
    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    /// Whether every request completed.
    pub fn is_done(&self) -> bool {
        self.slots.iter().all(|s| s.state == ReqState::Done)
    }

    /// Requests not yet completed.
    pub fn n_unfinished(&self) -> usize {
        self.slots.iter().filter(|s| s.state != ReqState::Done).count()
    }

    /// Mutable access to the step executor (backends read errors and
    /// harvest produced tokens through this).
    pub fn exec_mut(&mut self) -> &mut X {
        &mut self.exec
    }

    /// Record timestamped [`EngineEvent`]s for this run, labelled with the
    /// given graph node and replica index.
    pub fn enable_events(&mut self, node: usize, replica: usize) {
        self.ev_node = node;
        self.ev_replica = replica;
        self.events = Some(vec![]);
    }

    /// Take the recorded event stream (empty unless
    /// [`SchedCore::enable_events`] was called before running).
    pub fn take_events(&mut self) -> Vec<EngineEvent> {
        self.events.take().unwrap_or_default()
    }

    fn emit(&mut self, kind: EventKind) {
        if let Some(evs) = &mut self.events {
            evs.push(EngineEvent {
                node: self.ev_node,
                replica: self.ev_replica,
                t: self.clock,
                kind,
            });
        }
    }

    fn jitter(&mut self, t: f64) -> f64 {
        match (&mut self.noise, self.cfg.noise_sigma) {
            (Some(rng), Some(sigma)) => t * (1.0 + sigma * rng.normal()).max(0.2),
            _ => t,
        }
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.cfg.block_tokens as u64)
    }

    /// Earliest ready time among waiting requests.
    fn next_ready(&self) -> Option<f64> {
        self.waiting.peek().map(|Reverse((bits, _, _))| f64::from_bits(*bits))
    }

    /// Try to build a prefill batch (FCFS by ready time, token/block bounded).
    fn admit(&mut self) -> Vec<usize> {
        let mut batch = vec![];
        let mut batch_tokens = 0u64;
        while let Some(&Reverse((bits, _, idx))) = self.waiting.peek() {
            if self.running.len() + batch.len() >= self.cfg.max_num_seqs {
                break;
            }
            if f64::from_bits(bits) > self.clock {
                break; // FCFS: don't skip over not-yet-ready requests
            }
            let slot = &self.slots[idx];
            debug_assert_eq!(slot.state, ReqState::Waiting);
            let prompt = slot.req.input_len + slot.req.generated;
            // KV-resident requests re-enter without re-prefilling their
            // carried context; they only cost one admission token.
            let prefill_tokens = if slot.req.kv_resident && slot.req.generated > 0 {
                1
            } else {
                prompt
            };
            if batch_tokens + prefill_tokens as u64 > self.cfg.max_batch_tokens
                && !batch.is_empty()
            {
                break;
            }
            let need = self.blocks_for(prompt + 1);
            if self.free_blocks < need + self.cfg.watermark_blocks {
                break;
            }
            self.waiting.pop();
            self.free_blocks -= need;
            let slot = &mut self.slots[idx];
            slot.blocks = need;
            slot.ctx = prompt + 1; // prefill emits the first output token
            slot.state = ReqState::Running;
            slot.admit_seq = self.admit_counter;
            self.admit_counter += 1;
            batch_tokens += prefill_tokens as u64;
            batch.push(idx);
        }
        batch
    }

    fn finish(&mut self, idx: usize) {
        let (id, next) = {
            let slot = &mut self.slots[idx];
            slot.state = ReqState::Done;
            self.free_blocks += slot.blocks;
            slot.blocks = 0;
            (slot.req.id, slot.req.chain_next)
        };
        self.outcome.finished += 1;
        self.completions.push((id, self.clock));
        self.emit(EventKind::Completed { req: id });
        if let Some(nid) = next {
            if let Some(&nidx) = self.id_to_slot.get(&nid) {
                if self.slots[nidx].state == ReqState::Blocked {
                    self.slots[nidx].req.ready_time = self.clock;
                    self.slots[nidx].state = ReqState::Waiting;
                    self.enqueue_waiting(nidx);
                }
            }
        }
    }

    /// Preempt the most recently admitted running request (recompute).
    fn preempt_latest(&mut self) -> bool {
        let Some(pos) = self
            .running
            .iter()
            .enumerate()
            .max_by_key(|&(_, &i)| self.slots[i].admit_seq)
            .map(|(p, _)| p)
        else {
            return false;
        };
        let idx = self.running.swap_remove(pos);
        let slot = &mut self.slots[idx];
        self.free_blocks += slot.blocks;
        slot.blocks = 0;
        slot.ctx = 0;
        slot.state = ReqState::Waiting;
        slot.req.ready_time = self.clock;
        slot.req.kv_resident = false; // recompute: KV is gone
        let id = slot.req.id;
        self.outcome.preemptions += 1;
        self.emit(EventKind::Preempted { req: id });
        self.enqueue_waiting(idx);
        true
    }

    fn record_trace(&mut self) {
        if let Some(tr) = &mut self.iter_trace {
            tr.push((self.clock, self.running.len()));
        }
    }

    /// Run one scheduling step. Returns `false` if nothing could be done
    /// right now (caller decides whether to idle-advance).
    pub fn step(&mut self) -> bool {
        let batch = self.admit();
        if !batch.is_empty() {
            if self.events.is_some() {
                for &i in &batch {
                    let id = self.slots[i].req.id;
                    self.emit(EventKind::Admitted { req: id });
                }
            }
            fill_step_reqs(&mut self.scratch_admit, &self.slots, &batch);
            fill_step_reqs(&mut self.scratch_run, &self.slots, &self.running);
            let t = self.exec.prefill(&self.scratch_admit, &self.scratch_run);
            let t = self.jitter(t);
            self.clock += t;
            self.outcome.busy_time += t;
            self.outcome.prefill_iterations += 1;
            if self.events.is_some() {
                let new_tokens: u64 = self
                    .scratch_admit
                    .iter()
                    .map(|r| {
                        if r.kv_resident && r.generated > 0 {
                            1
                        } else {
                            (r.input_len + r.generated) as u64
                        }
                    })
                    .sum();
                self.emit(EventKind::Prefill { batch: batch.len(), new_tokens, dur: t });
            }
            for &i in &batch {
                self.slots[i].req.generated += 1;
                self.outcome.tokens_generated += 1;
                if self.slots[i].req.is_done() {
                    self.finish(i);
                } else {
                    self.running.push(i);
                }
            }
            self.record_trace();
            return true;
        }

        if self.running.is_empty() {
            return false;
        }

        if self.cfg.fast_forward {
            self.decode_run()
        } else {
            self.decode_once()
        }
    }

    /// One decode iteration, exact.
    fn decode_once(&mut self) -> bool {
        // Grow KV; preempt on OOM.
        let mut i = 0;
        while i < self.running.len() {
            let idx = self.running[i];
            let need_block = self.slots[idx].ctx % self.cfg.block_tokens == 0;
            if need_block {
                while self.free_blocks < 1 {
                    if self.running.len() <= 1 || !self.preempt_latest() {
                        break;
                    }
                }
                if self.slots[idx].state != ReqState::Running {
                    // preempt_latest evicted `idx` itself; running[i] now
                    // holds a different request — revisit this position.
                    continue;
                }
                if self.free_blocks >= 1 {
                    self.free_blocks -= 1;
                    self.slots[idx].blocks += 1;
                }
            }
            i += 1;
        }
        let batch = self.running.len();
        if batch == 0 {
            return false;
        }
        fill_step_reqs(&mut self.scratch_run, &self.slots, &self.running);
        let t = self.exec.decode(&self.scratch_run);
        let t = self.jitter(t);
        self.clock += t;
        self.outcome.busy_time += t;
        self.outcome.decode_iterations += 1;
        self.outcome.tokens_generated += batch as u64;
        if self.events.is_some() {
            let total_ctx: u64 = self.scratch_run.iter().map(|r| r.ctx as u64).sum();
            let max_ctx = self.scratch_run.iter().map(|r| r.ctx).max().unwrap_or(0);
            self.emit(EventKind::Decode { batch, iters: 1, total_ctx, max_ctx, dur: t });
        }
        let mut j = 0;
        while j < self.running.len() {
            let idx = self.running[j];
            let slot = &mut self.slots[idx];
            slot.ctx += 1;
            slot.req.generated += 1;
            if slot.req.is_done() {
                self.running.swap_remove(j);
                self.finish(idx);
            } else {
                j += 1;
            }
        }
        self.record_trace();
        true
    }

    /// Fast path: jump over `n` uniform decode iterations where `n` is
    /// bounded by the next completion, the next admission-ready prompt,
    /// and the block budget. The executor prices the run at its midpoint
    /// context; executors that must materialise every token decline the
    /// span and the core falls back to exact single iterations.
    fn decode_run(&mut self) -> bool {
        let batch = self.running.len();
        let min_remaining = self
            .running
            .iter()
            .map(|&i| self.slots[i].req.remaining())
            .min()
            .unwrap_or(0)
            .max(1);
        // Admission is impossible while the running set is full, no matter
        // how many prompts are ready — only a completion (already bounded
        // by `min_remaining`) can open a slot.
        let until_ready = if self.running.len() >= self.cfg.max_num_seqs {
            u32::MAX
        } else {
            match self.next_ready() {
                Some(t) if t > self.clock => u32::MAX,
                Some(_) => 1, // a prompt is admissible now -> go exact
                None => u32::MAX,
            }
        };
        let spare = self.free_blocks.saturating_sub(self.cfg.watermark_blocks);
        let until_oom = if spare == 0 {
            1
        } else {
            ((spare * self.cfg.block_tokens as u64) / batch as u64).max(1).min(u32::MAX as u64)
                as u32
        };
        let mut n = min_remaining.min(until_oom).min(until_ready).max(1);
        // Deadline bound: estimate the per-iteration cost at the current
        // context and cap the jump so the clock lands at most one
        // iteration past the deadline (stage replays depend on this).
        if let Some(d) = self.deadline {
            fill_step_reqs(&mut self.scratch_run, &self.slots, &self.running);
            let t_est = self.exec.estimate_decode(&self.scratch_run).max(1e-9);
            let room = ((d - self.clock) / t_est).ceil();
            if room < n as f64 {
                n = (room.max(1.0)) as u32;
            }
        }
        let n = n;
        if n <= 2 {
            return self.decode_once();
        }

        fill_step_reqs(&mut self.scratch_run, &self.slots, &self.running);
        let Some(t_span) = self.exec.decode_span(&self.scratch_run, n) else {
            return self.decode_once();
        };
        let t = self.jitter(t_span);
        self.clock += t;
        self.outcome.busy_time += t;
        self.outcome.decode_iterations += n as u64;
        self.outcome.tokens_generated += n as u64 * batch as u64;
        if self.events.is_some() {
            let total_ctx: u64 = self.scratch_run.iter().map(|r| r.ctx as u64).sum();
            let max_ctx = self.scratch_run.iter().map(|r| r.ctx).max().unwrap_or(0);
            self.emit(EventKind::Decode { batch, iters: n, total_ctx, max_ctx, dur: t });
        }

        let bt = self.cfg.block_tokens as u64;
        let mut blocks_used = 0u64;
        let mut j = 0;
        while j < self.running.len() {
            let idx = self.running[j];
            let slot = &mut self.slots[idx];
            let old_ctx = slot.ctx;
            slot.ctx += n;
            slot.req.generated += n;
            let new_blocks = (slot.ctx as u64).div_ceil(bt) - (old_ctx as u64).div_ceil(bt);
            blocks_used += new_blocks;
            slot.blocks += new_blocks;
            if slot.req.is_done() {
                self.running.swap_remove(j);
                self.finish(idx);
            } else {
                j += 1;
            }
        }
        self.free_blocks = self.free_blocks.saturating_sub(blocks_used);
        self.record_trace();
        true
    }

    /// Advance the clock while nothing is runnable (pipeline idling).
    /// Returns `false` if there is nothing to wait for (done, or blocked
    /// on a chain predecessor that lives in another engine).
    pub fn idle_until_ready(&mut self) -> bool {
        match self.next_ready() {
            Some(t) if t > self.clock => {
                self.clock = t;
                true
            }
            Some(_) => true,
            None => false,
        }
    }

    /// Run to completion (or until `deadline`). Returns the outcome so far.
    ///
    /// If requests are ready but can never be admitted (e.g. a
    /// hand-crafted config with a zero KV budget), the run bails out with
    /// the partial outcome instead of spinning forever.
    pub fn run(&mut self, deadline: Option<f64>) -> SimOutcome {
        self.deadline = deadline;
        loop {
            if let Some(d) = deadline {
                if self.clock >= d {
                    break;
                }
            }
            if self.step() {
                continue;
            }
            let before = self.clock;
            if !self.idle_until_ready() {
                break;
            }
            if self.clock <= before && !self.step() {
                // Wedged: ready work that can never be admitted.
                break;
            }
        }
        self.deadline = None;
        self.outcome.clock = self.clock;
        self.outcome.clone()
    }

    /// Extract unfinished requests (for stage transitions / preemption).
    /// Running requests keep their generated progress but lose KV state —
    /// they will re-prefill `input + generated` tokens when re-admitted.
    pub fn drain_unfinished(&mut self) -> Vec<EngineRequest> {
        let mut out = vec![];
        for slot in &mut self.slots {
            if slot.state != ReqState::Done {
                out.push(slot.req);
                slot.state = ReqState::Done;
            }
        }
        self.running.clear();
        self.waiting.clear();
        out
    }

    /// The accumulated outcome so far.
    pub fn outcome(&self) -> &SimOutcome {
        &self.outcome
    }

    /// Record a (clock, running-count) point per iteration (Fig. 3).
    pub fn enable_trace(&mut self) {
        self.iter_trace = Some(vec![]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::models::Registry;

    #[test]
    fn standard_config_errors_when_weights_do_not_fit() {
        let reg = Registry::paper();
        let spec = reg.get("llama-2-70b-chat").unwrap();
        // A 70B model cannot fit a single 16 GiB GPU under tp=1.
        let err = EngineConfig::standard(spec, 1, 16u64 << 30).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("llama-2-70b-chat"), "{msg}");
        assert!(msg.contains("tp=1"), "{msg}");
        // The same model under a sane cluster is fine.
        assert!(EngineConfig::standard(spec, 4, ClusterSpec::a100_node(8).mem_bytes).is_ok());
    }

    #[test]
    fn standard_config_errors_on_watermark_starvation() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap();
        // Barely more memory than the weights: KV budget below one block
        // above the watermark must be rejected, not wedge the engine.
        let weights = spec.weight_bytes_per_gpu(1);
        let err = EngineConfig::standard(spec, 1, weights + 1024).unwrap_err();
        assert!(err.to_string().contains("watermark"), "{err}");
    }

    #[test]
    fn run_bails_out_instead_of_wedging_on_zero_blocks() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = crate::costmodel::HardwareModel::new(ClusterSpec::a100_node(8));
        let mut cfg =
            EngineConfig::standard(&spec, 1, ClusterSpec::a100_node(8).mem_bytes).unwrap();
        // Hand-craft a degenerate budget the constructor would reject.
        cfg.kv_bytes_budget = 1;
        let reqs = vec![EngineRequest::fresh(0, 64, 32)];
        let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        let out = sim.run(None);
        assert_eq!(out.finished, 0, "nothing is admissible");
        assert!(!sim.is_done());
    }

    #[test]
    fn event_stream_covers_the_request_lifecycle() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let cluster = ClusterSpec::a100_node(8);
        let hw = crate::costmodel::HardwareModel::new(cluster.clone());
        let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
        let reqs: Vec<EngineRequest> = (0..20).map(|i| EngineRequest::fresh(i, 25, 40)).collect();
        let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        sim.enable_events(3, 1);
        let out = sim.run(None);
        let events = sim.take_events();
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.node == 3 && e.replica == 1));
        let count = |f: fn(&EventKind) -> bool| events.iter().filter(|e| f(&e.kind)).count();
        assert_eq!(count(|k| matches!(k, EventKind::Admitted { .. })), 20);
        assert_eq!(count(|k| matches!(k, EventKind::Completed { .. })), 20);
        let prefills = count(|k| matches!(k, EventKind::Prefill { .. })) as u64;
        assert_eq!(prefills, out.prefill_iterations);
        let decode_iters: u64 = events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Decode { iters, .. } => Some(iters as u64),
                _ => None,
            })
            .sum();
        assert_eq!(decode_iters, out.decode_iterations);
        // Event durations add up to the busy time.
        let dur: f64 = events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Prefill { dur, .. } | EventKind::Decode { dur, .. } => Some(dur),
                _ => None,
            })
            .sum();
        assert!((dur - out.busy_time).abs() < 1e-9, "dur {dur} vs busy {}", out.busy_time);
        // Timestamps are monotone.
        assert!(events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn events_do_not_change_results() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let cluster = ClusterSpec::a100_node(8);
        let hw = crate::costmodel::HardwareModel::new(cluster.clone());
        let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
        let reqs: Vec<EngineRequest> =
            (0..64).map(|i| EngineRequest::fresh(i, 20, 30 + (i % 17) as u32)).collect();
        let quiet =
            crate::engine::EngineSim::new(&spec, 1, &hw, cfg.clone(), reqs.clone(), 0.0, 0)
                .run(None);
        let mut traced = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        traced.enable_events(0, 0);
        let loud = traced.run(None);
        assert_eq!(quiet.clock.to_bits(), loud.clock.to_bits());
        assert_eq!(quiet, loud);
    }
}
