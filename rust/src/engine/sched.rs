//! The extracted vLLM-v0 scheduling core, shared by every execution
//! backend (§4.3's running phase meets §4.2's simulator).
//!
//! [`SchedCore`] owns the *scheduling discipline* — FCFS admission bounded
//! by `max_num_seqs`/`max_batch_tokens`, continuous batching, paged-KV
//! block accounting with preemption-by-recompute, ready times and fused
//! request chains — but not the *iteration execution*. Each iteration is
//! delegated to a [`StepExec`]:
//!
//! * [`crate::engine::sim::OracleStep`] **prices** iterations with an
//!   [`crate::costmodel::IterLatency`] oracle in virtual time (supports
//!   the exact aggregated fast-step path via [`StepExec::decode_tick`])
//!   — this is the classic [`crate::engine::EngineSim`], bit-identical
//!   to the pre-extraction simulator;
//! * [`crate::exec::pjrt::PjrtStep`] **executes** iterations on the real
//!   PJRT runtime ([`crate::runtime::TinyGpt`]) and reports measured
//!   wall-clock durations, so the same scheduler drives real serving.
//!
//! The core also emits a unified stream of timestamped [`EngineEvent`]s
//! (`Admitted`/`Prefill`/`Decode`/`Preempted`/`Completed`) from which the
//! runner and metrics layers build stage records, run reports and Gantt
//! charts identically for every backend.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use anyhow::{anyhow, Result};

use super::EngineRequest;
use crate::models::ModelSpec;
use crate::util::rng::Rng;

const GIB: f64 = (1u64 << 30) as f64;

/// Default bin count for [`AdmitPolicy::MultiBin`].
pub const DEFAULT_MULTI_BIN_BINS: u32 = 4;
/// Default queue count for [`AdmitPolicy::SkipJoinMlfq`].
pub const DEFAULT_SKIP_JOIN_QUEUES: u32 = 4;
/// Default starvation-bounding promotion clock (virtual seconds) for
/// [`AdmitPolicy::SkipJoinMlfq`].
pub const DEFAULT_SKIP_JOIN_PROMOTE: f64 = 30.0;

/// How the scheduling core orders the waiting queue when it builds a
/// prefill batch.
///
/// `Fcfs` is the historical discipline and stays byte-identical to the
/// pre-policy engine. The length-aware policies consume per-request
/// *predicted* output lengths ([`EngineRequest::predicted_len`], sampled
/// from the offline eCDF and refined mid-run by the online posterior) and
/// may admit a later arrival ahead of an earlier one; unlike FCFS they
/// *skip* candidates that don't fit the token/block budget instead of
/// treating them as a barrier, so a batch is never held hostage by one
/// long prompt.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum AdmitPolicy {
    /// First-come-first-served by ready time (the vLLM default).
    #[default]
    Fcfs,
    /// Shortest-predicted-job-first on the predicted remaining length.
    Spjf,
    /// Group candidates into `bins` geometric predicted-length bins and
    /// admit short bins first (arrival order within a bin) — multi-bin
    /// batching, arXiv 2412.04504.
    MultiBin {
        /// Number of length bins (≥ 1; 1 degenerates to FCFS order).
        bins: u32,
    },
    /// FastServe-style skip-join MLFQ: a candidate joins the queue level
    /// matching its predicted length and is promoted to the front after
    /// waiting `promote_after` seconds, bounding starvation.
    SkipJoinMlfq {
        /// Number of queue levels (≥ 1).
        queues: u32,
        /// Seconds a candidate may wait before promotion to level 0.
        promote_after: f64,
    },
}

impl AdmitPolicy {
    /// Parse a CLI/config spelling: `fcfs` (alias `fifo`), `spjf` (alias
    /// `sjf`), `multi-bin[:BINS]` (alias `multibin`) and
    /// `skip-join[:QUEUES[:PROMOTE_S]]` (aliases `skip-join-mlfq`,
    /// `mlfq`).
    pub fn parse(s: &str) -> Result<Self> {
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or_default();
        let args: Vec<&str> = parts.collect();
        let arg_u32 = |i: usize, default: u32| -> Result<u32> {
            match args.get(i) {
                None => Ok(default),
                Some(v) => v
                    .parse::<u32>()
                    .map_err(|e| anyhow!("bad admission policy arg {v:?} in {s:?}: {e}")),
            }
        };
        let arg_f64 = |i: usize, default: f64| -> Result<f64> {
            match args.get(i) {
                None => Ok(default),
                Some(v) => v
                    .parse::<f64>()
                    .map_err(|e| anyhow!("bad admission policy arg {v:?} in {s:?}: {e}")),
            }
        };
        let too_many = |max: usize| -> Result<()> {
            if args.len() > max {
                return Err(anyhow!("too many arguments in admission policy {s:?}"));
            }
            Ok(())
        };
        match head {
            "fcfs" | "fifo" => {
                too_many(0)?;
                Ok(AdmitPolicy::Fcfs)
            }
            "spjf" | "sjf" => {
                too_many(0)?;
                Ok(AdmitPolicy::Spjf)
            }
            "multi-bin" | "multibin" => {
                too_many(1)?;
                let bins = arg_u32(0, DEFAULT_MULTI_BIN_BINS)?;
                if bins == 0 {
                    return Err(anyhow!("multi-bin needs at least 1 bin"));
                }
                Ok(AdmitPolicy::MultiBin { bins })
            }
            "skip-join" | "skip-join-mlfq" | "mlfq" => {
                too_many(2)?;
                let queues = arg_u32(0, DEFAULT_SKIP_JOIN_QUEUES)?;
                let promote_after = arg_f64(1, DEFAULT_SKIP_JOIN_PROMOTE)?;
                if queues == 0 {
                    return Err(anyhow!("skip-join needs at least 1 queue"));
                }
                if !(promote_after > 0.0) {
                    return Err(anyhow!("skip-join promotion clock must be > 0"));
                }
                Ok(AdmitPolicy::SkipJoinMlfq { queues, promote_after })
            }
            _ => Err(anyhow!(
                "unknown admission policy {s:?}; known: {}",
                AdmitPolicy::names()
            )),
        }
    }

    /// Canonical spelling that round-trips through [`AdmitPolicy::parse`].
    pub fn name(&self) -> String {
        match self {
            AdmitPolicy::Fcfs => "fcfs".to_string(),
            AdmitPolicy::Spjf => "spjf".to_string(),
            AdmitPolicy::MultiBin { bins } => format!("multi-bin:{bins}"),
            AdmitPolicy::SkipJoinMlfq { queues, promote_after } => {
                format!("skip-join:{queues}:{promote_after}")
            }
        }
    }

    /// The accepted spellings, for CLI help and error messages.
    pub fn names() -> &'static str {
        "fcfs | spjf | multi-bin[:BINS] | skip-join[:QUEUES[:PROMOTE_S]]"
    }

    /// Geometric length-bin index used by `MultiBin` and the skip-join
    /// queue levels: bin edges at 16, 64, 256, … predicted tokens.
    /// Monotone non-decreasing in `predicted`, clamped to `bins - 1`.
    pub fn bin_index(predicted: u32, bins: u32) -> u32 {
        let mut bin = 0u32;
        let mut edge = 16u64;
        while bin + 1 < bins && predicted as u64 > edge {
            bin += 1;
            edge = edge.saturating_mul(4);
        }
        bin
    }
}

/// Counters of length-aware admission behaviour, all zero under FCFS
/// (which preserves the byte-identical default path).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdmitStats {
    /// Admissions that overtook an earlier-arrived, still-waiting request.
    pub queue_jumps: u64,
    /// Skip-join starvation promotions applied at admission.
    pub promotions: u64,
    /// Longest ready-to-admission wait observed (seconds).
    pub max_queue_wait: f64,
}

impl AdmitStats {
    /// Fold another replica's counters into this one.
    pub fn absorb(&mut self, other: &AdmitStats) {
        self.queue_jumps += other.queue_jumps;
        self.promotions += other.promotions;
        if other.max_queue_wait > self.max_queue_wait {
            self.max_queue_wait = other.max_queue_wait;
        }
    }
}

/// Engine scheduling parameters (vLLM defaults).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Maximum running requests per iteration (vLLM `max_num_seqs`).
    pub max_num_seqs: usize,
    /// Maximum prompt tokens batched into one prefill iteration.
    pub max_batch_tokens: u64,
    /// Tokens per KV block.
    pub block_tokens: u32,
    /// Blocks kept free as admission watermark.
    pub watermark_blocks: u64,
    /// Enable aggregated decode stepping (default on): while batch
    /// composition is provably stable — no completion due, no admission
    /// possible, no KV-block exhaustion within the window — the core
    /// advances over whole decode windows with O(1) bookkeeping per
    /// iteration, pricing each step *exactly* via
    /// [`StepExec::decode_tick`]. Results are bit-identical to per-token
    /// stepping; only wall-clock changes. Executors that must
    /// materialise every token (real hardware) decline the tick and run
    /// per-token regardless.
    pub fast_step: bool,
    /// Per-iteration multiplicative jitter σ (ground-truth realism);
    /// `None` for the planner's deterministic estimates.
    pub noise_sigma: Option<f64>,
    /// GPU memory available for KV blocks (set from cluster + weights).
    pub kv_bytes_budget: u64,
    /// Waiting-queue admission order (default [`AdmitPolicy::Fcfs`],
    /// byte-identical to the pre-policy engine).
    pub admit: AdmitPolicy,
}

impl EngineConfig {
    /// Standard config for a model replica under `tp`, on a cluster with
    /// `mem_bytes` per GPU.
    ///
    /// Errors (instead of silently producing a zero-block KV budget that
    /// would wedge the engine with no admissible requests) when the
    /// weights don't fit beside the per-GPU memory, or when the remaining
    /// KV budget cannot hold even one block above the admission watermark.
    pub fn standard(spec: &ModelSpec, tp: u32, mem_bytes: u64) -> Result<Self> {
        let weights = spec.weight_bytes_per_gpu(tp);
        if weights >= mem_bytes {
            return Err(anyhow!(
                "{}: weights need {:.1} GiB/GPU under tp={tp} but only {:.1} GiB are \
                 available — no KV budget remains (use a larger tp or more memory)",
                spec.name,
                weights as f64 / GIB,
                mem_bytes as f64 / GIB,
            ));
        }
        let kv_budget = (mem_bytes - weights) * tp as u64;
        let cfg = EngineConfig {
            max_num_seqs: 256,
            max_batch_tokens: 4096,
            block_tokens: 16,
            watermark_blocks: 8,
            fast_step: true,
            noise_sigma: None,
            kv_bytes_budget: kv_budget,
            admit: AdmitPolicy::Fcfs,
        };
        let block_bytes = cfg.block_tokens as u64 * spec.kv_bytes_per_token(tp) * tp as u64;
        if kv_budget < block_bytes.saturating_mul(cfg.watermark_blocks + 1) {
            return Err(anyhow!(
                "{}: KV budget {:.2} GiB under tp={tp} cannot hold one block above the \
                 admission watermark — the engine would never admit a request",
                spec.name,
                kv_budget as f64 / GIB,
            ));
        }
        Ok(cfg)
    }

    /// A plan is infeasible if the weights don't fit or not even one
    /// max-length sequence's KV fits beside them (§3's validity rule).
    pub fn feasible(&self, spec: &ModelSpec, tp: u32, mem_bytes: u64) -> bool {
        if spec.weight_bytes_per_gpu(tp) >= mem_bytes {
            return false;
        }
        let per_seq = spec.kv_bytes_per_token(tp) * tp as u64 * spec.max_seq as u64;
        self.kv_bytes_budget >= per_seq / 4
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ReqState {
    Blocked,
    Waiting,
    Running,
    Done,
}

#[derive(Debug, Clone)]
struct Slot {
    req: EngineRequest,
    state: ReqState,
    /// Tokens currently materialised in KV (prompt + generated so far).
    ctx: u32,
    blocks: u64,
    /// Admission order, for preempt-latest-first.
    admit_seq: u64,
}

/// Aggregate result of driving a scheduling core to (partial) completion.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimOutcome {
    /// Requests that completed.
    pub finished: usize,
    /// Virtual time at the end of the run (absolute for stage replays;
    /// relative when the simulation started at a canonical origin, as in
    /// [`crate::runner::state::ExecState::simulate_node_fast`]).
    pub clock: f64,
    /// Time spent actually executing iterations (vs waiting for inputs).
    pub busy_time: f64,
    /// Decode iterations executed (aggregated fast-step windows count
    /// every covered iteration).
    pub decode_iterations: u64,
    /// Prefill iterations executed.
    pub prefill_iterations: u64,
    /// Preemption-by-recompute events.
    pub preemptions: u64,
    /// Output tokens produced.
    pub tokens_generated: u64,
    /// Length-aware admission counters (all zero under FCFS).
    pub admit: AdmitStats,
}

/// A scheduler-side view of one request inside an iteration, handed to the
/// [`StepExec`] that prices or executes the iteration.
#[derive(Debug, Clone, Copy)]
pub struct StepReq {
    /// Request id.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Decode tokens produced before this iteration.
    pub generated: u32,
    /// Tokens materialised in KV (prompt + generated, +1 once admitted).
    pub ctx: u32,
    /// Whether the request's KV survived a stage boundary (re-admission
    /// skips the re-prefill *price*; real executors rebuild state anyway).
    pub kv_resident: bool,
}

/// One timestamped entry of the unified engine event stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineEvent {
    /// Graph node the engine runs (0 when standalone).
    pub node: usize,
    /// Data-parallel replica index within the node.
    pub replica: usize,
    /// Clock at which the event was recorded (virtual seconds for the sim
    /// backend, measured seconds for real backends).
    pub t: f64,
    /// What happened.
    pub kind: EventKind,
}

/// Event payloads of the unified stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A waiting request joined a prefill batch.
    Admitted {
        /// Request id.
        req: u64,
    },
    /// A prefill iteration executed.
    Prefill {
        /// Requests in the batch.
        batch: usize,
        /// Prompt tokens processed (KV-resident re-admissions count 1).
        new_tokens: u64,
        /// Iteration latency in seconds (jitter included).
        dur: f64,
    },
    /// One decode iteration. The aggregated fast-step path emits one
    /// event per covered iteration, so streams agree bit-for-bit with
    /// per-token stepping.
    Decode {
        /// Running requests in the batch.
        batch: usize,
        /// Iterations covered by this event (always 1 from the
        /// scheduling core; retained for consumers that fold runs).
        iters: u32,
        /// Total KV context across the batch before the iteration(s).
        total_ctx: u64,
        /// Longest context in the batch before the iteration(s).
        max_ctx: u32,
        /// Total latency of the covered iterations (jitter included).
        dur: f64,
    },
    /// A running request was preempted by recompute (KV blocks reclaimed).
    Preempted {
        /// Request id.
        req: u64,
    },
    /// A request generated its full output.
    Completed {
        /// Request id.
        req: u64,
    },
    /// A host-cached model's weights were swapped back onto its GPUs
    /// (model-residency subsystem; emitted at stage boundaries, never by
    /// the scheduling core itself).
    SwapIn {
        /// Total weight bytes moved across the node's GPUs.
        bytes: u64,
        /// Transfer duration in seconds (h2d link).
        dur: f64,
    },
    /// A model's weights were evicted to host memory to free HBM for a
    /// waiting model (proactive offload).
    SwapOut {
        /// Total weight bytes moved across the node's GPUs.
        bytes: u64,
        /// Transfer duration in seconds (d2h link).
        dur: f64,
    },
}

/// How one scheduler iteration is priced or executed. See module docs.
pub trait StepExec {
    /// Execute (or price) one prefill iteration over `admitted` (in FCFS
    /// batch order); `running` is the set of already-running requests
    /// (real executors rebuild device state for them). Returns the
    /// iteration latency in seconds, before jitter.
    fn prefill(&mut self, admitted: &[StepReq], running: &[StepReq]) -> f64;

    /// Execute (or price) one decode iteration over `running`. Returns the
    /// iteration latency in seconds, before jitter.
    fn decode(&mut self, running: &[StepReq]) -> f64;

    /// Price one decode iteration at an explicit batch composition —
    /// `batch` running requests whose KV contexts sum to `total_ctx`,
    /// the longest being `max_ctx` — without materialising per-request
    /// views. The aggregated fast-step path calls this once per covered
    /// iteration with O(1) bookkeeping; implementations must return
    /// exactly what [`StepExec::decode`] would return for the same
    /// composition (the core depends on that for bit-identity). Return
    /// `None` when every iteration must actually execute (real
    /// hardware); the core then falls back to per-token stepping.
    fn decode_tick(&mut self, batch: usize, total_ctx: u64, max_ctx: u32) -> Option<f64>;

    /// The first error the executor encountered, if any (real executors
    /// surface device failures here; pricing executors never fail).
    fn take_error(&mut self) -> Option<anyhow::Error> {
        None
    }
}

type ReadyKey = Reverse<(u64, u64, usize)>; // (ready_time bits, fcfs seq, slot)

/// The shared single-replica scheduling core. See module docs.
pub struct SchedCore<X: StepExec> {
    exec: X,
    cfg: EngineConfig,
    blocks_total: u64,
    free_blocks: u64,
    slots: Vec<Slot>,
    waiting: BinaryHeap<ReadyKey>,
    running: Vec<usize>,
    id_to_slot: HashMap<u64, usize>,
    clock: f64,
    outcome: SimOutcome,
    admit_counter: u64,
    fcfs_counter: u64,
    noise: Option<Rng>,
    /// Active run() deadline — breaks aggregated decode windows at the
    /// same clock a per-token replay would stop at, so a stage replay
    /// never overshoots its stage-end boundary.
    deadline: Option<f64>,
    events: Option<Vec<EngineEvent>>,
    ev_node: usize,
    ev_replica: usize,
    scratch_admit: Vec<StepReq>,
    scratch_run: Vec<StepReq>,
    /// KV-fit bound of the previous aggregated decode window, carried as
    /// the bracket seed for the next window's binary search (see
    /// [`SchedCore::decode_fast`]). Purely an accelerator: outcomes are
    /// bit-identical to an unseeded search.
    fast_k: u64,
    /// Completion times per request id (for the communicator).
    pub completions: Vec<(u64, f64)>,
    /// Optional (clock, running-count) trace for Fig. 3.
    pub iter_trace: Option<Vec<(f64, usize)>>,
}

/// Fill `dst` with step views of the slots named by `idxs`, in order.
fn fill_step_reqs(dst: &mut Vec<StepReq>, slots: &[Slot], idxs: &[usize]) {
    dst.clear();
    dst.extend(idxs.iter().map(|&i| {
        let s = &slots[i];
        StepReq {
            id: s.req.id,
            input_len: s.req.input_len,
            generated: s.req.generated,
            ctx: s.ctx,
            kv_resident: s.req.kv_resident,
        }
    }));
}

impl<X: StepExec> SchedCore<X> {
    /// Build a scheduling core over `requests`, starting its clock at
    /// `start_time`. KV capacity is `cfg.kv_bytes_budget / block_bytes`
    /// blocks (`block_bytes` = bytes one KV block occupies — model- and
    /// tp-dependent for priced simulations, nominal for real executors).
    pub fn with_exec(
        exec: X,
        cfg: EngineConfig,
        block_bytes: u64,
        requests: Vec<EngineRequest>,
        start_time: f64,
        noise_seed: u64,
    ) -> Self {
        let blocks_total = (cfg.kv_bytes_budget / block_bytes.max(1)).max(1);
        let noise = cfg.noise_sigma.map(|_| Rng::new(noise_seed ^ 0x5EED_0E0E));
        let mut core = SchedCore {
            exec,
            cfg,
            blocks_total,
            free_blocks: blocks_total,
            slots: Vec::with_capacity(requests.len()),
            waiting: BinaryHeap::with_capacity(requests.len()),
            running: vec![],
            id_to_slot: HashMap::with_capacity(requests.len()),
            clock: start_time,
            outcome: SimOutcome::default(),
            admit_counter: 0,
            fcfs_counter: 0,
            noise,
            deadline: None,
            events: None,
            ev_node: 0,
            ev_replica: 0,
            scratch_admit: vec![],
            scratch_run: vec![],
            fast_k: 0,
            completions: vec![],
            iter_trace: None,
        };
        for req in requests {
            core.push_request(req);
        }
        core
    }

    /// Inject a request into a core that is already running. The
    /// concurrent measured path uses this to forward cross-node
    /// completions mid-flight: the moment a producer request finishes,
    /// its dependent enters the consumer's engine with its measured
    /// ready time, instead of waiting for the whole producer node to
    /// drain. Admission follows the same `(ready_time, FCFS arrival)`
    /// key as construction-time requests.
    pub fn inject(&mut self, req: EngineRequest) {
        self.push_request(req);
    }

    /// Install (or clear) the deadline consulted by aggregated decode
    /// windows and stepping callers. [`SchedCore::run`] manages this
    /// itself; incremental drivers ([`crate::exec::ExecBackend::step_node`])
    /// set it once up front.
    pub fn set_deadline(&mut self, deadline: Option<f64>) {
        self.deadline = deadline;
    }

    fn push_request(&mut self, req: EngineRequest) {
        let idx = self.slots.len();
        let state = if req.is_done() {
            self.outcome.finished += 1;
            ReqState::Done
        } else if req.ready_time.is_infinite() {
            ReqState::Blocked
        } else {
            ReqState::Waiting
        };
        self.id_to_slot.insert(req.id, idx);
        self.slots.push(Slot { req, state, ctx: 0, blocks: 0, admit_seq: 0 });
        if state == ReqState::Waiting {
            self.enqueue_waiting(idx);
        }
    }

    fn enqueue_waiting(&mut self, idx: usize) {
        let t = self.slots[idx].req.ready_time.max(0.0);
        self.waiting.push(Reverse((t.to_bits(), self.fcfs_counter, idx)));
        self.fcfs_counter += 1;
    }

    /// Current virtual (or measured) time.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Total KV blocks the replica owns.
    pub fn blocks_total(&self) -> u64 {
        self.blocks_total
    }

    /// KV blocks currently free.
    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    /// Whether every request completed.
    pub fn is_done(&self) -> bool {
        self.slots.iter().all(|s| s.state == ReqState::Done)
    }

    /// Requests not yet completed.
    pub fn n_unfinished(&self) -> usize {
        self.slots.iter().filter(|s| s.state != ReqState::Done).count()
    }

    /// Mutable access to the step executor (backends read errors and
    /// harvest produced tokens through this).
    pub fn exec_mut(&mut self) -> &mut X {
        &mut self.exec
    }

    /// Record timestamped [`EngineEvent`]s for this run, labelled with the
    /// given graph node and replica index.
    pub fn enable_events(&mut self, node: usize, replica: usize) {
        self.ev_node = node;
        self.ev_replica = replica;
        self.events = Some(vec![]);
    }

    /// Take the recorded event stream (empty unless
    /// [`SchedCore::enable_events`] was called before running).
    pub fn take_events(&mut self) -> Vec<EngineEvent> {
        self.events.take().unwrap_or_default()
    }

    fn emit(&mut self, kind: EventKind) {
        if let Some(evs) = &mut self.events {
            evs.push(EngineEvent {
                node: self.ev_node,
                replica: self.ev_replica,
                t: self.clock,
                kind,
            });
        }
    }

    fn jitter(&mut self, t: f64) -> f64 {
        match (&mut self.noise, self.cfg.noise_sigma) {
            (Some(rng), Some(sigma)) => t * (1.0 + sigma * rng.normal()).max(0.2),
            _ => t,
        }
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.cfg.block_tokens as u64)
    }

    /// Earliest ready time among waiting requests.
    fn next_ready(&self) -> Option<f64> {
        self.waiting.peek().map(|Reverse((bits, _, _))| f64::from_bits(*bits))
    }

    /// Try to build a prefill batch. Dispatches on the configured
    /// [`AdmitPolicy`]; the FCFS arm is the historical admission loop,
    /// untouched, so the default path stays byte-identical.
    fn admit(&mut self) -> Vec<usize> {
        match self.cfg.admit {
            AdmitPolicy::Fcfs => self.admit_fcfs(),
            _ => self.admit_prioritized(),
        }
    }

    /// Predicted total output length of a slot's request: the runner's
    /// sampled/posterior estimate when present, the resolved output length
    /// otherwise (planner estimate-states resolve lengths *by* sampling,
    /// so the fallback is already the prediction there).
    fn predicted_remaining(&self, idx: usize) -> u32 {
        let r = &self.slots[idx].req;
        let total = if r.predicted_len > 0 { r.predicted_len } else { r.output_len };
        total.saturating_sub(r.generated).max(1)
    }

    /// Policy rank of a waiting candidate (lower admits first; FCFS seq
    /// breaks ties). Returns `(key, promoted)` where `promoted` marks a
    /// skip-join starvation promotion.
    fn rank(&self, idx: usize, ready_bits: u64) -> (u64, bool) {
        match self.cfg.admit {
            AdmitPolicy::Fcfs => (0, false),
            AdmitPolicy::Spjf => (self.predicted_remaining(idx) as u64, false),
            AdmitPolicy::MultiBin { bins } => {
                (AdmitPolicy::bin_index(self.predicted_remaining(idx), bins) as u64, false)
            }
            AdmitPolicy::SkipJoinMlfq { queues, promote_after } => {
                let level = AdmitPolicy::bin_index(self.predicted_remaining(idx), queues);
                let wait = self.clock - f64::from_bits(ready_bits);
                if level > 0 && wait >= promote_after {
                    (0, true) // starved: promote to the front queue
                } else {
                    (level as u64, false)
                }
            }
        }
    }

    /// Length-aware admission: drain every currently-ready candidate, rank
    /// by the policy key (FCFS seq as tie-break), and admit greedily under
    /// the same token/block/seat bounds as FCFS — but *skip* candidates
    /// that don't fit instead of stopping, so one long prompt can't hold
    /// the batch hostage. Skipped candidates re-enter the waiting heap
    /// under their original keys.
    fn admit_prioritized(&mut self) -> Vec<usize> {
        let mut cands: Vec<(u64, u64, usize)> = vec![];
        while let Some(&Reverse((bits, seq, idx))) = self.waiting.peek() {
            if f64::from_bits(bits) > self.clock {
                break;
            }
            self.waiting.pop();
            cands.push((bits, seq, idx));
        }
        if cands.is_empty() {
            return vec![];
        }
        let mut ranked: Vec<(u64, bool, u64, u64, usize)> = cands
            .into_iter()
            .map(|(bits, seq, idx)| {
                let (key, promoted) = self.rank(idx, bits);
                (key, promoted, seq, bits, idx)
            })
            .collect();
        ranked.sort_unstable_by_key(|&(key, _, seq, _, _)| (key, seq));
        let mut batch = vec![];
        let mut admitted_seqs: Vec<u64> = vec![];
        let mut batch_tokens = 0u64;
        let mut leftover: Vec<(u64, u64, usize)> = vec![];
        let mut min_left_seq = u64::MAX;
        for (_, promoted, seq, bits, idx) in ranked {
            if self.running.len() + batch.len() >= self.cfg.max_num_seqs {
                min_left_seq = min_left_seq.min(seq);
                leftover.push((bits, seq, idx));
                continue;
            }
            let slot = &self.slots[idx];
            debug_assert_eq!(slot.state, ReqState::Waiting);
            let prompt = slot.req.input_len + slot.req.generated;
            let prefill_tokens = if slot.req.kv_resident && slot.req.generated > 0 {
                1
            } else {
                prompt
            };
            let need = self.blocks_for(prompt + 1);
            let over_tokens = batch_tokens + prefill_tokens as u64 > self.cfg.max_batch_tokens
                && !batch.is_empty();
            if over_tokens || self.free_blocks < need + self.cfg.watermark_blocks {
                min_left_seq = min_left_seq.min(seq);
                leftover.push((bits, seq, idx));
                continue;
            }
            self.free_blocks -= need;
            let wait = (self.clock - f64::from_bits(bits)).max(0.0);
            if wait > self.outcome.admit.max_queue_wait {
                self.outcome.admit.max_queue_wait = wait;
            }
            if promoted {
                self.outcome.admit.promotions += 1;
            }
            let slot = &mut self.slots[idx];
            slot.blocks = need;
            slot.ctx = prompt + 1; // prefill emits the first output token
            slot.state = ReqState::Running;
            slot.admit_seq = self.admit_counter;
            self.admit_counter += 1;
            batch_tokens += prefill_tokens as u64;
            admitted_seqs.push(seq);
            batch.push(idx);
        }
        if min_left_seq != u64::MAX {
            self.outcome.admit.queue_jumps +=
                admitted_seqs.iter().filter(|&&s| s > min_left_seq).count() as u64;
        }
        for (bits, seq, idx) in leftover {
            self.waiting.push(Reverse((bits, seq, idx)));
        }
        batch
    }

    /// The historical prefill-batch builder (FCFS by ready time,
    /// token/block bounded) — the byte-identical default path.
    fn admit_fcfs(&mut self) -> Vec<usize> {
        let mut batch = vec![];
        let mut batch_tokens = 0u64;
        while let Some(&Reverse((bits, _, idx))) = self.waiting.peek() {
            if self.running.len() + batch.len() >= self.cfg.max_num_seqs {
                break;
            }
            if f64::from_bits(bits) > self.clock {
                break; // FCFS: don't skip over not-yet-ready requests
            }
            let slot = &self.slots[idx];
            debug_assert_eq!(slot.state, ReqState::Waiting);
            let prompt = slot.req.input_len + slot.req.generated;
            // KV-resident requests re-enter without re-prefilling their
            // carried context; they only cost one admission token.
            let prefill_tokens = if slot.req.kv_resident && slot.req.generated > 0 {
                1
            } else {
                prompt
            };
            if batch_tokens + prefill_tokens as u64 > self.cfg.max_batch_tokens
                && !batch.is_empty()
            {
                break;
            }
            let need = self.blocks_for(prompt + 1);
            if self.free_blocks < need + self.cfg.watermark_blocks {
                break;
            }
            self.waiting.pop();
            self.free_blocks -= need;
            let slot = &mut self.slots[idx];
            slot.blocks = need;
            slot.ctx = prompt + 1; // prefill emits the first output token
            slot.state = ReqState::Running;
            slot.admit_seq = self.admit_counter;
            self.admit_counter += 1;
            batch_tokens += prefill_tokens as u64;
            batch.push(idx);
        }
        batch
    }

    fn finish(&mut self, idx: usize) {
        let (id, next) = {
            let slot = &mut self.slots[idx];
            slot.state = ReqState::Done;
            self.free_blocks += slot.blocks;
            slot.blocks = 0;
            (slot.req.id, slot.req.chain_next)
        };
        self.outcome.finished += 1;
        self.completions.push((id, self.clock));
        self.emit(EventKind::Completed { req: id });
        if let Some(nid) = next {
            if let Some(&nidx) = self.id_to_slot.get(&nid) {
                if self.slots[nidx].state == ReqState::Blocked {
                    self.slots[nidx].req.ready_time = self.clock;
                    self.slots[nidx].state = ReqState::Waiting;
                    self.enqueue_waiting(nidx);
                }
            }
        }
    }

    /// Preempt the most recently admitted running request (recompute).
    fn preempt_latest(&mut self) -> bool {
        let Some(pos) = self
            .running
            .iter()
            .enumerate()
            .max_by_key(|&(_, &i)| self.slots[i].admit_seq)
            .map(|(p, _)| p)
        else {
            return false;
        };
        let idx = self.running.swap_remove(pos);
        let slot = &mut self.slots[idx];
        self.free_blocks += slot.blocks;
        slot.blocks = 0;
        slot.ctx = 0;
        slot.state = ReqState::Waiting;
        slot.req.ready_time = self.clock;
        slot.req.kv_resident = false; // recompute: KV is gone
        let id = slot.req.id;
        self.outcome.preemptions += 1;
        self.emit(EventKind::Preempted { req: id });
        self.enqueue_waiting(idx);
        true
    }

    fn record_trace(&mut self) {
        if let Some(tr) = &mut self.iter_trace {
            tr.push((self.clock, self.running.len()));
        }
    }

    /// Run one scheduling step. Returns `false` if nothing could be done
    /// right now (caller decides whether to idle-advance).
    pub fn step(&mut self) -> bool {
        let batch = self.admit();
        if !batch.is_empty() {
            if self.events.is_some() {
                for &i in &batch {
                    let id = self.slots[i].req.id;
                    self.emit(EventKind::Admitted { req: id });
                }
            }
            fill_step_reqs(&mut self.scratch_admit, &self.slots, &batch);
            fill_step_reqs(&mut self.scratch_run, &self.slots, &self.running);
            let t = self.exec.prefill(&self.scratch_admit, &self.scratch_run);
            let t = self.jitter(t);
            self.clock += t;
            self.outcome.busy_time += t;
            self.outcome.prefill_iterations += 1;
            if self.events.is_some() {
                let new_tokens: u64 = self
                    .scratch_admit
                    .iter()
                    .map(|r| {
                        if r.kv_resident && r.generated > 0 {
                            1
                        } else {
                            (r.input_len + r.generated) as u64
                        }
                    })
                    .sum();
                self.emit(EventKind::Prefill { batch: batch.len(), new_tokens, dur: t });
            }
            for &i in &batch {
                self.slots[i].req.generated += 1;
                self.outcome.tokens_generated += 1;
                if self.slots[i].req.is_done() {
                    self.finish(i);
                } else {
                    self.running.push(i);
                }
            }
            self.record_trace();
            return true;
        }

        if self.running.is_empty() {
            return false;
        }

        if self.cfg.fast_step {
            self.decode_fast()
        } else {
            self.decode_once()
        }
    }

    /// One decode iteration, exact.
    fn decode_once(&mut self) -> bool {
        // Grow KV; preempt on OOM.
        let mut i = 0;
        while i < self.running.len() {
            let idx = self.running[i];
            let need_block = self.slots[idx].ctx % self.cfg.block_tokens == 0;
            if need_block {
                while self.free_blocks < 1 {
                    if self.running.len() <= 1 || !self.preempt_latest() {
                        break;
                    }
                }
                if self.slots[idx].state != ReqState::Running {
                    // preempt_latest evicted `idx` itself; running[i] now
                    // holds a different request — revisit this position.
                    continue;
                }
                if self.free_blocks >= 1 {
                    self.free_blocks -= 1;
                    self.slots[idx].blocks += 1;
                }
            }
            i += 1;
        }
        let batch = self.running.len();
        if batch == 0 {
            return false;
        }
        fill_step_reqs(&mut self.scratch_run, &self.slots, &self.running);
        let t = self.exec.decode(&self.scratch_run);
        let t = self.jitter(t);
        self.clock += t;
        self.outcome.busy_time += t;
        self.outcome.decode_iterations += 1;
        self.outcome.tokens_generated += batch as u64;
        if self.events.is_some() {
            let total_ctx: u64 = self.scratch_run.iter().map(|r| r.ctx as u64).sum();
            let max_ctx = self.scratch_run.iter().map(|r| r.ctx).max().unwrap_or(0);
            self.emit(EventKind::Decode { batch, iters: 1, total_ctx, max_ctx, dur: t });
        }
        let mut j = 0;
        while j < self.running.len() {
            let idx = self.running[j];
            let slot = &mut self.slots[idx];
            slot.ctx += 1;
            slot.req.generated += 1;
            if slot.req.is_done() {
                self.running.swap_remove(j);
                self.finish(idx);
            } else {
                j += 1;
            }
        }
        self.record_trace();
        true
    }

    /// Aggregated decode stepping — the exact fast path. While batch
    /// composition is provably stable the clock advances over a window
    /// of up to `k` iterations with O(1) bookkeeping per iteration:
    ///
    /// * `k ≤ min_remaining` — no request completes strictly inside the
    ///   window, so seats, batch order and `running` are all fixed;
    /// * `k ≤ k_blocks` — the cumulative KV-block need of `k` growth
    ///   steps fits the free pool, so preemption can never fire inside
    ///   the window (`needed(k)` is monotone in `k`; binary-searched);
    /// * the loop breaks when the deadline is reached or a waiting
    ///   prompt crosses its ready time while seats are free — exactly
    ///   the clocks at which a per-token replay would stop decoding or
    ///   attempt an admission that could succeed.
    ///
    /// Each covered iteration is priced at its *exact* context via
    /// [`StepExec::decode_tick`] (`total_ctx` grows by `batch`, `max_ctx`
    /// by 1 per iteration), drawn through the same jitter stream, and
    /// accumulated onto the clock in the same order — so outcomes,
    /// events, completions and traces are bit-identical to per-token
    /// stepping. Per-slot context/progress/blocks are settled once at
    /// the window end (block growth telescopes to a `div_ceil`
    /// difference). Degenerate windows — an admissible prompt already
    /// waiting, immediate block pressure, a tick-declining executor, or
    /// a window too short to pay for its setup — fall back to
    /// [`SchedCore::decode_once`]. The KV-fit bracket is seeded from the
    /// previous window's bound (`fast_k`), collapsing the common
    /// steady-state case to O(1) probes without changing the result.
    fn decode_fast(&mut self) -> bool {
        let batch = self.running.len();
        let seats_free = batch < self.cfg.max_num_seqs;
        // An admissible prompt may be waiting right now (this step's
        // admit attempt failed only on block/token pressure): stay
        // per-token so every iteration re-attempts admission.
        if seats_free && self.next_ready().is_some_and(|t| t <= self.clock) {
            return self.decode_once();
        }
        let min_remaining = self
            .running
            .iter()
            .map(|&i| self.slots[i].req.remaining())
            .min()
            .unwrap_or(0)
            .max(1);
        // Largest k whose cumulative block growth fits the free pool
        // (decode may drain free blocks to zero — the watermark gates
        // admission only). needed(k) is monotone, so binary search.
        let bt = self.cfg.block_tokens as u64;
        let needed = |k: u64| -> u64 {
            self.running
                .iter()
                .map(|&i| {
                    let c = self.slots[i].ctx as u64;
                    (c + k).div_ceil(bt) - c.div_ceil(bt)
                })
                .sum()
        };
        let (mut lo, mut hi) = (0u64, min_remaining as u64);
        // Seed the bracket from the previous window's bound: batch
        // composition and the free pool usually persist across
        // consecutive stable windows, so last window's k is an excellent
        // first probe — confirming it (and refuting k+1) collapses the
        // search to O(1) `needed` evaluations instead of a fresh
        // bisection. Outcome-neutral: the loop below still converges to
        // the unique largest k with needed(k) <= free_blocks (`needed`
        // is monotone and touches neither the clock nor the jitter
        // stream), so results stay bit-identical to an unseeded search.
        let guess = self.fast_k.min(hi);
        if guess > 0 {
            if needed(guess) <= self.free_blocks {
                lo = guess;
                if guess < hi && needed(guess + 1) > self.free_blocks {
                    hi = guess;
                }
            } else {
                hi = guess - 1;
            }
        }
        while lo < hi {
            let mid = lo + (hi - lo).div_ceil(2);
            if needed(mid) <= self.free_blocks {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        self.fast_k = lo;
        let k = lo as u32;
        if k <= 2 {
            return self.decode_once();
        }

        let total_ctx0: u64 = self.running.iter().map(|&i| self.slots[i].ctx as u64).sum();
        let max_ctx0: u32 = self.running.iter().map(|&i| self.slots[i].ctx).max().unwrap_or(0);
        let mut done = 0u32;
        while done < k {
            if let Some(d) = self.deadline {
                if self.clock >= d {
                    break;
                }
            }
            if done > 0 && seats_free && self.next_ready().is_some_and(|t| t <= self.clock) {
                break; // a waiting prompt crossed its ready time mid-window
            }
            let total_ctx = total_ctx0 + done as u64 * batch as u64;
            let max_ctx = max_ctx0 + done;
            let Some(t) = self.exec.decode_tick(batch, total_ctx, max_ctx) else {
                break; // executor materialises every token (real hardware)
            };
            let t = self.jitter(t);
            self.clock += t;
            self.outcome.busy_time += t;
            self.emit(EventKind::Decode { batch, iters: 1, total_ctx, max_ctx, dur: t });
            self.record_trace();
            done += 1;
        }
        if done == 0 {
            return self.decode_once(); // tick declined on the first iteration
        }

        // Settle the window: per-slot context/progress/blocks and the
        // completion scan, mirroring decode_once's end-of-iteration
        // bookkeeping (completions can only land on the last iteration).
        self.outcome.decode_iterations += done as u64;
        self.outcome.tokens_generated += done as u64 * batch as u64;
        let mut blocks_used = 0u64;
        let mut j = 0;
        while j < self.running.len() {
            let idx = self.running[j];
            let slot = &mut self.slots[idx];
            let old_ctx = slot.ctx as u64;
            slot.ctx += done;
            slot.req.generated += done;
            let new_blocks = (old_ctx + done as u64).div_ceil(bt) - old_ctx.div_ceil(bt);
            blocks_used += new_blocks;
            slot.blocks += new_blocks;
            if slot.req.is_done() {
                self.running.swap_remove(j);
                self.finish(idx);
            } else {
                j += 1;
            }
        }
        debug_assert!(blocks_used <= self.free_blocks, "window overran its block bound");
        self.free_blocks -= blocks_used;
        if let Some(tr) = &mut self.iter_trace {
            // The last covered iteration's trace point must reflect the
            // post-completion running count, as per-token stepping does.
            if let Some(last) = tr.last_mut() {
                last.1 = self.running.len();
            }
        }
        true
    }

    /// Advance the clock while nothing is runnable (pipeline idling).
    /// Returns `false` if there is nothing to wait for (done, or blocked
    /// on a chain predecessor that lives in another engine).
    pub fn idle_until_ready(&mut self) -> bool {
        match self.next_ready() {
            Some(t) if t > self.clock => {
                self.clock = t;
                true
            }
            Some(_) => true,
            None => false,
        }
    }

    /// Run to completion (or until `deadline`). Returns the outcome so far.
    ///
    /// If requests are ready but can never be admitted (e.g. a
    /// hand-crafted config with a zero KV budget), the run bails out with
    /// the partial outcome instead of spinning forever.
    pub fn run(&mut self, deadline: Option<f64>) -> SimOutcome {
        self.deadline = deadline;
        loop {
            if let Some(d) = deadline {
                if self.clock >= d {
                    break;
                }
            }
            if self.step() {
                continue;
            }
            let before = self.clock;
            if !self.idle_until_ready() {
                break;
            }
            if self.clock <= before && !self.step() {
                // Wedged: ready work that can never be admitted.
                break;
            }
        }
        self.deadline = None;
        self.outcome.clock = self.clock;
        self.outcome.clone()
    }

    /// Extract unfinished requests (for stage transitions / preemption).
    /// Running requests keep their generated progress but lose KV state —
    /// they will re-prefill `input + generated` tokens when re-admitted.
    pub fn drain_unfinished(&mut self) -> Vec<EngineRequest> {
        let mut out = vec![];
        for slot in &mut self.slots {
            if slot.state != ReqState::Done {
                out.push(slot.req);
                slot.state = ReqState::Done;
            }
        }
        self.running.clear();
        self.waiting.clear();
        out
    }

    /// The accumulated outcome so far.
    pub fn outcome(&self) -> &SimOutcome {
        &self.outcome
    }

    /// Record a (clock, running-count) point per iteration (Fig. 3).
    pub fn enable_trace(&mut self) {
        self.iter_trace = Some(vec![]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::models::Registry;

    #[test]
    fn standard_config_errors_when_weights_do_not_fit() {
        let reg = Registry::paper();
        let spec = reg.get("llama-2-70b-chat").unwrap();
        // A 70B model cannot fit a single 16 GiB GPU under tp=1.
        let err = EngineConfig::standard(spec, 1, 16u64 << 30).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("llama-2-70b-chat"), "{msg}");
        assert!(msg.contains("tp=1"), "{msg}");
        // The same model under a sane cluster is fine.
        assert!(EngineConfig::standard(spec, 4, ClusterSpec::a100_node(8).mem_bytes).is_ok());
    }

    #[test]
    fn standard_config_errors_on_watermark_starvation() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap();
        // Barely more memory than the weights: KV budget below one block
        // above the watermark must be rejected, not wedge the engine.
        let weights = spec.weight_bytes_per_gpu(1);
        let err = EngineConfig::standard(spec, 1, weights + 1024).unwrap_err();
        assert!(err.to_string().contains("watermark"), "{err}");
    }

    #[test]
    fn run_bails_out_instead_of_wedging_on_zero_blocks() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = crate::costmodel::HardwareModel::new(ClusterSpec::a100_node(8));
        let mut cfg =
            EngineConfig::standard(&spec, 1, ClusterSpec::a100_node(8).mem_bytes).unwrap();
        // Hand-craft a degenerate budget the constructor would reject.
        cfg.kv_bytes_budget = 1;
        let reqs = vec![EngineRequest::fresh(0, 64, 32)];
        let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        let out = sim.run(None);
        assert_eq!(out.finished, 0, "nothing is admissible");
        assert!(!sim.is_done());
    }

    #[test]
    fn event_stream_covers_the_request_lifecycle() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let cluster = ClusterSpec::a100_node(8);
        let hw = crate::costmodel::HardwareModel::new(cluster.clone());
        let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
        let reqs: Vec<EngineRequest> = (0..20).map(|i| EngineRequest::fresh(i, 25, 40)).collect();
        let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        sim.enable_events(3, 1);
        let out = sim.run(None);
        let events = sim.take_events();
        assert!(!events.is_empty());
        assert!(events.iter().all(|e| e.node == 3 && e.replica == 1));
        let count = |f: fn(&EventKind) -> bool| events.iter().filter(|e| f(&e.kind)).count();
        assert_eq!(count(|k| matches!(k, EventKind::Admitted { .. })), 20);
        assert_eq!(count(|k| matches!(k, EventKind::Completed { .. })), 20);
        let prefills = count(|k| matches!(k, EventKind::Prefill { .. })) as u64;
        assert_eq!(prefills, out.prefill_iterations);
        let decode_iters: u64 = events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Decode { iters, .. } => Some(iters as u64),
                _ => None,
            })
            .sum();
        assert_eq!(decode_iters, out.decode_iterations);
        // Event durations add up to the busy time.
        let dur: f64 = events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Prefill { dur, .. } | EventKind::Decode { dur, .. } => Some(dur),
                _ => None,
            })
            .sum();
        assert!((dur - out.busy_time).abs() < 1e-9, "dur {dur} vs busy {}", out.busy_time);
        // Timestamps are monotone.
        assert!(events.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn admit_policy_parse_and_name_roundtrip() {
        for (spelling, want) in [
            ("fcfs", AdmitPolicy::Fcfs),
            ("fifo", AdmitPolicy::Fcfs),
            ("spjf", AdmitPolicy::Spjf),
            ("sjf", AdmitPolicy::Spjf),
            ("multi-bin", AdmitPolicy::MultiBin { bins: DEFAULT_MULTI_BIN_BINS }),
            ("multibin:6", AdmitPolicy::MultiBin { bins: 6 }),
            (
                "skip-join",
                AdmitPolicy::SkipJoinMlfq {
                    queues: DEFAULT_SKIP_JOIN_QUEUES,
                    promote_after: DEFAULT_SKIP_JOIN_PROMOTE,
                },
            ),
            (
                "mlfq:3:2.5",
                AdmitPolicy::SkipJoinMlfq { queues: 3, promote_after: 2.5 },
            ),
        ] {
            let parsed = AdmitPolicy::parse(spelling).unwrap();
            assert_eq!(parsed, want, "{spelling}");
            // The canonical name round-trips.
            assert_eq!(AdmitPolicy::parse(&parsed.name()).unwrap(), parsed);
        }
        for bad in ["nope", "multi-bin:0", "multi-bin:x", "skip-join:4:0", "fcfs:1", "spjf:2:3"] {
            assert!(AdmitPolicy::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn bin_index_is_monotone_and_clamped() {
        for bins in 1..=6u32 {
            let mut prev = 0;
            for p in 0..5000u32 {
                let b = AdmitPolicy::bin_index(p, bins);
                assert!(b >= prev, "bin regressed at {p}");
                assert!(b < bins, "bin {b} out of range for {bins}");
                prev = b;
            }
        }
        assert_eq!(AdmitPolicy::bin_index(1, 4), 0);
        assert_eq!(AdmitPolicy::bin_index(700, 4), 3);
    }

    fn sim_with(
        cfg: EngineConfig,
        reqs: Vec<EngineRequest>,
        events: bool,
    ) -> (SimOutcome, Vec<EngineEvent>) {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = crate::costmodel::HardwareModel::new(ClusterSpec::a100_node(8));
        let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        if events {
            sim.enable_events(0, 0);
        }
        let out = sim.run(None);
        let evs = sim.take_events();
        (out, evs)
    }

    fn base_cfg() -> EngineConfig {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap();
        EngineConfig::standard(spec, 1, ClusterSpec::a100_node(8).mem_bytes).unwrap()
    }

    #[test]
    fn spjf_admits_predicted_short_jobs_first() {
        // One long request enqueued first, shorts behind it, few seats:
        // FCFS admits the long first; SPJF overtakes it.
        let mut reqs = vec![EngineRequest::fresh(0, 64, 600)];
        for i in 1..9 {
            reqs.push(EngineRequest::fresh(i, 16, 8));
        }
        let mut cfg = base_cfg();
        cfg.max_num_seqs = 4;
        let (fcfs_out, fcfs_ev) = sim_with(cfg.clone(), reqs.clone(), true);
        cfg.admit = AdmitPolicy::Spjf;
        let (spjf_out, spjf_ev) = sim_with(cfg, reqs.clone(), true);
        let first_admitted = |evs: &[EngineEvent]| -> u64 {
            evs.iter()
                .find_map(|e| match e.kind {
                    EventKind::Admitted { req } => Some(req),
                    _ => None,
                })
                .unwrap()
        };
        assert_eq!(first_admitted(&fcfs_ev), 0, "FCFS admits arrival order");
        assert_ne!(first_admitted(&spjf_ev), 0, "SPJF overtakes the long job");
        assert!(spjf_out.admit.queue_jumps > 0, "{:?}", spjf_out.admit);
        assert_eq!(fcfs_out.admit, AdmitStats::default(), "FCFS keeps zero counters");
        // Both conserve work.
        assert_eq!(fcfs_out.finished, reqs.len());
        assert_eq!(spjf_out.finished, reqs.len());
        assert_eq!(fcfs_out.tokens_generated, spjf_out.tokens_generated);
    }

    #[test]
    fn skip_join_promotion_bounds_starvation() {
        // One long job and a crowd of shorts, all ready at t=0, single
        // seat: SPJF starves the long job until every short is done;
        // skip-join promotes it once its wait crosses the promotion clock.
        // The clock is set relative to the *measured* SPJF starvation so
        // the test is independent of the cost model's absolute iteration
        // latencies.
        let mut reqs = vec![EngineRequest::fresh(0, 32, 400)];
        for i in 1..=50u64 {
            reqs.push(EngineRequest::fresh(i, 16, 8));
        }
        let mut cfg = base_cfg();
        cfg.max_num_seqs = 1;
        cfg.admit = AdmitPolicy::Spjf;
        let (spjf_out, spjf_ev) = sim_with(cfg.clone(), reqs.clone(), true);
        let admit_time = |evs: &[EngineEvent]| {
            evs.iter()
                .find_map(|e| match e.kind {
                    EventKind::Admitted { req: 0 } => Some(e.t),
                    _ => None,
                })
                .expect("long job admitted")
        };
        let starved = admit_time(&spjf_ev);
        assert!(starved > 0.0, "SPJF must delay the long job behind the shorts");
        // The long job's wait is the maximum wait under SPJF.
        assert!((spjf_out.admit.max_queue_wait - starved).abs() < 1e-9);
        cfg.admit = AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after: starved / 4.0 };
        let (skip_out, skip_ev) = sim_with(cfg, reqs.clone(), true);
        assert_eq!(spjf_out.finished, reqs.len());
        assert_eq!(skip_out.finished, reqs.len());
        assert!(skip_out.admit.promotions >= 1, "{:?}", skip_out.admit);
        let promoted = admit_time(&skip_ev);
        assert!(
            promoted <= starved / 2.0,
            "promotion did not bound starvation: {promoted:.2}s vs SPJF {starved:.2}s"
        );
    }

    #[test]
    fn every_policy_conserves_requests_and_tokens() {
        let reqs: Vec<EngineRequest> = (0..100)
            .map(|i| EngineRequest::fresh(i, 10 + (i % 50) as u32, 4 + (i * 13 % 340) as u32))
            .collect();
        let want_tokens: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        for admit in [
            AdmitPolicy::Fcfs,
            AdmitPolicy::Spjf,
            AdmitPolicy::MultiBin { bins: 4 },
            AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after: 5.0 },
        ] {
            let mut cfg = base_cfg();
            cfg.max_num_seqs = 16;
            cfg.admit = admit;
            let (out, _) = sim_with(cfg, reqs.clone(), false);
            assert_eq!(out.finished, reqs.len(), "{admit:?} lost requests");
            assert_eq!(out.tokens_generated, want_tokens, "{admit:?} lost tokens");
        }
    }

    #[test]
    fn fast_step_is_bit_identical_across_policies() {
        // Aggregated stepping must be indistinguishable from per-token
        // stepping — same outcome bits, same event stream — under every
        // admission policy, with staggered ready times and an in-engine
        // chain keeping the waiting heap busy mid-run.
        let mut reqs: Vec<EngineRequest> = (0..40)
            .map(|i| EngineRequest::fresh(i, 10 + (i % 30) as u32, 8 + (i * 17 % 200) as u32))
            .collect();
        for (i, r) in reqs.iter_mut().enumerate() {
            if i % 3 == 1 {
                r.ready_time = 0.5 * i as f64;
            }
        }
        reqs[0].chain_next = Some(5);
        reqs[5].ready_time = EngineRequest::BLOCKED;
        for admit in [
            AdmitPolicy::Fcfs,
            AdmitPolicy::Spjf,
            AdmitPolicy::MultiBin { bins: 4 },
            AdmitPolicy::SkipJoinMlfq { queues: 4, promote_after: 2.0 },
        ] {
            let mut cfg = base_cfg();
            cfg.max_num_seqs = 8;
            cfg.admit = admit;
            cfg.fast_step = true;
            let (fast, fast_ev) = sim_with(cfg.clone(), reqs.clone(), true);
            cfg.fast_step = false;
            let (exact, exact_ev) = sim_with(cfg, reqs.clone(), true);
            assert_eq!(fast.clock.to_bits(), exact.clock.to_bits(), "{admit:?}");
            assert_eq!(fast.busy_time.to_bits(), exact.busy_time.to_bits(), "{admit:?}");
            assert_eq!(fast, exact, "{admit:?}");
            assert_eq!(fast_ev, exact_ev, "{admit:?}");
        }
    }

    #[test]
    fn fast_step_is_bit_identical_under_preemption_pressure() {
        // A KV budget tight enough to force preemption-by-recompute:
        // windows must stop short of every block-exhaustion point and
        // hand over to the per-token path without drifting a bit.
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap();
        let mut cfg = base_cfg();
        cfg.kv_bytes_budget = 3000 * spec.kv_bytes_per_token(1);
        let reqs: Vec<EngineRequest> =
            (0..16).map(|i| EngineRequest::fresh(i, 100, 800)).collect();
        cfg.fast_step = true;
        let (fast, fast_ev) = sim_with(cfg.clone(), reqs.clone(), true);
        cfg.fast_step = false;
        let (exact, exact_ev) = sim_with(cfg, reqs, true);
        assert!(exact.preemptions > 0, "fixture must exercise preemption");
        assert_eq!(fast.clock.to_bits(), exact.clock.to_bits());
        assert_eq!(fast, exact);
        assert_eq!(fast_ev, exact_ev);
    }

    #[test]
    fn fast_step_is_bit_identical_under_noise_and_deadline() {
        // Jitter draws one normal per iteration: the aggregated path
        // must consume the RNG stream in the same order, and a deadline
        // must break its windows at the same clock a per-token replay
        // stops at (including the drained remainder).
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = crate::costmodel::HardwareModel::new(ClusterSpec::a100_node(8));
        let reqs: Vec<EngineRequest> =
            (0..64).map(|i| EngineRequest::fresh(i, 20, 40 + (i % 300) as u32)).collect();
        let run = |fast: bool, deadline: Option<f64>| {
            let mut cfg = base_cfg();
            cfg.noise_sigma = Some(0.02);
            cfg.fast_step = fast;
            let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs.clone(), 0.0, 7);
            let out = sim.run(deadline);
            (out, sim.drain_unfinished())
        };
        for deadline in [None, Some(2.5)] {
            let (fast, fast_rest) = run(true, deadline);
            let (exact, exact_rest) = run(false, deadline);
            assert_eq!(fast.clock.to_bits(), exact.clock.to_bits(), "{deadline:?}");
            assert_eq!(fast, exact, "{deadline:?}");
            assert_eq!(fast_rest, exact_rest, "{deadline:?}");
        }
    }

    #[test]
    fn fast_step_traces_match_per_token_traces() {
        // The Fig. 3 iteration trace records one (clock, running) point
        // per decode iteration; aggregated windows must reproduce it
        // exactly, including the post-completion count on a window's
        // last iteration.
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = crate::costmodel::HardwareModel::new(ClusterSpec::a100_node(8));
        let reqs: Vec<EngineRequest> =
            (0..50).map(|i| EngineRequest::fresh(i, 20, 30 + (i % 60) as u32)).collect();
        let run = |fast: bool| {
            let mut cfg = base_cfg();
            cfg.fast_step = fast;
            let mut sim = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs.clone(), 0.0, 0);
            sim.enable_trace();
            sim.run(None);
            sim.iter_trace.take().unwrap()
        };
        let fast = run(true);
        let exact = run(false);
        assert_eq!(fast.len(), exact.len());
        for (f, e) in fast.iter().zip(&exact) {
            assert_eq!(f.0.to_bits(), e.0.to_bits());
            assert_eq!(f.1, e.1);
        }
    }

    #[test]
    fn events_do_not_change_results() {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let cluster = ClusterSpec::a100_node(8);
        let hw = crate::costmodel::HardwareModel::new(cluster.clone());
        let cfg = EngineConfig::standard(&spec, 1, cluster.mem_bytes).unwrap();
        let reqs: Vec<EngineRequest> =
            (0..64).map(|i| EngineRequest::fresh(i, 20, 30 + (i % 17) as u32)).collect();
        let quiet =
            crate::engine::EngineSim::new(&spec, 1, &hw, cfg.clone(), reqs.clone(), 0.0, 0)
                .run(None);
        let mut traced = crate::engine::EngineSim::new(&spec, 1, &hw, cfg, reqs, 0.0, 0);
        traced.enable_events(0, 0);
        let loud = traced.run(None);
        assert_eq!(quiet.clock.to_bits(), loud.clock.to_bits());
        assert_eq!(quiet, loud);
    }
}
