//! The single-replica engine simulator.
//!
//! Implements the vLLM-v0 scheduling loop:
//! 1. if prompts are waiting, KV blocks are available and the running set
//!    has room → run a *prefill* iteration over an FCFS batch of prompts
//!    (bounded by `max_batch_tokens`); the prefill also emits each
//!    request's first output token;
//! 2. otherwise run a *decode* iteration: every running request produces
//!    one token; requests that exhaust their KV-block budget trigger
//!    preemption-by-recompute of the most recently admitted request;
//! 3. requests that reach their output length leave and free their blocks.
//!
//! Requests carry absolute `ready_time`s (set by the communicator for
//! dependent models) and may form in-engine chains (fused self-loop nodes,
//! §4.1): completing a request unblocks its `chain_next` successor.
//!
//! A `fast_forward` mode jumps over maximal runs of uniform decode
//! iterations (no admission, no completion, no OOM in between), pricing
//! the run at its midpoint context — latency is piecewise-linear in
//! context, so the approximation error is the roofline crossover only.
//! This is what makes planning cheap (§4.2 "our request scheduling
//! simulator processes different execution plans in parallel").

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::EngineRequest;
use crate::costmodel::IterLatency;
use crate::models::ModelSpec;
use crate::util::rng::Rng;

/// Engine scheduling parameters (vLLM defaults).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Maximum running requests per iteration (vLLM `max_num_seqs`).
    pub max_num_seqs: usize,
    /// Maximum prompt tokens batched into one prefill iteration.
    pub max_batch_tokens: u64,
    /// Tokens per KV block.
    pub block_tokens: u32,
    /// Blocks kept free as admission watermark.
    pub watermark_blocks: u64,
    /// Enable event-jump acceleration for uniform decode runs.
    pub fast_forward: bool,
    /// Per-iteration multiplicative jitter σ (ground-truth realism);
    /// `None` for the planner's deterministic estimates.
    pub noise_sigma: Option<f64>,
    /// GPU memory available for KV blocks (set from cluster + weights).
    pub kv_bytes_budget: u64,
}

impl EngineConfig {
    /// Standard config for a model replica under `tp`, on a cluster with
    /// `mem_bytes` per GPU.
    pub fn standard(spec: &ModelSpec, tp: u32, mem_bytes: u64) -> Self {
        let weights = spec.weight_bytes_per_gpu(tp);
        let kv_budget = mem_bytes.saturating_sub(weights) * tp as u64;
        EngineConfig {
            max_num_seqs: 256,
            max_batch_tokens: 4096,
            block_tokens: 16,
            watermark_blocks: 8,
            fast_forward: true,
            noise_sigma: None,
            kv_bytes_budget: kv_budget,
        }
    }

    /// A plan is infeasible if the weights don't fit or not even one
    /// max-length sequence's KV fits beside them (§3's validity rule).
    pub fn feasible(&self, spec: &ModelSpec, tp: u32, mem_bytes: u64) -> bool {
        if spec.weight_bytes_per_gpu(tp) >= mem_bytes {
            return false;
        }
        let per_seq = spec.kv_bytes_per_token(tp) as u64 * tp as u64 * spec.max_seq as u64;
        self.kv_bytes_budget >= per_seq / 4
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ReqState {
    Blocked,
    Waiting,
    Running,
    Done,
}

#[derive(Debug, Clone)]
struct Slot {
    req: EngineRequest,
    state: ReqState,
    /// Tokens currently materialised in KV (prompt + generated so far).
    ctx: u32,
    blocks: u64,
    /// Admission order, for preempt-latest-first.
    admit_seq: u64,
}

/// Aggregate result of driving a simulation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SimOutcome {
    /// Requests that completed.
    pub finished: usize,
    /// Virtual time at the end of the run (absolute for stage replays;
    /// relative when the simulation started at a canonical origin, as in
    /// [`crate::runner::state::ExecState::simulate_node_fast`]).
    pub clock: f64,
    /// Time spent actually executing iterations (vs waiting for inputs).
    pub busy_time: f64,
    /// Decode iterations executed (fast-forwarded runs count each step).
    pub decode_iterations: u64,
    /// Prefill iterations executed.
    pub prefill_iterations: u64,
    /// Preemption-by-recompute events.
    pub preemptions: u64,
    /// Output tokens produced.
    pub tokens_generated: u64,
}

type ReadyKey = Reverse<(u64, u64, usize)>; // (ready_time bits, fcfs seq, slot)

/// Single-replica engine simulator. See module docs.
pub struct EngineSim<'a> {
    spec: &'a ModelSpec,
    tp: u32,
    lat: &'a dyn IterLatency,
    cfg: EngineConfig,
    blocks_total: u64,
    free_blocks: u64,
    slots: Vec<Slot>,
    waiting: BinaryHeap<ReadyKey>,
    running: Vec<usize>,
    id_to_slot: HashMap<u64, usize>,
    clock: f64,
    outcome: SimOutcome,
    admit_counter: u64,
    fcfs_counter: u64,
    noise: Option<Rng>,
    /// Active run() deadline — bounds fast-forward jumps so a stage replay
    /// never overshoots its stage-end boundary.
    deadline: Option<f64>,
    /// Completion times per request id (for the communicator).
    pub completions: Vec<(u64, f64)>,
    /// Optional (clock, running-count) trace for Fig. 3.
    pub iter_trace: Option<Vec<(f64, usize)>>,
}

impl<'a> EngineSim<'a> {
    /// Build a replica simulator over `requests`, starting its clock at
    /// `start_time`. KV capacity is derived from the config's budget.
    pub fn new(
        spec: &'a ModelSpec,
        tp: u32,
        lat: &'a dyn IterLatency,
        cfg: EngineConfig,
        requests: Vec<EngineRequest>,
        start_time: f64,
        noise_seed: u64,
    ) -> Self {
        let block_bytes = cfg.block_tokens as u64 * spec.kv_bytes_per_token(tp) as u64 * tp as u64;
        let blocks_total = (cfg.kv_bytes_budget / block_bytes.max(1)).max(1);
        let noise = cfg.noise_sigma.map(|_| Rng::new(noise_seed ^ 0x5EED_0E0E));
        let mut sim = EngineSim {
            spec,
            tp,
            lat,
            cfg,
            blocks_total,
            free_blocks: blocks_total,
            slots: Vec::with_capacity(requests.len()),
            waiting: BinaryHeap::with_capacity(requests.len()),
            running: vec![],
            id_to_slot: HashMap::with_capacity(requests.len()),
            clock: start_time,
            outcome: SimOutcome::default(),
            admit_counter: 0,
            fcfs_counter: 0,
            noise,
            deadline: None,
            completions: vec![],
            iter_trace: None,
        };
        for req in requests {
            sim.push_request(req);
        }
        sim
    }

    fn push_request(&mut self, req: EngineRequest) {
        let idx = self.slots.len();
        let state = if req.is_done() {
            self.outcome.finished += 1;
            ReqState::Done
        } else if req.ready_time.is_infinite() {
            ReqState::Blocked
        } else {
            ReqState::Waiting
        };
        self.id_to_slot.insert(req.id, idx);
        self.slots.push(Slot { req, state, ctx: 0, blocks: 0, admit_seq: 0 });
        if state == ReqState::Waiting {
            self.enqueue_waiting(idx);
        }
    }

    fn enqueue_waiting(&mut self, idx: usize) {
        let t = self.slots[idx].req.ready_time.max(0.0);
        self.waiting.push(Reverse((t.to_bits(), self.fcfs_counter, idx)));
        self.fcfs_counter += 1;
    }

    /// Current virtual time.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Total KV blocks the replica owns.
    pub fn blocks_total(&self) -> u64 {
        self.blocks_total
    }

    /// KV blocks currently free.
    pub fn free_blocks(&self) -> u64 {
        self.free_blocks
    }

    /// Whether every request completed.
    pub fn is_done(&self) -> bool {
        self.slots.iter().all(|s| s.state == ReqState::Done)
    }

    /// Requests not yet completed.
    pub fn n_unfinished(&self) -> usize {
        self.slots.iter().filter(|s| s.state != ReqState::Done).count()
    }

    fn jitter(&mut self, t: f64) -> f64 {
        match (&mut self.noise, self.cfg.noise_sigma) {
            (Some(rng), Some(sigma)) => t * (1.0 + sigma * rng.normal()).max(0.2),
            _ => t,
        }
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.cfg.block_tokens as u64)
    }

    /// Earliest ready time among waiting requests.
    fn next_ready(&self) -> Option<f64> {
        self.waiting.peek().map(|Reverse((bits, _, _))| f64::from_bits(*bits))
    }

    /// Try to build a prefill batch (FCFS by ready time, token/block bounded).
    fn admit(&mut self) -> Vec<usize> {
        let mut batch = vec![];
        let mut batch_tokens = 0u64;
        while let Some(&Reverse((bits, _, idx))) = self.waiting.peek() {
            if self.running.len() + batch.len() >= self.cfg.max_num_seqs {
                break;
            }
            if f64::from_bits(bits) > self.clock {
                break; // FCFS: don't skip over not-yet-ready requests
            }
            let slot = &self.slots[idx];
            debug_assert_eq!(slot.state, ReqState::Waiting);
            let prompt = slot.req.input_len + slot.req.generated;
            // KV-resident requests re-enter without re-prefilling their
            // carried context; they only cost one admission token.
            let prefill_tokens = if slot.req.kv_resident && slot.req.generated > 0 {
                1
            } else {
                prompt
            };
            if batch_tokens + prefill_tokens as u64 > self.cfg.max_batch_tokens && !batch.is_empty() {
                break;
            }
            let need = self.blocks_for(prompt + 1);
            if self.free_blocks < need + self.cfg.watermark_blocks {
                break;
            }
            self.waiting.pop();
            self.free_blocks -= need;
            let slot = &mut self.slots[idx];
            slot.blocks = need;
            slot.ctx = prompt + 1; // prefill emits the first output token
            slot.state = ReqState::Running;
            slot.admit_seq = self.admit_counter;
            self.admit_counter += 1;
            batch_tokens += prefill_tokens as u64;
            batch.push(idx);
        }
        batch
    }

    fn finish(&mut self, idx: usize) {
        let (id, next) = {
            let slot = &mut self.slots[idx];
            slot.state = ReqState::Done;
            self.free_blocks += slot.blocks;
            slot.blocks = 0;
            (slot.req.id, slot.req.chain_next)
        };
        self.outcome.finished += 1;
        self.completions.push((id, self.clock));
        if let Some(nid) = next {
            if let Some(&nidx) = self.id_to_slot.get(&nid) {
                if self.slots[nidx].state == ReqState::Blocked {
                    self.slots[nidx].req.ready_time = self.clock;
                    self.slots[nidx].state = ReqState::Waiting;
                    self.enqueue_waiting(nidx);
                }
            }
        }
    }

    /// Preempt the most recently admitted running request (recompute).
    fn preempt_latest(&mut self) -> bool {
        let Some(pos) = self
            .running
            .iter()
            .enumerate()
            .max_by_key(|&(_, &i)| self.slots[i].admit_seq)
            .map(|(p, _)| p)
        else {
            return false;
        };
        let idx = self.running.swap_remove(pos);
        let slot = &mut self.slots[idx];
        self.free_blocks += slot.blocks;
        slot.blocks = 0;
        slot.ctx = 0;
        slot.state = ReqState::Waiting;
        slot.req.ready_time = self.clock;
        slot.req.kv_resident = false; // recompute: KV is gone
        self.outcome.preemptions += 1;
        self.enqueue_waiting(idx);
        true
    }

    fn record_trace(&mut self) {
        if let Some(tr) = &mut self.iter_trace {
            tr.push((self.clock, self.running.len()));
        }
    }

    /// Run one scheduling step. Returns `false` if nothing could be done
    /// right now (caller decides whether to idle-advance).
    pub fn step(&mut self) -> bool {
        let batch = self.admit();
        if !batch.is_empty() {
            let lens: Vec<u32> = batch
                .iter()
                .map(|&i| {
                    let r = &self.slots[i].req;
                    if r.kv_resident && r.generated > 0 {
                        1
                    } else {
                        r.input_len + r.generated
                    }
                })
                .collect();
            let t = self.lat.prefill(self.spec, self.tp, &lens);
            let t = self.jitter(t);
            self.clock += t;
            self.outcome.busy_time += t;
            self.outcome.prefill_iterations += 1;
            for &i in &batch {
                self.slots[i].req.generated += 1;
                self.outcome.tokens_generated += 1;
                if self.slots[i].req.is_done() {
                    self.finish(i);
                } else {
                    self.running.push(i);
                }
            }
            self.record_trace();
            return true;
        }

        if self.running.is_empty() {
            return false;
        }

        if self.cfg.fast_forward {
            self.decode_run()
        } else {
            self.decode_once()
        }
    }

    /// One decode iteration, exact.
    fn decode_once(&mut self) -> bool {
        // Grow KV; preempt on OOM.
        let mut i = 0;
        while i < self.running.len() {
            let idx = self.running[i];
            let need_block = self.slots[idx].ctx % self.cfg.block_tokens == 0;
            if need_block {
                while self.free_blocks < 1 {
                    if self.running.len() <= 1 || !self.preempt_latest() {
                        break;
                    }
                }
                if self.slots[idx].state != ReqState::Running {
                    // preempt_latest evicted `idx` itself; running[i] now
                    // holds a different request — revisit this position.
                    continue;
                }
                if self.free_blocks >= 1 {
                    self.free_blocks -= 1;
                    self.slots[idx].blocks += 1;
                }
            }
            i += 1;
        }
        let batch = self.running.len();
        if batch == 0 {
            return false;
        }
        let total_ctx: u64 = self.running.iter().map(|&i| self.slots[i].ctx as u64).sum();
        let max_ctx = self.running.iter().map(|&i| self.slots[i].ctx).max().unwrap();
        let t = self.lat.decode(self.spec, self.tp, batch, total_ctx, max_ctx);
        let t = self.jitter(t);
        self.clock += t;
        self.outcome.busy_time += t;
        self.outcome.decode_iterations += 1;
        self.outcome.tokens_generated += batch as u64;
        let mut j = 0;
        while j < self.running.len() {
            let idx = self.running[j];
            let slot = &mut self.slots[idx];
            slot.ctx += 1;
            slot.req.generated += 1;
            if slot.req.is_done() {
                self.running.swap_remove(j);
                self.finish(idx);
            } else {
                j += 1;
            }
        }
        self.record_trace();
        true
    }

    /// Fast path: jump over `n` uniform decode iterations where `n` is
    /// bounded by the next completion, the next admission-ready prompt,
    /// and the block budget. Prices the run at its midpoint context.
    fn decode_run(&mut self) -> bool {
        let batch = self.running.len();
        let min_remaining = self
            .running
            .iter()
            .map(|&i| self.slots[i].req.remaining())
            .min()
            .unwrap_or(0)
            .max(1);
        // Admission is impossible while the running set is full, no matter
        // how many prompts are ready — only a completion (already bounded
        // by `min_remaining`) can open a slot.
        let until_ready = if self.running.len() >= self.cfg.max_num_seqs {
            u32::MAX
        } else {
            match self.next_ready() {
                Some(t) if t > self.clock => u32::MAX,
                Some(_) => 1, // a prompt is admissible now -> go exact
                None => u32::MAX,
            }
        };
        let spare = self.free_blocks.saturating_sub(self.cfg.watermark_blocks);
        let until_oom = if spare == 0 {
            1
        } else {
            ((spare * self.cfg.block_tokens as u64) / batch as u64).max(1).min(u32::MAX as u64)
                as u32
        };
        let mut n = min_remaining.min(until_oom).min(until_ready).max(1);
        // Deadline bound: estimate the per-iteration cost at the current
        // context and cap the jump so the clock lands at most one
        // iteration past the deadline (stage replays depend on this).
        if let Some(d) = self.deadline {
            let total_ctx0: u64 = self.running.iter().map(|&i| self.slots[i].ctx as u64).sum();
            let max_ctx0 = self.running.iter().map(|&i| self.slots[i].ctx).max().unwrap();
            let t_est = self.lat.decode(self.spec, self.tp, batch, total_ctx0, max_ctx0).max(1e-9);
            let room = ((d - self.clock) / t_est).ceil();
            if room < n as f64 {
                n = (room.max(1.0)) as u32;
            }
        }
        let n = n;
        if n <= 2 {
            return self.decode_once();
        }

        let total_ctx0: u64 = self.running.iter().map(|&i| self.slots[i].ctx as u64).sum();
        let mid = n as u64 / 2;
        let total_ctx_mid = total_ctx0 + mid * batch as u64;
        let max_ctx_mid =
            self.running.iter().map(|&i| self.slots[i].ctx).max().unwrap() + mid as u32;
        let t_one = self.lat.decode(self.spec, self.tp, batch, total_ctx_mid, max_ctx_mid);
        let t = self.jitter(t_one * n as f64);
        self.clock += t;
        self.outcome.busy_time += t;
        self.outcome.decode_iterations += n as u64;
        self.outcome.tokens_generated += n as u64 * batch as u64;

        let bt = self.cfg.block_tokens as u64;
        let mut blocks_used = 0u64;
        let mut j = 0;
        while j < self.running.len() {
            let idx = self.running[j];
            let slot = &mut self.slots[idx];
            let old_ctx = slot.ctx;
            slot.ctx += n;
            slot.req.generated += n;
            let new_blocks = (slot.ctx as u64).div_ceil(bt) - (old_ctx as u64).div_ceil(bt);
            blocks_used += new_blocks;
            slot.blocks += new_blocks;
            if slot.req.is_done() {
                self.running.swap_remove(j);
                self.finish(idx);
            } else {
                j += 1;
            }
        }
        self.free_blocks = self.free_blocks.saturating_sub(blocks_used);
        self.record_trace();
        true
    }

    /// Advance the clock while nothing is runnable (pipeline idling).
    /// Returns `false` if there is nothing to wait for (done, or blocked
    /// on a chain predecessor that lives in another engine).
    pub fn idle_until_ready(&mut self) -> bool {
        match self.next_ready() {
            Some(t) if t > self.clock => {
                self.clock = t;
                true
            }
            Some(_) => true,
            None => false,
        }
    }

    /// Run to completion (or until `deadline`). Returns the outcome so far.
    pub fn run(&mut self, deadline: Option<f64>) -> SimOutcome {
        self.deadline = deadline;
        loop {
            if let Some(d) = deadline {
                if self.clock >= d {
                    break;
                }
            }
            if !self.step() && !self.idle_until_ready() {
                break;
            }
        }
        self.deadline = None;
        self.outcome.clock = self.clock;
        self.outcome.clone()
    }

    /// Extract unfinished requests (for stage transitions / preemption).
    /// Running requests keep their generated progress but lose KV state —
    /// they will re-prefill `input + generated` tokens when re-admitted.
    pub fn drain_unfinished(&mut self) -> Vec<EngineRequest> {
        let mut out = vec![];
        for slot in &mut self.slots {
            if slot.state != ReqState::Done {
                out.push(slot.req);
                slot.state = ReqState::Done;
            }
        }
        self.running.clear();
        self.waiting.clear();
        out
    }

    /// The accumulated outcome so far.
    pub fn outcome(&self) -> &SimOutcome {
        &self.outcome
    }

    /// Record a (clock, running-count) point per iteration (Fig. 3).
    pub fn enable_trace(&mut self) {
        self.iter_trace = Some(vec![]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::costmodel::HardwareModel;
    use crate::models::Registry;

    fn fixture() -> (crate::models::ModelSpec, HardwareModel) {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = HardwareModel::new(ClusterSpec::a100_node(8));
        (spec, hw)
    }

    fn reqs(n: usize, input: u32, output: u32) -> Vec<EngineRequest> {
        (0..n as u64).map(|i| EngineRequest::fresh(i, input, output)).collect()
    }

    fn sim<'a>(
        spec: &'a crate::models::ModelSpec,
        hw: &'a HardwareModel,
        cfg: EngineConfig,
        rs: Vec<EngineRequest>,
    ) -> EngineSim<'a> {
        EngineSim::new(spec, 1, hw, cfg, rs, 0.0, 0)
    }

    #[test]
    fn completes_all_requests() {
        let (spec, hw) = fixture();
        let cfg = EngineConfig::standard(&spec, 1, ClusterSpec::a100_node(8).mem_bytes);
        let mut s = sim(&spec, &hw, cfg, reqs(100, 20, 50));
        let out = s.run(None);
        assert_eq!(out.finished, 100);
        assert!(s.is_done());
        assert_eq!(out.tokens_generated, 100 * 50);
        assert!(out.clock > 0.0);
        assert_eq!(s.completions.len(), 100);
    }

    #[test]
    fn fast_forward_matches_exact_closely() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let mut cfg = EngineConfig::standard(&spec, 1, mem);
        cfg.fast_forward = false;
        let t_exact = sim(&spec, &hw, cfg.clone(), reqs(200, 25, 120)).run(None).clock;
        cfg.fast_forward = true;
        let t_fast = sim(&spec, &hw, cfg, reqs(200, 25, 120)).run(None).clock;
        let err = (t_fast - t_exact).abs() / t_exact;
        assert!(err < 0.02, "fast {t_fast} vs exact {t_exact} (err {err})");
    }

    #[test]
    fn anchor_chatglm_1k_requests_one_gpu() {
        // Paper §5.1: chatglm3-6b, 1000 requests, limit 512 -> ~37-48 s
        // inference-only on 1 GPU. Average output ≈ 180 tokens.
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem);
        let mut rng = Rng::new(1);
        let rs: Vec<EngineRequest> = (0..1000)
            .map(|i| {
                let out = crate::workload::lengths::true_output_len(
                    "chatglm3-6b", 0.0, 21, 512, 8192, &mut rng,
                );
                EngineRequest::fresh(i, 21, out)
            })
            .collect();
        let t = sim(&spec, &hw, cfg, rs).run(None).clock;
        assert!((25.0..70.0).contains(&t), "1-GPU time {t} (paper ~37-48 s)");
    }

    #[test]
    fn data_parallel_split_is_sublinear() {
        // 8 replicas over 1/8 of the workload each must NOT be 8x faster
        // (the paper's central observation).
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem);
        let mut rng = Rng::new(2);
        let all: Vec<EngineRequest> = (0..1000)
            .map(|i| {
                let o = crate::workload::lengths::true_output_len(
                    "chatglm3-6b", 0.0, 21, 512, 8192, &mut rng,
                );
                EngineRequest::fresh(i, 21, o)
            })
            .collect();
        let t1 = sim(&spec, &hw, cfg.clone(), all.clone()).run(None).clock;
        let part: Vec<EngineRequest> = all.iter().step_by(8).copied().collect();
        let t8 = sim(&spec, &hw, cfg, part).run(None).clock;
        let speedup = t1 / t8;
        assert!(speedup > 1.5, "speedup {speedup}");
        assert!(speedup < 7.0, "speedup {speedup} suspiciously linear");
    }

    #[test]
    fn respects_ready_times() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem);
        let mut rs = reqs(10, 30, 20);
        for (i, r) in rs.iter_mut().enumerate() {
            r.ready_time = 100.0 + i as f64;
        }
        let mut s = sim(&spec, &hw, cfg, rs);
        let out = s.run(None);
        assert!(out.clock >= 100.0, "clock {} must wait for ready time", out.clock);
        assert_eq!(out.finished, 10);
        assert!(out.busy_time < out.clock);
    }

    #[test]
    fn chain_successors_unblock_in_order() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem);
        // A 3-link chain: 0 -> 1 -> 2, plus an independent request 3.
        let mut rs = reqs(4, 50, 30);
        rs[0].chain_next = Some(1);
        rs[1].ready_time = EngineRequest::BLOCKED;
        rs[1].chain_next = Some(2);
        rs[2].ready_time = EngineRequest::BLOCKED;
        let mut s = sim(&spec, &hw, cfg, rs);
        let out = s.run(None);
        assert_eq!(out.finished, 4);
        let t = |id: u64| s.completions.iter().find(|(i, _)| *i == id).unwrap().1;
        assert!(t(0) < t(1) && t(1) < t(2), "chain order violated");
    }

    #[test]
    fn preemption_by_recompute_under_block_pressure() {
        let (spec, hw) = fixture();
        let mut cfg = EngineConfig::standard(&spec, 1, ClusterSpec::a100_node(8).mem_bytes);
        cfg.kv_bytes_budget = 3000 * spec.kv_bytes_per_token(1) as u64;
        cfg.fast_forward = false;
        let mut s = sim(&spec, &hw, cfg, reqs(16, 100, 800));
        let out = s.run(None);
        assert_eq!(out.finished, 16, "all requests must still complete");
        assert!(out.preemptions > 0, "expected OOM preemptions");
    }

    #[test]
    fn drain_unfinished_preserves_progress() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem);
        let mut s = sim(&spec, &hw, cfg, reqs(100, 20, 400));
        s.run(Some(2.0));
        let rest = s.drain_unfinished();
        assert!(!rest.is_empty());
        let progressed = rest.iter().filter(|r| r.generated > 0).count();
        assert!(progressed > 0, "some requests should carry progress");
        for r in &rest {
            assert!(r.generated < r.output_len);
        }
    }

    #[test]
    fn trace_records_running_counts() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let mut cfg = EngineConfig::standard(&spec, 1, mem);
        cfg.fast_forward = false;
        let mut s = sim(&spec, &hw, cfg, reqs(50, 20, 60));
        s.enable_trace();
        s.run(None);
        let trace = s.iter_trace.as_ref().unwrap();
        assert!(!trace.is_empty());
        let peak = trace.iter().map(|&(_, n)| n).max().unwrap();
        assert!(peak <= 256 && peak >= 40, "peak {peak}");
    }

    #[test]
    fn noise_changes_clock_but_not_results() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let mut cfg = EngineConfig::standard(&spec, 1, mem);
        cfg.noise_sigma = Some(0.03);
        let t_a = EngineSim::new(&spec, 1, &hw, cfg.clone(), reqs(64, 20, 80), 0.0, 1)
            .run(None)
            .clock;
        let t_b = EngineSim::new(&spec, 1, &hw, cfg.clone(), reqs(64, 20, 80), 0.0, 2)
            .run(None)
            .clock;
        assert_ne!(t_a, t_b);
        cfg.noise_sigma = None;
        let t_c = EngineSim::new(&spec, 1, &hw, cfg, reqs(64, 20, 80), 0.0, 3).run(None).clock;
        for t in [t_a, t_b] {
            assert!((t - t_c).abs() / t_c < 0.1, "noisy {t} vs clean {t_c}");
        }
    }
}
