//! The virtual-time engine simulator: the shared scheduling core
//! ([`crate::engine::sched::SchedCore`]) priced by an
//! [`crate::costmodel::IterLatency`] oracle.
//!
//! Implements the vLLM-v0 scheduling loop:
//! 1. if prompts are waiting, KV blocks are available and the running set
//!    has room → run a *prefill* iteration over an FCFS batch of prompts
//!    (bounded by `max_batch_tokens`); the prefill also emits each
//!    request's first output token;
//! 2. otherwise run a *decode* iteration: every running request produces
//!    one token; requests that exhaust their KV-block budget trigger
//!    preemption-by-recompute of the most recently admitted request;
//! 3. requests that reach their output length leave and free their blocks.
//!
//! Requests carry absolute `ready_time`s (set by the communicator for
//! dependent models) and may form in-engine chains (fused self-loop nodes,
//! §4.1): completing a request unblocks its `chain_next` successor.
//!
//! A `fast_step` mode aggregates maximal runs of stable-composition
//! decode iterations (no admission, no completion, no OOM in between)
//! into one window with O(1) bookkeeping per iteration, pricing every
//! iteration at its *exact* context through [`StepExec::decode_tick`] —
//! results are bit-identical to per-token stepping; only wall-clock
//! changes. This is what makes planning cheap (§4.2 "our request
//! scheduling simulator processes different execution plans in
//! parallel").
//!
//! The scheduling discipline itself lives in [`crate::engine::sched`] and
//! is shared with the real PJRT execution path
//! ([`crate::exec::pjrt::PjrtBackend`]); this module contributes only the
//! oracle-priced [`StepExec`] implementation.

pub use super::sched::{EngineConfig, SimOutcome};
use super::sched::{SchedCore, StepExec, StepReq};
use super::EngineRequest;
use crate::costmodel::IterLatency;
use crate::models::ModelSpec;

/// [`StepExec`] that *prices* iterations with an [`IterLatency`] oracle in
/// virtual time — never executes anything. This is the planner's and the
/// virtual running phase's executor.
pub struct OracleStep<'a> {
    spec: &'a ModelSpec,
    tp: u32,
    lat: &'a dyn IterLatency,
}

impl<'a> OracleStep<'a> {
    /// Price iterations of `spec` under `tp` with the given oracle.
    pub fn new(spec: &'a ModelSpec, tp: u32, lat: &'a dyn IterLatency) -> Self {
        OracleStep { spec, tp, lat }
    }

    fn decode_at(&self, running: &[StepReq]) -> f64 {
        let total_ctx: u64 = running.iter().map(|r| r.ctx as u64).sum();
        let max_ctx = running.iter().map(|r| r.ctx).max().unwrap_or(0);
        self.lat.decode(self.spec, self.tp, running.len(), total_ctx, max_ctx)
    }
}

impl StepExec for OracleStep<'_> {
    fn prefill(&mut self, admitted: &[StepReq], _running: &[StepReq]) -> f64 {
        let lens: Vec<u32> = admitted
            .iter()
            .map(|r| {
                if r.kv_resident && r.generated > 0 {
                    1
                } else {
                    r.input_len + r.generated
                }
            })
            .collect();
        self.lat.prefill(self.spec, self.tp, &lens)
    }

    fn decode(&mut self, running: &[StepReq]) -> f64 {
        self.decode_at(running)
    }

    fn decode_tick(&mut self, batch: usize, total_ctx: u64, max_ctx: u32) -> Option<f64> {
        // The same oracle call decode_at() makes, at the same arguments
        // the core would have materialised — bit-identical by
        // construction.
        Some(self.lat.decode(self.spec, self.tp, batch, total_ctx, max_ctx))
    }
}

/// Single-replica engine simulator: the scheduling core under an oracle
/// executor. See module docs.
pub type EngineSim<'a> = SchedCore<OracleStep<'a>>;

impl<'a> SchedCore<OracleStep<'a>> {
    /// Build a replica simulator over `requests`, starting its clock at
    /// `start_time`. KV capacity is derived from the config's budget.
    pub fn new(
        spec: &'a ModelSpec,
        tp: u32,
        lat: &'a dyn IterLatency,
        cfg: EngineConfig,
        requests: Vec<EngineRequest>,
        start_time: f64,
        noise_seed: u64,
    ) -> Self {
        let block_bytes = cfg.block_tokens as u64 * spec.kv_bytes_per_token(tp) * tp as u64;
        SchedCore::with_exec(
            OracleStep::new(spec, tp, lat),
            cfg,
            block_bytes,
            requests,
            start_time,
            noise_seed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::costmodel::HardwareModel;
    use crate::models::Registry;
    use crate::util::rng::Rng;

    fn fixture() -> (crate::models::ModelSpec, HardwareModel) {
        let reg = Registry::paper();
        let spec = reg.get("chatglm3-6b").unwrap().clone();
        let hw = HardwareModel::new(ClusterSpec::a100_node(8));
        (spec, hw)
    }

    fn reqs(n: usize, input: u32, output: u32) -> Vec<EngineRequest> {
        (0..n as u64).map(|i| EngineRequest::fresh(i, input, output)).collect()
    }

    fn sim<'a>(
        spec: &'a crate::models::ModelSpec,
        hw: &'a HardwareModel,
        cfg: EngineConfig,
        rs: Vec<EngineRequest>,
    ) -> EngineSim<'a> {
        EngineSim::new(spec, 1, hw, cfg, rs, 0.0, 0)
    }

    #[test]
    fn completes_all_requests() {
        let (spec, hw) = fixture();
        let cfg = EngineConfig::standard(&spec, 1, ClusterSpec::a100_node(8).mem_bytes).unwrap();
        let mut s = sim(&spec, &hw, cfg, reqs(100, 20, 50));
        let out = s.run(None);
        assert_eq!(out.finished, 100);
        assert!(s.is_done());
        assert_eq!(out.tokens_generated, 100 * 50);
        assert!(out.clock > 0.0);
        assert_eq!(s.completions.len(), 100);
    }

    #[test]
    fn fast_step_is_bit_identical_to_exact() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let mut cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        cfg.fast_step = false;
        let exact = sim(&spec, &hw, cfg.clone(), reqs(200, 25, 120)).run(None);
        cfg.fast_step = true;
        let fast = sim(&spec, &hw, cfg, reqs(200, 25, 120)).run(None);
        assert_eq!(fast.clock.to_bits(), exact.clock.to_bits());
        assert_eq!(fast.busy_time.to_bits(), exact.busy_time.to_bits());
        assert_eq!(fast, exact);
    }

    #[test]
    fn anchor_chatglm_1k_requests_one_gpu() {
        // Paper §5.1: chatglm3-6b, 1000 requests, limit 512 -> ~37-48 s
        // inference-only on 1 GPU. Average output ≈ 180 tokens.
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        let mut rng = Rng::new(1);
        let rs: Vec<EngineRequest> = (0..1000)
            .map(|i| {
                let out = crate::workload::lengths::true_output_len(
                    "chatglm3-6b", 0.0, 21, 512, 8192, &mut rng,
                );
                EngineRequest::fresh(i, 21, out)
            })
            .collect();
        let t = sim(&spec, &hw, cfg, rs).run(None).clock;
        assert!((25.0..70.0).contains(&t), "1-GPU time {t} (paper ~37-48 s)");
    }

    #[test]
    fn data_parallel_split_is_sublinear() {
        // 8 replicas over 1/8 of the workload each must NOT be 8x faster
        // (the paper's central observation).
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        let mut rng = Rng::new(2);
        let all: Vec<EngineRequest> = (0..1000)
            .map(|i| {
                let o = crate::workload::lengths::true_output_len(
                    "chatglm3-6b", 0.0, 21, 512, 8192, &mut rng,
                );
                EngineRequest::fresh(i, 21, o)
            })
            .collect();
        let t1 = sim(&spec, &hw, cfg.clone(), all.clone()).run(None).clock;
        let part: Vec<EngineRequest> = all.iter().step_by(8).copied().collect();
        let t8 = sim(&spec, &hw, cfg, part).run(None).clock;
        let speedup = t1 / t8;
        assert!(speedup > 1.5, "speedup {speedup}");
        assert!(speedup < 7.0, "speedup {speedup} suspiciously linear");
    }

    #[test]
    fn respects_ready_times() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        let mut rs = reqs(10, 30, 20);
        for (i, r) in rs.iter_mut().enumerate() {
            r.ready_time = 100.0 + i as f64;
        }
        let mut s = sim(&spec, &hw, cfg, rs);
        let out = s.run(None);
        assert!(out.clock >= 100.0, "clock {} must wait for ready time", out.clock);
        assert_eq!(out.finished, 10);
        assert!(out.busy_time < out.clock);
    }

    #[test]
    fn chain_successors_unblock_in_order() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        // A 3-link chain: 0 -> 1 -> 2, plus an independent request 3.
        let mut rs = reqs(4, 50, 30);
        rs[0].chain_next = Some(1);
        rs[1].ready_time = EngineRequest::BLOCKED;
        rs[1].chain_next = Some(2);
        rs[2].ready_time = EngineRequest::BLOCKED;
        let mut s = sim(&spec, &hw, cfg, rs);
        let out = s.run(None);
        assert_eq!(out.finished, 4);
        let t = |id: u64| s.completions.iter().find(|(i, _)| *i == id).unwrap().1;
        assert!(t(0) < t(1) && t(1) < t(2), "chain order violated");
    }

    #[test]
    fn preemption_by_recompute_under_block_pressure() {
        let (spec, hw) = fixture();
        let mut cfg =
            EngineConfig::standard(&spec, 1, ClusterSpec::a100_node(8).mem_bytes).unwrap();
        cfg.kv_bytes_budget = 3000 * spec.kv_bytes_per_token(1);
        cfg.fast_step = false;
        let mut s = sim(&spec, &hw, cfg, reqs(16, 100, 800));
        let out = s.run(None);
        assert_eq!(out.finished, 16, "all requests must still complete");
        assert!(out.preemptions > 0, "expected OOM preemptions");
    }

    #[test]
    fn drain_unfinished_preserves_progress() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        let mut s = sim(&spec, &hw, cfg, reqs(100, 20, 400));
        s.run(Some(2.0));
        let rest = s.drain_unfinished();
        assert!(!rest.is_empty());
        let progressed = rest.iter().filter(|r| r.generated > 0).count();
        assert!(progressed > 0, "some requests should carry progress");
        for r in &rest {
            assert!(r.generated < r.output_len);
        }
    }

    #[test]
    fn trace_records_running_counts() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let mut cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        cfg.fast_step = false;
        let mut s = sim(&spec, &hw, cfg, reqs(50, 20, 60));
        s.enable_trace();
        s.run(None);
        let trace = s.iter_trace.as_ref().unwrap();
        assert!(!trace.is_empty());
        let peak = trace.iter().map(|&(_, n)| n).max().unwrap();
        assert!(peak <= 256 && peak >= 40, "peak {peak}");
    }

    #[test]
    fn noise_changes_clock_but_not_results() {
        let (spec, hw) = fixture();
        let mem = ClusterSpec::a100_node(8).mem_bytes;
        let mut cfg = EngineConfig::standard(&spec, 1, mem).unwrap();
        cfg.noise_sigma = Some(0.03);
        let t_a = EngineSim::new(&spec, 1, &hw, cfg.clone(), reqs(64, 20, 80), 0.0, 1)
            .run(None)
            .clock;
        let t_b = EngineSim::new(&spec, 1, &hw, cfg.clone(), reqs(64, 20, 80), 0.0, 2)
            .run(None)
            .clock;
        assert_ne!(t_a, t_b);
        cfg.noise_sigma = None;
        let t_c = EngineSim::new(&spec, 1, &hw, cfg, reqs(64, 20, 80), 0.0, 3).run(None).clock;
        for t in [t_a, t_b] {
            assert!((t - t_c).abs() / t_c < 0.1, "noisy {t} vs clean {t_c}");
        }
    }
}
