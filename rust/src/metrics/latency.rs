//! Serving-latency metrics for open-loop traffic runs.
//!
//! Once arrivals are an ongoing process, makespan is the wrong objective:
//! a traffic run is measured over its warmup+measurement window with the
//! serving metrics — per-app TTFT (time to first token), TPOT (time per
//! output token), p50/p99 request latency, and SLO attainment — collected
//! in a [`TrafficReport`] that rides in
//! [`RunReport::traffic`](crate::metrics::RunReport) and the Gantt
//! footer.
//!
//! Conventions (one [`RequestSample`] per node-level request):
//! * **latency** = `finish − arrival` (queue wait included),
//! * **residence** = `finish − admit` (execution time after admission),
//! * **TPOT** = `residence / L` with `L = max(output_len, 1)` — the
//!   simulator resolves whole requests at stage boundaries, so the
//!   per-token time is the residence spread over the generated tokens,
//! * **TTFT** = `(admit − arrival) + residence / L` — queue wait plus one
//!   token's worth of generation,
//! * a sample is **in-window** iff its *arrival* lies in
//!   `[warmup, warmup + duration)`; only in-window samples (and rejects)
//!   are measured,
//! * **SLO attainment** = in-window samples with `latency ≤ slo`, divided
//!   by in-window samples *plus* in-window rejected requests (a dropped
//!   request is a missed SLO, not a free pass).
//!
//! All percentiles go through
//! [`util::stats::percentile_sorted`](crate::util::stats::percentile_sorted).

use crate::traffic::QueueCounters;
use crate::util::json::Json;
use crate::util::stats::percentile_sorted;

/// One completed node-level request of a traffic run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSample {
    /// Owning app (index into the traffic mix).
    pub app_id: usize,
    /// Wall-clock arrival time of the job (seconds).
    pub arrival: f64,
    /// Time the job was admitted out of the queue.
    pub admit: f64,
    /// Time the request finished generating.
    pub finish: f64,
    /// Generated output tokens.
    pub output_len: u32,
}

impl RequestSample {
    /// End-to-end request latency: `finish − arrival`.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Time per output token: post-admission residence spread over the
    /// generated tokens.
    pub fn tpot(&self) -> f64 {
        (self.finish - self.admit) / self.output_len.max(1) as f64
    }

    /// Time to first token: queue wait plus one token's generation time.
    pub fn ttft(&self) -> f64 {
        (self.admit - self.arrival) + self.tpot()
    }
}

/// Per-app traffic metadata and counters fed into the report builder.
#[derive(Debug, Clone)]
pub struct AppTrafficStats {
    /// The app's scenario name.
    pub name: String,
    /// Fair-share weight the run used.
    pub weight: f64,
    /// The app's latency SLO, if one was set.
    pub slo: Option<f64>,
    /// Job-level admission counters from the queue.
    pub counters: QueueCounters,
    /// Rejected *requests* (jobs × the app's node count) whose arrival
    /// fell inside the measurement window — they count against SLO
    /// attainment.
    pub rejected_in_window: u64,
}

/// Per-app windowed serving metrics. Latency fields are `None` when no
/// in-window sample completed (serialised as JSON `null`).
#[derive(Debug, Clone, PartialEq)]
pub struct AppLatency {
    /// Owning app.
    pub app_id: usize,
    /// The app's scenario name.
    pub name: String,
    /// Fair-share weight the run used.
    pub weight: f64,
    /// The app's latency SLO, if one was set.
    pub slo: Option<f64>,
    /// Jobs the arrival stream offered (whole horizon).
    pub offered: u64,
    /// Jobs admitted into execution (whole horizon).
    pub admitted: u64,
    /// Jobs dropped on overflow (whole horizon).
    pub rejected: u64,
    /// Jobs parked on overflow and run later (whole horizon).
    pub deferred: u64,
    /// In-window completed request samples.
    pub completed: u64,
    /// Mean time to first token.
    pub ttft_mean: Option<f64>,
    /// p99 time to first token.
    pub ttft_p99: Option<f64>,
    /// Mean time per output token.
    pub tpot_mean: Option<f64>,
    /// Median request latency.
    pub latency_p50: Option<f64>,
    /// p99 request latency.
    pub latency_p99: Option<f64>,
    /// Fraction of in-window requests (completed + rejected) within the
    /// SLO; `None` when the app has no SLO or nothing was measured.
    pub slo_attainment: Option<f64>,
}

/// The serving-metrics section of a traffic run's [`RunReport`]
/// (`report.traffic` / the `"traffic"` JSON key).
///
/// [`RunReport`]: crate::metrics::RunReport
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficReport {
    /// Measurement-window length in seconds.
    pub duration: f64,
    /// Warmup seconds before the window opened.
    pub warmup: f64,
    /// Jobs offered across all apps (whole horizon).
    pub offered: u64,
    /// Jobs admitted across all apps.
    pub admitted: u64,
    /// Jobs rejected across all apps.
    pub rejected: u64,
    /// Jobs deferred across all apps.
    pub deferred: u64,
    /// Mean admission-queue depth over the run's stage boundaries.
    pub queue_depth_mean: f64,
    /// Maximum admission-queue depth observed.
    pub queue_depth_max: usize,
    /// Per-app windowed metrics, indexed by app id.
    pub per_app: Vec<AppLatency>,
}

impl TrafficReport {
    /// Build the report: filter `samples` to the measurement window,
    /// compute per-app TTFT/TPOT/latency percentiles (via
    /// [`percentile_sorted`]) and SLO attainment, and total the queue
    /// counters.
    pub fn build(
        duration: f64,
        warmup: f64,
        apps: Vec<AppTrafficStats>,
        samples: &[RequestSample],
        queue_depth_mean: f64,
        queue_depth_max: usize,
    ) -> Self {
        let in_window =
            |s: &&RequestSample| s.arrival >= warmup && s.arrival < warmup + duration;
        let per_app = apps
            .iter()
            .enumerate()
            .map(|(app_id, a)| {
                let mine: Vec<&RequestSample> = samples
                    .iter()
                    .filter(|s| s.app_id == app_id)
                    .filter(in_window)
                    .collect();
                let mut latencies: Vec<f64> = mine.iter().map(|s| s.latency()).collect();
                let mut ttfts: Vec<f64> = mine.iter().map(|s| s.ttft()).collect();
                latencies.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
                ttfts.sort_by(|a, b| a.partial_cmp(b).expect("ttfts are finite"));
                let mean = |xs: &[f64]| {
                    (!xs.is_empty()).then(|| xs.iter().sum::<f64>() / xs.len() as f64)
                };
                let pct = |xs: &[f64], q: f64| {
                    (!xs.is_empty()).then(|| percentile_sorted(xs, q))
                };
                let slo_attainment = a.slo.and_then(|slo| {
                    let denom = latencies.len() as u64 + a.rejected_in_window;
                    (denom > 0).then(|| {
                        latencies.iter().filter(|&&l| l <= slo).count() as f64
                            / denom as f64
                    })
                });
                AppLatency {
                    app_id,
                    name: a.name.clone(),
                    weight: a.weight,
                    slo: a.slo,
                    offered: a.counters.offered,
                    admitted: a.counters.admitted,
                    rejected: a.counters.rejected,
                    deferred: a.counters.deferred,
                    completed: mine.len() as u64,
                    ttft_mean: mean(&ttfts),
                    ttft_p99: pct(&ttfts, 0.99),
                    tpot_mean: mean(&mine.iter().map(|s| s.tpot()).collect::<Vec<_>>()),
                    latency_p50: pct(&latencies, 0.50),
                    latency_p99: pct(&latencies, 0.99),
                    slo_attainment,
                }
            })
            .collect::<Vec<_>>();
        TrafficReport {
            duration,
            warmup,
            offered: per_app.iter().map(|a| a.offered).sum(),
            admitted: per_app.iter().map(|a| a.admitted).sum(),
            rejected: per_app.iter().map(|a| a.rejected).sum(),
            deferred: per_app.iter().map(|a| a.deferred).sum(),
            queue_depth_mean,
            queue_depth_max,
            per_app,
        }
    }

    /// Serialize as the `"traffic"` section of the run-report JSON.
    pub fn to_json(&self) -> Json {
        let opt = |x: Option<f64>| x.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("duration", Json::Num(self.duration)),
            ("warmup", Json::Num(self.warmup)),
            ("offered", Json::Num(self.offered as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("deferred", Json::Num(self.deferred as f64)),
            ("queue_depth_mean", Json::Num(self.queue_depth_mean)),
            ("queue_depth_max", Json::Num(self.queue_depth_max as f64)),
            (
                "apps",
                Json::Arr(
                    self.per_app
                        .iter()
                        .map(|a| {
                            Json::obj(vec![
                                ("app", Json::Str(a.name.clone())),
                                ("weight", Json::Num(a.weight)),
                                ("slo", opt(a.slo)),
                                ("offered", Json::Num(a.offered as f64)),
                                ("admitted", Json::Num(a.admitted as f64)),
                                ("rejected", Json::Num(a.rejected as f64)),
                                ("deferred", Json::Num(a.deferred as f64)),
                                ("completed", Json::Num(a.completed as f64)),
                                ("ttft_mean", opt(a.ttft_mean)),
                                ("ttft_p99", opt(a.ttft_p99)),
                                ("tpot_mean", opt(a.tpot_mean)),
                                ("latency_p50", opt(a.latency_p50)),
                                ("latency_p99", opt(a.latency_p99)),
                                ("slo_attainment", opt(a.slo_attainment)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(slo: Option<f64>, rejected_in_window: u64) -> AppTrafficStats {
        AppTrafficStats {
            name: "app".into(),
            weight: 1.0,
            slo,
            counters: QueueCounters {
                offered: 120,
                admitted: 100,
                rejected: 20,
                deferred: 0,
            },
            rejected_in_window,
        }
    }

    /// Latencies 1..=100 s: known percentile values under linear
    /// interpolation (p50 = 50.5, p99 = 99.01 at pos 98.01).
    fn ladder() -> Vec<RequestSample> {
        (1..=100)
            .map(|k| RequestSample {
                app_id: 0,
                arrival: 0.0,
                admit: 0.0,
                finish: k as f64,
                output_len: 1,
            })
            .collect()
    }

    #[test]
    fn p50_p99_on_known_distribution() {
        let r =
            TrafficReport::build(10.0, 0.0, vec![stats(None, 0)], &ladder(), 0.0, 0);
        let a = &r.per_app[0];
        assert_eq!(a.completed, 100);
        assert!((a.latency_p50.unwrap() - 50.5).abs() < 1e-9, "{:?}", a.latency_p50);
        assert!((a.latency_p99.unwrap() - 99.01).abs() < 1e-9, "{:?}", a.latency_p99);
        // With zero queue wait and L = 1, TTFT == latency.
        assert!((a.ttft_p99.unwrap() - 99.01).abs() < 1e-9);
        assert!((a.ttft_mean.unwrap() - 50.5).abs() < 1e-9);
        assert!((a.tpot_mean.unwrap() - 50.5).abs() < 1e-9);
        assert_eq!(a.slo_attainment, None, "no SLO set");
    }

    #[test]
    fn ttft_tpot_decomposition() {
        // Arrive 0, admitted 2 (queue wait 2), finish 12 (residence 10),
        // 5 tokens → TPOT 2, TTFT 2 + 2 = 4, latency 12.
        let s = RequestSample {
            app_id: 0,
            arrival: 0.0,
            admit: 2.0,
            finish: 12.0,
            output_len: 5,
        };
        assert!((s.tpot() - 2.0).abs() < 1e-12);
        assert!((s.ttft() - 4.0).abs() < 1e-12);
        assert!((s.latency() - 12.0).abs() < 1e-12);
        // Zero-length outputs clamp L to 1 instead of dividing by zero.
        let z = RequestSample { output_len: 0, ..s };
        assert!((z.tpot() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn warmup_window_filters_by_arrival() {
        let mk = |arrival: f64| RequestSample {
            app_id: 0,
            arrival,
            admit: arrival,
            finish: arrival + 1.0,
            output_len: 1,
        };
        // Window [10, 20): 9.99 and 20.0 are out, 10.0 and 19.99 are in.
        let samples = vec![mk(9.99), mk(10.0), mk(19.99), mk(20.0)];
        let r = TrafficReport::build(10.0, 10.0, vec![stats(None, 0)], &samples, 0.0, 0);
        assert_eq!(r.per_app[0].completed, 2);
    }

    #[test]
    fn slo_attainment_counts_rejects_as_misses() {
        // SLO 50 s over the 1..=100 ladder: 50 of 100 within. 100
        // rejected in-window requests drag it to 50/200.
        let r = TrafficReport::build(
            10.0,
            0.0,
            vec![stats(Some(50.0), 100)],
            &ladder(),
            0.0,
            0,
        );
        assert!((r.per_app[0].slo_attainment.unwrap() - 0.25).abs() < 1e-12);
        // Without rejects: exactly half.
        let r =
            TrafficReport::build(10.0, 0.0, vec![stats(Some(50.0), 0)], &ladder(), 0.0, 0);
        assert!((r.per_app[0].slo_attainment.unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_window_yields_nulls_not_panics() {
        let r = TrafficReport::build(10.0, 0.0, vec![stats(Some(1.0), 0)], &[], 0.0, 0);
        let a = &r.per_app[0];
        assert_eq!(a.completed, 0);
        assert_eq!(a.latency_p50, None);
        assert_eq!(a.ttft_mean, None);
        assert_eq!(a.slo_attainment, None);
        let json = r.to_json().to_string();
        assert!(json.contains("\"latency_p50\":null"), "{json}");
    }

    #[test]
    fn json_shape_and_totals() {
        let mut apps = vec![stats(Some(50.0), 0), stats(None, 0)];
        apps[1].name = "other".into();
        apps[1].counters =
            QueueCounters { offered: 10, admitted: 8, rejected: 0, deferred: 2 };
        let samples: Vec<RequestSample> = ladder()
            .into_iter()
            .chain((1..=10).map(|k| RequestSample {
                app_id: 1,
                arrival: 0.0,
                admit: 0.5,
                finish: k as f64 + 0.5,
                output_len: 4,
            }))
            .collect();
        let r = TrafficReport::build(30.0, 0.0, apps, &samples, 1.5, 7);
        assert_eq!(r.offered, 130);
        assert_eq!(r.admitted, 108);
        assert_eq!(r.rejected, 20);
        assert_eq!(r.deferred, 2);
        let json = r.to_json();
        assert_eq!(json.get("queue_depth_max").and_then(|x| x.as_u64()), Some(7));
        let apps = json.get("apps").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(apps.len(), 2);
        assert_eq!(apps[1].get("app").and_then(|x| x.as_str()), Some("other"));
        assert_eq!(apps[0].get("slo").and_then(|x| x.as_f64()), Some(50.0));
        assert!(apps[0].get("ttft_p99").and_then(|x| x.as_f64()).is_some());
    }
}
