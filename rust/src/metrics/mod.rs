//! Run reports, GPU-idle accounting and Gantt rendering (§5's metrics).

pub mod gantt;
pub mod latency;

use crate::costmodel::OnlineStats;
use crate::engine::AdmitStats;
use crate::exec::EventSummary;
use crate::plan::ExecPlan;
use crate::planner::eval::EvalStats;
use crate::residency::ResidencyStats;

/// What happened in one executed stage.
#[derive(Debug, Clone)]
pub struct StageRecord {
    /// Stage start (absolute virtual time).
    pub start: f64,
    /// Stage end (absolute virtual time).
    pub end: f64,
    /// (node, plan) pairs that ran.
    pub entries: Vec<(usize, ExecPlan)>,
    /// Nodes that had to (re)load models this stage.
    pub loaded_nodes: Vec<usize>,
    /// Loading wall-clock paid at stage start (max over parallel loads).
    pub load_time: f64,
    /// Busy GPU-seconds accumulated by each entry (same order as
    /// `entries`), loading included.
    pub busy_gpu_seconds: Vec<f64>,
    /// Digest of the stage's unified engine event stream (same shape for
    /// every [`crate::exec::ExecBackend`]).
    pub events: EventSummary,
    /// Wall-clock the stage lost to weight swapping that could not be
    /// overlapped with compute (0.0 unless oversubscription triggered).
    pub swap_stall: f64,
}

impl StageRecord {
    /// Stage duration in virtual seconds.
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }

    /// GPUs the stage occupied.
    pub fn gpus_used(&self) -> u32 {
        self.entries.iter().map(|(_, p)| p.n_gpus()).sum()
    }
}

/// Iteration-level statistics of a measured (real-backend) run: the
/// observed latencies next to what the virtual hardware model predicts
/// for the same batch compositions — the measured-vs-predicted hook that
/// validates the sampling-then-simulation cost model against real
/// iterations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MeasuredStats {
    /// Prefill iterations executed on the device.
    pub prefills: u64,
    /// Decode iterations executed on the device.
    pub decode_iters: u64,
    /// Tokens generated on the device.
    pub tokens: u64,
    /// Mean measured prefill iteration latency (seconds).
    pub prefill_mean: f64,
    /// Mean measured decode iteration latency (seconds).
    pub decode_mean: f64,
    /// Median measured decode iteration latency.
    pub decode_p50: f64,
    /// p99 measured decode iteration latency.
    pub decode_p99: f64,
    /// Mean decode latency the virtual hardware model predicts for the
    /// same (batch, context) compositions (NaN when unavailable).
    pub predicted_decode_mean: f64,
    /// Seconds of node wall-clock that ran overlapped across the run:
    /// per stage, `max(0, Σ node walls − stage span)`, summed. Exactly 0
    /// under the sequential lowering (`--sequential-measured`), positive
    /// when the concurrent event loop interleaved nodes.
    pub overlap_seconds: f64,
    /// Per-node `(node, busy_seconds, wall_seconds)` over the run: busy
    /// is device compute time, wall is the node's own measured span
    /// inside its stages. Their ratio shows how well the event loop kept
    /// each node's device fed.
    pub node_busy_wall: Vec<(usize, f64, f64)>,
}

impl MeasuredStats {
    /// Measured-vs-predicted mean decode latency error ratio
    /// `|pred - measured| / measured` (NaN when either side is missing).
    pub fn decode_prediction_error(&self) -> f64 {
        if self.predicted_decode_mean.is_nan() || self.decode_mean == 0.0 {
            f64::NAN
        } else {
            crate::util::stats::error_ratio(self.predicted_decode_mean, self.decode_mean)
        }
    }
}

/// Per-app accounting of one application instance inside a multi-app
/// workload run: when it arrived, when it finished, and its "stretch"
/// (completion time relative to arrival).
#[derive(Debug, Clone)]
pub struct AppReport {
    /// App id inside the workload (composition order).
    pub app_id: usize,
    /// The app's own scenario name.
    pub name: String,
    /// Virtual arrival time (0 = present at run start).
    pub arrival: f64,
    /// Relative priority weight the workload assigned the app.
    pub weight: f64,
    /// Global node ids of this app in the composed graph (keys the
    /// timeline's `entries` back to apps, e.g. for per-app Gantt lanes).
    pub nodes: Vec<usize>,
    /// Total requests across the app's nodes.
    pub n_requests: u64,
    /// Requests that completed (== `n_requests` for a finished run).
    pub completed: u64,
    /// Absolute virtual time the app's last request completed (equals
    /// `arrival` for an app with no requests).
    pub finish: f64,
    /// The app's stretch: `finish - arrival`, the makespan it observed
    /// from its own arrival.
    pub makespan: f64,
}

/// Workload-level accounting of a multi-app run (`None` on plain
/// single-app runs): how many apps arrived mid-run, how many forced
/// replans those arrivals triggered, and the per-app reports.
#[derive(Debug, Clone, Default)]
pub struct WorkloadReport {
    /// Apps that arrived at t > 0 and were activated mid-run.
    pub arrivals: u64,
    /// Forced replans of the remaining work those arrivals triggered
    /// (only planning policies replan; 0 for the baselines).
    pub arrival_replans: u64,
    /// Per-app accounting, indexed by app id.
    pub per_app: Vec<AppReport>,
}

/// End-to-end result of running one application under one policy (§5's
/// bar charts: inference time + extra time, idle time, estimation error).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Scenario (application) name.
    pub scenario: String,
    /// Canonical policy name that produced this run.
    pub policy: String,
    /// Execution backend the run used (`"sim"` or `"pjrt"`).
    pub backend: String,
    /// Canonical engine admission-policy name (`"fcfs"` unless opted in).
    pub admit_policy: String,
    /// Admission counters accumulated over every committed stage (all
    /// zero under FCFS, which never jumps the queue).
    pub admission: AdmitStats,
    /// Weight-swap counters accumulated by the residency subsystem (all
    /// zero unless `--oversubscribe` triggered actual swapping).
    pub residency: ResidencyStats,
    /// Scheduling/search wall-clock ("extra time", the hatched bar part).
    pub extra_time: f64,
    /// Algorithm 1's own wall-clock share of `extra_time`
    /// ([`crate::planner::PlannedApp::search_time`]); `0.0` for policies
    /// that don't plan offline.
    pub search_time: f64,
    /// Planner candidate-evaluation counters (threads, cache hits and
    /// misses); all-zero for policies that don't plan offline.
    pub planner: EvalStats,
    /// Virtual inference time (loading included).
    pub inference_time: f64,
    /// `extra_time + inference_time`.
    pub end_to_end_time: f64,
    /// The planner's own prediction of `inference_time` (NaN if the
    /// policy doesn't produce one).
    pub estimated_inference_time: f64,
    /// Number of executed stages.
    pub n_stages: usize,
    /// Per-stage execution records.
    pub timeline: Vec<StageRecord>,
    /// Iteration-level measured-vs-predicted statistics (real backends
    /// only; `None` for the simulated substrate).
    pub measured: Option<MeasuredStats>,
    /// Drift/replan accounting of the runtime length-feedback loop
    /// (`None` unless online refinement ran under a policy that
    /// participates in it).
    pub online: Option<OnlineStats>,
    /// Multi-app workload accounting: arrivals, arrival-forced replans
    /// and per-app makespans (`None` on single-app runs).
    pub workload: Option<WorkloadReport>,
    /// Open-loop serving metrics — per-app TTFT/TPOT, latency
    /// percentiles, SLO attainment and admission-queue statistics
    /// (`None` except on `samullm traffic` runs).
    pub traffic: Option<latency::TrafficReport>,
    /// Cluster GPU count the run was scheduled on.
    pub n_gpus: u32,
}

impl RunReport {
    /// GPU idle time: gpu-seconds with no model computing (or loading) on
    /// the GPU, summed over the whole run (§5.3's idle analysis).
    pub fn gpu_idle_time(&self) -> f64 {
        let mut idle = 0.0;
        for s in &self.timeline {
            let dur = s.duration();
            let total = self.n_gpus as f64 * dur;
            let busy: f64 = s.busy_gpu_seconds.iter().sum();
            idle += (total - busy).max(0.0);
        }
        idle
    }

    /// Cost-model error ratio `|est - actual| / actual` (§5.5).
    pub fn estimation_error(&self) -> f64 {
        if self.estimated_inference_time.is_nan() {
            f64::NAN
        } else {
            crate::util::stats::error_ratio(self.estimated_inference_time, self.inference_time)
        }
    }

    /// Fraction of end-to-end time spent searching (§5.1 reports 4.5–10.5%).
    pub fn extra_time_ratio(&self) -> f64 {
        self.extra_time / self.end_to_end_time
    }

    /// JSON rendering (CLI output contract).
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let timeline: Vec<Json> = self
            .timeline
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("start", Json::Num(s.start)),
                    ("end", Json::Num(s.end)),
                    (
                        "entries",
                        Json::Arr(
                            s.entries
                                .iter()
                                .map(|(n, p)| {
                                    Json::obj(vec![
                                        ("node", Json::Num(*n as f64)),
                                        ("dp", Json::Num(p.dp as f64)),
                                        ("tp", Json::Num(p.tp as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("load_time", Json::Num(s.load_time)),
                    ("swap_stall", Json::Num(s.swap_stall)),
                    (
                        "events",
                        Json::obj(vec![
                            ("admitted", Json::Num(s.events.admitted as f64)),
                            ("prefills", Json::Num(s.events.prefills as f64)),
                            ("decode_iters", Json::Num(s.events.decode_iters as f64)),
                            ("preemptions", Json::Num(s.events.preemptions as f64)),
                            ("completions", Json::Num(s.events.completions as f64)),
                            ("busy_time", Json::Num(s.events.busy_time)),
                            ("swaps_in", Json::Num(s.events.swaps_in as f64)),
                            ("swaps_out", Json::Num(s.events.swaps_out as f64)),
                            ("swap_bytes", Json::Num(s.events.swap_bytes as f64)),
                            ("swap_time", Json::Num(s.events.swap_time)),
                        ]),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("backend", Json::Str(self.backend.clone())),
            (
                "admission",
                Json::obj(vec![
                    ("policy", Json::Str(self.admit_policy.clone())),
                    ("queue_jumps", Json::Num(self.admission.queue_jumps as f64)),
                    ("promotions", Json::Num(self.admission.promotions as f64)),
                    ("max_queue_wait", Json::Num(self.admission.max_queue_wait)),
                ]),
            ),
            (
                "residency",
                Json::obj(vec![
                    ("swaps_in", Json::Num(self.residency.swaps_in as f64)),
                    ("swaps_out", Json::Num(self.residency.swaps_out as f64)),
                    ("bytes_in", Json::Num(self.residency.bytes_in as f64)),
                    ("bytes_out", Json::Num(self.residency.bytes_out as f64)),
                    ("stall_seconds", Json::Num(self.residency.stall_seconds)),
                    (
                        "overlapped_seconds",
                        Json::Num(self.residency.overlapped_seconds),
                    ),
                ]),
            ),
            ("extra_time", Json::Num(self.extra_time)),
            ("search_time", Json::Num(self.search_time)),
            (
                "planner",
                Json::obj(vec![
                    ("threads", Json::Num(self.planner.threads as f64)),
                    ("candidates", Json::Num(self.planner.candidates as f64)),
                    ("cache_hits", Json::Num(self.planner.cache_hits as f64)),
                    ("cache_misses", Json::Num(self.planner.cache_misses as f64)),
                    ("dep_dry_runs", Json::Num(self.planner.dep_dry_runs as f64)),
                    ("budget_exhausted", Json::Bool(self.planner.budget_exhausted)),
                ]),
            ),
            ("inference_time", Json::Num(self.inference_time)),
            ("end_to_end_time", Json::Num(self.end_to_end_time)),
            (
                "estimated_inference_time",
                if self.estimated_inference_time.is_nan() {
                    Json::Null
                } else {
                    Json::Num(self.estimated_inference_time)
                },
            ),
            ("gpu_idle_time", Json::Num(self.gpu_idle_time())),
            ("n_stages", Json::Num(self.n_stages as f64)),
            ("n_gpus", Json::Num(self.n_gpus as f64)),
            (
                "online",
                match &self.online {
                    None => Json::Null,
                    Some(o) => Json::obj(vec![
                        ("replans", Json::Num(o.replans as f64)),
                        ("drift", Json::Num(o.drift)),
                        ("replan_time", Json::Num(o.replan_time)),
                        ("pre_est_total", Json::Num(o.pre_est_total)),
                        ("post_est_total", Json::Num(o.post_est_total)),
                    ]),
                },
            ),
            (
                "workload",
                match &self.workload {
                    None => Json::Null,
                    Some(w) => Json::obj(vec![
                        ("arrivals", Json::Num(w.arrivals as f64)),
                        ("arrival_replans", Json::Num(w.arrival_replans as f64)),
                        (
                            "per_app",
                            Json::Arr(
                                w.per_app
                                    .iter()
                                    .map(|a| {
                                        Json::obj(vec![
                                            ("app_id", Json::Num(a.app_id as f64)),
                                            ("name", Json::Str(a.name.clone())),
                                            ("arrival", Json::Num(a.arrival)),
                                            ("weight", Json::Num(a.weight)),
                                            (
                                                "nodes",
                                                Json::Arr(
                                                    a.nodes
                                                        .iter()
                                                        .map(|&n| Json::Num(n as f64))
                                                        .collect(),
                                                ),
                                            ),
                                            ("n_requests", Json::Num(a.n_requests as f64)),
                                            ("completed", Json::Num(a.completed as f64)),
                                            ("finish", Json::Num(a.finish)),
                                            ("makespan", Json::Num(a.makespan)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                },
            ),
            (
                "traffic",
                match &self.traffic {
                    None => Json::Null,
                    Some(t) => t.to_json(),
                },
            ),
            (
                "measured",
                match &self.measured {
                    None => Json::Null,
                    Some(m) => Json::obj(vec![
                        ("prefills", Json::Num(m.prefills as f64)),
                        ("decode_iters", Json::Num(m.decode_iters as f64)),
                        ("tokens", Json::Num(m.tokens as f64)),
                        ("prefill_mean", Json::Num(m.prefill_mean)),
                        ("decode_mean", Json::Num(m.decode_mean)),
                        ("decode_p50", Json::Num(m.decode_p50)),
                        ("decode_p99", Json::Num(m.decode_p99)),
                        (
                            "predicted_decode_mean",
                            if m.predicted_decode_mean.is_nan() {
                                Json::Null
                            } else {
                                Json::Num(m.predicted_decode_mean)
                            },
                        ),
                        ("overlap_seconds", Json::Num(m.overlap_seconds)),
                        (
                            "node_busy_wall",
                            Json::Arr(
                                m.node_busy_wall
                                    .iter()
                                    .map(|&(n, b, w)| {
                                        Json::obj(vec![
                                            ("node", Json::Num(n as f64)),
                                            ("busy", Json::Num(b)),
                                            ("wall", Json::Num(w)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                },
            ),
            ("timeline", Json::Arr(timeline)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(start: f64, end: f64, gpus: Vec<u32>, busy: Vec<f64>) -> StageRecord {
        StageRecord {
            start,
            end,
            entries: gpus
                .into_iter()
                .enumerate()
                .map(|(i, g)| (i, ExecPlan::new(g, 1)))
                .collect(),
            loaded_nodes: vec![],
            load_time: 0.0,
            busy_gpu_seconds: busy,
            events: EventSummary { completions: 7, ..Default::default() },
            swap_stall: 0.0,
        }
    }

    fn report(timeline: Vec<StageRecord>) -> RunReport {
        let inference = timeline.last().map(|s| s.end).unwrap_or(0.0);
        RunReport {
            scenario: "t".into(),
            policy: "p".into(),
            backend: "sim".into(),
            admit_policy: "fcfs".into(),
            admission: AdmitStats::default(),
            residency: ResidencyStats::default(),
            extra_time: 10.0,
            search_time: 8.0,
            planner: EvalStats {
                candidates: 4,
                cache_hits: 3,
                cache_misses: 1,
                dep_dry_runs: 0,
                threads: 2,
                budget_exhausted: false,
            },
            inference_time: inference,
            end_to_end_time: 10.0 + inference,
            estimated_inference_time: inference * 1.2,
            n_stages: timeline.len(),
            timeline,
            measured: None,
            online: None,
            workload: None,
            traffic: None,
            n_gpus: 8,
        }
    }

    #[test]
    fn idle_time_counts_unused_gpus() {
        // One stage, 100 s, 6 of 8 GPUs fully busy -> 200 gpu-s idle.
        let r = report(vec![record(0.0, 100.0, vec![4, 2], vec![400.0, 200.0])]);
        assert!((r.gpu_idle_time() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn idle_time_counts_underutilized_entries() {
        // 8 GPUs assigned but a node idles half its time.
        let r = report(vec![record(0.0, 100.0, vec![8], vec![400.0])]);
        assert!((r.gpu_idle_time() - 400.0).abs() < 1e-9);
    }

    #[test]
    fn error_and_ratio() {
        let r = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]);
        assert!((r.estimation_error() - 0.2).abs() < 1e-9);
        assert!((r.extra_time_ratio() - 10.0 / 110.0).abs() < 1e-9);
    }

    #[test]
    fn json_reports_search_time_and_planner_counters() {
        // The §5.1 "extra time" decomposition must reach experiment JSON.
        let j = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]).to_json();
        assert!(j.contains("\"search_time\":8"), "{j}");
        assert!(j.contains("\"planner\":{"), "{j}");
        assert!(j.contains("\"cache_hits\":3"), "{j}");
        assert!(j.contains("\"candidates\":4"), "{j}");
        assert!(j.contains("\"threads\":2"), "{j}");
        assert!(j.contains("\"budget_exhausted\":false"), "{j}");
    }

    #[test]
    fn json_reports_backend_events_and_measured_stats() {
        let mut r = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]);
        let j = r.to_json();
        assert!(j.contains("\"backend\":\"sim\""), "{j}");
        assert!(j.contains("\"events\":{"), "{j}");
        assert!(j.contains("\"completions\":7"), "{j}");
        assert!(j.contains("\"measured\":null"), "{j}");
        r.backend = "pjrt".into();
        r.measured = Some(MeasuredStats {
            prefills: 3,
            decode_iters: 40,
            tokens: 43,
            prefill_mean: 0.01,
            decode_mean: 0.002,
            decode_p50: 0.002,
            decode_p99: 0.004,
            predicted_decode_mean: 0.003,
            overlap_seconds: 12.5,
            node_busy_wall: vec![(0, 40.0, 50.0), (1, 30.0, 60.0)],
        });
        let j = r.to_json();
        assert!(j.contains("\"backend\":\"pjrt\""), "{j}");
        assert!(j.contains("\"measured\":{"), "{j}");
        assert!(j.contains("\"decode_iters\":40"), "{j}");
        assert!(j.contains("\"predicted_decode_mean\":0.003"), "{j}");
        assert!(j.contains("\"overlap_seconds\":12.5"), "{j}");
        assert!(j.contains("\"node_busy_wall\":["), "{j}");
        assert!(j.contains("\"node\":1,\"busy\":30,\"wall\":60"), "{j}");
    }

    #[test]
    fn json_reports_online_feedback_stats() {
        let mut r = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]);
        let j = r.to_json();
        assert!(j.contains("\"online\":null"), "{j}");
        r.online = Some(OnlineStats {
            replans: 2,
            drift: 0.8,
            replan_time: 0.25,
            pre_est_total: 120.0,
            post_est_total: 95.0,
        });
        let j = r.to_json();
        assert!(j.contains("\"online\":{"), "{j}");
        assert!(j.contains("\"replans\":2"), "{j}");
        assert!(j.contains("\"drift\":0.8"), "{j}");
        assert!(j.contains("\"pre_est_total\":120"), "{j}");
        assert!(j.contains("\"post_est_total\":95"), "{j}");
    }

    #[test]
    fn json_reports_workload_per_app_section() {
        let mut r = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]);
        let j = r.to_json();
        assert!(j.contains("\"workload\":null"), "{j}");
        r.workload = Some(WorkloadReport {
            arrivals: 1,
            arrival_replans: 1,
            per_app: vec![
                AppReport {
                    app_id: 0,
                    name: "chain-summary-20".into(),
                    arrival: 0.0,
                    weight: 1.0,
                    nodes: vec![0, 1],
                    n_requests: 120,
                    completed: 120,
                    finish: 90.0,
                    makespan: 90.0,
                },
                AppReport {
                    app_id: 1,
                    name: "ensembling-200".into(),
                    arrival: 30.0,
                    weight: 2.0,
                    nodes: vec![2, 3],
                    n_requests: 400,
                    completed: 400,
                    finish: 100.0,
                    makespan: 70.0,
                },
            ],
        });
        let j = r.to_json();
        assert!(j.contains("\"workload\":{"), "{j}");
        assert!(j.contains("\"arrivals\":1"), "{j}");
        assert!(j.contains("\"arrival_replans\":1"), "{j}");
        assert!(j.contains("\"per_app\":["), "{j}");
        assert!(j.contains("\"makespan\":70"), "{j}");
        assert!(j.contains("\"name\":\"ensembling-200\""), "{j}");
        assert!(j.contains("\"nodes\":[2,3]"), "{j}");
    }

    #[test]
    fn json_reports_traffic_section() {
        let mut r = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]);
        let j = r.to_json();
        assert!(j.contains("\"traffic\":null"), "{j}");
        r.traffic = Some(latency::TrafficReport {
            duration: 60.0,
            warmup: 10.0,
            offered: 50,
            admitted: 45,
            rejected: 5,
            deferred: 0,
            queue_depth_mean: 1.25,
            queue_depth_max: 6,
            per_app: vec![latency::AppLatency {
                app_id: 0,
                name: "stream-a".into(),
                weight: 2.0,
                slo: Some(60.0),
                offered: 50,
                admitted: 45,
                rejected: 5,
                deferred: 0,
                completed: 90,
                ttft_mean: Some(1.5),
                ttft_p99: Some(4.0),
                tpot_mean: Some(0.05),
                latency_p50: Some(12.0),
                latency_p99: Some(44.0),
                slo_attainment: Some(0.9),
            }],
        });
        let j = r.to_json();
        assert!(j.contains("\"traffic\":{"), "{j}");
        assert!(j.contains("\"queue_depth_max\":6"), "{j}");
        assert!(j.contains("\"ttft_p99\":4"), "{j}");
        assert!(j.contains("\"latency_p99\":44"), "{j}");
        assert!(j.contains("\"slo_attainment\":0.9"), "{j}");
        assert!(j.contains("\"app\":\"stream-a\""), "{j}");
    }

    #[test]
    fn json_reports_residency_counters() {
        let mut r = report(vec![record(0.0, 100.0, vec![8], vec![800.0])]);
        let j = r.to_json();
        // The block is always present (mirrors "admission") and all-zero
        // on runs that never swapped.
        assert!(j.contains("\"residency\":{"), "{j}");
        assert!(j.contains("\"swaps_in\":0"), "{j}");
        assert!(j.contains("\"swap_stall\":0"), "{j}");
        r.residency = ResidencyStats {
            swaps_in: 3,
            swaps_out: 2,
            bytes_in: 36_000_000_000,
            bytes_out: 24_000_000_000,
            stall_seconds: 4.5,
            overlapped_seconds: 1.5,
        };
        r.timeline[0].swap_stall = 4.5;
        r.timeline[0].events.swaps_in = 3;
        let j = r.to_json();
        assert!(j.contains("\"swaps_in\":3"), "{j}");
        assert!(j.contains("\"swaps_out\":2"), "{j}");
        assert!(j.contains("\"stall_seconds\":4.5"), "{j}");
        assert!(j.contains("\"overlapped_seconds\":1.5"), "{j}");
        assert!(j.contains("\"swap_stall\":4.5"), "{j}");
    }

    #[test]
    fn measured_prediction_error_is_relative() {
        let m = MeasuredStats {
            decode_mean: 0.002,
            predicted_decode_mean: 0.003,
            ..Default::default()
        };
        assert!((m.decode_prediction_error() - 0.5).abs() < 1e-12);
        assert!(MeasuredStats::default().decode_prediction_error().is_nan());
    }
}
