//! Text Gantt rendering of run timelines (Figs. 9, 13, 15).

use super::RunReport;

/// Render a run's schedule as an ASCII Gantt chart: one row per node,
/// columns are time buckets, cell digits are the GPU count the node held.
/// Multi-app workload runs label each lane with the owning app
/// (`a<app> n<node>`) and append a per-app arrival/makespan footer.
pub fn render(report: &RunReport, width: usize) -> String {
    let total = report.inference_time.max(1e-9);
    let mut nodes: Vec<usize> = report
        .timeline
        .iter()
        .flat_map(|s| s.entries.iter().map(|(n, _)| *n))
        .collect();
    nodes.sort_unstable();
    nodes.dedup();
    let app_of = |node: usize| -> Option<usize> {
        report
            .workload
            .as_ref()?
            .per_app
            .iter()
            .find(|a| a.nodes.contains(&node))
            .map(|a| a.app_id)
    };

    let mut out = String::new();
    out.push_str(&format!(
        "policy={} inference={:.1}s stages={}\n",
        report.policy, report.inference_time, report.n_stages
    ));
    if let Some(o) = &report.online {
        out.push_str(&format!(
            "online feedback: replans={} max-drift={:.2} est {:.1}s -> {:.1}s\n",
            o.replans, o.drift, o.pre_est_total, o.post_est_total
        ));
    }
    if report.admit_policy != "fcfs" {
        out.push_str(&format!(
            "admission: policy={} queue-jumps={} promotions={} max-wait={:.1}s\n",
            report.admit_policy,
            report.admission.queue_jumps,
            report.admission.promotions,
            report.admission.max_queue_wait
        ));
    }
    if report.residency.any() {
        out.push_str(&format!(
            "residency: swaps in={} out={} moved={:.1}GB stalled={:.1}s overlapped={:.1}s\n",
            report.residency.swaps_in,
            report.residency.swaps_out,
            (report.residency.bytes_in + report.residency.bytes_out) as f64 / 1e9,
            report.residency.stall_seconds,
            report.residency.overlapped_seconds
        ));
    }
    if let Some(m) = &report.measured {
        out.push_str(&format!(
            "measured: decode-iters={} tokens={} overlap={:.1}s\n",
            m.decode_iters, m.tokens, m.overlap_seconds
        ));
        for &(node, busy, wall) in &m.node_busy_wall {
            let ratio = if wall > 0.0 { busy / wall } else { 0.0 };
            out.push_str(&format!(
                "  node {node:>3} busy={busy:>7.2}s wall={wall:>7.2}s busy/wall={ratio:.2}\n"
            ));
        }
    }
    for &node in &nodes {
        let mut row = vec![b'.'; width];
        for s in &report.timeline {
            if let Some((_, plan)) = s.entries.iter().find(|(n, _)| *n == node) {
                let a = ((s.start / total) * width as f64) as usize;
                let b = (((s.end / total) * width as f64).ceil() as usize).min(width);
                let ch = match plan.n_gpus() {
                    g @ 0..=9 => b'0' + g as u8,
                    _ => b'#',
                };
                for c in row.iter_mut().take(b).skip(a.min(width)) {
                    *c = ch;
                }
            }
        }
        let label = match app_of(node) {
            Some(app) => format!("a{app} n{node:>3}"),
            None => format!("node {node:>3}"),
        };
        out.push_str(&format!("{label:>8} |{}|\n", String::from_utf8_lossy(&row)));
    }
    let marks = (0..=4).map(|i| format!("{:.0}s", total * i as f64 / 4.0)).collect::<Vec<_>>();
    out.push_str(&format!("          {}\n", marks.join(" … ")));
    if let Some(w) = &report.workload {
        out.push_str(&format!(
            "workload: arrivals={} arrival-replans={}\n",
            w.arrivals, w.arrival_replans
        ));
        for a in &w.per_app {
            out.push_str(&format!(
                "  app {} {:<28} arrival={:>7.1}s finish={:>8.1}s makespan={:>8.1}s \
                 weight={:.1} reqs={}\n",
                a.app_id, a.name, a.arrival, a.finish, a.makespan, a.weight, a.n_requests
            ));
        }
    }
    if let Some(t) = &report.traffic {
        out.push_str(&format!(
            "traffic: window={:.0}s warmup={:.0}s offered={} admitted={} rejected={} \
             deferred={} depth mean={:.1} max={}\n",
            t.duration,
            t.warmup,
            t.offered,
            t.admitted,
            t.rejected,
            t.deferred,
            t.queue_depth_mean,
            t.queue_depth_max
        ));
        let fmt = |v: Option<f64>| match v {
            Some(x) => format!("{x:.2}"),
            None => "-".into(),
        };
        for a in &t.per_app {
            let slo = match a.slo_attainment {
                Some(x) => format!("{:.0}%", x * 100.0),
                None => "-".into(),
            };
            out.push_str(&format!(
                "  app {} {:<28} weight={:.1} ttft={}s tpot={}s p50={}s p99={}s slo={}\n",
                a.app_id,
                a.name,
                a.weight,
                fmt(a.ttft_mean),
                fmt(a.tpot_mean),
                fmt(a.latency_p50),
                fmt(a.latency_p99),
                slo
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StageRecord;
    use crate::plan::ExecPlan;

    #[test]
    fn renders_rows_per_node() {
        let report = RunReport {
            scenario: "x".into(),
            policy: "ours".into(),
            backend: "sim".into(),
            admit_policy: "fcfs".into(),
            admission: Default::default(),
            residency: Default::default(),
            extra_time: 0.0,
            search_time: 0.0,
            planner: Default::default(),
            inference_time: 100.0,
            end_to_end_time: 100.0,
            estimated_inference_time: f64::NAN,
            n_stages: 2,
            timeline: vec![
                StageRecord {
                    start: 0.0,
                    end: 50.0,
                    entries: vec![(0, ExecPlan::new(4, 1)), (1, ExecPlan::new(2, 2))],
                    loaded_nodes: vec![0, 1],
                    load_time: 10.0,
                    busy_gpu_seconds: vec![200.0, 200.0],
                    events: Default::default(),
                    swap_stall: 0.0,
                },
                StageRecord {
                    start: 50.0,
                    end: 100.0,
                    entries: vec![(1, ExecPlan::new(4, 2))],
                    loaded_nodes: vec![1],
                    load_time: 15.0,
                    busy_gpu_seconds: vec![400.0],
                    events: Default::default(),
                    swap_stall: 0.0,
                },
            ],
            measured: None,
            online: None,
            workload: None,
            traffic: None,
            n_gpus: 8,
        };
        let g = render(&report, 40);
        assert!(g.contains("node   0"));
        assert!(g.contains("node   1"));
        // Node 0 holds 4 GPUs in the first half.
        assert!(g.lines().find(|l| l.contains("node   0")).unwrap().contains('4'));
        // Node 1 upgrades to 8 GPUs (4x2) in the second half.
        assert!(g.lines().find(|l| l.contains("node   1")).unwrap().contains('8'));
        // No feedback loop, no annotation; no swaps, no residency line.
        assert!(!g.contains("online feedback"));
        assert!(!g.contains("residency:"));

        let mut with_swaps = report.clone();
        with_swaps.residency = crate::residency::ResidencyStats {
            swaps_in: 2,
            swaps_out: 1,
            bytes_in: 24_000_000_000,
            bytes_out: 12_000_000_000,
            stall_seconds: 3.0,
            overlapped_seconds: 1.0,
        };
        let g = render(&with_swaps, 40);
        assert!(
            g.contains("residency: swaps in=2 out=1 moved=36.0GB stalled=3.0s overlapped=1.0s"),
            "{g}"
        );

        let mut with_measured = report.clone();
        with_measured.measured = Some(crate::metrics::MeasuredStats {
            decode_iters: 40,
            tokens: 43,
            overlap_seconds: 12.5,
            node_busy_wall: vec![(0, 40.0, 50.0)],
            ..Default::default()
        });
        let g = render(&with_measured, 40);
        assert!(g.contains("measured: decode-iters=40 tokens=43 overlap=12.5s"), "{g}");
        assert!(g.contains("node   0 busy=  40.00s wall=  50.00s busy/wall=0.80"), "{g}");

        let mut with_online = report;
        with_online.online = Some(crate::costmodel::OnlineStats {
            replans: 1,
            drift: 0.62,
            replan_time: 0.1,
            pre_est_total: 110.0,
            post_est_total: 98.5,
        });
        let g = render(&with_online, 40);
        assert!(
            g.contains("online feedback: replans=1 max-drift=0.62 est 110.0s -> 98.5s"),
            "{g}"
        );

        // Workload runs label lanes by app and append the per-app footer.
        let mut with_workload = with_online;
        with_workload.workload = Some(crate::metrics::WorkloadReport {
            arrivals: 1,
            arrival_replans: 1,
            per_app: vec![
                crate::metrics::AppReport {
                    app_id: 0,
                    name: "chain".into(),
                    arrival: 0.0,
                    weight: 1.0,
                    nodes: vec![0],
                    n_requests: 10,
                    completed: 10,
                    finish: 50.0,
                    makespan: 50.0,
                },
                crate::metrics::AppReport {
                    app_id: 1,
                    name: "ens".into(),
                    arrival: 25.0,
                    weight: 1.0,
                    nodes: vec![1],
                    n_requests: 20,
                    completed: 20,
                    finish: 100.0,
                    makespan: 75.0,
                },
            ],
        });
        let g = render(&with_workload, 40);
        assert!(g.contains("a0 n  0"), "{g}");
        assert!(g.contains("a1 n  1"), "{g}");
        assert!(g.contains("workload: arrivals=1 arrival-replans=1"), "{g}");
        assert!(g.contains("app 1"), "{g}");
        assert!(g.contains("makespan="), "{g}");

        // Traffic runs append the serving-metrics footer.
        let mut with_traffic = with_workload;
        with_traffic.traffic = Some(crate::metrics::latency::TrafficReport {
            duration: 60.0,
            warmup: 5.0,
            offered: 40,
            admitted: 36,
            rejected: 4,
            deferred: 0,
            queue_depth_mean: 1.5,
            queue_depth_max: 7,
            per_app: vec![crate::metrics::latency::AppLatency {
                app_id: 0,
                name: "stream-a".into(),
                weight: 2.0,
                slo: Some(30.0),
                offered: 40,
                admitted: 36,
                rejected: 4,
                deferred: 0,
                completed: 72,
                ttft_mean: Some(1.25),
                ttft_p99: Some(3.5),
                tpot_mean: Some(0.04),
                latency_p50: Some(8.0),
                latency_p99: Some(21.5),
                slo_attainment: Some(0.95),
            }],
        });
        let g = render(&with_traffic, 40);
        assert!(
            g.contains(
                "traffic: window=60s warmup=5s offered=40 admitted=36 rejected=4 \
                 deferred=0 depth mean=1.5 max=7"
            ),
            "{g}"
        );
        assert!(g.contains("weight=2.0 ttft=1.25s tpot=0.04s p50=8.00s p99=21.50s slo=95%"), "{g}");
    }
}
