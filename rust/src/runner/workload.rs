//! Multi-application workloads: compose `N` app instances into one
//! jointly planned, jointly executed [`Scenario`] with per-app arrival
//! times, weights and provenance.
//!
//! The composition itself is a disjoint union ([`AppGraph::compose`]):
//! node ids are offset per app, cross-node dependencies are remapped, and
//! every node carries `(app, local_id)` provenance so the same LLM used
//! by two apps stays two model instances (placement owners are node ids).
//! Apps with `arrival > 0` are masked out of the initial state and enter
//! the run through the drift/replan path — see
//! [`crate::runner::run_workload_with_backend`].

use crate::graph::AppGraph;
use crate::runner::{AppRequest, Scenario};

/// One application instance of a multi-app workload, after composition.
#[derive(Debug, Clone)]
pub struct WorkloadApp {
    /// Index of this app in the workload (== provenance `app` stamp).
    pub app_id: usize,
    /// The app's own scenario name ("ensembling-1000", …).
    pub name: String,
    /// Virtual time at which the app becomes available. Apps with
    /// `arrival > 0` are invisible to planning and execution until the
    /// first stage boundary at or after this time.
    pub arrival: f64,
    /// Relative priority weight. On batch workload runs the joint
    /// planner optimises global throughput, so the weight is recorded in
    /// the per-app report as metadata; on open-loop traffic runs
    /// ([`crate::runner::traffic`]) the same per-entry weight drives
    /// weighted fair-share admission and is a real scheduling priority.
    pub weight: f64,
    /// Global node ids of this app in the composed graph.
    pub nodes: Vec<usize>,
    /// Total requests across this app's nodes.
    pub n_requests: u64,
}

/// A composed multi-app workload: the joint scenario plus per-app
/// metadata. Build one from a declarative
/// [`crate::spec::workload::WorkloadSpec`], or directly via
/// [`WorkloadScenario::compose`].
#[derive(Debug, Clone)]
pub struct WorkloadScenario {
    /// Workload name (becomes `RunReport::scenario`).
    pub name: String,
    /// The composed joint scenario (full workloads for every app,
    /// including ones that arrive later — the runner masks those until
    /// their arrival).
    pub scenario: Scenario,
    /// Per-app metadata, indexed by `app_id`.
    pub apps: Vec<WorkloadApp>,
}

impl WorkloadScenario {
    /// Compose `(scenario, arrival, weight)` parts into one workload.
    /// Part order is preserved (it defines app ids and node-id offsets);
    /// arrivals need not be sorted.
    pub fn compose(parts: Vec<(Scenario, f64, f64)>, name: &str) -> Self {
        let scenarios: Vec<&Scenario> = parts.iter().map(|(s, _, _)| s).collect();
        let scenario = compose_scenarios(&scenarios, name);
        let by_app = scenario.graph.nodes_by_app();
        let apps = parts
            .iter()
            .enumerate()
            .map(|(app_id, (s, arrival, weight))| WorkloadApp {
                app_id,
                name: s.name.clone(),
                arrival: *arrival,
                weight: *weight,
                nodes: by_app[app_id].clone(),
                n_requests: s.workloads.iter().map(|w| w.len() as u64).sum(),
            })
            .collect();
        WorkloadScenario { name: name.to_string(), scenario, apps }
    }

    /// Apps that arrive strictly after t = 0, as `(arrival, app_id)`
    /// sorted by arrival time (ties by app id) — the runner's pending
    /// queue.
    pub fn pending_arrivals(&self) -> Vec<(f64, usize)> {
        let mut v: Vec<(f64, usize)> = self
            .apps
            .iter()
            .filter(|a| a.arrival > 0.0)
            .map(|a| (a.arrival, a.app_id))
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).expect("arrival times are finite"));
        v
    }

    /// Per-node workloads with every app arriving after t = 0 masked to
    /// an empty request list — the planner- and state-visible view at run
    /// start. Returns `None` when no app arrives late (the scenario's own
    /// workloads are already the full picture — the zero-arrival path
    /// stays byte-identical to a plain single-app run).
    pub fn masked_workloads(&self) -> Option<Vec<Vec<AppRequest>>> {
        if self.apps.iter().all(|a| a.arrival <= 0.0) {
            return None;
        }
        let mut masked = self.scenario.workloads.clone();
        for app in self.apps.iter().filter(|a| a.arrival > 0.0) {
            for &ni in &app.nodes {
                masked[ni].clear();
            }
        }
        Some(masked)
    }
}

/// Disjoint union of scenarios: graphs composed via [`AppGraph::compose`]
/// (per-app provenance stamped), workloads concatenated in part order
/// with cross-node dependency ids offset. The exact composition
/// [`crate::apps::mixed::merge`] has always performed — kept
/// bit-identical so the legacy `AppSpec::Mixed` path reproduces the seed
/// outputs.
pub fn compose_scenarios(parts: &[&Scenario], name: &str) -> Scenario {
    let graphs: Vec<&AppGraph> = parts.iter().map(|p| &p.graph).collect();
    let graph = AppGraph::compose(&graphs);
    let mut workloads: Vec<Vec<AppRequest>> = vec![];
    let mut offset = 0usize;
    for part in parts {
        for w in &part.workloads {
            workloads.push(
                w.iter()
                    .map(|r| {
                        let mut r = *r;
                        if let Some((n, id)) = r.dep {
                            r.dep = Some((n + offset, id));
                        }
                        r
                    })
                    .collect(),
            );
        }
        offset += part.graph.n_nodes();
    }
    Scenario { name: name.to_string(), graph, workloads }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{chain_summary, ensembling};

    /// The seed `mixed::merge` implementation, inlined verbatim: the
    /// reference `compose_scenarios` must stay bit-compatible with.
    fn legacy_merge(a: Scenario, b: Scenario, name: &str) -> Scenario {
        let mut graph = a.graph.clone();
        let offset = graph.n_nodes();
        for n in &b.graph.nodes {
            graph.add_node(&n.model, &n.label, n.max_out);
        }
        for &(f, t) in &b.graph.edges {
            graph.add_edge(f + offset, t + offset);
        }
        let mut workloads = a.workloads;
        for w in b.workloads {
            workloads.push(
                w.into_iter()
                    .map(|mut r| {
                        if let Some((n, id)) = r.dep {
                            r.dep = Some((n + offset, id));
                        }
                        r
                    })
                    .collect(),
            );
        }
        Scenario { name: name.to_string(), graph, workloads }
    }

    #[test]
    fn compose_matches_legacy_merge_shape() {
        let cs = chain_summary::build(10, 2, 300, 7);
        let en = ensembling::build(50, 128, 7 ^ 0x4D49_58);
        let merged = legacy_merge(cs.clone(), en.clone(), "m");
        let composed = compose_scenarios(&[&cs, &en], "m");
        assert_eq!(composed.graph.n_nodes(), merged.graph.n_nodes());
        assert_eq!(composed.graph.edges, merged.graph.edges);
        for (x, y) in composed.graph.nodes.iter().zip(&merged.graph.nodes) {
            assert_eq!(
                (x.id, &x.model, &x.label, x.max_out),
                (y.id, &y.model, &y.label, y.max_out)
            );
        }
        assert_eq!(composed.workloads.len(), merged.workloads.len());
        for (a, b) in composed.workloads.iter().zip(&merged.workloads) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.input_len, y.input_len);
                assert_eq!(x.true_output_len, y.true_output_len);
                assert_eq!(x.dep, y.dep);
                assert_eq!(x.chain_next, y.chain_next);
                assert_eq!(x.chain_blocked, y.chain_blocked);
            }
        }
    }

    #[test]
    fn workload_scenario_metadata_and_masking() {
        let cs = chain_summary::build(5, 1, 200, 1);
        let en = ensembling::build(40, 128, 2);
        let n_cs_nodes = cs.graph.n_nodes();
        let n_en_reqs: u64 = en.workloads.iter().map(|w| w.len() as u64).sum();
        let wl = WorkloadScenario::compose(
            vec![(cs, 0.0, 1.0), (en, 45.0, 2.0)],
            "pair",
        );
        assert_eq!(wl.apps.len(), 2);
        assert_eq!(wl.apps[1].n_requests, n_en_reqs);
        assert_eq!(wl.apps[1].weight, 2.0);
        assert_eq!(wl.pending_arrivals(), vec![(45.0, 1)]);
        let masked = wl.masked_workloads().expect("app 1 arrives late");
        for &ni in &wl.apps[0].nodes {
            assert!(!masked[ni].is_empty(), "arrived app keeps its work");
        }
        for &ni in &wl.apps[1].nodes {
            assert!(masked[ni].is_empty(), "pending app is masked");
            assert!(ni >= n_cs_nodes, "app 1 nodes come after app 0's");
        }
        // Zero-arrival workloads report no mask at all.
        let cs2 = chain_summary::build(5, 1, 200, 1);
        let en2 = ensembling::build(40, 128, 2);
        let all_now =
            WorkloadScenario::compose(vec![(cs2, 0.0, 1.0), (en2, 0.0, 1.0)], "now");
        assert!(all_now.masked_workloads().is_none());
        assert!(all_now.pending_arrivals().is_empty());
    }
}
