//! The running phase (§4.3): execute an application under a scheduling
//! policy against the ground-truth substrate, with the dynamic scheduler,
//! NVLink-constrained minimum-reload placement, and full reporting.
//!
//! Policies are trait objects ([`crate::policy::Policy`]); the runner
//! never knows which concrete policy it drives. [`run_with`] is the core
//! loop, [`run_policy`] the by-name convenience, and
//! [`crate::session::SamuLlm`] the session facade that owns a reusable
//! [`RunContext`].
//!
//! The "communicator" of Fig. 6 is realised by the completion log inside
//! [`state::ExecState`]: node outputs become dependent requests' ready
//! times (templates and payload routing carry no cost in virtual time).

pub mod dynamic;
pub mod state;
pub mod traffic;
pub mod workload;

pub use state::{AppRequest, ExecState};
pub use traffic::{run_traffic, run_traffic_with_backend};
pub use workload::{WorkloadApp, WorkloadScenario};

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::Result;

use crate::cluster::{ClusterSpec, Placement};
use crate::costmodel::{online, CostModel, HardwareModel, IterLatency, OnlineSampler, SwapCost};
use crate::engine::sched::{AdmitPolicy, EngineEvent, EventKind};
use crate::exec::{BackendMode, EventSummary, ExecBackend, SimBackend};
use crate::graph::AppGraph;
use crate::metrics::{AppReport, MeasuredStats, RunReport, StageRecord, WorkloadReport};
use crate::models::Registry;
use crate::plan::{ExecPlan, Stage};
use crate::planner::eval::EvalStats;
use crate::planner::SimCache;
use crate::policy::{self, PlanCtx, Policy, StageCtx};
use crate::residency::{self, ResidencyManager};
use crate::util::rng::Rng;
use crate::util::stats;

/// A runnable experiment: the application graph plus per-node workloads
/// with ground-truth output lengths.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario name (becomes `RunReport::scenario`).
    pub name: String,
    /// The application computation graph.
    pub graph: AppGraph,
    /// Per-node request workloads with ground-truth output lengths.
    pub workloads: Vec<Vec<AppRequest>>,
}

/// Runner options (ablation switches of §5.5 included).
#[derive(Debug, Clone)]
pub struct RunOpts {
    /// Seed for workload materialisation, sampling and planning.
    pub seed: u64,
    /// Disable preemption (§5.5 ablation).
    pub no_preemption: bool,
    /// Give every policy the true output lengths (§5.5 cost-model study).
    pub known_lengths: bool,
    /// Ground-truth per-iteration jitter.
    pub noise_sigma: f64,
    /// Planner candidate-evaluation worker threads (`0` = auto). Plans
    /// are identical for every value — only search wall-clock changes.
    pub threads: usize,
    /// Let the planner memoize simulations in the context's shared
    /// [`SimCache`] (on by default; results are identical either way).
    pub sim_cache: bool,
    /// Runtime length-feedback loop (§4.3 refinement, off by default —
    /// the offline-estimate path is bit-identical to every pre-feedback
    /// release): fold observed completion lengths into a per-model
    /// posterior, re-estimate in-flight requests conditionally
    /// (`X | X > generated`), and let the `ours` policy escalate from
    /// stage repair to a full re-plan when drift exceeds
    /// [`RunOpts::replan_threshold`].
    pub online_refinement: bool,
    /// Drift score above which the dynamic scheduler replans the
    /// remaining application (only with `online_refinement`). The score
    /// mixes per-model mean-length drift and stage-makespan drift; the
    /// default leaves headroom over the paper's ≲50% baseline cost-model
    /// error band.
    pub replan_threshold: f64,
    /// Weight of one observed completion in offline-trace-sample
    /// equivalents when blending the online posterior (only with
    /// `online_refinement`).
    pub online_weight: f64,
    /// Engine admission policy (FCFS by default — byte-identical to the
    /// pre-policy releases). Non-FCFS policies consume per-request length
    /// predictions sampled by the planner's estimate view (refined by the
    /// online posterior when `online_refinement` is on).
    pub admit: AdmitPolicy,
    /// Let stages oversubscribe the cluster: the planner may emit stages
    /// whose aggregate weight footprint exceeds HBM and the residency
    /// subsystem ([`crate::residency`]) time-slices the GPUs between
    /// sub-stages, paying modeled swap latency. Off by default —
    /// bit-identical to the strict path; with it on, a workload that fits
    /// never triggers a swap and stays bit-identical too.
    pub oversubscribe: bool,
    /// Override the cluster's host-to-device copy bandwidth (bytes/s) for
    /// swap-cost pricing (`None` = the cluster spec's own `h2d_bw`; the
    /// d2h side scales by the spec's d2h/h2d ratio).
    pub h2d_bw: Option<f64>,
    /// Aggregated fast-step decode in every engine simulation (on by
    /// default). Exact: stable-batch decode windows are advanced one
    /// priced iteration at a time without per-iteration scheduling
    /// bookkeeping, so outcomes, events and counters are bit-identical
    /// to per-token stepping — only simulation wall-clock changes. Turn
    /// off to force the reference per-token path
    /// ([`crate::engine::sched::EngineConfig::fast_step`]).
    pub fast_step: bool,
    /// Anytime-search wall-clock budget in seconds for every Algorithm 1
    /// search this run performs (the offline plan and each mid-run
    /// re-plan). `None` = search to convergence, bit-identical to every
    /// unbudgeted release. With a budget, an expiring search returns
    /// best-so-far — always a complete, executable plan — and the report
    /// flags it via [`EvalStats::budget_exhausted`], so re-plans at
    /// stage boundaries (arrivals, drift, open-loop traffic) stop
    /// blocking the cluster
    /// ([`crate::planner::GreedyPlanner::search_budget`]).
    pub search_budget: Option<f64>,
    /// Force the *sequential* measured lowering
    /// ([`ExecState::run_stage_measured`]): stage nodes run one after
    /// another on the device and their measured times chain. Off by
    /// default — measured stages with ≥ 2 runnable nodes interleave
    /// through the backend's stepping interface
    /// ([`ExecState::run_stage_concurrent`]), so the stage wall-clock is
    /// the max over nodes, as the simulator assumes. Virtual runs ignore
    /// this entirely. Escape hatch: `--sequential-measured`.
    pub sequential_measured: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            seed: 7,
            no_preemption: false,
            known_lengths: false,
            noise_sigma: 0.02,
            threads: 0,
            sim_cache: true,
            online_refinement: false,
            replan_threshold: online::DEFAULT_REPLAN_THRESHOLD,
            online_weight: online::DEFAULT_OBS_WEIGHT,
            admit: AdmitPolicy::Fcfs,
            oversubscribe: false,
            h2d_bw: None,
            fast_step: true,
            search_budget: None,
            sequential_measured: false,
        }
    }
}

/// Shared run wiring for one cluster: the model registry, the calibrated
/// cost model, the hardware ground truth and the planner's memoized
/// simulation cache. Build once (a session does) and reuse across runs.
pub struct RunContext {
    /// Model registry resolving graph nodes to specs.
    pub registry: Registry,
    /// The calibrated sampling-then-simulation cost model.
    pub cost: CostModel,
    /// Ground-truth latency oracle the running phase executes against.
    pub hw: HardwareModel,
    /// The cluster both phases schedule onto.
    pub cluster: ClusterSpec,
    /// Memoized planner simulations, shared across every planning search
    /// this context hosts (each `Policy::prepare` call — so repeated and
    /// compared runs plan against a warm cache).
    pub sim_cache: Arc<SimCache>,
}

impl RunContext {
    /// Assemble the wiring for `cluster`, calibrating the cost model with
    /// `seed`.
    pub fn new(cluster: &ClusterSpec, seed: u64) -> Self {
        RunContext {
            registry: Registry::paper(),
            cost: CostModel::calibrated(cluster, seed),
            hw: HardwareModel::new(cluster.clone()),
            cluster: cluster.clone(),
            sim_cache: Arc::new(SimCache::new()),
        }
    }
}

/// Run `scenario` under the registry policy named `policy` and report
/// §5's metrics. Panics on an unknown policy name — use
/// [`crate::session::SamuLlm`] for validated-up-front configuration.
pub fn run_policy(
    policy: &str,
    scenario: &Scenario,
    cluster: &ClusterSpec,
    opts: &RunOpts,
) -> RunReport {
    let mut p = policy::create(policy).expect("unknown policy name");
    let ctx = RunContext::new(cluster, opts.seed);
    run_with(p.as_mut(), scenario, &ctx, opts)
}

/// Run a composed multi-app [`WorkloadScenario`] under the registry
/// policy named `policy` on the virtual-time substrate. Panics on an
/// unknown policy name — use [`crate::session::SamuLlm::run_workload`]
/// for validated-up-front configuration.
pub fn run_workload(
    policy: &str,
    workload: &WorkloadScenario,
    cluster: &ClusterSpec,
    opts: &RunOpts,
) -> RunReport {
    let mut p = policy::create(policy).expect("unknown policy name");
    let ctx = RunContext::new(cluster, opts.seed);
    run_workload_with(p.as_mut(), workload, &ctx, opts)
}

/// Run `scenario` under an instantiated policy, reusing `ctx`'s wiring,
/// on the default virtual-time substrate ([`SimBackend`] over the
/// context's hardware ground truth). Numerically identical to every
/// pre-`ExecBackend` release.
pub fn run_with(
    policy: &mut dyn Policy,
    scenario: &Scenario,
    ctx: &RunContext,
    opts: &RunOpts,
) -> RunReport {
    let mut backend = SimBackend::new(&ctx.hw, ctx.cluster.mem_bytes);
    run_with_backend(policy, scenario, ctx, opts, &mut backend)
        .expect("the simulated substrate is infallible")
}

/// Run a multi-app workload under an instantiated policy on the default
/// virtual-time substrate. A zero-arrival workload runs through exactly
/// the single-app code path (plus the per-app report), so its numbers are
/// bit-identical to running the equivalent hand-merged scenario.
pub fn run_workload_with(
    policy: &mut dyn Policy,
    workload: &WorkloadScenario,
    ctx: &RunContext,
    opts: &RunOpts,
) -> RunReport {
    let mut backend = SimBackend::new(&ctx.hw, ctx.cluster.mem_bytes);
    run_workload_with_backend(policy, workload, ctx, opts, &mut backend)
        .expect("the simulated substrate is infallible")
}

/// Run a multi-app workload against an arbitrary [`ExecBackend`].
///
/// Apps with `arrival == 0` are planned jointly up front; apps with
/// `arrival > 0` are masked out of the initial state and activated at the
/// first stage boundary at or after their arrival time (stage boundaries
/// are the §4.3 decision points) — planning policies absorb an arrival as
/// a forced re-plan of remaining-work-plus-new-app through the same
/// [`crate::planner::GreedyPlanner::plan_from_state`] path the
/// length-feedback loop uses. If the active apps drain before the next
/// arrival, the clock idle-jumps to it. The report gains a
/// [`WorkloadReport`](crate::metrics::WorkloadReport) with per-app
/// makespans/stretch.
pub fn run_workload_with_backend(
    policy: &mut dyn Policy,
    workload: &WorkloadScenario,
    ctx: &RunContext,
    opts: &RunOpts,
    backend: &mut dyn ExecBackend,
) -> Result<RunReport> {
    run_core(policy, &workload.scenario, Some(workload), ctx, opts, backend)
}

/// Run `scenario` under an instantiated policy against an arbitrary
/// [`ExecBackend`] — the one code path shared by the simulated substrate
/// and the real PJRT serving runtime.
///
/// Planning always happens in virtual time (the paper's
/// sampling-then-simulation cost model); the backend decides how planned
/// stages *execute*:
/// * [`BackendMode::Virtual`] — the §4.3 first-finish discipline with
///   projection and deadline replay (today's experiments);
/// * [`BackendMode::Measured`] — real, irreversible execution: each
///   stage's nodes run to completion concurrently (interleaved through
///   the backend's stepping interface, so the stage wall-clock is the
///   max over nodes; sequentially under `--sequential-measured` or when
///   the backend cannot step), the report clocks are measured seconds,
///   and [`RunReport::measured`](crate::metrics::RunReport) compares
///   measured iteration latencies against the hardware model's
///   predictions and reports the concurrency actually achieved
///   (`overlap_seconds`, per-node busy/wall).
pub fn run_with_backend(
    policy: &mut dyn Policy,
    scenario: &Scenario,
    ctx: &RunContext,
    opts: &RunOpts,
    backend: &mut dyn ExecBackend,
) -> Result<RunReport> {
    run_core(policy, scenario, None, ctx, opts, backend)
}

/// The one execution loop behind [`run_with_backend`] (single app,
/// `workload = None`) and [`run_workload_with_backend`] (multi-app, with
/// arrival activation and per-app reporting). With `workload = None` or a
/// zero-arrival workload every step is byte-identical to the pre-workload
/// release.
fn run_core(
    policy: &mut dyn Policy,
    scenario: &Scenario,
    workload: Option<&WorkloadScenario>,
    ctx: &RunContext,
    opts: &RunOpts,
    backend: &mut dyn ExecBackend,
) -> Result<RunReport> {
    let RunContext { registry, cost, hw, cluster, sim_cache } = ctx;
    let graph = &scenario.graph;
    let measured_mode = backend.mode() == BackendMode::Measured;

    // Multi-app arrivals: apps arriving at t > 0 are masked out of the
    // initial (planning + execution) state and activated at the first
    // stage boundary at or after their arrival time.
    let masked = workload.and_then(|w| w.masked_workloads());
    let init_workloads: &[Vec<AppRequest>] = masked.as_deref().unwrap_or(&scenario.workloads);
    let mut pending: Vec<(f64, usize)> =
        workload.map(|w| w.pending_arrivals()).unwrap_or_default();
    let mut arrived_nodes: Vec<usize> = vec![];
    let mut arrivals = 0u64;

    // ---- planning phase -------------------------------------------------
    let mut extra_time = 0.0;
    let planned = policy.prepare(&PlanCtx {
        graph,
        workloads: init_workloads,
        cluster,
        registry,
        cost,
        opts,
        sim_cache: opts.sim_cache.then_some(sim_cache),
    });
    let mut search_time = 0.0;
    let mut planner_stats = EvalStats::default();
    if let Some(plan) = &planned {
        extra_time += plan.search_time;
        search_time = plan.search_time;
        planner_stats = plan.eval;
    }

    // ---- running phase ---------------------------------------------------
    let mut true_state = ExecState::init(init_workloads, |_, r| r.true_output_len);
    true_state.admit = opts.admit;
    true_state.fast_step = opts.fast_step;
    if !measured_mode {
        true_state.noise_sigma = Some(opts.noise_sigma);
        true_state.noise_seed = opts.seed ^ 0x7275_6E;
    }

    let mut est_rng = Rng::new(opts.seed ^ 0xE571);
    // The runtime length-feedback loop (§4.3): observed completions feed
    // a per-model posterior; the policy-visible estimate is refreshed
    // from it after every committed stage. Off by default — the frozen
    // path below is bit-identical to the pre-feedback releases.
    let mut online_sampler = opts
        .online_refinement
        .then(|| OnlineSampler::new(cost.sampler.clone(), opts.online_weight));
    let mut observed: HashSet<(usize, u64)> = HashSet::new();
    let mut placement = Placement::empty(cluster.n_gpus);
    let loader = |owner: u64, tp: u32| -> f64 {
        registry
            .get(&graph.nodes[owner as usize].model)
            .map(|s| s.load_time(tp))
            .unwrap_or(0.0)
    };

    // Residency: swap-cost pricing plus the run-long resident/host-cached
    // bookkeeping. With `oversubscribe` off the manager is never consulted
    // and its counters stay zero (the report block is all-zero).
    let swap = match opts.h2d_bw {
        Some(bw) => SwapCost::with_h2d(cluster, bw),
        None => SwapCost::new(cluster),
    };
    let mut res_mgr = ResidencyManager::new();

    let mut timeline: Vec<StageRecord> = vec![];
    let mut all_events: Vec<EngineEvent> = vec![];
    // Measured-mode concurrency accounting: seconds of node wall-clock
    // that ran overlapped (Σ node walls − stage span, clamped at 0 — the
    // sequential lowering chains walls so it contributes exactly 0), and
    // per-node (busy, wall) sums for the busy/wall ratio in the report.
    let mut overlap_seconds = 0.0f64;
    let mut node_busy_wall: HashMap<usize, (f64, f64)> = HashMap::new();
    let mut locked: HashMap<usize, ExecPlan> = HashMap::new();
    let mut prev_stage: Option<Stage> = None;
    let mut guard = 0usize;

    loop {
        // Activate every pending app whose arrival time has passed; if
        // the active apps drained before the next arrival, idle-jump the
        // clock to it. Stage boundaries are the §4.3 decision points, so
        // an arrival mid-stage is absorbed at the boundary that follows.
        if let Some(w) = workload {
            while let Some(&(t, app_id)) = pending.first() {
                if t <= true_state.clock + 1e-9 {
                    let app = &w.apps[app_id];
                    for &ni in &app.nodes {
                        let reqs = &scenario.workloads[ni];
                        true_state.activate_node(ni, reqs, |r| r.true_output_len);
                    }
                    arrived_nodes.extend(app.nodes.iter().copied());
                    arrivals += 1;
                    pending.remove(0);
                } else if true_state.all_done() {
                    true_state.clock = t; // idle gap until the arrival
                } else {
                    break;
                }
            }
        }
        if true_state.all_done() {
            break;
        }
        guard += 1;
        assert!(
            guard <= 16 * graph.n_nodes() + 256,
            "runner failed to converge for {}",
            scenario.name
        );

        // Policies see an estimate of reality: true progress, sampled (or
        // known) remaining lengths, no jitter.
        let decision_t0 = std::time::Instant::now();
        let est_state = estimate_view(
            &true_state,
            graph,
            cost,
            registry,
            opts,
            &mut est_rng,
            online_sampler.as_mut(),
        );
        // Length-aware admission: the same per-stage estimate the planner
        // prices with becomes the engines' per-request prediction, so the
        // online posterior's refinements migrate mispredicted requests
        // between bins/queues at the next stage boundary. FCFS ignores
        // predictions entirely — nothing is installed.
        if opts.admit != AdmitPolicy::Fcfs {
            for (ni, reqs) in true_state.nodes.iter_mut().enumerate() {
                for (r, e) in reqs.iter_mut().zip(&est_state.nodes[ni]) {
                    if !r.is_done() {
                        r.predicted_len = e.output_len;
                    }
                }
            }
        }
        let stage = policy.plan_stage(&StageCtx {
            graph,
            true_state: &true_state,
            est_state: &est_state,
            prev_stage: prev_stage.as_ref(),
            cluster,
            registry,
            cost,
            locked: if opts.no_preemption { Some(&locked) } else { None },
            online: online_sampler.as_ref(),
            arrived: &arrived_nodes,
        });
        arrived_nodes.clear();
        extra_time += decision_t0.elapsed().as_secs_f64();
        let Some(stage) = stage else {
            panic!("policy {} produced no stage with unfinished work", policy.name());
        };
        debug_assert!(stage.n_gpus() <= cluster.n_gpus || opts.oversubscribe);

        if opts.no_preemption {
            for e in &stage.entries {
                locked.entry(e.node).or_insert(e.plan);
            }
        }

        // Packed stage: aggregate demand exceeds the cluster, so the
        // strict minimum-reload transition cannot place it. Lower it into
        // first-finish sub-stages that time-slice the GPUs, paying modeled
        // swap latency at every boundary (the residency subsystem's job).
        if opts.oversubscribe && stage.n_gpus() > cluster.n_gpus {
            let out = residency::run_packed_stage(
                &stage,
                &mut true_state,
                graph,
                registry,
                cluster,
                &swap,
                &mut res_mgr,
                backend,
                measured_mode,
            )?;
            for sub in &out.subs {
                let busy: Vec<f64> = sub
                    .stage
                    .entries
                    .iter()
                    .map(|e| {
                        let node_res = sub.result.nodes.iter().find(|n| n.node == e.node);
                        let busy =
                            node_res.map(|n| n.busy_time).unwrap_or(0.0) * e.plan.tp as f64;
                        let load = sub.load_delay.get(&e.node).copied().unwrap_or(0.0)
                            * e.plan.n_gpus() as f64;
                        busy + load
                    })
                    .collect();
                timeline.push(StageRecord {
                    start: sub.result.start,
                    end: sub.result.end,
                    entries: sub.stage.entries.iter().map(|e| (e.node, e.plan)).collect(),
                    loaded_nodes: sub.load_delay.keys().copied().collect(),
                    load_time: sub.load_delay.values().copied().fold(0.0, f64::max),
                    busy_gpu_seconds: busy,
                    events: EventSummary::from_events(&sub.events),
                    swap_stall: sub.swap_stall,
                });
                all_events.extend(sub.events.iter().cloned());
            }
            if let Some(os) = online_sampler.as_mut() {
                for e in &stage.entries {
                    let model = &graph.nodes[e.node].model;
                    for r in &true_state.nodes[e.node] {
                        if r.is_done() && observed.insert((e.node, r.id)) {
                            os.record(model, r.output_len);
                        }
                    }
                }
            }
            // Land the placement on the final sub-stage's layout (geometry
            // only — the lowering already charged all loading), so the
            // next fitting stage's minimum-reload transition prices from
            // what is actually on the GPUs.
            let final_needs: Vec<(u64, u32, u32)> = out
                .final_stage
                .entries
                .iter()
                .map(|e| (e.node as u64, e.plan.dp, e.plan.tp))
                .collect();
            if let Some(r) =
                Placement::transition(&placement, &final_needs, cluster, &|_, _| 0.0)
            {
                placement = r.placement;
            }
            prev_stage = Some(out.final_stage);
            continue;
        }

        // Placement: minimum-reload transition (§4.3). Measured backends
        // track placement for the record but pay no virtual loading time
        // (the real model loads once, at backend construction).
        let needs: Vec<(u64, u32, u32)> =
            stage.entries.iter().map(|e| (e.node as u64, e.plan.dp, e.plan.tp)).collect();
        let reload = Placement::transition(&placement, &needs, cluster, &loader)
            .expect("stage must fit the cluster");
        placement = reload.placement.clone();
        let mut load_delay: HashMap<usize, f64> = if measured_mode {
            HashMap::new()
        } else {
            reload.load_time_by_owner.iter().map(|(&o, &t)| (o as usize, t)).collect()
        };

        let mut events: Vec<EngineEvent> = vec![];
        // Warm-load override: a model a packed boundary displaced to host
        // memory reloads over the h2d link instead of from storage, when
        // that is cheaper. Host copies only ever exist after a packed
        // displacement, so a run that never oversubscribed skips this
        // wholesale and stays bit-identical.
        let mut swap_stall = 0.0;
        if opts.oversubscribe && !measured_mode {
            for e in &stage.entries {
                let Some(d) = load_delay.get_mut(&e.node) else { continue };
                if !res_mgr.is_host_cached(e.node) {
                    continue;
                }
                let Some(spec) = registry.get(&graph.nodes[e.node].model) else { continue };
                let warm = swap.load_secs(spec, e.plan.tp);
                if warm < *d {
                    let bytes = SwapCost::bytes_total(spec, e.plan.dp, e.plan.tp);
                    res_mgr.stats.swaps_in += 1;
                    res_mgr.stats.bytes_in += bytes;
                    res_mgr.stats.stall_seconds += warm;
                    events.push(EngineEvent {
                        node: e.node,
                        replica: 0,
                        t: true_state.clock,
                        kind: EventKind::SwapIn { bytes, dur: warm },
                    });
                    swap_stall += warm;
                    *d = warm;
                }
            }
        }
        let res = if measured_mode {
            let res = if opts.sequential_measured || !backend.supports_stepping() {
                true_state.run_stage_measured(&stage, graph, registry, backend, Some(&mut events))?
            } else {
                true_state.run_stage_concurrent(
                    &stage,
                    graph,
                    registry,
                    backend,
                    Some(&mut events),
                )?
            };
            let span = (res.end - res.start).max(0.0);
            let walls: f64 = res.nodes.iter().map(|n| n.wall).sum();
            overlap_seconds += (walls - span).max(0.0);
            for n in &res.nodes {
                let e = node_busy_wall.entry(n.node).or_insert((0.0, 0.0));
                e.0 += n.busy_time;
                e.1 += n.wall;
            }
            res
        } else {
            let before_done = true_state.completed.len();
            let res = true_state.run_stage(
                &stage,
                graph,
                registry,
                backend,
                &load_delay,
                false,
                false,
                Some(&mut events),
            );
            // Livelock guard: a stage that completed nothing and took no
            // time is re-run to completion of its fastest node. (As
            // before the refactor, the record keeps the first pass's
            // per-node numbers; the state carries the re-run's progress.)
            if true_state.completed.len() == before_done && res.end - res.start < 1e-9 {
                true_state.run_stage(
                    &stage,
                    graph,
                    registry,
                    backend,
                    &load_delay,
                    false,
                    true,
                    Some(&mut events),
                );
            }
            res
        };

        let busy: Vec<f64> = stage
            .entries
            .iter()
            .map(|e| {
                let node_res = res.nodes.iter().find(|n| n.node == e.node);
                let busy = node_res.map(|n| n.busy_time).unwrap_or(0.0) * e.plan.tp as f64;
                let load = load_delay.get(&e.node).copied().unwrap_or(0.0)
                    * e.plan.n_gpus() as f64;
                busy + load
            })
            .collect();
        timeline.push(StageRecord {
            start: res.start,
            end: true_state.clock,
            entries: stage.entries.iter().map(|e| (e.node, e.plan)).collect(),
            loaded_nodes: load_delay.keys().copied().collect(),
            load_time: if measured_mode { 0.0 } else { reload.load_time },
            busy_gpu_seconds: busy,
            events: EventSummary::from_events(&events),
            swap_stall,
        });
        all_events.append(&mut events);
        // Residency bookkeeping mirrors the planner's: models dropped from
        // the GPUs between fitting stages are discarded (the strict path
        // never host-caches — only packed displacement does).
        if opts.oversubscribe {
            for node in res_mgr.resident_nodes() {
                if !stage.entries.iter().any(|e| e.node == node) {
                    res_mgr.discard(node);
                }
            }
            for e in &stage.entries {
                if let Some(spec) = registry.get(&graph.nodes[e.node].model) {
                    res_mgr.note_resident(
                        e.node,
                        e.plan,
                        SwapCost::bytes_per_gpu(spec, e.plan.tp),
                        true_state.clock,
                    );
                }
            }
        }
        // Feedback: every request the committed stage finished contributes
        // its ground-truth length to the model's posterior.
        if let Some(os) = online_sampler.as_mut() {
            for e in &stage.entries {
                let model = &graph.nodes[e.node].model;
                for r in &true_state.nodes[e.node] {
                    if r.is_done() && observed.insert((e.node, r.id)) {
                        os.record(model, r.output_len);
                    }
                }
            }
        }
        prev_stage = Some(stage);
    }

    let inference_time = true_state.clock;
    let measured = measured_mode
        .then(|| {
            measured_stats(
                &all_events,
                &timeline,
                graph,
                registry,
                hw,
                overlap_seconds,
                &node_busy_wall,
            )
        })
        .flatten();
    // Drift/replan accounting only exists when the feedback loop ran and
    // the policy participates in it (`None` for baselines).
    let online_stats = online_sampler.is_some().then(|| policy.online_stats()).flatten();
    // Per-app accounting for multi-app workload runs: completion times
    // relative to each app's arrival ("stretch").
    let workload_report = workload.map(|w| WorkloadReport {
        arrivals,
        arrival_replans: policy.arrival_replans(),
        per_app: w
            .apps
            .iter()
            .map(|a| {
                let node_set: HashSet<usize> = a.nodes.iter().copied().collect();
                let mut finish = a.arrival;
                let mut completed = 0u64;
                for (&(ni, _), &t) in &true_state.completed {
                    if node_set.contains(&ni) {
                        completed += 1;
                        finish = finish.max(t);
                    }
                }
                AppReport {
                    app_id: a.app_id,
                    name: a.name.clone(),
                    arrival: a.arrival,
                    weight: a.weight,
                    nodes: a.nodes.clone(),
                    n_requests: a.n_requests,
                    completed,
                    finish,
                    makespan: finish - a.arrival,
                }
            })
            .collect(),
    });
    Ok(RunReport {
        scenario: scenario.name.clone(),
        policy: policy.name().to_string(),
        backend: backend.name().to_string(),
        admit_policy: opts.admit.name(),
        admission: true_state.admit_stats,
        residency: res_mgr.stats,
        extra_time,
        search_time,
        planner: planner_stats,
        inference_time,
        end_to_end_time: extra_time + inference_time,
        estimated_inference_time: planned.map(|p| p.est_total).unwrap_or(f64::NAN),
        n_stages: timeline.len(),
        timeline,
        measured,
        online: online_stats,
        workload: workload_report,
        traffic: None,
        n_gpus: cluster.n_gpus,
    })
}

/// Fold a measured run's event stream into [`MeasuredStats`], pricing
/// each real decode iteration with the virtual hardware model at the same
/// batch/context so the report carries measured-vs-predicted latencies
/// (the cost-model validation hook §4.2 promises).
fn measured_stats(
    events: &[EngineEvent],
    timeline: &[StageRecord],
    graph: &AppGraph,
    registry: &Registry,
    hw: &dyn IterLatency,
    overlap_seconds: f64,
    node_busy_wall: &HashMap<usize, (f64, f64)>,
) -> Option<MeasuredStats> {
    // Per-node plan of the stage each event belongs to (by timestamp).
    let plan_at = |node: usize, t: f64| -> ExecPlan {
        timeline
            .iter()
            .find(|s| t <= s.end + 1e-12 && s.entries.iter().any(|(n, _)| *n == node))
            .and_then(|s| {
                s.entries.iter().find(|(n, _)| *n == node).map(|(_, p)| *p)
            })
            .unwrap_or(ExecPlan::new(1, 1))
    };
    let mut decode_durs = vec![];
    let mut predicted = vec![];
    let mut prefill_durs = vec![];
    let mut tokens = 0u64;
    for ev in events {
        match ev.kind {
            EventKind::Prefill { batch, dur, .. } => {
                prefill_durs.push(dur);
                tokens += batch as u64;
            }
            EventKind::Decode { batch, iters, total_ctx, max_ctx, dur } => {
                tokens += iters as u64 * batch as u64;
                let per_iter = dur / iters.max(1) as f64;
                decode_durs.push(per_iter);
                if let Some(spec) = registry.get(&graph.nodes[ev.node].model) {
                    let plan = plan_at(ev.node, ev.t);
                    predicted.push(hw.decode(spec, plan.tp, batch, total_ctx, max_ctx));
                }
            }
            _ => {}
        }
    }
    let dsum = stats::summarize(&decode_durs)?;
    let psum = stats::summarize(&prefill_durs);
    Some(MeasuredStats {
        prefills: prefill_durs.len() as u64,
        decode_iters: decode_durs.len() as u64,
        tokens,
        prefill_mean: psum.map(|s| s.mean).unwrap_or(0.0),
        decode_mean: dsum.mean,
        decode_p50: dsum.p50,
        decode_p99: dsum.p99,
        predicted_decode_mean: if predicted.is_empty() {
            f64::NAN
        } else {
            predicted.iter().sum::<f64>() / predicted.len() as f64
        },
        overlap_seconds,
        node_busy_wall: {
            let mut v: Vec<(usize, f64, f64)> =
                node_busy_wall.iter().map(|(&n, &(b, w))| (n, b, w)).collect();
            v.sort_by_key(|e| e.0);
            v
        },
    })
}

/// Build the policy-visible state: true progress and completions, but
/// remaining output lengths re-sampled from the eCDF (unless the §5.5
/// "known lengths" ablation is on). With the feedback loop on, samples
/// come from the online posterior instead, conditioned on each in-flight
/// request's progress (`X | X > generated`).
pub(crate) fn estimate_view(
    true_state: &ExecState,
    graph: &AppGraph,
    cost: &CostModel,
    registry: &Registry,
    opts: &RunOpts,
    rng: &mut Rng,
    mut online: Option<&mut OnlineSampler>,
) -> ExecState {
    let mut est = true_state.clone();
    est.noise_sigma = None;
    // The estimate's output lengths ARE the predictions — engine policies
    // fall back to them when `predicted_len == 0`, so the clone must not
    // carry the true state's installed predictions (stale by one stage,
    // and shadowing the fresh sample). No-op under FCFS (never installed).
    for reqs in est.nodes.iter_mut() {
        for r in reqs.iter_mut() {
            r.predicted_len = 0;
        }
    }
    if opts.known_lengths {
        return est;
    }
    for (ni, reqs) in est.nodes.iter_mut().enumerate() {
        let node = &graph.nodes[ni];
        let spec = registry.get(&node.model).expect("model");
        for r in reqs.iter_mut() {
            if !r.is_done() {
                let s = match online.as_deref_mut() {
                    Some(os) => os.sample_total(
                        &node.model,
                        r.input_len,
                        node.max_out,
                        spec.max_seq,
                        r.generated,
                        rng,
                    ),
                    None => cost.sampler.sample(
                        &node.model,
                        r.input_len,
                        node.max_out,
                        spec.max_seq,
                        rng,
                    ),
                };
                r.output_len = s.max(r.generated + 1);
            }
        }
    }
    est
}

/// Convenience: run the three §5 paper policies and return their reports.
pub fn compare_policies(
    scenario: &Scenario,
    cluster: &ClusterSpec,
    opts: &RunOpts,
) -> Vec<RunReport> {
    policy::PAPER.iter().map(|&p| run_policy(p, scenario, cluster, opts)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ensemble(n_models: usize, n_reqs: usize, seed: u64) -> Scenario {
        let models = Registry::ensembling_models();
        let mut graph = AppGraph::default();
        let mut workloads = vec![];
        let mut rng = Rng::new(seed);
        for i in 0..n_models {
            let m = models[i % models.len()];
            graph.add_node(m, &format!("m{i}"), 256);
            workloads.push(
                (0..n_reqs as u64)
                    .map(|id| {
                        AppRequest::simple(
                            id,
                            20,
                            crate::workload::lengths::true_output_len(
                                m, 0.05, 20, 256, 2048, &mut rng,
                            ),
                        )
                    })
                    .collect(),
            );
        }
        Scenario { name: format!("ensemble-{n_models}x{n_reqs}"), graph, workloads }
    }

    #[test]
    fn samullm_completes_and_reports() {
        let cluster = ClusterSpec::a100_node(8);
        let sc = tiny_ensemble(4, 120, 1);
        let r = run_policy("ours", &sc, &cluster, &RunOpts::default());
        assert!(r.inference_time > 0.0);
        assert!(r.n_stages >= 1);
        assert!(!r.estimated_inference_time.is_nan());
        // The §5 "extra time" decomposition is visible in the report:
        // Algorithm 1's search time plus its evaluation counters.
        assert!(r.search_time > 0.0);
        assert!(r.extra_time >= r.search_time);
        assert!(r.planner.candidates > 0);
        assert!(r.planner.threads >= 1);
        // Cost-model error in the paper's observed band (≤ ~50%).
        assert!(r.estimation_error() < 0.6, "error {}", r.estimation_error());
        assert!(r.end_to_end_time >= r.inference_time);
    }

    #[test]
    fn all_policies_complete_same_workload() {
        let cluster = ClusterSpec::a100_node(8);
        let sc = tiny_ensemble(5, 100, 2);
        for p in policy::names() {
            let r = run_policy(p, &sc, &cluster, &RunOpts::default());
            assert!(r.inference_time > 0.0, "{p}");
            // Non-planning policies report zero search time (not NaN).
            if p != "ours" {
                assert_eq!(r.search_time, 0.0, "{p}");
                assert_eq!(r.planner.candidates, 0, "{p}");
            }
            // Every stage fits the cluster.
            for s in &r.timeline {
                assert!(s.gpus_used() <= 8, "{p} stage over budget");
            }
        }
    }

    #[test]
    fn ours_not_slower_than_max_on_small_workload() {
        // The paper's headline: for small workloads Max wastes GPUs.
        let cluster = ClusterSpec::a100_node(8);
        let sc = tiny_ensemble(6, 150, 3);
        let ours = run_policy("ours", &sc, &cluster, &RunOpts::default());
        let max = run_policy("max-heuristic", &sc, &cluster, &RunOpts::default());
        assert!(
            ours.inference_time < max.inference_time * 1.15,
            "ours {} vs max {}",
            ours.inference_time,
            max.inference_time
        );
    }

    #[test]
    fn no_preemption_never_changes_plans() {
        let cluster = ClusterSpec::a100_node(8);
        let sc = tiny_ensemble(5, 150, 4);
        let opts = RunOpts { no_preemption: true, ..Default::default() };
        for p in ["ours", "min-heuristic", "round-robin"] {
            let r = run_policy(p, &sc, &cluster, &opts);
            let mut seen: HashMap<usize, ExecPlan> = HashMap::new();
            for s in &r.timeline {
                for (n, plan) in &s.entries {
                    if let Some(prev) = seen.get(n) {
                        assert_eq!(prev, plan, "{p}: node {n} plan changed");
                    }
                    seen.insert(*n, *plan);
                }
            }
        }
    }

    #[test]
    fn oversubscribed_run_completes_on_tiny_cluster() {
        // Three ensembling models cannot be co-resident on 2 GPUs; the
        // packed path must time-slice them and still drain everything.
        let cluster = ClusterSpec::a100_node(2);
        let sc = tiny_ensemble(3, 40, 6);
        let opts = RunOpts { oversubscribe: true, ..Default::default() };
        let r = run_policy("ours", &sc, &cluster, &opts);
        assert!(r.inference_time > 0.0);
        assert!(r.n_stages >= 1);
        // Every request drained (run_core only exits on all_done, so the
        // real check is that the packed lowering neither panicked nor
        // tripped the convergence guard).
        let completions: u64 = r.timeline.iter().map(|s| s.events.completions).sum();
        assert_eq!(completions, 3 * 40, "all injected requests completed");
        // Sub-stage records always fit the physical cluster.
        for s in &r.timeline {
            assert!(s.gpus_used() <= 2, "sub-stage over the physical budget");
            assert!(s.swap_stall >= 0.0);
        }
    }

    #[test]
    fn known_lengths_reduces_estimation_error() {
        let cluster = ClusterSpec::a100_node(8);
        let sc = tiny_ensemble(3, 200, 5);
        let unknown = run_policy("ours", &sc, &cluster, &RunOpts::default());
        let known = run_policy(
            "ours",
            &sc,
            &cluster,
            &RunOpts { known_lengths: true, ..Default::default() },
        );
        // Not guaranteed per-seed, but should hold comfortably here.
        assert!(
            known.estimation_error() <= unknown.estimation_error() + 0.05,
            "known {} vs unknown {}",
            known.estimation_error(),
            unknown.estimation_error()
        );
    }
}
