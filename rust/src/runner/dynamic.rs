//! The dynamic scheduler (§4.3): follow the planned stage sequence, and
//! when reality diverges (a different model finished first), repair the
//! next stage instead of redoing the search:
//!
//! * drop entries whose node already finished;
//! * keep an unfinished node from the previous stage running under its old
//!   plan if the next stage doesn't mention it and GPUs remain;
//! * if the planned stages run out while work remains, synthesize
//!   keep-last-plan stages.

use std::collections::HashMap;

use crate::baselines::heuristics::smallest_valid_plan;
use crate::cluster::ClusterSpec;
use crate::graph::AppGraph;
use crate::models::Registry;
use crate::plan::{ExecPlan, Stage, StageEntry};
use crate::planner::PlannedApp;
use crate::runner::state::ExecState;

/// Stateful repair-as-you-go wrapper around a [`PlannedApp`].
pub struct DynamicScheduler {
    planned: Option<PlannedApp>,
    next_idx: usize,
    /// Most recent plan each node ran with (for keep-running / fallback).
    last_plans: HashMap<usize, ExecPlan>,
    /// Accept planned *packed* stages whose plans sum past the cluster
    /// (the runner lowers them through [`crate::residency`]); off by
    /// default, mirroring [`crate::runner::RunOpts::oversubscribe`].
    /// Without it, an oversized planned stage is silently skipped — the
    /// planner only emits one when the same flag was set.
    pub oversubscribe: bool,
}

impl DynamicScheduler {
    /// Wrap a planned app (or nothing, for pure fallback scheduling).
    pub fn new(planned: Option<PlannedApp>) -> Self {
        DynamicScheduler { planned, next_idx: 0, last_plans: HashMap::new(), oversubscribe: false }
    }

    /// Stages consumed so far (diagnostics). Resets when a replan is
    /// adopted via [`DynamicScheduler::adopt`].
    pub fn consumed(&self) -> usize {
        self.next_idx
    }

    /// Replace the planned stage sequence mid-run (drift-triggered
    /// replanning): the new plan's stages are consumed from the start,
    /// while the last-used-plan history survives so the keep-running rule
    /// and the fallback still know what every node last ran with.
    pub fn adopt(&mut self, planned: PlannedApp) {
        self.planned = Some(planned);
        self.next_idx = 0;
    }

    /// Most recent plan each node ran with (feeds a replan's
    /// `initial_plans`, so keeping a resident model is priced as free).
    pub fn last_plans(&self) -> &HashMap<usize, ExecPlan> {
        &self.last_plans
    }

    /// Predicted elapsed virtual time across the planned stages consumed
    /// so far, relative to the current plan's own start (`None` before
    /// any stage is consumed or without a plan). Compared against the
    /// actually elapsed clock, this is the makespan half of the §4.3
    /// drift score.
    pub fn predicted_elapsed(&self) -> Option<f64> {
        let planned = self.planned.as_ref()?;
        if self.next_idx == 0 || planned.est_windows.is_empty() {
            return None;
        }
        let k = self.next_idx.min(planned.est_windows.len());
        Some(planned.est_windows[k - 1].1 - planned.est_windows[0].0)
    }

    /// Produce the next stage to run.
    pub fn next_stage(
        &mut self,
        graph: &AppGraph,
        true_state: &ExecState,
        prev_stage: Option<&Stage>,
        cluster: &ClusterSpec,
        registry: &Registry,
        locked: Option<&HashMap<usize, ExecPlan>>,
    ) -> Option<Stage> {
        let stage = self
            .planned_next(graph, true_state, prev_stage, cluster, locked)
            .or_else(|| self.fallback(graph, true_state, cluster, registry, locked))?;
        for e in &stage.entries {
            self.last_plans.insert(e.node, e.plan);
        }
        Some(stage)
    }

    fn planned_next(
        &mut self,
        graph: &AppGraph,
        true_state: &ExecState,
        prev_stage: Option<&Stage>,
        cluster: &ClusterSpec,
        locked: Option<&HashMap<usize, ExecPlan>>,
    ) -> Option<Stage> {
        let planned = self.planned.as_ref()?;
        while self.next_idx < planned.stages.len() {
            let mut stage = planned.stages[self.next_idx].clone();
            self.next_idx += 1;
            // Drop finished nodes (reality may be ahead of the plan).
            stage.entries.retain(|e| !true_state.finished_nodes.contains(&e.node));
            // No-preemption: never change a started node's plan.
            if let Some(locked) = locked {
                for e in stage.entries.iter_mut() {
                    if let Some(&p) = locked.get(&e.node) {
                        e.plan = p;
                    }
                }
            }
            // §4.3 keep-running rule: unfinished leftovers of the previous
            // stage join with their old plans if GPUs remain. (A packed
            // stage never grows this way — its lowering already
            // time-slices everything the budget can't hold.)
            if let Some(prev) = prev_stage {
                for e in &prev.entries {
                    if true_state.finished_nodes.contains(&e.node) {
                        continue;
                    }
                    if stage.nodes().contains(&e.node) {
                        continue;
                    }
                    if stage.n_gpus() + e.plan.n_gpus() <= cluster.n_gpus {
                        stage.entries.push(*e);
                    }
                }
            }
            // Validity repair: dependencies must hold after the edits.
            let nodes = stage.nodes();
            stage
                .entries
                .retain(|e| graph.is_ready(e.node, &true_state.finished_nodes, &nodes));
            let fits = stage.n_gpus() <= cluster.n_gpus;
            if !stage.entries.is_empty() && (fits || self.oversubscribe) {
                return Some(stage);
            }
        }
        None
    }

    /// Plan exhausted but work remains (cost-model underestimates): keep
    /// last-known plans, fair-share anything never scheduled.
    fn fallback(
        &self,
        graph: &AppGraph,
        true_state: &ExecState,
        cluster: &ClusterSpec,
        registry: &Registry,
        locked: Option<&HashMap<usize, ExecPlan>>,
    ) -> Option<Stage> {
        let mut stage = Stage::default();
        let ready = graph.ready_nodes(&true_state.finished_nodes, &stage.nodes());
        let mut budget = cluster.n_gpus;
        for node in ready {
            let plan = locked
                .and_then(|l| l.get(&node).copied())
                .or_else(|| self.last_plans.get(&node).copied())
                .or_else(|| {
                    let spec = registry.get(&graph.nodes[node].model)?;
                    smallest_valid_plan(spec, cluster, budget.max(1))
                });
            if let Some(plan) = plan {
                if plan.n_gpus() <= budget {
                    budget -= plan.n_gpus();
                    stage.entries.push(StageEntry { node, plan });
                }
            }
        }
        (!stage.entries.is_empty()).then_some(stage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runner::state::AppRequest;

    fn ctx() -> (AppGraph, Vec<Vec<AppRequest>>, ClusterSpec, Registry) {
        let mut g = AppGraph::default();
        g.add_node("chatglm3-6b", "a", 256);
        g.add_node("alpaca-13b", "b", 256);
        g.add_node("koala-13b", "c", 256);
        let w: Vec<Vec<AppRequest>> =
            (0..3).map(|_| (0..50).map(|i| AppRequest::simple(i, 20, 100)).collect()).collect();
        (g, w, ClusterSpec::a100_node(8), Registry::paper())
    }

    fn planned(stages: Vec<Vec<(usize, u32, u32)>>) -> PlannedApp {
        PlannedApp {
            stages: stages
                .into_iter()
                .map(|es| Stage {
                    entries: es
                        .into_iter()
                        .map(|(n, dp, tp)| StageEntry { node: n, plan: ExecPlan::new(dp, tp) })
                        .collect(),
                })
                .collect(),
            est_windows: vec![],
            est_first_finisher: vec![],
            est_total: 100.0,
            search_time: 0.1,
            eval: Default::default(),
        }
    }

    #[test]
    fn follows_plan_when_reality_agrees() {
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(Some(planned(vec![
            vec![(0, 4, 1), (1, 4, 1)],
            vec![(2, 8, 1)],
        ])));
        let s1 = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(s1.entries.len(), 2);
        let s2 = d.next_stage(&g, &st, Some(&s1), &c, &reg, None).unwrap();
        // Stage 2 keeps unfinished leftovers 0 and 1 running (keep-running
        // rule) next to the planned node 2 — all fit in 8 GPUs? 8+4+4 > 8,
        // so leftovers are dropped in plan order until they fit.
        assert!(s2.nodes().contains(&2));
        assert!(s2.n_gpus() <= 8);
    }

    #[test]
    fn packed_stage_needs_the_oversubscribe_switch() {
        // A planned stage summing past the cluster (4+4+4 = 12 GPUs on 8)
        // is skipped by default — and accepted verbatim with the switch,
        // so the runner's residency lowering gets to time-slice it.
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let packed = vec![vec![(0, 4, 1), (1, 4, 1), (2, 4, 1)]];
        let mut d = DynamicScheduler::new(Some(planned(packed.clone())));
        let s = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert!(s.n_gpus() <= 8, "without the switch the fallback takes over");
        let mut d = DynamicScheduler::new(Some(planned(packed)));
        d.oversubscribe = true;
        let s = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(s.entries.len(), 3);
        assert_eq!(s.n_gpus(), 12, "packed stage passes through untouched");
    }

    #[test]
    fn drops_finished_nodes_from_planned_stage() {
        let (g, w, c, reg) = ctx();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        st.finished_nodes.insert(0);
        let mut d = DynamicScheduler::new(Some(planned(vec![vec![(0, 4, 1), (1, 4, 1)]])));
        let s = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(s.entries.len(), 1);
        assert_eq!(s.entries[0].node, 1);
    }

    #[test]
    fn keep_running_rule_preserves_leftover() {
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(Some(planned(vec![
            vec![(0, 4, 1), (1, 4, 1)],
            vec![(2, 4, 1)],
        ])));
        let s1 = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        // Pretend node 1 finished but node 0 did not (divergence).
        let mut st2 = st.clone();
        st2.finished_nodes.insert(1);
        let s2 = d.next_stage(&g, &st2, Some(&s1), &c, &reg, None).unwrap();
        assert!(s2.nodes().contains(&2), "planned node enters");
        assert!(s2.nodes().contains(&0), "unfinished leftover keeps running");
        assert_eq!(s2.plan_of(0), Some(ExecPlan::new(4, 1)), "same plan as before");
    }

    #[test]
    fn fallback_when_plan_exhausted() {
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(Some(planned(vec![])));
        let s = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert!(!s.entries.is_empty());
        assert!(s.n_gpus() <= 8);
    }

    #[test]
    fn locked_plans_override_planned_changes() {
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut locked = HashMap::new();
        locked.insert(0usize, ExecPlan::new(1, 1));
        let mut d = DynamicScheduler::new(Some(planned(vec![vec![(0, 8, 1)]])));
        let s = d.next_stage(&g, &st, None, &c, &reg, Some(&locked)).unwrap();
        assert_eq!(s.plan_of(0), Some(ExecPlan::new(1, 1)));
    }

    #[test]
    fn exhausted_plan_synthesizes_keep_last_plan_stages() {
        // The cost model underestimated: the planned sequence ran out while
        // node 1 still has work. The fallback must keep node 1 running
        // under the *last plan it actually used*, not a fresh fair share.
        let (g, w, c, reg) = ctx();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(Some(planned(vec![
            vec![(0, 4, 1), (1, 2, 2)],
            vec![(2, 8, 1)],
        ])));
        let s1 = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(d.consumed(), 1);
        // Nodes 0 and 2 finish; node 1 drags on past the planned stages.
        st.finished_nodes.insert(0);
        let s2 = d.next_stage(&g, &st, Some(&s1), &c, &reg, None).unwrap();
        assert!(s2.nodes().contains(&2));
        st.finished_nodes.insert(2);
        let s3 = d.next_stage(&g, &st, Some(&s2), &c, &reg, None).unwrap();
        assert_eq!(d.consumed(), 2, "planned sequence is exhausted");
        assert_eq!(s3.nodes(), vec![1].into_iter().collect());
        assert_eq!(
            s3.plan_of(1),
            Some(ExecPlan::new(2, 2)),
            "fallback must keep node 1's last-used plan"
        );
    }

    #[test]
    fn fallback_without_history_synthesizes_valid_plans() {
        // No planned stages and no last-used plans at all: the fallback
        // synthesizes plans greedily in node order (first ready node gets
        // the biggest valid footprint) and stays inside the cluster.
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(None);
        let s = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert!(!s.entries.is_empty());
        assert!(s.n_gpus() <= c.n_gpus);
        assert!(s.nodes().contains(&0), "first ready node must be scheduled");
        for e in &s.entries {
            let spec = reg.get(&g.nodes[e.node].model).unwrap();
            assert!(e.plan.is_valid_for(spec, &c), "node {} got invalid plan", e.node);
        }
    }

    #[test]
    fn fallback_respects_locked_plans() {
        // No-preemption + exhausted plan: the synthesized stage must pin
        // locked nodes to their locked plans instead of re-deriving them.
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut locked = HashMap::new();
        locked.insert(0usize, ExecPlan::new(1, 2));
        let mut d = DynamicScheduler::new(Some(planned(vec![])));
        let s = d.next_stage(&g, &st, None, &c, &reg, Some(&locked)).unwrap();
        assert_eq!(s.plan_of(0), Some(ExecPlan::new(1, 2)));
        assert!(s.n_gpus() <= c.n_gpus);
    }

    #[test]
    fn keep_running_leftover_dropped_when_gpus_are_full() {
        // The next planned stage already fills the node: an unfinished
        // leftover from the previous stage must NOT squeeze in.
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(Some(planned(vec![
            vec![(0, 4, 1)],
            vec![(1, 8, 1)],
        ])));
        let s1 = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        // Node 0 did not finish, but stage 2 takes all 8 GPUs for node 1.
        let s2 = d.next_stage(&g, &st, Some(&s1), &c, &reg, None).unwrap();
        assert!(s2.nodes().contains(&1));
        assert!(!s2.nodes().contains(&0), "leftover must be dropped: no GPUs remain");
        assert_eq!(s2.n_gpus(), 8);
    }

    #[test]
    fn adopt_resets_consumption_and_keeps_plan_history() {
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut d = DynamicScheduler::new(Some(planned(vec![vec![(0, 2, 2), (1, 4, 1)]])));
        let s1 = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(d.consumed(), 1);
        assert_eq!(d.last_plans().get(&0), Some(&ExecPlan::new(2, 2)));

        // A replan arrives: consumption restarts on the new sequence...
        d.adopt(planned(vec![vec![(2, 8, 1)], vec![(1, 1, 1)]]));
        assert_eq!(d.consumed(), 0);
        let s2 = d.next_stage(&g, &st, Some(&s1), &c, &reg, None).unwrap();
        assert!(s2.nodes().contains(&2));
        assert_eq!(d.consumed(), 1);
        // ...and the pre-replan history survives for the fallback: after
        // the new plan runs out, node 0 keeps its old (2,2) plan.
        let mut st2 = st.clone();
        st2.finished_nodes.insert(1);
        st2.finished_nodes.insert(2);
        let s3 = d.next_stage(&g, &st2, None, &c, &reg, None).unwrap();
        let s4 = d.next_stage(&g, &st2, Some(&s3), &c, &reg, None).unwrap();
        assert_eq!(s4.plan_of(0), Some(ExecPlan::new(2, 2)));
    }

    #[test]
    fn predicted_elapsed_tracks_consumed_windows() {
        let (g, w, c, reg) = ctx();
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut p = planned(vec![vec![(0, 4, 1)], vec![(1, 4, 1)]]);
        p.est_windows = vec![(50.0, 80.0), (80.0, 130.0)];
        let mut d = DynamicScheduler::new(Some(p));
        assert_eq!(d.predicted_elapsed(), None, "nothing consumed yet");
        d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(d.predicted_elapsed(), Some(30.0));
        d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(d.predicted_elapsed(), Some(80.0));
        assert_eq!(DynamicScheduler::new(None).predicted_elapsed(), None);
    }

    #[test]
    fn finished_nodes_drop_even_from_fallback_stages() {
        // Drop-finished-node applies to synthesized stages too.
        let (g, w, c, reg) = ctx();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        st.finished_nodes.insert(0);
        st.finished_nodes.insert(2);
        let mut d = DynamicScheduler::new(None);
        let s = d.next_stage(&g, &st, None, &c, &reg, None).unwrap();
        assert_eq!(s.nodes(), vec![1].into_iter().collect());
    }
}
