//! The open-loop running phase: execute a
//! [`TrafficScenario`](crate::traffic::TrafficScenario) — arrival streams
//! through a bounded, weighted-fair-share admission queue — and report
//! serving metrics instead of makespan.
//!
//! Structure mirrors the batch loop (`runner::run_core`) deliberately:
//! stage boundaries are the §4.3 decision points, the policy sees the
//! same estimated-state view, placement transitions pay the same
//! minimum-reload cost, and arrivals reach planning policies through the
//! same `StageCtx::arrived` forced-replan channel the workload layer
//! introduced. What changes is the *boundary protocol*: before each
//! stage, due arrivals are offered to the [`AdmissionQueue`], then up to
//! `admit_quantum` jobs are admitted by weighted fair share and their
//! per-node requests injected via [`ExecState::inject_requests`]. The
//! admission queue therefore sits *in front of* the scheduling core — no
//! engine or scheduler change, and batch runs (`run`/`workload`) never
//! touch this code path, so they stay bit-identical.
//!
//! Planning against a rate: the policy's offline plan is prepared over
//! [`planning_workloads`](crate::traffic::TrafficScenario::planning_workloads)
//! — a sampled window of the actual arrival streams — so the steady-state
//! placement is priced by simulating the request mix the run will see.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, Result};

use crate::cluster::{ClusterSpec, Placement};
use crate::costmodel::OnlineSampler;
use crate::engine::sched::EngineEvent;
use crate::exec::{BackendMode, EventSummary, ExecBackend, SimBackend};
use crate::metrics::latency::{AppTrafficStats, RequestSample, TrafficReport};
use crate::metrics::{RunReport, StageRecord};
use crate::plan::{ExecPlan, Stage};
use crate::planner::eval::EvalStats;
use crate::policy::{self, PlanCtx, Policy, StageCtx};
use crate::traffic::{AdmissionQueue, QueuedJob, TrafficScenario};
use crate::util::rng::Rng;

use super::{estimate_view, ExecState, RunContext, RunOpts};

/// Run a traffic mix under the registry policy named `policy` on the
/// virtual-time substrate and report serving metrics. Panics on an
/// unknown policy name — use [`crate::session::SamuLlm::run_traffic`]
/// for validated-up-front configuration.
pub fn run_traffic(
    policy: &str,
    traffic: &TrafficScenario,
    cluster: &ClusterSpec,
    opts: &RunOpts,
) -> RunReport {
    let mut p = policy::create(policy).expect("unknown policy name");
    let ctx = RunContext::new(cluster, opts.seed);
    let mut backend = SimBackend::new(&ctx.hw, ctx.cluster.mem_bytes);
    run_traffic_with_backend(p.as_mut(), traffic, &ctx, opts, &mut backend)
        .expect("the simulated substrate is infallible")
}

/// Run an open-loop traffic mix under an instantiated policy against an
/// [`ExecBackend`]. Only virtual-time backends are supported: arrival
/// timestamps live on the virtual clock, which a measured backend does
/// not share.
pub fn run_traffic_with_backend(
    policy: &mut dyn Policy,
    traffic: &TrafficScenario,
    ctx: &RunContext,
    opts: &RunOpts,
    backend: &mut dyn ExecBackend,
) -> Result<RunReport> {
    let RunContext { registry, cost, hw: _, cluster, sim_cache } = ctx;
    let scenario = &traffic.scenario;
    let graph = &scenario.graph;
    let cfg = &traffic.cfg;
    if backend.mode() == BackendMode::Measured {
        return Err(anyhow!(
            "open-loop traffic runs on the virtual-time substrate only \
             (arrival timestamps live on the virtual clock); measured \
             execution goes through the batch path's concurrent stage \
             lowering (run_stage_concurrent) instead"
        ));
    }
    if opts.oversubscribe {
        // Oversubscribed placement targets the batch (offline) loop: a
        // packed stage time-slices the whole cluster between sub-stages,
        // which would head-of-line-block latency-sensitive arrivals for a
        // full weight round-trip. Keep the serving path strict.
        return Err(anyhow!(
            "--oversubscribe applies to batch runs only; traffic runs \
             require every stage to fit the cluster"
        ));
    }
    debug_assert!(cfg.admit_quantum >= 1, "TrafficSpec::build resolves the quantum");

    // ---- planning phase: price the placement over a sampled arrival
    // window (planning against a rate) --------------------------------
    let planning = traffic.planning_workloads();
    let mut extra_time = 0.0;
    let planned = policy.prepare(&PlanCtx {
        graph,
        workloads: &planning,
        cluster,
        registry,
        cost,
        opts,
        sim_cache: opts.sim_cache.then_some(sim_cache),
    });
    let mut search_time = 0.0;
    let mut planner_stats = EvalStats::default();
    if let Some(plan) = &planned {
        extra_time += plan.search_time;
        search_time = plan.search_time;
        planner_stats = plan.eval;
    }

    // ---- running phase: the run starts idle and fills via admission --
    let mut true_state = ExecState::init(&scenario.workloads, |_, r| r.true_output_len);
    true_state.admit = opts.admit;
    true_state.noise_sigma = Some(opts.noise_sigma);
    true_state.noise_seed = opts.seed ^ 0x7275_6E;

    let mut est_rng = Rng::new(opts.seed ^ 0xE571);
    let mut online_sampler = opts
        .online_refinement
        .then(|| OnlineSampler::new(cost.sampler.clone(), opts.online_weight));
    let mut observed: HashSet<(usize, u64)> = HashSet::new();
    let mut placement = Placement::empty(cluster.n_gpus);
    let loader = |owner: u64, tp: u32| -> f64 {
        registry
            .get(&graph.nodes[owner as usize].model)
            .map(|s| s.load_time(tp))
            .unwrap_or(0.0)
    };

    let weights: Vec<f64> = traffic.apps.iter().map(|a| a.weight).collect();
    let mut queue = AdmissionQueue::new(&weights, cfg.queue_capacity, cfg.queue_policy);
    // Arrival cursors, one per app, into the pre-generated streams.
    let mut next_arrival = vec![0usize; traffic.apps.len()];
    // Admission provenance per injected request:
    // (node, id) -> (app, arrival, admit, output_len).
    let mut admitted_meta: HashMap<(usize, u64), (usize, f64, f64, u32)> = HashMap::new();
    // Request-level rejected counts whose arrival fell in the window.
    let mut rejected_in_window = vec![0u64; traffic.apps.len()];
    let in_window =
        |t: f64| t >= cfg.warmup && t < cfg.warmup + cfg.duration;
    let total_jobs = traffic.total_jobs();

    let mut arrived_nodes: Vec<usize> = vec![];
    let mut timeline: Vec<StageRecord> = vec![];
    let mut locked: HashMap<usize, ExecPlan> = HashMap::new();
    let mut prev_stage: Option<Stage> = None;
    let mut guard = 0usize;

    loop {
        // Boundary protocol, step 1: offer every arrival whose timestamp
        // has passed to the admission queue (rejects are final).
        for (app_id, app) in traffic.apps.iter().enumerate() {
            while next_arrival[app_id] < app.arrivals.len()
                && app.arrivals[next_arrival[app_id]] <= true_state.clock + 1e-9
            {
                let t = app.arrivals[next_arrival[app_id]];
                let seq = next_arrival[app_id] as u64;
                if !queue.offer(QueuedJob { app_id, seq, arrival: t }) && in_window(t) {
                    rejected_in_window[app_id] += app.nodes.len() as u64;
                }
                next_arrival[app_id] += 1;
            }
        }
        // Step 2: admit up to the fair-share quantum; each admitted job
        // injects one request per app node (fresh progress, appended —
        // completed work keeps its completion-log entries).
        for _ in 0..cfg.admit_quantum {
            let Some(job) = queue.pop_fair() else { break };
            let app = &traffic.apps[job.app_id];
            for (&node, pool) in app.nodes.iter().zip(&app.pools) {
                let tmpl = pool[(job.seq % pool.len() as u64) as usize];
                let req = super::AppRequest::simple(job.seq, tmpl.input_len, tmpl.true_output_len);
                true_state.inject_requests(node, &[req], |r| r.true_output_len);
                admitted_meta.insert(
                    (node, job.seq),
                    (job.app_id, job.arrival, true_state.clock, tmpl.true_output_len.max(1)),
                );
                if !arrived_nodes.contains(&node) {
                    arrived_nodes.push(node);
                }
            }
        }
        // Step 3: queue-depth accounting at the decision point.
        queue.record_depth();

        // Step 4: termination / pacing. All work drained: admit the
        // remaining backlog at this same clock (the quantum paces it), or
        // idle-jump to the next arrival, or finish.
        if true_state.all_done() {
            if !queue.is_empty() {
                continue;
            }
            let upcoming = traffic
                .apps
                .iter()
                .enumerate()
                .filter_map(|(i, a)| a.arrivals.get(next_arrival[i]).copied())
                .fold(f64::INFINITY, f64::min);
            if upcoming.is_finite() {
                true_state.clock = true_state.clock.max(upcoming);
                continue;
            }
            break;
        }
        guard += 1;
        assert!(
            guard <= 16 * graph.n_nodes() + 256 + 4 * total_jobs as usize,
            "traffic runner failed to converge for {}",
            traffic.name
        );

        // Steps 5+: identical to the batch loop — estimate view, policy
        // stage, minimum-reload placement, first-finish execution.
        let decision_t0 = std::time::Instant::now();
        let est_state = estimate_view(
            &true_state,
            graph,
            cost,
            registry,
            opts,
            &mut est_rng,
            online_sampler.as_mut(),
        );
        // Install the fresh estimates as admission predictions, exactly
        // as in the batch loop (no-op under FCFS).
        if opts.admit != crate::engine::AdmitPolicy::Fcfs {
            for (ni, reqs) in true_state.nodes.iter_mut().enumerate() {
                for (r, e) in reqs.iter_mut().zip(&est_state.nodes[ni]) {
                    if !r.is_done() {
                        r.predicted_len = e.output_len;
                    }
                }
            }
        }
        let stage = policy.plan_stage(&StageCtx {
            graph,
            true_state: &true_state,
            est_state: &est_state,
            prev_stage: prev_stage.as_ref(),
            cluster,
            registry,
            cost,
            locked: if opts.no_preemption { Some(&locked) } else { None },
            online: online_sampler.as_ref(),
            arrived: &arrived_nodes,
        });
        arrived_nodes.clear();
        extra_time += decision_t0.elapsed().as_secs_f64();
        let Some(stage) = stage else {
            panic!("policy {} produced no stage with unfinished work", policy.name());
        };
        debug_assert!(stage.n_gpus() <= cluster.n_gpus);

        if opts.no_preemption {
            for e in &stage.entries {
                locked.entry(e.node).or_insert(e.plan);
            }
        }

        let needs: Vec<(u64, u32, u32)> =
            stage.entries.iter().map(|e| (e.node as u64, e.plan.dp, e.plan.tp)).collect();
        let reload = Placement::transition(&placement, &needs, cluster, &loader)
            .expect("stage must fit the cluster");
        placement = reload.placement.clone();
        let load_delay: HashMap<usize, f64> =
            reload.load_time_by_owner.iter().map(|(&o, &t)| (o as usize, t)).collect();

        let mut events: Vec<EngineEvent> = vec![];
        let before_done = true_state.completed.len();
        let res = true_state.run_stage(
            &stage,
            graph,
            registry,
            backend,
            &load_delay,
            false,
            false,
            Some(&mut events),
        );
        // Livelock guard, as in the batch loop: a stage that completed
        // nothing and took no time is re-run to its fastest node's finish.
        if true_state.completed.len() == before_done && res.end - res.start < 1e-9 {
            true_state.run_stage(
                &stage,
                graph,
                registry,
                backend,
                &load_delay,
                false,
                true,
                Some(&mut events),
            );
        }

        let busy: Vec<f64> = stage
            .entries
            .iter()
            .map(|e| {
                let node_res = res.nodes.iter().find(|n| n.node == e.node);
                let busy = node_res.map(|n| n.busy_time).unwrap_or(0.0) * e.plan.tp as f64;
                let load = load_delay.get(&e.node).copied().unwrap_or(0.0)
                    * e.plan.n_gpus() as f64;
                busy + load
            })
            .collect();
        timeline.push(StageRecord {
            start: res.start,
            end: true_state.clock,
            entries: stage.entries.iter().map(|e| (e.node, e.plan)).collect(),
            loaded_nodes: load_delay.keys().copied().collect(),
            load_time: reload.load_time,
            busy_gpu_seconds: busy,
            events: EventSummary::from_events(&events),
            swap_stall: 0.0,
        });
        if let Some(os) = online_sampler.as_mut() {
            for e in &stage.entries {
                let model = &graph.nodes[e.node].model;
                for r in &true_state.nodes[e.node] {
                    if r.is_done() && observed.insert((e.node, r.id)) {
                        os.record(model, r.output_len);
                    }
                }
            }
        }
        prev_stage = Some(stage);
    }

    // ---- reporting: join completion times onto admission provenance --
    let samples: Vec<RequestSample> = admitted_meta
        .iter()
        .map(|(&(node, id), &(app_id, arrival, admit, output_len))| {
            let finish = *true_state
                .completed
                .get(&(node, id))
                .expect("every admitted request runs to completion before the drain ends");
            RequestSample { app_id, arrival, admit, finish, output_len }
        })
        .collect();
    let app_stats: Vec<AppTrafficStats> = traffic
        .apps
        .iter()
        .enumerate()
        .map(|(i, a)| AppTrafficStats {
            name: a.name.clone(),
            weight: a.weight,
            slo: a.slo,
            counters: queue.counters()[i],
            rejected_in_window: rejected_in_window[i],
        })
        .collect();
    let traffic_report = TrafficReport::build(
        cfg.duration,
        cfg.warmup,
        app_stats,
        &samples,
        queue.depth_mean(),
        queue.depth_max(),
    );

    let inference_time = true_state.clock;
    let online_stats = online_sampler.is_some().then(|| policy.online_stats()).flatten();
    Ok(RunReport {
        scenario: traffic.name.clone(),
        policy: policy.name().to_string(),
        backend: backend.name().to_string(),
        admit_policy: opts.admit.name(),
        admission: true_state.admit_stats,
        residency: crate::residency::ResidencyStats::default(),
        extra_time,
        search_time,
        planner: planner_stats,
        inference_time,
        end_to_end_time: extra_time + inference_time,
        estimated_inference_time: planned.map(|p| p.est_total).unwrap_or(f64::NAN),
        n_stages: timeline.len(),
        timeline,
        measured: None,
        online: online_stats,
        workload: None,
        traffic: Some(traffic_report),
        n_gpus: cluster.n_gpus,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::poisson_pair_traffic;

    fn small_traffic() -> TrafficScenario {
        poisson_pair_traffic(1.5, 1.5, 2.0, 12.0).build(42).expect("valid spec")
    }

    #[test]
    fn open_loop_run_reports_serving_metrics() {
        let cluster = ClusterSpec::a100_node(8);
        let ts = small_traffic();
        assert!(ts.total_jobs() > 4, "stream too quiet for the test");
        let r = run_traffic("ours", &ts, &cluster, &RunOpts::default());
        assert!(r.inference_time > 0.0);
        assert!(r.n_stages >= 1);
        assert!(r.workload.is_none(), "traffic runs use the traffic report");
        let t = r.traffic.as_ref().expect("traffic section present");
        assert_eq!(t.per_app.len(), 2);
        assert_eq!(t.offered, ts.total_jobs());
        assert_eq!(t.offered, t.admitted + t.rejected, "defer admits everything");
        for a in &t.per_app {
            assert!(a.completed > 0, "{}: nothing measured", a.name);
            assert!(a.ttft_mean.unwrap() >= 0.0);
            assert!(a.tpot_mean.unwrap() > 0.0);
            assert!(a.latency_p50.unwrap() <= a.latency_p99.unwrap() + 1e-9);
            assert!((0.0..=1.0).contains(&a.slo_attainment.unwrap()));
        }
        // The sampled-window plan exists and was priced.
        assert!(!r.estimated_inference_time.is_nan());
        // JSON carries the traffic section.
        let json = r.to_json().to_string();
        assert!(json.contains("\"traffic\":{"), "{json}");
        assert!(json.contains("\"ttft_mean\""), "{json}");
    }

    #[test]
    fn traffic_runs_are_seed_deterministic() {
        let cluster = ClusterSpec::a100_node(8);
        let ts = small_traffic();
        let opts = RunOpts::default();
        let a = run_traffic("round-robin", &ts, &cluster, &opts);
        let b = run_traffic("round-robin", &ts, &cluster, &opts);
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        let (ta, tb) = (a.traffic.unwrap(), b.traffic.unwrap());
        assert_eq!(ta, tb, "whole serving report is bit-identical");
    }

    #[test]
    fn oversubscribe_is_rejected_for_traffic() {
        let cluster = ClusterSpec::a100_node(8);
        let ts = small_traffic();
        let mut p = policy::create("ours").unwrap();
        let ctx = RunContext::new(&cluster, 7);
        let opts = RunOpts { oversubscribe: true, ..Default::default() };
        let mut backend = SimBackend::new(&ctx.hw, ctx.cluster.mem_bytes);
        let err = run_traffic_with_backend(p.as_mut(), &ts, &ctx, &opts, &mut backend)
            .unwrap_err();
        assert!(err.to_string().contains("batch runs only"), "{err}");
    }

    #[test]
    fn measured_backend_is_rejected() {
        struct FakeMeasured;
        impl ExecBackend for FakeMeasured {
            fn name(&self) -> &'static str {
                "fake"
            }
            fn mode(&self) -> BackendMode {
                BackendMode::Measured
            }
            fn run_node(
                &mut self,
                _req: &crate::exec::NodeRun,
            ) -> Result<crate::exec::NodeOutcome> {
                unreachable!("rejected before execution")
            }
        }
        let cluster = ClusterSpec::a100_node(8);
        let ts = small_traffic();
        let mut p = policy::create("round-robin").unwrap();
        let ctx = RunContext::new(&cluster, 7);
        let err = run_traffic_with_backend(
            p.as_mut(),
            &ts,
            &ctx,
            &RunOpts::default(),
            &mut FakeMeasured,
        )
        .unwrap_err();
        assert!(err.to_string().contains("virtual-time"), "{err}");
    }
}
