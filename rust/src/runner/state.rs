//! Application execution state: per-node remaining workloads, cross-node
//! completion log, and stage execution (shared by the planner's what-if
//! simulations and the running phase's "ground truth" execution).
//!
//! Stage semantics follow §3/§4.2: a stage runs its nodes concurrently
//! (dependencies inside a stage = model-level pipeline parallelism,
//! simulated in topological order); the stage ends when the first node
//! finishes its remaining workload; everyone else is drained and carries
//! progress forward. Nodes whose plan (and hence placement) survives the
//! boundary keep their KV caches (`kv_resident`); restarted nodes pay the
//! vLLM recompute re-prefill — the same rule for every policy, so
//! comparisons are fair.

use std::collections::{HashMap, HashSet};

use anyhow::Result;

use crate::costmodel::IterLatency;
use crate::engine::sched::{AdmitPolicy, AdmitStats, EngineEvent};
use crate::engine::session::remaining_flops;
use crate::engine::sim::EngineConfig;
use crate::engine::EngineRequest;
use crate::exec::{ExecBackend, NodeOutcome, NodeRun};
use crate::graph::AppGraph;
use crate::models::Registry;
use crate::plan::Stage;

/// One application-level request (graph semantics attached).
#[derive(Debug, Clone, Copy)]
pub struct AppRequest {
    /// Request id, unique within its node.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Ground-truth output length (hidden from the planner).
    pub true_output_len: u32,
    /// Next request in this node's fused self-loop chain.
    pub chain_next: Option<u64>,
    /// True if an in-node chain predecessor must complete first.
    pub chain_blocked: bool,
    /// Cross-node dependency: (producer node, producer request id).
    pub dep: Option<(usize, u64)>,
}

impl AppRequest {
    /// A dependency-free, chain-free request.
    pub fn simple(id: u64, input_len: u32, true_output_len: u32) -> Self {
        AppRequest {
            id,
            input_len,
            true_output_len,
            chain_next: None,
            chain_blocked: false,
            dep: None,
        }
    }
}

/// A request with its *resolved* output length (sampled by the planner,
/// true for the runner) and progress.
#[derive(Debug, Clone, Copy)]
pub struct StatefulReq {
    /// Request id, unique within its node.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Resolved output length (sampled for the planner, true for the
    /// runner).
    pub output_len: u32,
    /// Tokens generated so far.
    pub generated: u32,
    /// Next request in this node's fused self-loop chain.
    pub chain_next: Option<u64>,
    /// True if an in-node chain predecessor must complete first.
    pub chain_blocked: bool,
    /// Cross-node dependency: (producer node, producer request id).
    pub dep: Option<(usize, u64)>,
    /// Predicted total output length for length-aware admission (0 = no
    /// prediction; see [`EngineRequest::predicted_len`]). Installed by the
    /// runner from the planner's estimate state when a non-FCFS policy is
    /// active, refreshed when the online refiner re-samples.
    pub predicted_len: u32,
}

impl StatefulReq {
    /// Whether the request generated its full output.
    pub fn is_done(&self) -> bool {
        self.generated >= self.output_len
    }
}

/// Per-node stage outcome.
#[derive(Debug, Clone)]
pub struct NodeStageResult {
    /// Graph node id.
    pub node: usize,
    /// Absolute virtual finish time of the node's whole remaining
    /// workload (pass-1 estimate; equals actual when it finishes first).
    pub projected_finish: f64,
    /// Busy time accumulated inside the executed window.
    pub busy_time: f64,
    /// Tokens generated inside the executed window.
    pub tokens: u64,
    /// Whether the node completed all requests within the stage.
    pub finished: bool,
    /// Seconds from the node's own start within the stage to its finish
    /// (0 for a node with nothing to run). Under concurrent measured
    /// lowering every node starts at the stage start, so the per-node
    /// busy/wall ratio and the stage's overlap both derive from this.
    pub wall: f64,
}

/// Result of executing one stage.
#[derive(Debug, Clone)]
pub struct StageResult {
    /// Stage start (absolute virtual time).
    pub start: f64,
    /// Stage end (the first-finish boundary, or all-done for run-to-end).
    pub end: f64,
    /// Per-node outcomes.
    pub nodes: Vec<NodeStageResult>,
}

/// Execution state of an application run (one per executor).
#[derive(Debug, Clone)]
pub struct ExecState {
    /// Remaining requests per node (resolved lengths).
    pub nodes: Vec<Vec<StatefulReq>>,
    /// Completion log: (node, request) -> absolute completion time.
    pub completed: HashMap<(usize, u64), f64>,
    /// Nodes whose whole workload has completed.
    pub finished_nodes: HashSet<usize>,
    /// Current absolute virtual time.
    pub clock: f64,
    /// Ground-truth jitter σ (None for planner estimates).
    pub noise_sigma: Option<f64>,
    /// Seed for the jitter stream.
    pub noise_seed: u64,
    /// Admission policy every node's engine runs with (FCFS by default —
    /// byte-identical to the pre-policy behaviour).
    pub admit: AdmitPolicy,
    /// Admission counters accumulated across committed stages (queue
    /// jumps, starvation promotions, max queue wait).
    pub admit_stats: AdmitStats,
    /// Enable the engine's aggregated fast-step decode path
    /// ([`EngineConfig::fast_step`]). Exact — bit-identical outcomes
    /// either way — so it is deliberately *not* part of
    /// [`ExecState::node_workload_fingerprint`].
    pub fast_step: bool,
}

impl ExecState {
    /// Build the initial state, resolving each request's output length via
    /// `resolve(node_id, &req)` (eCDF sample or ground truth).
    pub fn init(
        workloads: &[Vec<AppRequest>],
        mut resolve: impl FnMut(usize, &AppRequest) -> u32,
    ) -> Self {
        let nodes: Vec<Vec<StatefulReq>> = workloads
            .iter()
            .enumerate()
            .map(|(ni, reqs)| {
                reqs.iter()
                    .map(|r| StatefulReq {
                        id: r.id,
                        input_len: r.input_len,
                        output_len: resolve(ni, r).max(1),
                        generated: 0,
                        chain_next: r.chain_next,
                        chain_blocked: r.chain_blocked,
                        dep: r.dep,
                        predicted_len: 0,
                    })
                    .collect()
            })
            .collect();
        // A node with nothing to run (an empty workload — e.g. a
        // not-yet-arrived app of a multi-app workload, masked out until
        // [`ExecState::activate_node`]) counts as finished so no policy
        // ever tries to schedule it. Fresh requests resolve to ≥ 1 output
        // tokens, so populated nodes are never flagged here.
        let finished_nodes = nodes
            .iter()
            .enumerate()
            .filter(|(_, reqs)| reqs.iter().all(|r| r.is_done()))
            .map(|(ni, _)| ni)
            .collect();
        ExecState {
            nodes,
            completed: HashMap::new(),
            finished_nodes,
            clock: 0.0,
            noise_sigma: None,
            noise_seed: 0,
            admit: AdmitPolicy::Fcfs,
            admit_stats: AdmitStats::default(),
            fast_step: true,
        }
    }

    /// Activate a node that was initialised with an empty (masked)
    /// workload — the arrival path of multi-app workloads: install its
    /// requests, resolving each output length via `resolve`, and clear its
    /// finished flag so policies start scheduling it. No-op semantics for
    /// an empty `reqs` (the node simply stays finished).
    pub fn activate_node(
        &mut self,
        node: usize,
        reqs: &[AppRequest],
        mut resolve: impl FnMut(&AppRequest) -> u32,
    ) {
        self.nodes[node] = reqs
            .iter()
            .map(|r| StatefulReq {
                id: r.id,
                input_len: r.input_len,
                output_len: resolve(r).max(1),
                generated: 0,
                chain_next: r.chain_next,
                chain_blocked: r.chain_blocked,
                dep: r.dep,
                predicted_len: 0,
            })
            .collect();
        if !self.nodes[node].is_empty() {
            self.finished_nodes.remove(&node);
        }
    }

    /// Append requests to a node without touching the ones already there
    /// — the open-loop traffic admission path: unlike
    /// [`ExecState::activate_node`] (which *replaces* a masked workload),
    /// injection accumulates, so completed requests keep their entries in
    /// the completion log and in-flight requests keep their progress.
    /// Output lengths resolve via `resolve`; the node's finished flag is
    /// cleared so policies start scheduling it again.
    pub fn inject_requests(
        &mut self,
        node: usize,
        reqs: &[AppRequest],
        mut resolve: impl FnMut(&AppRequest) -> u32,
    ) {
        if reqs.is_empty() {
            return;
        }
        self.nodes[node].extend(reqs.iter().map(|r| StatefulReq {
            id: r.id,
            input_len: r.input_len,
            output_len: resolve(r).max(1),
            generated: 0,
            chain_next: r.chain_next,
            chain_blocked: r.chain_blocked,
            dep: r.dep,
            predicted_len: 0,
        }));
        self.finished_nodes.remove(&node);
    }

    /// Whether every node finished its workload.
    pub fn all_done(&self) -> bool {
        self.finished_nodes.len() == self.nodes.len()
    }

    /// Ids of nodes with remaining work, ascending.
    pub fn unfinished_nodes(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|n| !self.finished_nodes.contains(n)).collect()
    }

    /// Remaining FLOPs for a node (the throughput objective's numerator).
    pub fn node_remaining_flops(&self, node: usize, graph: &AppGraph, registry: &Registry) -> f64 {
        let spec = registry.get(&graph.nodes[node].model).expect("model in registry");
        let ereqs: Vec<EngineRequest> = self.nodes[node]
            .iter()
            .filter(|r| !r.is_done())
            .map(|r| EngineRequest {
                id: r.id,
                input_len: r.input_len,
                output_len: r.output_len,
                ready_time: 0.0,
                generated: r.generated,
                chain_next: None,
                kv_resident: false,
                predicted_len: 0,
            })
            .collect();
        remaining_flops(spec, &ereqs)
    }

    /// Fast completion-time estimate for a single `(node, plan)` candidate:
    /// the duration (seconds since the would-be stage start, loading
    /// included, clamped to ≥ 1 µs) of the outcome returned by
    /// [`ExecState::simulate_node_fast`]. Used by the planner's candidate
    /// scoring (not by state commits, which remain exact).
    #[allow(clippy::too_many_arguments)] // established planner fast path
    pub fn estimate_node_time_fast(
        &self,
        node: usize,
        plan: crate::plan::ExecPlan,
        graph: &AppGraph,
        registry: &Registry,
        lat: &dyn IterLatency,
        mem_bytes: u64,
        load_delay: f64,
    ) -> f64 {
        self.simulate_node_fast(node, plan, graph, registry, lat, mem_bytes, load_delay)
            .clock
            .max(1e-6)
    }

    /// Fast single-node candidate simulation: DP replicas are
    /// statistically symmetric, so simulating only the heaviest
    /// round-robin share bounds the session finish time at 1/dp of the
    /// cost. Only valid for nodes whose dependencies are all satisfied
    /// (no same-stage producers).
    ///
    /// The returned outcome is expressed in *relative* virtual time: its
    /// `clock` is the duration since the would-be stage start (loading
    /// delay included), independent of `self.clock`. That translation
    /// invariance is what makes the result safe to memoize in a
    /// [`crate::planner::SimCache`] and replay at any later clock.
    #[allow(clippy::too_many_arguments)] // established planner fast path
    pub fn simulate_node_fast(
        &self,
        node: usize,
        plan: crate::plan::ExecPlan,
        graph: &AppGraph,
        registry: &Registry,
        lat: &dyn IterLatency,
        mem_bytes: u64,
        load_delay: f64,
    ) -> crate::engine::sim::SimOutcome {
        let spec = registry.get(&graph.nodes[node].model).expect("model");
        // Simulate at a canonical origin (stage start = 0) so equal
        // workloads produce bit-equal outcomes regardless of the absolute
        // clock — floating-point accumulation is origin-sensitive.
        let start = load_delay;
        let reqs = self.build_engine_requests(node, start, &HashMap::new(), load_delay == 0.0);
        if reqs.is_empty() {
            return crate::engine::sim::SimOutcome { clock: load_delay, ..Default::default() };
        }
        let parts = crate::engine::session::split_round_robin(&reqs, plan.dp);
        let heaviest = parts
            .into_iter()
            .max_by_key(|p| {
                p.iter()
                    .map(|r| r.remaining() as u64 + (r.input_len as u64 >> 3))
                    .sum::<u64>()
            })
            .unwrap_or_default();
        let cfg = EngineConfig {
            noise_sigma: None,
            admit: self.admit,
            fast_step: self.fast_step,
            ..EngineConfig::standard(spec, plan.tp, mem_bytes)
                .unwrap_or_else(|e| panic!("candidate plan reached the engine: {e}"))
        };
        let mut sim = crate::engine::sim::EngineSim::new(
            spec,
            plan.tp,
            lat,
            cfg,
            heaviest,
            start,
            0,
        );
        sim.run(None)
    }

    /// Resume-point variant of [`ExecState::simulate_node_fast`] for
    /// incremental re-simulation: consult `cache` under the node's
    /// **delta key** — model, plan, load delay, and `fingerprint`
    /// ([`ExecState::node_workload_fingerprint`], pass a precomputed
    /// value when pricing many candidates against one state) — and only
    /// run a fresh simulation when the node's workload or placement
    /// actually changed since the cached entry was written.
    ///
    /// Because the fast estimator prices in *relative* virtual time, a
    /// replan ([`crate::planner::GreedyPlanner::plan_from_state`]) that
    /// shares the cache resumes every unchanged node from its memoized
    /// outcome verbatim: only nodes whose requests progressed, whose
    /// predictions were refreshed, or whose candidate plan/loading
    /// differs are re-priced. Hits are bit-identical to recomputation.
    #[allow(clippy::too_many_arguments)] // established planner fast path
    pub fn simulate_node_from(
        &self,
        cache: &crate::planner::SimCache,
        node: usize,
        fingerprint: u64,
        plan: crate::plan::ExecPlan,
        graph: &AppGraph,
        registry: &Registry,
        lat: &dyn IterLatency,
        mem_bytes: u64,
        load_delay: f64,
    ) -> crate::engine::sim::SimOutcome {
        let key = crate::planner::simcache::SimKey::new(
            &graph.nodes[node].model,
            plan,
            fingerprint,
            load_delay,
        );
        cache.get_or_compute(key, || {
            self.simulate_node_fast(node, plan, graph, registry, lat, mem_bytes, load_delay)
        })
    }

    /// Fingerprint of this node's remaining workload exactly as
    /// [`ExecState::simulate_node_fast`] will see it: per live request —
    /// id, input length, resolved output length, progress, chain link and
    /// ready state (every runnable request is ready exactly at stage
    /// start; chain-blocked successors get a sentinel — if finer-grained
    /// ready times ever appear here, they must be folded into this hash).
    /// Requests whose cross-node producer has not completed are excluded,
    /// mirroring the estimator.
    ///
    /// Two states with equal fingerprints (same model, plan, load delay)
    /// are guaranteed the same simulation outcome, which is what lets
    /// [`crate::planner::SimCache`] hits replace fresh simulations
    /// without disturbing planner parity.
    pub fn node_workload_fingerprint(&self, node: usize) -> u64 {
        use crate::planner::simcache::Fnv;
        let done_ids: HashSet<u64> = self.nodes[node]
            .iter()
            .filter(|r| r.is_done())
            .map(|r| r.id)
            .collect();
        let mut h = Fnv::new();
        // The admission policy shapes batch composition, so it is part of
        // the key (a cached outcome under one policy must never answer a
        // query under another). Under FCFS this folds the same constants
        // for every node — equality patterns, and hence planner cache
        // hit/miss parity, are preserved.
        h.push(match self.admit {
            AdmitPolicy::Fcfs => 0,
            AdmitPolicy::Spjf => 1,
            AdmitPolicy::MultiBin { bins } => 2 | ((bins as u64) << 8),
            AdmitPolicy::SkipJoinMlfq { queues, .. } => 3 | ((queues as u64) << 8),
        });
        if let AdmitPolicy::SkipJoinMlfq { promote_after, .. } = self.admit {
            h.push(promote_after.to_bits());
        }
        for r in &self.nodes[node] {
            if r.is_done() {
                continue;
            }
            if let Some(dep) = r.dep {
                if !self.completed.contains_key(&dep) {
                    // Excluded from the simulation, hence from the key.
                    continue;
                }
            }
            let blocked =
                r.chain_blocked && !Self::chain_pred_done(&self.nodes[node], r.id, &done_ids);
            // All runnable requests become ready exactly at stage start;
            // blocked chain successors get a sentinel.
            let ready_q: u64 = if blocked { u64::MAX } else { 0 };
            h.push(r.id);
            h.push((r.input_len as u64) << 32 | r.output_len as u64);
            h.push(r.generated as u64);
            h.push(r.chain_next.map(|c| c ^ 0x8000_0000_0000_0000).unwrap_or(u64::MAX - 1));
            h.push(ready_q);
            // Predictions steer non-FCFS admission order (constant 0 under
            // FCFS, where they are never installed).
            h.push(r.predicted_len as u64);
        }
        h.finish()
    }

    /// Materialise engine requests for `node` at stage start, resolving
    /// ready times from the completion log and `stage_completions` (same-
    /// stage producers already simulated in topological order). Requests
    /// whose cross-node dependency is not yet satisfiable are skipped.
    fn build_engine_requests(
        &self,
        node: usize,
        start: f64,
        stage_completions: &HashMap<(usize, u64), f64>,
        kept: bool,
    ) -> Vec<EngineRequest> {
        let mut out = vec![];
        let done_ids: HashSet<u64> = self.nodes[node]
            .iter()
            .filter(|r| r.is_done())
            .map(|r| r.id)
            .collect();
        for r in &self.nodes[node] {
            if r.is_done() {
                continue;
            }
            let mut ready = start;
            if let Some(dep) = r.dep {
                if self.completed.contains_key(&dep) {
                    // producer output already available
                } else if let Some(&t) = stage_completions.get(&dep) {
                    ready = t.max(start);
                } else {
                    continue; // producer not reachable this stage
                }
            }
            let blocked = r.chain_blocked
                && !self.completed.keys().any(|&(n, id)| n == node && {
                    // chain predecessor done check below via done_ids
                    let _ = id;
                    false
                })
                && !Self::chain_pred_done(&self.nodes[node], r.id, &done_ids);
            out.push(EngineRequest {
                id: r.id,
                input_len: r.input_len,
                output_len: r.output_len,
                ready_time: if blocked { EngineRequest::BLOCKED } else { ready },
                generated: r.generated,
                chain_next: r.chain_next,
                // Kept nodes (plan + placement unchanged, §4.3) retain
                // their KV across the stage boundary.
                kv_resident: kept && r.generated > 0,
                predicted_len: r.predicted_len,
            });
        }
        out
    }

    fn chain_pred_done(reqs: &[StatefulReq], id: u64, done_ids: &HashSet<u64>) -> bool {
        // The predecessor is the request whose chain_next == id.
        match reqs.iter().find(|r| r.chain_next == Some(id)) {
            Some(pred) => done_ids.contains(&pred.id),
            None => true, // no predecessor recorded -> treat as ready
        }
    }

    /// Execute (or dry-run) one stage against a virtual-time backend.
    ///
    /// * `backend` — the execution substrate (virtual backends only: the
    ///   two-pass project-then-replay structure requires rewindable time;
    ///   measured backends go through
    ///   [`ExecState::run_stage_measured`]).
    /// * `load_delay[node]` — seconds of model-loading before the node's
    ///   engines start (0 when kept resident, §4.3).
    /// * `dry_run` — compute projected finishes without mutating state
    ///   (used by the planner's candidate evaluation).
    /// * `run_to_end` — if false (default semantics), the stage ends at
    ///   the first node completion; if true it runs until all nodes finish
    ///   (used for the final stage and no-preemption execution).
    /// * `trace` — optional unified event stream collector (commit pass
    ///   only; results are identical with or without it).
    #[allow(clippy::too_many_arguments)] // established stage-execution signature
    pub fn run_stage(
        &mut self,
        stage: &Stage,
        graph: &AppGraph,
        registry: &Registry,
        backend: &mut dyn ExecBackend,
        load_delay: &HashMap<usize, f64>,
        dry_run: bool,
        run_to_end: bool,
        trace: Option<&mut Vec<EngineEvent>>,
    ) -> StageResult {
        let start = self.clock;
        let order = graph.topo_order(&stage.entries.iter().map(|e| e.node).collect::<Vec<_>>());

        // Pass 1: run every node to completion to learn projected finishes.
        let mut stage_completions: HashMap<(usize, u64), f64> = HashMap::new();
        let mut projected: HashMap<usize, f64> = HashMap::new();
        let mut runnable: HashSet<usize> = HashSet::new();
        for &node in &order {
            let plan = stage.plan_of(node).unwrap();
            let spec = registry.get(&graph.nodes[node].model).expect("model");
            let delay = load_delay.get(&node).copied().unwrap_or(0.0);
            let kept = !load_delay.contains_key(&node);
            let reqs = self.build_engine_requests(node, start + delay, &stage_completions, kept);
            let out = self.run_node_on(
                backend,
                node,
                graph,
                spec,
                plan,
                &reqs,
                start + delay,
                None,
                false,
            );
            for (id, t) in &out.completions {
                stage_completions.insert((node, *id), *t);
            }
            // A node with zero runnable requests this stage "finishes" at
            // start (it will be reconsidered next stage).
            let finish = if reqs.is_empty() {
                start + delay
            } else {
                runnable.insert(node);
                out.finish_time
            };
            projected.insert(node, finish);
        }

        // The first-finish boundary only counts nodes that actually had
        // work; a co-scheduled consumer with nothing ready yet must not end
        // the stage at zero duration.
        let stage_end = if run_to_end || runnable.is_empty() {
            projected.values().copied().fold(start, f64::max)
        } else {
            projected
                .iter()
                .filter(|(n, _)| runnable.contains(n))
                .map(|(_, &t)| t)
                .fold(f64::INFINITY, f64::min)
                .max(start)
        };

        let mut results = vec![];
        if dry_run {
            for &node in &order {
                results.push(NodeStageResult {
                    node,
                    projected_finish: projected[&node],
                    busy_time: 0.0,
                    tokens: 0,
                    finished: (projected[&node] - stage_end) < 1e-9,
                    wall: (projected[&node] - start).max(0.0),
                });
            }
            return StageResult { start, end: stage_end, nodes: results };
        }

        // Pass 2: replay with the stage-end deadline and commit state.
        let mut trace = trace;
        let mut replay_completions: HashMap<(usize, u64), f64> = HashMap::new();
        for &node in &order {
            let plan = stage.plan_of(node).unwrap();
            let spec = registry.get(&graph.nodes[node].model).expect("model");
            let delay = load_delay.get(&node).copied().unwrap_or(0.0);
            let kept = !load_delay.contains_key(&node);
            let reqs = self.build_engine_requests(node, start + delay, &replay_completions, kept);
            let mut out = self.run_node_on(
                backend,
                node,
                graph,
                spec,
                plan,
                &reqs,
                start + delay,
                Some(stage_end),
                trace.is_some(),
            );
            for (id, t) in &out.completions {
                replay_completions.insert((node, *id), *t);
            }
            if let Some(t) = trace.as_mut() {
                t.append(&mut out.events);
            }
            let res =
                self.commit_node(node, &out, projected[&node], (projected[&node] - start).max(0.0));
            results.push(res);
        }
        self.clock = stage_end;
        StageResult { start, end: stage_end, nodes: results }
    }

    /// Drive one node through `backend` (panicking on backend errors —
    /// virtual backends are infallible and this path is virtual-only).
    #[allow(clippy::too_many_arguments)] // internal forwarding helper
    fn run_node_on(
        &self,
        backend: &mut dyn ExecBackend,
        node: usize,
        graph: &AppGraph,
        spec: &crate::models::ModelSpec,
        plan: crate::plan::ExecPlan,
        reqs: &[EngineRequest],
        start_time: f64,
        deadline: Option<f64>,
        collect_events: bool,
    ) -> NodeOutcome {
        backend
            .run_node(&NodeRun {
                node,
                model: &graph.nodes[node].model,
                spec,
                plan,
                requests: reqs,
                start_time,
                deadline,
                noise_sigma: self.noise_sigma,
                noise_seed: self.noise_seed ^ ((node as u64) << 8),
                collect_events,
                admit: self.admit,
                fast_step: self.fast_step,
            })
            .unwrap_or_else(|e| panic!("stage execution failed: {e:#}"))
    }

    /// Commit a node outcome: completions, carried progress, finish flag.
    /// `wall` is the node's own span within the stage (start-to-finish
    /// seconds) as the caller's lowering defines it.
    fn commit_node(
        &mut self,
        node: usize,
        out: &NodeOutcome,
        projected_finish: f64,
        wall: f64,
    ) -> NodeStageResult {
        let mut progress: HashMap<u64, u32> = HashMap::new();
        for r in &out.remaining {
            progress.insert(r.id, r.generated);
        }
        let completed_here: HashSet<u64> = out.completions.iter().map(|(id, _)| *id).collect();
        for r in self.nodes[node].iter_mut() {
            if completed_here.contains(&r.id) {
                r.generated = r.output_len;
            } else if let Some(&g) = progress.get(&r.id) {
                r.generated = g;
            }
        }
        for (id, t) in &out.completions {
            self.completed.insert((node, *id), *t);
        }
        for rep in &out.replicas {
            self.admit_stats.absorb(&rep.admit);
        }
        let finished = self.nodes[node].iter().all(|r| r.is_done());
        if finished {
            self.finished_nodes.insert(node);
        }
        let busy: f64 = out.replicas.iter().map(|r| r.busy_time).sum();
        let tokens: u64 = out.replicas.iter().map(|r| r.tokens_generated).sum();
        NodeStageResult { node, projected_finish, busy_time: busy, tokens, finished, wall }
    }

    /// Execute one stage on a *measured* backend (real hardware) with the
    /// **sequential** lowering: no projections, no deadline replays. Nodes
    /// run one after another in dependency order — even when the plan
    /// places them on disjoint GPU subsets — each to the completion of
    /// its runnable requests, and their measured finish times chain: the
    /// stage ends when the last node finishes, i.e. the stage wall-clock
    /// is the *sum* of node times. This is the conservative fallback (and
    /// the `--sequential-measured` escape hatch);
    /// [`ExecState::run_stage_concurrent`] is the default lowering that
    /// interleaves the nodes and reports the *max*, matching what the
    /// simulator and the plans it validates assume.
    pub fn run_stage_measured(
        &mut self,
        stage: &Stage,
        graph: &AppGraph,
        registry: &Registry,
        backend: &mut dyn ExecBackend,
        trace: Option<&mut Vec<EngineEvent>>,
    ) -> Result<StageResult> {
        let start = self.clock;
        let order = graph.topo_order(&stage.entries.iter().map(|e| e.node).collect::<Vec<_>>());
        let mut trace = trace;
        let mut stage_completions: HashMap<(usize, u64), f64> = HashMap::new();
        let mut results = vec![];
        let mut t = start;
        for &node in &order {
            let plan = stage.plan_of(node).unwrap();
            let spec = registry.get(&graph.nodes[node].model).expect("model");
            let reqs = self.build_engine_requests(node, t, &stage_completions, false);
            if reqs.is_empty() {
                results.push(NodeStageResult {
                    node,
                    projected_finish: t,
                    busy_time: 0.0,
                    tokens: 0,
                    finished: self.nodes[node].iter().all(|r| r.is_done()),
                    wall: 0.0,
                });
                continue;
            }
            let mut out = backend.run_node(&NodeRun {
                node,
                model: &graph.nodes[node].model,
                spec,
                plan,
                requests: &reqs,
                start_time: t,
                deadline: None,
                noise_sigma: None,
                noise_seed: 0,
                collect_events: trace.is_some(),
                admit: self.admit,
                fast_step: self.fast_step,
            })?;
            for (id, ct) in &out.completions {
                stage_completions.insert((node, *id), *ct);
            }
            if let Some(tr) = trace.as_mut() {
                tr.append(&mut out.events);
            }
            let finish = out.finish_time.max(t);
            let res = self.commit_node(node, &out, finish, finish - t);
            results.push(res);
            t = finish;
        }
        self.clock = t.max(start);
        Ok(StageResult { start, end: self.clock, nodes: results })
    }

    /// Materialise one dep-satisfied request of `node` for mid-flight
    /// injection into a running engine, mirroring the field mapping of
    /// [`ExecState::build_engine_requests`]: `ready` is the producer's
    /// measured completion time (clamped to the stage start by the
    /// caller), and chain-blocked successors keep their sentinel unless
    /// their predecessor already finished — in state, or earlier in this
    /// stage (`stage_completions`).
    fn consumer_request(
        &self,
        node: usize,
        id: u64,
        ready: f64,
        stage_completions: &HashMap<(usize, u64), f64>,
    ) -> Option<EngineRequest> {
        let r = self.nodes[node].iter().find(|r| r.id == id)?;
        if r.is_done() {
            return None;
        }
        let done_ids: HashSet<u64> = self.nodes[node]
            .iter()
            .filter(|x| x.is_done())
            .map(|x| x.id)
            .collect();
        let pred_done = Self::chain_pred_done(&self.nodes[node], r.id, &done_ids)
            || self.nodes[node]
                .iter()
                .find(|p| p.chain_next == Some(r.id))
                .is_some_and(|p| stage_completions.contains_key(&(node, p.id)));
        let blocked = r.chain_blocked && !pred_done;
        Some(EngineRequest {
            id: r.id,
            input_len: r.input_len,
            output_len: r.output_len,
            ready_time: if blocked { EngineRequest::BLOCKED } else { ready },
            generated: r.generated,
            chain_next: r.chain_next,
            kv_resident: false,
            predicted_len: r.predicted_len,
        })
    }

    /// Start `node` on a stepping backend with the given requests (shared
    /// by the initial fan-out and lazy consumer starts of
    /// [`ExecState::run_stage_concurrent`]).
    #[allow(clippy::too_many_arguments)] // internal forwarding helper
    fn start_node_on(
        &self,
        backend: &mut dyn ExecBackend,
        node: usize,
        graph: &AppGraph,
        registry: &Registry,
        stage: &Stage,
        reqs: &[EngineRequest],
        start_time: f64,
        collect_events: bool,
    ) -> Result<crate::exec::NodeHandle> {
        let plan = stage.plan_of(node).unwrap();
        let spec = registry.get(&graph.nodes[node].model).expect("model");
        backend.start_node(&NodeRun {
            node,
            model: &graph.nodes[node].model,
            spec,
            plan,
            requests: reqs,
            start_time,
            deadline: None,
            noise_sigma: None,
            noise_seed: 0,
            collect_events,
            admit: self.admit,
            fast_step: self.fast_step,
        })
    }

    /// Execute one stage on a *measured* backend with **concurrent node
    /// lowering** — the event loop the plans are priced for. Every node
    /// with runnable work starts at the stage clock; their scheduler
    /// iterations interleave through the backend's stepping interface
    /// ([`crate::exec::ExecBackend::step_node`]), always advancing the
    /// node whose measured clock is earliest, so the stage's wall-clock
    /// is the *max* over nodes (what the simulator assumes) rather than
    /// the sequential lowering's *sum*. Cross-node completions are
    /// forwarded mid-flight: the moment a producer request finishes, its
    /// dependents are injected into their consumer's engine (which is
    /// started lazily on its first injection if it had nothing runnable
    /// at stage start) with the measured completion time as ready time.
    /// Event streams from the interleaved nodes are merged time-ordered
    /// into `trace`.
    ///
    /// Falls back to [`ExecState::run_stage_measured`] — identical
    /// results, summed wall-clock — when the backend does not support
    /// stepping or fewer than two nodes could run this stage.
    pub fn run_stage_concurrent(
        &mut self,
        stage: &Stage,
        graph: &AppGraph,
        registry: &Registry,
        backend: &mut dyn ExecBackend,
        trace: Option<&mut Vec<EngineEvent>>,
    ) -> Result<StageResult> {
        let start = self.clock;
        let order = graph.topo_order(&stage.entries.iter().map(|e| e.node).collect::<Vec<_>>());
        let in_stage: HashSet<usize> = order.iter().copied().collect();

        // Initial per-node workloads (dep-satisfiable right now) and the
        // pending dependents whose in-stage producer has yet to complete.
        let mut initial: HashMap<usize, Vec<EngineRequest>> = HashMap::new();
        let mut pending: HashMap<(usize, u64), Vec<(usize, u64)>> = HashMap::new();
        let mut involved: HashSet<usize> = HashSet::new();
        for &node in &order {
            let reqs = self.build_engine_requests(node, start, &HashMap::new(), false);
            if !reqs.is_empty() {
                involved.insert(node);
            }
            initial.insert(node, reqs);
            for r in &self.nodes[node] {
                if r.is_done() {
                    continue;
                }
                if let Some(dep) = r.dep {
                    if !self.completed.contains_key(&dep) && in_stage.contains(&dep.0) {
                        pending.entry(dep).or_default().push((node, r.id));
                        involved.insert(node);
                    }
                }
            }
        }
        if !backend.supports_stepping() || involved.len() < 2 {
            return self.run_stage_measured(stage, graph, registry, backend, trace);
        }

        let collect = trace.is_some();
        let mut handles: HashMap<usize, crate::exec::NodeHandle> = HashMap::new();
        let mut clocks: HashMap<usize, f64> = HashMap::new();
        let mut parked: HashSet<usize> = HashSet::new();
        let mut stage_completions: HashMap<(usize, u64), f64> = HashMap::new();
        for &node in &order {
            let reqs = &initial[&node];
            if reqs.is_empty() {
                continue;
            }
            let h =
                self.start_node_on(backend, node, graph, registry, stage, reqs, start, collect)?;
            handles.insert(node, h);
            clocks.insert(node, start);
        }

        // The event loop: advance the unparked in-flight node whose
        // measured clock is earliest. Nodes park when idle (starved for
        // injections) or done, and are woken by injections; the loop ends
        // when everyone is parked — at that point no producer can emit
        // further completions, so no pending dependent is satisfiable.
        loop {
            let next = handles
                .keys()
                .filter(|n| !parked.contains(*n))
                .min_by(|a, b| {
                    clocks[a]
                        .partial_cmp(&clocks[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(b))
                })
                .copied();
            let Some(node) = next else { break };
            let out = backend.step_node(handles[&node])?;
            clocks.insert(node, out.clock);
            for &(id, t) in &out.completions {
                stage_completions.insert((node, id), t);
                let Some(consumers) = pending.remove(&(node, id)) else { continue };
                for (cn, cid) in consumers {
                    let Some(req) = self.consumer_request(cn, cid, t.max(start), &stage_completions)
                    else {
                        continue;
                    };
                    if let Some(&ch) = handles.get(&cn) {
                        backend.push_node_requests(ch, vec![req])?;
                        parked.remove(&cn);
                    } else {
                        let ch = self.start_node_on(
                            backend, cn, graph, registry, stage, &[req], start, collect,
                        )?;
                        handles.insert(cn, ch);
                        clocks.insert(cn, start);
                    }
                }
            }
            match out.status {
                crate::exec::StepStatus::Progressed => {}
                crate::exec::StepStatus::Idle | crate::exec::StepStatus::Done => {
                    parked.insert(node);
                }
            }
        }

        // Harvest: finish every in-flight node, commit, and merge events
        // time-ordered. The stage ends at the latest node finish.
        let mut trace = trace;
        let mut merged: Vec<EngineEvent> = vec![];
        let mut results = vec![];
        let mut end = start;
        for &node in &order {
            let Some(&h) = handles.get(&node) else {
                results.push(NodeStageResult {
                    node,
                    projected_finish: start,
                    busy_time: 0.0,
                    tokens: 0,
                    finished: self.nodes[node].iter().all(|r| r.is_done()),
                    wall: 0.0,
                });
                continue;
            };
            let mut out = backend.finish_node(h)?;
            merged.append(&mut out.events);
            let finish = out.finish_time.max(start);
            let res = self.commit_node(node, &out, finish, finish - start);
            results.push(res);
            end = end.max(finish);
        }
        merged.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal));
        if let Some(tr) = trace.as_mut() {
            tr.append(&mut merged);
        }
        self.clock = end;
        Ok(StageResult { start, end, nodes: results })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::costmodel::HardwareModel;
    use crate::exec::SimBackend;
    use crate::plan::{ExecPlan, StageEntry};

    fn two_model_app() -> (AppGraph, Vec<Vec<AppRequest>>) {
        let mut g = AppGraph::default();
        let a = g.add_node("chatglm3-6b", "a", 256);
        let b = g.add_node("mistral-7b-instruct", "b", 256);
        let _ = (a, b);
        let wa: Vec<AppRequest> = (0..200).map(|i| AppRequest::simple(i, 20, 100)).collect();
        let wb: Vec<AppRequest> = (0..400).map(|i| AppRequest::simple(i, 20, 100)).collect();
        (g, vec![wa, wb])
    }

    fn ctx() -> (ClusterSpec, Registry, HardwareModel) {
        let c = ClusterSpec::a100_node(8);
        let hw = HardwareModel::new(c.clone());
        (c, Registry::paper(), hw)
    }

    fn stage(entries: Vec<(usize, u32, u32)>) -> Stage {
        Stage {
            entries: entries
                .into_iter()
                .map(|(n, dp, tp)| StageEntry { node: n, plan: ExecPlan::new(dp, tp) })
                .collect(),
        }
    }

    #[test]
    fn stage_ends_at_first_finish() {
        let (c, reg, hw) = ctx();
        let (g, w) = two_model_app();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let s = stage(vec![(0, 4, 1), (1, 4, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        let res = st.run_stage(&s, &g, &reg, &mut b, &HashMap::new(), false, false, None);
        // Node 0 has half the workload of node 1 on equal GPUs -> finishes
        // first; stage must end at node 0's finish.
        let n0 = res.nodes.iter().find(|n| n.node == 0).unwrap();
        let n1 = res.nodes.iter().find(|n| n.node == 1).unwrap();
        assert!(n0.finished);
        assert!(!n1.finished);
        assert!((res.end - n0.projected_finish).abs() < 1e-6);
        assert!(st.finished_nodes.contains(&0));
        assert!(!st.all_done());
        // Node 1 carries progress.
        let progressed = st.nodes[1].iter().filter(|r| r.generated > 0 && !r.is_done()).count();
        assert!(progressed > 0 || st.nodes[1].iter().any(|r| r.is_done()));
    }

    #[test]
    fn dry_run_does_not_mutate() {
        let (c, reg, hw) = ctx();
        let (g, w) = two_model_app();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let before = st.clone();
        let s = stage(vec![(0, 4, 1), (1, 4, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        let res = st.run_stage(&s, &g, &reg, &mut b, &HashMap::new(), true, false, None);
        assert!(res.end > res.start);
        assert_eq!(st.clock, before.clock);
        assert_eq!(st.completed.len(), before.completed.len());
        assert!(st.finished_nodes.is_empty());
    }

    #[test]
    fn load_delay_pushes_finish_out() {
        let (c, reg, hw) = ctx();
        let (g, w) = two_model_app();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let s = stage(vec![(0, 8, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        let no_delay =
            st.clone().run_stage(&s, &g, &reg, &mut b, &HashMap::new(), true, false, None);
        let mut delays = HashMap::new();
        delays.insert(0usize, 20.0);
        let delayed = st.run_stage(&s, &g, &reg, &mut b, &delays, true, false, None);
        assert!((delayed.end - no_delay.end - 20.0).abs() < 1.0);
    }

    #[test]
    fn cross_node_pipeline_dependency() {
        // Producer node 0 -> consumer node 1, co-scheduled: consumer's
        // requests only start after their producer request completes.
        let (c, reg, hw) = ctx();
        let mut g = AppGraph::default();
        let a = g.add_node("chatglm3-6b", "prod", 128);
        let b = g.add_node("mistral-7b-instruct", "cons", 128);
        g.add_edge(a, b);
        let wa: Vec<AppRequest> = (0..50).map(|i| AppRequest::simple(i, 30, 120)).collect();
        let wb: Vec<AppRequest> = (0..50)
            .map(|i| AppRequest { dep: Some((a, i)), ..AppRequest::simple(i, 60, 60) })
            .collect();
        let mut st = ExecState::init(&[wa, wb], |_, r| r.true_output_len);
        let s = stage(vec![(a, 4, 1), (b, 4, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        let res = st.run_stage(&s, &g, &reg, &mut b, &HashMap::new(), false, true, None);
        assert!(st.all_done());
        // Consumer must finish after producer started producing.
        let fa = res.nodes.iter().find(|n| n.node == a).unwrap().projected_finish;
        let fb = res.nodes.iter().find(|n| n.node == b).unwrap().projected_finish;
        assert!(fb > 0.0 && fa > 0.0);
        assert!(fb >= fa * 0.5, "consumer can't finish long before producer");
    }

    #[test]
    fn chain_blocked_requests_wait_for_predecessor() {
        let (c, reg, hw) = ctx();
        let mut g = AppGraph::default();
        let a = g.add_node("chatglm3-6b", "summarizer", 128);
        // Two-chunk chain: 0 -> 1.
        let w = vec![vec![
            AppRequest { chain_next: Some(1), ..AppRequest::simple(0, 100, 50) },
            AppRequest { chain_blocked: true, ..AppRequest::simple(1, 100, 50) },
        ]];
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let s = stage(vec![(a, 1, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        st.run_stage(&s, &g, &reg, &mut b, &HashMap::new(), false, true, None);
        assert!(st.all_done());
        let t0 = st.completed[&(a, 0)];
        let t1 = st.completed[&(a, 1)];
        assert!(t1 > t0);
    }

    #[test]
    fn measured_stage_runs_nodes_sequentially_to_completion() {
        use crate::exec::pjrt::{MockModel, PjrtBackend};
        let (_, reg, _) = ctx();
        let mut g = AppGraph::default();
        let a = g.add_node("chatglm3-6b", "prod", 64);
        let b = g.add_node("mistral-7b-instruct", "cons", 64);
        g.add_edge(a, b);
        let wa: Vec<AppRequest> = (0..6).map(|i| AppRequest::simple(i, 8, 5)).collect();
        let wb: Vec<AppRequest> = (0..6)
            .map(|i| AppRequest { dep: Some((a, i)), ..AppRequest::simple(i, 8, 4) })
            .collect();
        let mut st = ExecState::init(&[wa, wb], |_, r| r.true_output_len);
        let mut backend = PjrtBackend::with_model(Box::new(MockModel::new(4, 64)));
        let s = stage(vec![(a, 1, 1), (b, 1, 1)]);
        let mut events = vec![];
        let res = st
            .run_stage_measured(&s, &g, &reg, &mut backend, Some(&mut events))
            .unwrap();
        // Both nodes ran to completion (producer first, consumer after).
        assert!(st.all_done());
        assert_eq!(st.completed.len(), 12);
        assert!(res.end >= res.start);
        // The consumer's requests completed at or after its producer's.
        for i in 0..6u64 {
            assert!(st.completed[&(b, i)] >= st.completed[&(a, i)] - 1e-12);
        }
        // The unified event stream covers both nodes.
        let nodes: std::collections::HashSet<usize> = events.iter().map(|e| e.node).collect();
        assert_eq!(nodes, [a, b].into_iter().collect());
    }

    #[test]
    fn empty_nodes_start_finished_and_activation_revives_them() {
        let (c, reg, hw) = ctx();
        let (g, mut w) = two_model_app();
        let deferred = std::mem::take(&mut w[1]); // app "arrives later"
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        assert!(st.finished_nodes.contains(&1), "masked node starts finished");
        assert!(!st.finished_nodes.contains(&0));
        // Run node 0 to completion: the run looks all-done...
        let s = stage(vec![(0, 8, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        st.run_stage(&s, &g, &reg, &mut b, &HashMap::new(), false, true, None);
        assert!(st.all_done());
        // ...until the arrival installs the deferred workload.
        st.activate_node(1, &deferred, |r| r.true_output_len);
        assert!(!st.all_done());
        assert_eq!(st.nodes[1].len(), deferred.len());
        let s2 = stage(vec![(1, 8, 1)]);
        st.run_stage(&s2, &g, &reg, &mut b, &HashMap::new(), false, true, None);
        assert!(st.all_done());
        assert_eq!(st.completed.len(), 600);
    }

    #[test]
    fn resume_after_stage_boundary_completes_everything() {
        let (c, reg, hw) = ctx();
        let (g, w) = two_model_app();
        let mut st = ExecState::init(&w, |_, r| r.true_output_len);
        let s1 = stage(vec![(0, 4, 1), (1, 4, 1)]);
        let mut b = SimBackend::new(&hw, c.mem_bytes);
        st.run_stage(&s1, &g, &reg, &mut b, &HashMap::new(), false, false, None);
        // Second stage: all GPUs to the survivor.
        let s2 = stage(vec![(1, 8, 1)]);
        let mut delays = HashMap::new();
        delays.insert(1usize, 10.0);
        st.run_stage(&s2, &g, &reg, &mut b, &delays, false, true, None);
        assert!(st.all_done());
        assert_eq!(st.completed.len(), 600);
    }
}
