//! Competitor scheduling policies (§5): Max-heuristic and Min-heuristic,
//! plus the no-preemption variants used in the §5.5 ablation.

pub mod heuristics;

pub use heuristics::{max_heuristic_stage, min_heuristic_stage, smallest_valid_plan};


/// Which scheduling policy drives a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Ours: Algorithm 1 planning + dynamic stage adjustment (§4).
    SamuLlm,
    /// All GPUs to one LLM at a time, best plan per the cost model (§5).
    MaxHeuristic,
    /// All GPUs split as evenly as possible across all ready LLMs (§5,
    /// inspired by Saturn's Min heuristic).
    MinHeuristic,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::SamuLlm => "ours",
            PolicyKind::MaxHeuristic => "max-heuristic",
            PolicyKind::MinHeuristic => "min-heuristic",
        }
    }

    pub const ALL: [PolicyKind; 3] =
        [PolicyKind::SamuLlm, PolicyKind::MaxHeuristic, PolicyKind::MinHeuristic];
}
