//! Stage-construction primitives for the §5 competitor policies
//! (Max-heuristic / Min-heuristic). The policy objects themselves live in
//! [`crate::policy`]; this module keeps the reusable scheduling math.

pub mod heuristics;

pub use heuristics::{
    fair_share_stage, max_heuristic_stage, min_heuristic_stage, smallest_valid_plan,
};
