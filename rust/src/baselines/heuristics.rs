//! Stage construction for the §5 competitor policies.

use std::collections::{HashMap, HashSet};

use crate::cluster::ClusterSpec;
use crate::costmodel::IterLatency;
use crate::exec::SimBackend;
use crate::graph::AppGraph;
use crate::models::{ModelSpec, Registry};
use crate::plan::{ExecPlan, Stage, StageEntry};
use crate::runner::state::ExecState;

/// Minimum GPUs a model needs (smallest valid tp).
pub fn min_gpus(spec: &ModelSpec, cluster: &ClusterSpec) -> Option<u32> {
    cluster
        .valid_tp()
        .into_iter()
        .find(|&tp| ExecPlan::new(1, tp).is_valid_for(spec, cluster))
}

/// The largest-utilisation plan for `spec` inside a `gpus` budget, using
/// the smallest valid tp (pure data parallelism when the model fits one
/// GPU — the Min-heuristic's shape).
pub fn smallest_valid_plan(spec: &ModelSpec, cluster: &ClusterSpec, gpus: u32) -> Option<ExecPlan> {
    let tp = min_gpus(spec, cluster)?;
    if tp > gpus {
        return None;
    }
    let dp = (gpus / tp).max(1);
    let plan = ExecPlan::new(dp, tp);
    plan.is_valid_for(spec, cluster).then_some(plan)
}

/// Max-heuristic (§5): all GPUs to a single ready LLM, with the plan the
/// cost model says completes its remaining workload fastest.
pub fn max_heuristic_stage(
    graph: &AppGraph,
    est_state: &ExecState,
    registry: &Registry,
    cluster: &ClusterSpec,
    lat: &dyn IterLatency,
) -> Option<Stage> {
    let ready = graph.ready_nodes(&est_state.finished_nodes, &HashSet::new());
    let node = *ready.first()?;
    let spec = registry.get(&graph.nodes[node].model)?;
    // Full-node plans: dp*tp == n_gpus.
    let mut best: Option<(f64, ExecPlan)> = None;
    for tp in cluster.valid_tp() {
        let dp = cluster.n_gpus / tp;
        let plan = ExecPlan::new(dp, tp);
        if !plan.is_valid_for(spec, cluster) {
            continue;
        }
        let stage = Stage { entries: vec![StageEntry { node, plan }] };
        let mut scratch = est_state.clone();
        let mut backend = SimBackend::new(lat, cluster.mem_bytes);
        let res = scratch.run_stage(
            &stage,
            graph,
            registry,
            &mut backend,
            &HashMap::new(),
            true,
            false,
            None,
        );
        let t = res.end - res.start;
        if best.map(|(bt, _)| t < bt).unwrap_or(true) {
            best = Some((t, plan));
        }
    }
    best.map(|(_, plan)| Stage { entries: vec![StageEntry { node, plan }] })
}

/// Min-heuristic (§5): split all GPUs as evenly as possible across as many
/// ready LLMs as possible. `locked` pins plans of already-running nodes
/// (used by both the normal variant — which re-splits every stage — and
/// the no-preemption variant, which passes every running node as locked).
pub fn min_heuristic_stage(
    graph: &AppGraph,
    est_state: &ExecState,
    registry: &Registry,
    cluster: &ClusterSpec,
    locked: &HashMap<usize, ExecPlan>,
) -> Option<Stage> {
    fair_share_stage(graph, est_state, registry, cluster, locked, 0)
}

/// Fair-share stage construction shared by Min-heuristic and the
/// round-robin baseline: pinned plans first (in node order, so results
/// are reproducible), then ready nodes — priority order rotated left by
/// `rotation` — get their minimum footprints, then leftover GPUs are
/// dealt one at a time in the same order. `rotation == 0` is exactly the
/// Min-heuristic.
pub fn fair_share_stage(
    graph: &AppGraph,
    est_state: &ExecState,
    registry: &Registry,
    cluster: &ClusterSpec,
    locked: &HashMap<usize, ExecPlan>,
    rotation: usize,
) -> Option<Stage> {
    let mut entries: Vec<StageEntry> = vec![];
    let mut gpus_left = cluster.n_gpus;
    // Locked nodes first (unchanged plans), sorted so admission under a
    // tight budget doesn't depend on HashMap iteration order.
    let mut pinned: Vec<(usize, ExecPlan)> = locked.iter().map(|(&n, &p)| (n, p)).collect();
    pinned.sort_unstable_by_key(|&(n, _)| n);
    for (node, plan) in pinned {
        if est_state.finished_nodes.contains(&node) {
            continue;
        }
        if plan.n_gpus() <= gpus_left {
            entries.push(StageEntry { node, plan });
            gpus_left -= plan.n_gpus();
        }
    }
    let in_stage: HashSet<usize> = entries.iter().map(|e| e.node).collect();
    let mut ready: Vec<usize> = graph
        .ready_nodes(&est_state.finished_nodes, &in_stage)
        .into_iter()
        .filter(|n| !in_stage.contains(n))
        .collect();
    ready.sort_unstable();
    if !ready.is_empty() {
        ready.rotate_left(rotation % ready.len());
    }

    // Figure out how many of the ready models fit, greedy on minimum
    // footprints in priority order.
    let mut chosen: Vec<(usize, u32)> = vec![]; // (node, min_gpus)
    let mut budget = gpus_left;
    for &n in &ready {
        let spec = registry.get(&graph.nodes[n].model)?;
        if let Some(mg) = min_gpus(spec, cluster) {
            if mg <= budget {
                chosen.push((n, mg));
                budget -= mg;
            }
        }
    }
    if chosen.is_empty() {
        return (!entries.is_empty()).then_some(Stage { entries });
    }
    // Distribute the remaining budget round-robin (+1 each) for evenness.
    let mut alloc: Vec<u32> = chosen.iter().map(|&(_, mg)| mg).collect();
    let mut i = 0;
    let n_alloc = alloc.len();
    while budget > 0 {
        alloc[i % n_alloc] += 1;
        budget -= 1;
        i += 1;
    }
    for ((node, _), gpus) in chosen.iter().zip(alloc) {
        let spec = registry.get(&graph.nodes[*node].model)?;
        if let Some(plan) = smallest_valid_plan(spec, cluster, gpus) {
            entries.push(StageEntry { node: *node, plan });
        }
    }
    (!entries.is_empty()).then_some(Stage { entries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::runner::state::AppRequest;

    fn ctx() -> (ClusterSpec, Registry, CostModel) {
        let c = ClusterSpec::a100_node(8);
        let cm = CostModel::calibrated(&c, 1);
        (c, Registry::paper(), cm)
    }

    fn app(models: &[&str], reqs: usize) -> (AppGraph, Vec<Vec<AppRequest>>) {
        let mut g = AppGraph::default();
        let mut w = vec![];
        for m in models {
            g.add_node(m, m, 256);
            w.push((0..reqs as u64).map(|i| AppRequest::simple(i, 20, 120)).collect());
        }
        (g, w)
    }

    #[test]
    fn max_uses_all_gpus_on_one_node() {
        let (c, reg, cm) = ctx();
        let (g, w) = app(&["chatglm3-6b", "alpaca-13b"], 500);
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let stage = max_heuristic_stage(&g, &st, &reg, &c, &cm.iter_model).unwrap();
        assert_eq!(stage.entries.len(), 1);
        assert_eq!(stage.n_gpus(), 8);
    }

    #[test]
    fn min_splits_evenly() {
        let (c, reg, _) = ctx();
        let (g, w) = app(&["chatglm3-6b", "alpaca-13b", "koala-13b", "mpt-7b-chat"], 500);
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let stage = min_heuristic_stage(&g, &st, &reg, &c, &HashMap::new()).unwrap();
        assert_eq!(stage.entries.len(), 4);
        for e in &stage.entries {
            assert_eq!(e.plan.n_gpus(), 2, "{e:?}");
        }
    }

    #[test]
    fn min_respects_big_model_footprint() {
        let (c, reg, _) = ctx();
        let (g, w) = app(&["llama-2-70b-chat", "mistral-7b-instruct"], 300);
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let stage = min_heuristic_stage(&g, &st, &reg, &c, &HashMap::new()).unwrap();
        let p70 = stage.plan_of(0).unwrap();
        assert!(p70.tp >= 2, "70B can't run at tp=1: {p70:?}");
        assert!(stage.n_gpus() <= 8);
    }

    #[test]
    fn min_with_more_models_than_gpus() {
        let (c, reg, _) = ctx();
        let names: Vec<&str> = Registry::ensembling_models();
        let (g, w) = app(&names, 100);
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let stage = min_heuristic_stage(&g, &st, &reg, &c, &HashMap::new()).unwrap();
        // 9 models, 8 GPUs -> at most 8 scheduled, 1 GPU each.
        assert!(stage.entries.len() <= 8);
        assert!(stage.n_gpus() <= 8);
        assert!(stage.entries.len() >= 7);
    }

    #[test]
    fn locked_plans_survive() {
        let (c, reg, _) = ctx();
        let (g, w) = app(&["chatglm3-6b", "alpaca-13b", "koala-13b"], 400);
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        let mut locked = HashMap::new();
        locked.insert(0usize, ExecPlan::new(1, 1));
        let stage = min_heuristic_stage(&g, &st, &reg, &c, &locked).unwrap();
        assert_eq!(stage.plan_of(0), Some(ExecPlan::new(1, 1)));
        // Remaining 7 GPUs split across the other two (4/3 or 3/4).
        let g1 = stage.plan_of(1).unwrap().n_gpus();
        let g2 = stage.plan_of(2).unwrap().n_gpus();
        assert_eq!(g1 + g2, 7);
        assert!((g1 as i32 - g2 as i32).abs() <= 1);
    }

    #[test]
    fn smallest_valid_plan_prefers_dp() {
        let (c, reg, _) = ctx();
        let small = reg.get("mistral-7b-instruct").unwrap();
        let plan = smallest_valid_plan(small, &c, 4).unwrap();
        assert_eq!(plan, ExecPlan::new(4, 1));
        let big = reg.get("llama-2-70b-chat").unwrap();
        let plan = smallest_valid_plan(big, &c, 4).unwrap();
        assert_eq!(plan.tp, 2);
        assert_eq!(plan.dp, 2);
    }
}
