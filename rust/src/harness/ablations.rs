//! Design-choice ablations beyond the paper's own (§Perf / DESIGN.md):
//!
//! * `faststep` — wall-clock speedup of the aggregated decode stepping
//!   (the optimization that keeps planning cheap) against per-token
//!   stepping, plus a bit-identity check (the aggregation is exact);
//! * `noise` — robustness of the scheduling result to ground-truth
//!   iteration jitter (how sensitive are the §5 conclusions?);
//! * `tracesize` — cost-model estimation error vs the size of the eCDF
//!   trace (the paper uses 10 000 requests; how few suffice?).

use std::fmt::Write as _;

use crate::cluster::ClusterSpec;
use crate::costmodel::{CostModel, HardwareModel};
use crate::engine::sim::{EngineConfig, EngineSim};
use crate::engine::EngineRequest;
use crate::models::Registry;
use crate::runner::{run_policy, RunOpts};
use crate::util::rng::Rng;

fn cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

/// Aggregated fast-step vs per-token stepping. The two paths are
/// bit-identical by construction (the window aggregation replays the
/// exact per-iteration clock), so unlike the historical approximate
/// fast-forward mode there is no accuracy axis — the report pins the
/// bit-identity and measures the wall-clock speedup.
pub fn ablate_faststep() -> String {
    let mut out = String::from("=== Ablation: aggregated fast-step decode mode ===\n");
    let c = cluster();
    let registry = Registry::paper();
    let hw = HardwareModel::new(c.clone());
    let mut rng = Rng::new(71);
    for (model, n) in
        [("chatglm3-6b", 500usize), ("vicuna-13b-v1.5", 2000), ("llama-2-70b-chat", 300)]
    {
        let spec = registry.get(model).unwrap();
        let reqs: Vec<EngineRequest> = (0..n as u64)
            .map(|i| {
                let o = crate::workload::lengths::true_output_len(
                    model, 0.0, 40, 512, 4096, &mut rng,
                );
                EngineRequest::fresh(i, 40, o)
            })
            .collect();
        let tp = if model.contains("70b") { 2 } else { 1 };
        let mut cfg = EngineConfig::standard(spec, tp, c.mem_bytes).unwrap();
        cfg.fast_step = false;
        let w0 = std::time::Instant::now();
        let exact = EngineSim::new(spec, tp, &hw, cfg.clone(), reqs.clone(), 0.0, 0).run(None);
        let exact_wall = w0.elapsed().as_secs_f64();
        cfg.fast_step = true;
        let w1 = std::time::Instant::now();
        let fast = EngineSim::new(spec, tp, &hw, cfg, reqs, 0.0, 0).run(None);
        let fast_wall = w1.elapsed().as_secs_f64();
        writeln!(
            out,
            "{model:<22} n={n:<5} total={:.1}s bit-identical={} | sim wall: {:.1}ms -> {:.1}ms ({:.1}x faster)",
            exact.clock,
            fast.clock.to_bits() == exact.clock.to_bits(),
            exact_wall * 1e3,
            fast_wall * 1e3,
            exact_wall / fast_wall.max(1e-9),
        )
        .unwrap();
    }
    out
}

/// Scheduling robustness to ground-truth jitter.
pub fn ablate_noise() -> String {
    let mut out = String::from("=== Ablation: ground-truth iteration jitter ===\n");
    let s = crate::spec::AppSpec::ensembling(800, 256).build(5).expect("spec");
    let c = cluster();
    for sigma in [0.0, 0.02, 0.05, 0.10] {
        let opts = RunOpts { noise_sigma: sigma, ..Default::default() };
        let ours = run_policy("ours", &s, &c, &opts);
        let max = run_policy("max-heuristic", &s, &c, &opts);
        writeln!(
            out,
            "sigma={sigma:<5} ours={:>6.1}s max={:>6.1}s speedup={:.2}x stages={}",
            ours.end_to_end_time,
            max.end_to_end_time,
            max.end_to_end_time / ours.end_to_end_time,
            ours.n_stages
        )
        .unwrap();
    }
    out.push_str("(conclusion shape should be jitter-invariant)\n");
    out
}

/// eCDF trace size vs estimation error (paper uses 10 000 samples).
pub fn ablate_tracesize() -> String {
    let mut out = String::from("=== Ablation: eCDF trace size vs estimation error ===\n");
    let c = cluster();
    let registry = Registry::paper();
    let hw = HardwareModel::new(c.clone());
    let model = "vicuna-13b-v1.5";
    let spec = registry.get(model).unwrap();
    // Ground truth run.
    let mut rng = Rng::new(9);
    let reqs: Vec<EngineRequest> = (0..1000u64)
        .map(|i| {
            let o = crate::workload::lengths::true_output_len(model, 0.08, 25, 512, 4096, &mut rng);
            EngineRequest::fresh(i, 25, o)
        })
        .collect();
    let cfg = EngineConfig::standard(spec, 1, c.mem_bytes).unwrap();
    let truth = EngineSim::new(spec, 1, &hw, cfg.clone(), reqs.clone(), 0.0, 0).run(None).clock;
    let cm = CostModel::calibrated(&c, 1);

    for trace_n in [50usize, 200, 1000, 10_000] {
        // Build a sampler from a reduced trace.
        let lens: Vec<u32> = crate::workload::norobots::trace(model, trace_n, 99)
            .into_iter()
            .map(|r| r.output_len)
            .collect();
        let ecdf = crate::costmodel::Ecdf::from_samples(lens);
        let mut srng = Rng::new(4);
        let est_reqs: Vec<EngineRequest> = reqs
            .iter()
            .map(|r| {
                let o = ecdf.sample(&mut srng).min(512).max(1);
                EngineRequest::fresh(r.id, r.input_len, o)
            })
            .collect();
        let est = EngineSim::new(spec, 1, &cm.iter_model, cfg.clone(), est_reqs, 0.0, 0)
            .run(None)
            .clock;
        writeln!(
            out,
            "trace={trace_n:<6} est={est:>6.1}s truth={truth:>6.1}s error={:>5.1}%",
            100.0 * (est - truth).abs() / truth
        )
        .unwrap();
    }
    out.push_str("(diminishing returns past ~1000 trace samples)\n");
    out
}

/// Run every ablation and concatenate their reports.
pub fn all() -> String {
    format!("{}\n{}\n{}", ablate_faststep(), ablate_noise(), ablate_tracesize())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faststep_ablation_is_bit_identical_on_every_model() {
        let text = ablate_faststep();
        let rows: Vec<&str> =
            text.lines().filter(|l| l.contains("bit-identical=")).collect();
        assert_eq!(rows.len(), 3, "{text}");
        for line in rows {
            assert!(line.contains("bit-identical=true"), "fast-step diverged: {line}");
        }
    }

    #[test]
    fn tracesize_ablation_runs() {
        let text = ablate_tracesize();
        assert!(text.matches("error=").count() == 4);
    }
}
