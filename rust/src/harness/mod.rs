//! Experiment harness: regenerate every table and figure of the paper's
//! evaluation (see DESIGN.md's experiment index). Each `figN()` returns
//! printable text with the same rows/series the paper reports; the
//! `figures` binary dispatches on ids.

pub mod ablations;

use std::fmt::Write as _;

use crate::cluster::ClusterSpec;
use crate::costmodel::{CostModel, Ecdf, HardwareModel, LinearIterModel};
use crate::costmodel::{flops, IterLatency};
use crate::engine::sim::{EngineConfig, EngineSim};
use crate::engine::EngineRequest;
use crate::metrics::{gantt, RunReport};
use crate::models::Registry;
use crate::policy;
use crate::runner::{run_policy, RunOpts, Scenario};
use crate::spec::AppSpec;
use crate::util::rng::Rng;
use crate::workload::{booksum, norobots, routerbench};

fn cluster() -> ClusterSpec {
    ClusterSpec::a100_node(8)
}

fn header(id: &str, caption: &str) -> String {
    format!("=== {id}: {caption} ===\n")
}

/// Shared three-policy comparison row: "<label> ours max min (speedups)".
fn compare_row(out: &mut String, label: &str, reports: &[RunReport]) {
    let ours = &reports[0];
    write!(out, "{label:<28}").unwrap();
    for r in reports {
        write!(
            out,
            " | {:>13} e2e={:>7.1}s inf={:>7.1}s extra={:>5.1}s",
            r.policy, r.end_to_end_time, r.inference_time, r.extra_time
        )
        .unwrap();
    }
    for r in &reports[1..] {
        write!(
            out,
            " | {} speedup: e2e {:.2}x inf {:.2}x",
            r.policy,
            r.end_to_end_time / ours.end_to_end_time,
            r.inference_time / ours.inference_time
        )
        .unwrap();
    }
    out.push('\n');
}

fn run_all(scenario: &Scenario, opts: &RunOpts) -> Vec<RunReport> {
    policy::PAPER.iter().map(|&p| run_policy(p, scenario, &cluster(), opts)).collect()
}

/// The miscalibration regime the runtime length-feedback loop exists
/// for: a four-model ensembling-style app whose true output lengths are
/// log-shifted against the offline No Robots trace in *opposing*
/// directions (half the models answer ~e× longer than the trace
/// suggests, half ~e× shorter), so the offline plan inverts the real
/// per-model workload ratios. Shared by `benches/bench_runtime.rs` and
/// `tests/integration_online.rs` so the CI guard and the published
/// `BENCH_runtime.json` numbers measure the exact same workload.
pub fn shifted_length_scenario(n_requests: usize, seed: u64) -> Scenario {
    let registry = Registry::paper();
    let models = [
        ("vicuna-13b-v1.5", 1.0),
        ("chatglm3-6b", -1.0),
        ("mistral-7b-instruct", 1.0),
        ("alpaca-13b", -1.0),
    ];
    let mut graph = crate::graph::AppGraph::default();
    let mut workloads = vec![];
    let mut rng = Rng::new(seed ^ 0x5817F7);
    for (i, (model, shift)) in models.iter().enumerate() {
        graph.add_node(model, &format!("m{i}"), 512);
        let spec = registry.get(model).expect("model");
        workloads.push(
            (0..n_requests as u64)
                .map(|id| {
                    let input_len = rng.range_u64(10, 120) as u32;
                    let out = crate::workload::lengths::true_output_len(
                        model, *shift, input_len, 512, spec.max_seq, &mut rng,
                    );
                    crate::runner::AppRequest::simple(id, input_len, out)
                })
                .collect(),
        );
    }
    Scenario { name: format!("shifted-lengths-{n_requests}"), graph, workloads }
}

/// The §5.4-style heterogeneous pair as a declarative workload: a
/// chain-summary app present at t = 0 plus an ensembling app arriving
/// `arrival` seconds in (0 = both up front). Shared by
/// `benches/bench_workload.rs` and `tests/integration_workload.rs`, so
/// the CI guard and the published `BENCH_workload.json` numbers measure
/// the exact same mixture.
pub fn staggered_pair_workload(
    n_docs: usize,
    n_ens: usize,
    arrival: f64,
) -> crate::spec::WorkloadSpec {
    use crate::spec::{WorkloadEntry, WorkloadSpec};
    WorkloadSpec {
        name: format!("pair-{n_docs}docs-{n_ens}ens-arr{arrival:.0}"),
        entries: vec![
            WorkloadEntry::new(AppSpec::chain_summary(n_docs, 2, 300)),
            WorkloadEntry {
                app: AppSpec::ensembling(n_ens, 128),
                arrival,
                weight: 1.0,
                seed: None,
            },
        ],
    }
}

/// An open-loop Poisson pair for traffic experiments: two small two-node
/// custom apps (distinct 6-13B models, synthetic template pools) with
/// Poisson arrivals at `rate_a`/`rate_b` requests per second, weights
/// `weight_a`:1, a deliberately tight admission queue (capacity 8, defer
/// on overflow, quantum 2 per stage boundary) so backlog forms and the
/// weighted fair share is visible in the latency percentiles, and a 60 s
/// SLO on both streams. Shared by `benches/bench_traffic.rs` and
/// `tests/integration_traffic.rs`, so the CI guard and the published
/// `BENCH_traffic.json` numbers measure the exact same mixture.
pub fn poisson_pair_traffic(
    rate_a: f64,
    rate_b: f64,
    weight_a: f64,
    duration: f64,
) -> crate::spec::TrafficSpec {
    use crate::spec::{ArrivalSpec, NodeSpec, TrafficEntry, TrafficSpec, WorkloadGen};
    use crate::traffic::QueuePolicy;
    let app = |name: &str, gen: &str, judge: &str| AppSpec::Custom {
        name: name.into(),
        nodes: vec![
            NodeSpec {
                model: gen.into(),
                label: "gen".into(),
                max_out: 96,
                workload: WorkloadGen::Synthetic {
                    n_requests: 32,
                    input_min: 10,
                    input_max: 80,
                },
            },
            NodeSpec {
                model: judge.into(),
                label: "judge".into(),
                max_out: 64,
                workload: WorkloadGen::Synthetic {
                    n_requests: 32,
                    input_min: 10,
                    input_max: 60,
                },
            },
        ],
        edges: vec![],
    };
    TrafficSpec {
        name: format!("poisson-pair-{rate_a:.0}x{rate_b:.0}-w{weight_a:.0}"),
        entries: vec![
            TrafficEntry {
                app: app("stream-a", "mistral-7b-instruct", "chatglm3-6b"),
                process: ArrivalSpec::Poisson { rate: rate_a },
                weight: weight_a,
                slo: Some(60.0),
                seed: None,
            },
            TrafficEntry {
                app: app("stream-b", "vicuna-13b-v1.5", "alpaca-13b"),
                process: ArrivalSpec::Poisson { rate: rate_b },
                weight: 1.0,
                slo: Some(60.0),
                seed: None,
            },
        ],
        duration,
        warmup: 0.0,
        queue_capacity: 8,
        queue_policy: QueuePolicy::Defer,
        admit_quantum: 2,
    }
}

/// Scenario construction goes through the declarative spec layer only.
fn scenario(spec: AppSpec, seed: u64) -> Scenario {
    spec.build(seed).expect("harness specs are valid")
}

/// Fig. 2: output-length eCDFs by input region / category.
pub fn fig2() -> String {
    let mut out = header("Fig 2", "output-length eCDFs (vicuna-13b, No Robots trace)");
    let t = norobots::trace("vicuna-13b-v1.5", 10_000, 2024);
    let grid: Vec<u32> = (0..=10).map(|i| i * 100).collect();
    out.push_str("(a) by input-length region\n");
    for (label, lens) in norobots::by_input_region(&t, &[5, 50, 120, 250, 401]) {
        let e = Ecdf::from_samples(lens);
        let curve: Vec<String> =
            e.curve(&grid).iter().map(|(x, p)| format!("{x}:{p:.2}")).collect();
        writeln!(out, "  {label:>10} {}", curve.join(" ")).unwrap();
    }
    out.push_str("(b) by category\n");
    for (cat, lens) in norobots::by_category(&t) {
        let e = Ecdf::from_samples(lens);
        let curve: Vec<String> =
            e.curve(&grid).iter().map(|(x, p)| format!("{x}:{p:.2}")).collect();
        writeln!(out, "  {:>10} {}", cat.name(), curve.join(" ")).unwrap();
    }
    // KS spread, the quantitative version of "the eCDFs are similar".
    let cats = norobots::by_category(&t);
    let base = Ecdf::from_samples(cats[0].1.clone());
    let max_ks = cats[1..]
        .iter()
        .map(|(_, l)| base.ks_distance(&Ecdf::from_samples(l.clone())))
        .fold(0.0, f64::max);
    writeln!(out, "max KS distance across categories: {max_ks:.3} (similar ⇔ small)").unwrap();
    out
}

/// Fig. 3: running request count per iteration, "real" vs simulated.
pub fn fig3() -> String {
    let mut out = header(
        "Fig 3",
        "running requests per iteration: ground truth vs cost-model simulation (vicuna-13b, 1000 reqs)",
    );
    let c = cluster();
    let registry = Registry::paper();
    let spec = registry.get("vicuna-13b-v1.5").unwrap();
    let hw = HardwareModel::new(c.clone());
    let cm = CostModel::calibrated(&c, 3);
    let mut rng_true = Rng::new(31);
    let mut rng_est = Rng::new(77);

    let mk = |lens: Vec<u32>| -> Vec<EngineRequest> {
        lens.iter()
            .enumerate()
            .map(|(i, &o)| EngineRequest::fresh(i as u64, 150, o))
            .collect()
    };
    let true_lens: Vec<u32> = (0..1000)
        .map(|_| {
            crate::workload::lengths::true_output_len(
                "vicuna-13b-v1.5",
                0.0,
                150,
                1024,
                4096,
                &mut rng_true,
            )
        })
        .collect();
    let est_lens: Vec<u32> = (0..1000)
        .map(|_| cm.sampler.sample("vicuna-13b-v1.5", 150, 1024, 4096, &mut rng_est))
        .collect();

    let run = |lens: Vec<u32>, lat: &dyn IterLatency, label: &str, out: &mut String| -> f64 {
        // fast_step reproduces the per-iteration trace exactly; stepped
        // per token anyway so the figure measures the path it describes.
        let mut cfg = EngineConfig::standard(spec, 1, c.mem_bytes).unwrap();
        cfg.fast_step = false;
        let mut sim = EngineSim::new(spec, 1, lat, cfg, mk(lens), 0.0, 5);
        sim.enable_trace();
        let res = sim.run(None);
        let trace = sim.iter_trace.as_ref().unwrap();
        let step = (trace.len() / 24).max(1);
        let series: Vec<String> = trace
            .iter()
            .step_by(step)
            .enumerate()
            .map(|(i, (_, n))| format!("{}:{n}", i * step))
            .collect();
        writeln!(
            out,
            "  {label:<10} iters={} total={:.1}s\n    {}",
            trace.len(),
            res.clock,
            series.join(" ")
        )
        .unwrap();
        res.clock
    };
    let t_real = run(true_lens, &hw, "real", &mut out);
    let t_sim = run(est_lens, &cm.iter_model, "simulated", &mut out);
    let load = spec.load_time(1);
    writeln!(
        out,
        "estimated total (incl. load {load:.0}s): {:.0}s vs real {:.0}s  (error {:.1}%; paper: 98s vs 92s, 6.5%)",
        t_sim + load,
        t_real + load,
        100.0 * (t_sim - t_real).abs() / t_real
    )
    .unwrap();
    out
}

/// Fig. 4: per-iteration latency components vs their linear predictors.
pub fn fig4() -> String {
    let mut out = header("Fig 4", "per-iteration latency components + linear fits (7B probe)");
    let c = cluster();
    let hw = HardwareModel::new(c.clone());
    let lm = LinearIterModel::fit_from_profile(&hw);
    let registry = Registry::paper();
    let spec = registry.get("mistral-7b-instruct").unwrap();
    for b in [8usize, 64, 256] {
        writeln!(out, "#seq B={b}  (x = FLOPs -> comp seconds; fits r2={:?})", lm.fit_quality(b))
            .unwrap();
        for ctx in [64u32, 256, 1024, 2048] {
            let total_ctx = b as u64 * ctx as u64;
            let comp = hw.decode_components(spec, 1, b, total_ctx, ctx);
            let fl = flops::decode_flops(spec, b, total_ctx);
            writeln!(
                out,
                "  ctx={ctx:>5} flops={fl:.2e} comp={:.4} prep={:.4} samp={:.4} | linear total={:.4} truth total={:.4}",
                comp.comp,
                comp.prep,
                comp.samp,
                lm.decode(spec, 1, b, total_ctx, ctx),
                comp.total()
            )
            .unwrap();
        }
    }
    out
}

/// Fig. 7: ensembling running time vs #requests, out limits 256/512.
pub fn fig7(quick: bool) -> String {
    let mut out = header("Fig 7", "LLM ensembling: running time vs #requests (3 policies)");
    let sizes: &[usize] = if quick { &[1000, 4000] } else { &[1000, 2000, 4000, 7000, 10000] };
    for &max_out in &[256u32, 512] {
        writeln!(out, "-- max output length limit = {max_out}").unwrap();
        for &n in sizes {
            let sc = scenario(AppSpec::ensembling(n, max_out), 42 + n as u64);
            let reports = run_all(&sc, &RunOpts::default());
            compare_row(&mut out, &format!("{n} requests"), &reports);
        }
    }
    out
}

/// Table 1: routing request counts/ratios.
pub fn table1() -> String {
    let mut out = header("Table 1", "LLM selection frequency (RouterBench)");
    let d = routerbench::dataset(1);
    let total = d.len();
    writeln!(out, "{:<28} {:>9} {:>7}", "Model", "#Request", "Ratio").unwrap();
    for (model, _) in routerbench::TABLE1 {
        let n = d.iter().filter(|r| r.model == model).count();
        writeln!(out, "{model:<28} {n:>9} {:>7.2}", n as f64 / total as f64).unwrap();
    }
    writeln!(out, "{:<28} {total:>9} {:>7.2}", "Total:", 1.0).unwrap();
    out
}

/// Fig. 8: routing with unknown vs known output lengths.
pub fn fig8() -> String {
    let mut out = header("Fig 8", "LLM routing: running time w/o and w/ known output lengths");
    let sc = scenario(AppSpec::routing(4096, false), 7);
    for known in [false, true] {
        let opts = RunOpts { known_lengths: known, ..Default::default() };
        let reports = run_all(&sc, &opts);
        compare_row(&mut out, if known { "known lengths" } else { "unknown lengths" }, &reports);
    }
    out
}

/// Fig. 9: routing schedules as Gantt charts (known lengths).
pub fn fig9() -> String {
    let mut out = header("Fig 9", "LLM routing schedules (known output lengths)");
    let sc = scenario(AppSpec::routing(4096, false), 7);
    let opts = RunOpts { known_lengths: true, ..Default::default() };
    for p in policy::PAPER {
        let r = run_policy(p, &sc, &cluster(), &opts);
        out.push_str(&gantt::render(&r, 72));
        out.push('\n');
    }
    out
}

/// Fig. 10: sampled document lengths.
pub fn fig10() -> String {
    let mut out = header("Fig 10", "lengths of 100 sampled documents (chunks)");
    let docs = booksum::documents(100, 42);
    let mut lens: Vec<u32> = docs.iter().map(|d| d.n_chunks).collect();
    let series: Vec<String> = lens.iter().map(|l| l.to_string()).collect();
    writeln!(out, "per-doc: {}", series.join(" ")).unwrap();
    lens.sort_unstable();
    writeln!(
        out,
        "median={} max={} total={} (paper: median 3, max ~60)",
        lens[lens.len() / 2],
        lens.last().unwrap(),
        booksum::total_chunks(&docs)
    )
    .unwrap();
    out
}

/// Fig. 11: chain summary under varying #docs / eval times / max out len.
pub fn fig11(quick: bool) -> String {
    let mut out = header("Fig 11", "chain summary running time (3 policies)");
    let opts = RunOpts::default();
    let docs: &[usize] = if quick { &[100] } else { &[100, 300, 500] };
    writeln!(out, "-- (a) vary #documents (eval=1, max_out=500)").unwrap();
    for &n in docs {
        let s = scenario(AppSpec::chain_summary(n, 1, 500), 21);
        compare_row(&mut out, &format!("{n} docs"), &run_all(&s, &opts));
    }
    writeln!(out, "-- (b) vary eval times (docs=100, max_out=500)").unwrap();
    let evals: &[u32] = if quick { &[2] } else { &[2, 4, 8] };
    for &e in evals {
        let s = scenario(AppSpec::chain_summary(100, e, 500), 22);
        compare_row(&mut out, &format!("eval x{e}"), &run_all(&s, &opts));
    }
    writeln!(out, "-- (c) vary max output length (docs=100, eval=1)").unwrap();
    let outs: &[u32] = if quick { &[900] } else { &[100, 500, 900] };
    for &mo in outs {
        let s = scenario(AppSpec::chain_summary(100, 1, mo), 23);
        compare_row(&mut out, &format!("max_out {mo}"), &run_all(&s, &opts));
    }
    // GPU idle-time comparison (§5.3's analysis).
    let s = scenario(AppSpec::chain_summary(100, 2, 500), 24);
    let rs = run_all(&s, &opts);
    let idle: Vec<String> =
        rs.iter().map(|r| format!("{}={:.0} gpu·s", r.policy, r.gpu_idle_time())).collect();
    writeln!(out, "GPU idle time: {} (paper: max 1.2x, min 1.5x of ours)", idle.join(", "))
        .unwrap();
    out
}

/// Fig. 12: mixed application across workload combinations.
pub fn fig12(quick: bool) -> String {
    let mut out = header("Fig 12", "mixed app (chain summary + 5000-req ensembling)");
    let opts = RunOpts::default();
    let docs: &[usize] = if quick { &[100] } else { &[100, 200, 300, 400, 500] };
    let n_ens = if quick { 1000 } else { 5000 };
    for &n in docs {
        let s = scenario(AppSpec::mixed(n, n_ens, 900, 256, 4), 33);
        let reports = run_all(&s, &opts);
        compare_row(&mut out, &format!("({n}, {n_ens})"), &reports);
        // Whole-app vs sequential for Ours (§5.4's extra finding).
        let cs = scenario(AppSpec::chain_summary(n, 4, 900), 33);
        let en = scenario(AppSpec::ensembling(n_ens, 256), 33 ^ 0x4D49_58);
        let r1 = run_policy("ours", &cs, &cluster(), &opts);
        let r2 = run_policy("ours", &en, &cluster(), &opts);
        let seq = r1.end_to_end_time + r2.end_to_end_time;
        writeln!(
            out,
            "    ours sequential two-apps: {seq:.1}s -> whole-app is {:.2}x faster",
            seq / reports[0].end_to_end_time
        )
        .unwrap();
    }
    out
}

/// Fig. 13: mixed-app schedules at (400, 5000).
pub fn fig13(quick: bool) -> String {
    let mut out = header("Fig 13", "mixed app schedules at (400 docs, 5000 ensembling reqs)");
    let (docs, ens) = if quick { (100, 1000) } else { (400, 5000) };
    let s = scenario(AppSpec::mixed(docs, ens, 900, 256, 4), 44);
    for p in policy::PAPER {
        let r = run_policy(p, &s, &cluster(), &RunOpts::default());
        out.push_str(&gantt::render(&r, 72));
        out.push('\n');
    }
    out
}

/// Fig. 14: ablation — no-preemption variants and known output lengths.
pub fn fig14(quick: bool) -> String {
    let mut out =
        header("Fig 14", "ablation on the mixed app (500 docs, 5000 ens; eval x4; out 900/512)");
    let (docs, ens) = if quick { (100, 1000) } else { (500, 5000) };
    let s = scenario(AppSpec::mixed(docs, ens, 900, 512, 4), 55);
    let c = cluster();
    let base = RunOpts::default();
    let ours = run_policy("ours", &s, &c, &base);
    let ours_np = run_policy("ours", &s, &c, &RunOpts { no_preemption: true, ..base.clone() });
    let ours_known = run_policy("ours", &s, &c, &RunOpts { known_lengths: true, ..base.clone() });
    let min = run_policy("min-heuristic", &s, &c, &base);
    let min_np =
        run_policy("min-heuristic", &s, &c, &RunOpts { no_preemption: true, ..base.clone() });
    let min_known =
        run_policy("min-heuristic", &s, &c, &RunOpts { known_lengths: true, ..base.clone() });
    for (label, r) in [
        ("ours", &ours),
        ("ours (no preemption)", &ours_np),
        ("ours (known lengths)", &ours_known),
        ("min", &min),
        ("min (no preemption)", &min_np),
        ("min (known lengths)", &min_known),
    ] {
        writeln!(
            out,
            "{label:<24} e2e={:>8.1}s inf={:>8.1}s  vs ours {:.2}x",
            r.end_to_end_time,
            r.inference_time,
            r.end_to_end_time / ours.end_to_end_time
        )
        .unwrap();
    }
    writeln!(
        out,
        "preemption speedup: ours {:.2}x, min {:.2}x (paper: 1.0-1.2x / 1.3-1.4x)",
        ours_np.end_to_end_time / ours.end_to_end_time,
        min_np.end_to_end_time / min.end_to_end_time
    )
    .unwrap();
    writeln!(
        out,
        "cost-model error: unknown lengths {:.1}% -> known lengths {:.1}% (paper: avg 25.6% -> 17.0%)",
        100.0 * ours.estimation_error(),
        100.0 * ours_known.estimation_error()
    )
    .unwrap();
    out
}

/// Fig. 15: Ours with vs without preemption (Gantt).
pub fn fig15(quick: bool) -> String {
    let mut out = header("Fig 15", "ours w/ and w/o preemption (mixed app, ens limit 256)");
    let (docs, ens) = if quick { (100, 1000) } else { (500, 5000) };
    let s = scenario(AppSpec::mixed(docs, ens, 900, 256, 4), 66);
    let c = cluster();
    let with = run_policy("ours", &s, &c, &RunOpts::default());
    let without = run_policy(
        "ours",
        &s,
        &c,
        &RunOpts { no_preemption: true, ..Default::default() },
    );
    out.push_str("(a) ours\n");
    out.push_str(&gantt::render(&with, 72));
    out.push_str("(b) ours, no preemption\n");
    out.push_str(&gantt::render(&without, 72));
    out
}

/// §5.5 error study: cost-model error ratio across all applications.
pub fn errors(quick: bool) -> String {
    let mut out = header("Errors", "cost-model error ratios across applications (§5.5)");
    let c = cluster();
    let scenarios: Vec<Scenario> = vec![
        scenario(AppSpec::ensembling(if quick { 500 } else { 2000 }, 256), 1),
        scenario(AppSpec::routing(4096, false), 2),
        scenario(AppSpec::chain_summary(if quick { 50 } else { 200 }, 2, 500), 3),
    ];
    let mut errs = vec![];
    for s in &scenarios {
        for known in [false, true] {
            let r = run_policy(
                "ours",
                s,
                &c,
                &RunOpts { known_lengths: known, ..Default::default() },
            );
            let e = r.estimation_error();
            errs.push(e);
            writeln!(
                out,
                "{:<38} known={known:<5} est={:>8.1}s real={:>8.1}s error={:>5.1}%",
                s.name,
                r.estimated_inference_time,
                r.inference_time,
                100.0 * e
            )
            .unwrap();
        }
    }
    let max = errs.iter().copied().fold(0.0, f64::max);
    writeln!(out, "max error {:.1}% (paper band: 6.5-38.7%)", 100.0 * max).unwrap();
    out
}

/// Dispatch by figure id.
pub fn run_figure(id: &str, quick: bool) -> Option<String> {
    Some(match id {
        "fig2" => fig2(),
        "fig3" => fig3(),
        "fig4" => fig4(),
        "fig7" => fig7(quick),
        "table1" => table1(),
        "fig8" => fig8(),
        "fig9" => fig9(),
        "fig10" => fig10(),
        "fig11" => fig11(quick),
        "fig12" => fig12(quick),
        "fig13" => fig13(quick),
        "fig14" => fig14(quick),
        "fig15" => fig15(quick),
        "errors" => errors(quick),
        "ablations" => ablations::all(),
        _ => return None,
    })
}

/// All known figure ids, in paper order.
pub const ALL_FIGURES: [&str; 15] = [
    "fig2", "fig3", "fig4", "fig7", "table1", "fig8", "fig9", "fig10", "fig11", "fig12",
    "fig13", "fig14", "fig15", "errors", "ablations",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cheap_figures_render() {
        for id in ["fig2", "fig4", "table1", "fig10"] {
            let s = run_figure(id, true).unwrap();
            assert!(s.len() > 100, "{id} output too small");
        }
    }

    #[test]
    fn unknown_figure_is_none() {
        assert!(run_figure("fig99", true).is_none());
    }
}
