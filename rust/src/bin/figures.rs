//! Regenerate the paper's tables and figures: `figures <id>|all [--quick]`.
//! Ids: fig2 fig3 fig4 fig7 table1 fig8 fig9 fig10 fig11 fig12 fig13
//!      fig14 fig15 errors  (see DESIGN.md's experiment index).

use samullm::harness;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let ids: Vec<&str> = args.iter().filter(|a| *a != "--quick").map(|s| s.as_str()).collect();
    let ids: Vec<&str> = if ids.is_empty() || ids == ["all"] {
        harness::ALL_FIGURES.to_vec()
    } else {
        ids
    };
    for id in ids {
        match harness::run_figure(id, quick) {
            Some(text) => println!("{text}"),
            None => eprintln!("unknown figure id: {id} (known: {:?})", harness::ALL_FIGURES),
        }
    }
}
