//! Model-residency subsystem: weight swap costs, oversubscribed placement
//! and proactive offload.
//!
//! Prior releases treated "the stage fits the cluster" as a hard
//! invariant: a stage whose plans sum to more GPUs than exist was simply
//! invalid ([`crate::plan::Stage::is_valid`]). This module relaxes that —
//! opt-in via `--oversubscribe` — by giving weights a *residency
//! lifecycle*:
//!
//! * **resident** — weights occupy HBM and the model may run;
//! * **host-cached** — weights were swapped out over the d2h link and can
//!   be swapped back in at warm-transfer cost
//!   ([`crate::costmodel::SwapCost::load_secs`]), far cheaper than a cold
//!   load from checkpoint ([`crate::models::ModelSpec::load_time`]);
//! * **discarded** — a drained model's weights are released without a
//!   host copy (finished nodes never rerun, and weights are immutable, so
//!   nothing needs preserving).
//!
//! [`run_packed_stage`] lowers one *packed* stage — a planner stage whose
//! aggregate GPU demand exceeds the cluster — into a sequence of
//! first-finish **sub-stages** that time-slice the GPUs. At every
//! sub-stage boundary it:
//!
//! 1. retires drained models (proactive offload: the freed HBM lets the
//!    next joiner's weight transfer overlap the running models' decode
//!    tail, FastServe-style);
//! 2. admits pending models first-fit (dependency-aware), pricing their
//!    loads cold, warm, or partially overlapped;
//! 3. optionally *displaces* a long-running model to make room for a
//!    wide pending one, when the modeled swap round-trip is cheaper than
//!    waiting for GPUs to free naturally ([`SWAP_WAIT_FACTOR`]).
//!
//! Every swap is visible on the unified event stream
//! ([`SwapIn`](crate::engine::sched::EventKind::SwapIn) /
//! [`SwapOut`](crate::engine::sched::EventKind::SwapOut))
//! and aggregated into [`ResidencyStats`] for the run report. With
//! oversubscription disabled — or enabled but never triggered because
//! every stage fits — nothing here runs and results are bit-identical to
//! the pre-residency releases.

use std::collections::{HashMap, HashSet, VecDeque};

use anyhow::Result;

use crate::cluster::ClusterSpec;
use crate::costmodel::SwapCost;
use crate::engine::sched::{EngineEvent, EventKind};
use crate::exec::ExecBackend;
use crate::graph::AppGraph;
use crate::models::Registry;
use crate::plan::{ExecPlan, Stage, StageEntry};
use crate::runner::state::{ExecState, StageResult};

/// Displacement hysteresis: a running model is swapped out for a pending
/// one only when the expected natural wait for GPUs exceeds this multiple
/// of the swap round-trip (victim evict + victim's later warm reload).
/// The margin absorbs the unpriced cost of the victim's lost KV cache
/// (it re-prefills on rejoin).
pub const SWAP_WAIT_FACTOR: f64 = 2.0;

/// A model whose weights currently occupy HBM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidentModel {
    /// The plan the weights are sharded for.
    pub plan: ExecPlan,
    /// Weight bytes per participating GPU under that sharding.
    pub bytes_per_gpu: u64,
    /// Pinned models may not be evicted (in-flight this sub-stage).
    pub pinned: bool,
    /// Clock of the model's latest scheduled sub-stage (LRU key).
    pub last_use: f64,
}

/// Swap-traffic counters for one run (reported in
/// [`crate::metrics::RunReport::residency`]). All-zero whenever
/// oversubscription is off or never triggered.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResidencyStats {
    /// Warm weight loads over the h2d link (each has a `SwapIn` event).
    pub swaps_in: u64,
    /// Weight releases from HBM (each has a `SwapOut` event): d2h
    /// offloads of displaced models plus drained-model discards.
    pub swaps_out: u64,
    /// Total weight bytes moved onto GPUs by swap-ins.
    pub bytes_in: u64,
    /// Total weight bytes released from GPUs by swap-outs.
    pub bytes_out: u64,
    /// Swap seconds on the critical path: paid warm-load delays plus
    /// d2h evictions that serialized before a displacement load.
    pub stall_seconds: f64,
    /// Swap/load seconds hidden behind computation: transfers credited
    /// against the previous sub-stage's decode tail (proactive offload)
    /// and off-path d2h copies.
    pub overlapped_seconds: f64,
}

impl ResidencyStats {
    /// Fold another run segment's counters into this one.
    pub fn absorb(&mut self, o: &ResidencyStats) {
        self.swaps_in += o.swaps_in;
        self.swaps_out += o.swaps_out;
        self.bytes_in += o.bytes_in;
        self.bytes_out += o.bytes_out;
        self.stall_seconds += o.stall_seconds;
        self.overlapped_seconds += o.overlapped_seconds;
    }

    /// Whether any swap traffic happened at all.
    pub fn any(&self) -> bool {
        self.swaps_in + self.swaps_out > 0
    }
}

/// Tracks which models' weights are resident in HBM, which have a host
/// copy, and the swap traffic generated while managing them.
///
/// Purely bookkeeping — transfer *times* are priced by the caller with
/// [`SwapCost`], so the manager can serve both the planner's estimate
/// pass and the runner's ground-truth pass without knowing which it is.
#[derive(Debug, Clone, Default)]
pub struct ResidencyManager {
    resident: HashMap<usize, ResidentModel>,
    host_cached: HashSet<usize>,
    /// Swap-traffic counters accumulated so far.
    pub stats: ResidencyStats,
}

impl ResidencyManager {
    /// An empty manager (nothing resident, nothing cached).
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `node`'s weights now occupy HBM under `plan`.
    pub fn note_resident(&mut self, node: usize, plan: ExecPlan, bytes_per_gpu: u64, now: f64) {
        self.resident
            .insert(node, ResidentModel { plan, bytes_per_gpu, pinned: false, last_use: now });
    }

    /// The plan `node`'s resident weights are sharded for, if resident.
    pub fn resident_plan(&self, node: usize) -> Option<ExecPlan> {
        self.resident.get(&node).map(|r| r.plan)
    }

    /// Whether `node`'s weights are in HBM (under any sharding).
    pub fn is_resident(&self, node: usize) -> bool {
        self.resident.contains_key(&node)
    }

    /// Whether a host copy of `node`'s weights exists (warm reload).
    pub fn is_host_cached(&self, node: usize) -> bool {
        self.host_cached.contains(&node)
    }

    /// Refresh `node`'s LRU timestamp.
    pub fn touch(&mut self, node: usize, now: f64) {
        if let Some(r) = self.resident.get_mut(&node) {
            r.last_use = r.last_use.max(now);
        }
    }

    /// Pin `node` against eviction (it has in-flight work this sub-stage).
    pub fn pin(&mut self, node: usize) {
        if let Some(r) = self.resident.get_mut(&node) {
            r.pinned = true;
        }
    }

    /// Release `node`'s eviction pin.
    pub fn unpin(&mut self, node: usize) {
        if let Some(r) = self.resident.get_mut(&node) {
            r.pinned = false;
        }
    }

    /// Whether `node` is currently pinned.
    pub fn is_pinned(&self, node: usize) -> bool {
        self.resident.get(&node).map(|r| r.pinned).unwrap_or(false)
    }

    /// Evict `node` to the host cache. Returns the evicted entry, or
    /// `None` if the node is pinned or not resident (pins are inviolable:
    /// a model with in-flight iterations never loses its weights).
    pub fn evict(&mut self, node: usize) -> Option<ResidentModel> {
        if self.is_pinned(node) {
            return None;
        }
        let r = self.resident.remove(&node)?;
        self.host_cached.insert(node);
        Some(r)
    }

    /// Release `node`'s weights without a host copy (drained model).
    pub fn discard(&mut self, node: usize) -> Option<ResidentModel> {
        self.resident.remove(&node)
    }

    /// Ids of all currently resident models.
    pub fn resident_nodes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.resident.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// The least-recently-used unpinned resident model, if any.
    pub fn lru_candidate(&self) -> Option<usize> {
        self.resident
            .iter()
            .filter(|(_, r)| !r.pinned)
            .min_by(|a, b| a.1.last_use.total_cmp(&b.1.last_use).then(a.0.cmp(b.0)))
            .map(|(&n, _)| n)
    }

    /// GPUs occupied by resident weights (sum of resident plans).
    pub fn resident_gpus(&self) -> u32 {
        self.resident.values().map(|r| r.plan.n_gpus()).sum()
    }

    /// Aggregate weight bytes resident across the whole cluster.
    pub fn resident_weight_bytes(&self) -> u64 {
        self.resident
            .values()
            .map(|r| r.bytes_per_gpu.saturating_mul(r.plan.n_gpus() as u64))
            .sum()
    }

    /// The §4.3-style swap-vs-wait rule: displace only when waiting for
    /// GPUs to free naturally costs more than [`SWAP_WAIT_FACTOR`] swap
    /// round-trips.
    pub fn swap_vs_wait(swap_secs: f64, expected_wait: f64) -> bool {
        expected_wait > SWAP_WAIT_FACTOR * swap_secs
    }
}

/// One first-finish sub-stage produced by lowering a packed stage.
#[derive(Debug, Clone)]
pub struct SubStageOutcome {
    /// The models that actually ran (always fits the cluster).
    pub stage: Stage,
    /// The stage-execution result (projected finishes, busy times).
    pub result: StageResult,
    /// Per-node load delays paid at this boundary (cold + warm).
    pub load_delay: HashMap<usize, f64>,
    /// Swap seconds on this sub-stage's critical path (warm loads paid
    /// after overlap credit, plus serialized d2h evictions).
    pub swap_stall: f64,
    /// Engine events of the sub-stage, including the boundary's
    /// `SwapIn`/`SwapOut` records.
    pub events: Vec<EngineEvent>,
}

/// The lowering of one packed stage: the sub-stages run plus the final
/// active set (the next stage's `prev_plans` for reload accounting).
#[derive(Debug, Clone, Default)]
pub struct PackedOutcome {
    /// Sub-stages in execution order.
    pub subs: Vec<SubStageOutcome>,
    /// The last sub-stage's entries (what is on the GPUs afterwards).
    pub final_stage: Stage,
}

/// Lower a packed stage (aggregate GPU demand may exceed the cluster)
/// into first-finish sub-stages that time-slice the GPUs, and execute
/// them against `backend`, mutating `state` and `mgr`.
///
/// `measured` selects [`ExecState::run_stage_measured`] per sub-stage
/// (real backends); swap stalls then advance the measured clock directly,
/// since measured execution has no per-node virtual load delay. Entries
/// whose dependencies cannot be satisfied within the packed stage are
/// left unscheduled — the caller's outer loop re-plans them.
#[allow(clippy::too_many_arguments)] // mirrors the run_stage signature family
pub fn run_packed_stage(
    packed: &Stage,
    state: &mut ExecState,
    graph: &AppGraph,
    registry: &Registry,
    cluster: &ClusterSpec,
    swap: &SwapCost,
    mgr: &mut ResidencyManager,
    backend: &mut dyn ExecBackend,
    measured: bool,
) -> Result<PackedOutcome> {
    let total_hbm = cluster.mem_bytes.saturating_mul(cluster.n_gpus as u64);
    let spec_of = |node: usize| registry.get(&graph.nodes[node].model).expect("model");
    let bytes_total =
        |e: &StageEntry| SwapCost::bytes_total(spec_of(e.node), e.plan.dp, e.plan.tp);

    let mut out = PackedOutcome::default();
    let mut pendq: VecDeque<StageEntry> = packed.entries.iter().copied().collect();
    let mut active: Vec<StageEntry> = vec![];
    let mut prev_result: Option<StageResult> = None;
    let mut prev_dur = 0.0f64;
    // Each sub-stage drains at least one model; displacements re-enqueue,
    // so allow a generous multiple before bailing to the outer loop.
    let rounds_cap = 4 * packed.entries.len() + 16;

    for _round in 0..rounds_cap {
        let now = state.clock;
        let mut events: Vec<EngineEvent> = vec![];
        let mut load_delay: HashMap<usize, f64> = HashMap::new();
        let mut swap_stall = 0.0f64;

        // -- boundary 1: retire drained models (proactive offload) --------
        let unfinished: HashSet<usize> = state.unfinished_nodes().into_iter().collect();
        let drained: Vec<StageEntry> =
            active.iter().copied().filter(|e| !unfinished.contains(&e.node)).collect();
        active.retain(|e| unfinished.contains(&e.node));
        for e in &drained {
            let was_resident = mgr.discard(e.node).is_some();
            if was_resident && !pendq.is_empty() {
                // Weights released at the drain boundary — no d2h copy
                // (finished models never rerun), and the freed HBM lets
                // the joiner's transfer overlap the survivors' decode
                // tail (credited at admission below).
                let bytes = bytes_total(e);
                events.push(EngineEvent {
                    node: e.node,
                    replica: 0,
                    t: now,
                    kind: EventKind::SwapOut { bytes, dur: 0.0 },
                });
                mgr.stats.swaps_out += 1;
                mgr.stats.bytes_out += bytes;
            }
        }

        // Drop pending entries whose node drained through another path
        // (defensive; keeps the queue consistent with state).
        pendq.retain(|e| unfinished.contains(&e.node));

        let finished: HashSet<usize> =
            (0..graph.n_nodes()).filter(|n| !unfinished.contains(n)).collect();
        let mut used: u32 = active.iter().map(|e| e.plan.n_gpus()).sum();

        // Overlap headroom: a joiner's transfer can start during the
        // previous sub-stage's tail if its weights fit the HBM freed by
        // the drained models (aggregate check).
        let mut overlap_bytes_free = total_hbm.saturating_sub(mgr.resident_weight_bytes());

        // Admission pricing shared by first-fit and displacement paths.
        // Returns the paid delay; updates events/stats/manager.
        let mut admit = |e: &StageEntry,
                         extra_stall: f64,
                         allow_overlap: bool,
                         events: &mut Vec<EngineEvent>,
                         mgr: &mut ResidencyManager|
         -> Option<f64> {
            let spec = spec_of(e.node);
            if mgr.resident_plan(e.node) == Some(e.plan) {
                // Kept resident under the same sharding: no load at all
                // (and KV survives, matching the §4.3 kept semantics).
                mgr.touch(e.node, now);
                return None;
            }
            let warm = mgr.is_host_cached(e.node);
            let base =
                if warm { swap.load_secs(spec, e.plan.tp) } else { spec.load_time(e.plan.tp) };
            let bytes = bytes_total(e);
            let credit = if allow_overlap && bytes <= overlap_bytes_free {
                overlap_bytes_free -= bytes;
                base.min(prev_dur)
            } else {
                0.0
            };
            let paid = (base - credit).max(0.0) + extra_stall;
            if warm {
                events.push(EngineEvent {
                    node: e.node,
                    replica: 0,
                    t: now,
                    kind: EventKind::SwapIn { bytes, dur: base },
                });
                mgr.stats.swaps_in += 1;
                mgr.stats.bytes_in += bytes;
                mgr.stats.stall_seconds += paid;
            }
            mgr.stats.overlapped_seconds += credit;
            mgr.note_resident(e.node, e.plan, SwapCost::bytes_per_gpu(spec, e.plan.tp), now);
            mgr.pin(e.node);
            Some(paid)
        };

        // -- boundary 2: first-fit admission (dependency-aware) -----------
        loop {
            let in_active: HashSet<usize> = active.iter().map(|a| a.node).collect();
            let slot = pendq.iter().position(|e| {
                let mut in_stage = in_active.clone();
                in_stage.insert(e.node);
                used + e.plan.n_gpus() <= cluster.n_gpus
                    && graph.is_ready(e.node, &finished, &in_stage)
            });
            let Some(i) = slot else { break };
            let e = pendq.remove(i).unwrap();
            if let Some(paid) = admit(&e, 0.0, true, &mut events, mgr) {
                swap_stall += if mgr.is_host_cached(e.node) { paid } else { 0.0 };
                load_delay.insert(e.node, paid);
            }
            used += e.plan.n_gpus();
            active.push(e);
        }

        // -- boundary 3: swap-vs-wait displacement (at most one) ----------
        // Only with a previous sub-stage's projections to price the wait,
        // and only for the frontmost ready pending entry that did not fit.
        if let Some(pr) = &prev_result {
            let in_active: HashSet<usize> = active.iter().map(|a| a.node).collect();
            let head = pendq
                .iter()
                .position(|e| {
                    let mut in_stage = in_active.clone();
                    in_stage.insert(e.node);
                    graph.is_ready(e.node, &finished, &in_stage)
                })
                .map(|i| pendq[i]);
            if let Some(e) = head {
                let need = e.plan.n_gpus().saturating_sub(cluster.n_gpus - used);
                let proj: HashMap<usize, f64> =
                    pr.nodes.iter().map(|n| (n.node, n.projected_finish)).collect();
                // Victim: the unpinned active model latest to finish that
                // alone frees enough GPUs (near-finishers drain naturally).
                let victim = active
                    .iter()
                    .filter(|v| v.plan.n_gpus() >= need && !mgr.is_pinned(v.node))
                    .max_by(|a, b| {
                        let fa = proj.get(&a.node).copied().unwrap_or(f64::INFINITY);
                        let fb = proj.get(&b.node).copied().unwrap_or(f64::INFINITY);
                        fa.total_cmp(&fb)
                    })
                    .copied();
                if need > 0 {
                    if let Some(v) = victim {
                        // Natural wait: when would enough GPUs free if we
                        // just let the active models run?
                        let mut finishes: Vec<(f64, u32)> = active
                            .iter()
                            .map(|a| {
                                (proj.get(&a.node).copied().unwrap_or(f64::INFINITY),
                                 a.plan.n_gpus())
                            })
                            .collect();
                        finishes.sort_by(|a, b| a.0.total_cmp(&b.0));
                        let mut freed = 0u32;
                        let mut wait_until = f64::INFINITY;
                        for (t, g) in finishes {
                            freed += g;
                            if freed >= need {
                                wait_until = t;
                                break;
                            }
                        }
                        let expected_wait = (wait_until - now).max(0.0);
                        let vspec = spec_of(v.node);
                        let evict_dur = if mgr.is_host_cached(v.node) {
                            0.0 // weights immutable: the host copy is still valid
                        } else {
                            swap.evict_secs(vspec, v.plan.tp)
                        };
                        let round_trip = evict_dur + swap.load_secs(vspec, v.plan.tp);
                        if ResidencyManager::swap_vs_wait(round_trip, expected_wait)
                            && mgr.evict(v.node).is_some()
                        {
                            let vbytes = bytes_total(&v);
                            events.push(EngineEvent {
                                node: v.node,
                                replica: 0,
                                t: now,
                                kind: EventKind::SwapOut { bytes: vbytes, dur: evict_dur },
                            });
                            mgr.stats.swaps_out += 1;
                            mgr.stats.bytes_out += vbytes;
                            mgr.stats.stall_seconds += evict_dur;
                            active.retain(|a| a.node != v.node);
                            used -= v.plan.n_gpus();
                            // The victim rejoins later (warm) with its KV
                            // gone — back of the queue.
                            pendq.retain(|p| p.node != e.node);
                            pendq.push_back(v);
                            // The joiner's load serializes behind the
                            // evict (HBM must free first); no overlap.
                            if let Some(paid) = admit(&e, evict_dur, false, &mut events, mgr) {
                                swap_stall += paid;
                                load_delay.insert(e.node, paid);
                            }
                            used += e.plan.n_gpus();
                            active.push(e);
                        }
                    }
                }
            }
        }

        if active.is_empty() {
            // Nothing admissible (unsatisfiable dependencies within this
            // packed stage) — hand the remainder back to the outer loop.
            break;
        }

        // -- run the sub-stage (first-finish discipline) ------------------
        let stage = Stage { entries: active.clone() };
        let result = if measured {
            // Measured execution has no per-node virtual delay: the swap
            // stall is real wall time the devices spend on transfers.
            state.clock += swap_stall;
            state.run_stage_measured(&stage, graph, registry, backend, Some(&mut events))?
        } else {
            let before_done = state.completed.len();
            let res = state.run_stage(
                &stage,
                graph,
                registry,
                backend,
                &load_delay,
                false,
                false,
                Some(&mut events),
            );
            // Livelock guard, as in the outer runner loop: a sub-stage
            // that completed nothing in zero time re-runs to its fastest
            // node's completion.
            if state.completed.len() == before_done && res.end - res.start < 1e-9 {
                state.run_stage(
                    &stage,
                    graph,
                    registry,
                    backend,
                    &load_delay,
                    false,
                    true,
                    Some(&mut events),
                );
            }
            res
        };
        for e in &active {
            mgr.unpin(e.node);
            mgr.touch(e.node, state.clock);
        }
        prev_dur = (result.end - result.start).max(0.0);
        prev_result = Some(result.clone());
        out.subs
            .push(SubStageOutcome { stage: stage.clone(), result, load_delay, swap_stall, events });
        out.final_stage = stage;
        if pendq.is_empty() {
            break; // every packed entry got on the GPUs at least once
        }
    }
    Ok(out)
}

/// Whether `stage` plus the minimal plans of `leftover` ready nodes
/// overcommit the cluster — the gate for packed-stage planning. Packing
/// engages only when even the *smallest* valid footprint of everything
/// runnable cannot coexist, so workloads that fit (the entire paper
/// suite) never take this path.
pub fn overcommitted(
    stage: &Stage,
    leftover: &[StageEntry],
    cluster: &ClusterSpec,
    registry: &Registry,
    graph: &AppGraph,
) -> bool {
    let min_gpus = |e: &StageEntry| {
        registry
            .get(&graph.nodes[e.node].model)
            .and_then(|s| ExecPlan::minimal(s, cluster))
            .map(|p| p.n_gpus())
            .unwrap_or(e.plan.n_gpus())
    };
    let demand: u32 = stage.entries.iter().map(|e| min_gpus(e)).sum::<u32>()
        + leftover.iter().map(min_gpus).sum::<u32>();
    demand > cluster.n_gpus
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn entry(node: usize, dp: u32, tp: u32) -> StageEntry {
        StageEntry { node, plan: ExecPlan::new(dp, tp) }
    }

    #[test]
    fn residency_lifecycle_and_lru() {
        let mut m = ResidencyManager::new();
        m.note_resident(0, ExecPlan::new(1, 1), 10 << 30, 1.0);
        m.note_resident(1, ExecPlan::new(1, 2), 20 << 30, 2.0);
        assert!(m.is_resident(0) && m.is_resident(1));
        assert_eq!(m.resident_gpus(), 3);
        assert_eq!(m.resident_weight_bytes(), (10u64 << 30) + 2 * (20u64 << 30));
        // LRU prefers the oldest unpinned model.
        assert_eq!(m.lru_candidate(), Some(0));
        m.touch(0, 5.0);
        assert_eq!(m.lru_candidate(), Some(1));
        // Evict moves weights to the host cache.
        assert!(m.evict(1).is_some());
        assert!(!m.is_resident(1) && m.is_host_cached(1));
        // Discard releases without a host copy.
        assert!(m.discard(0).is_some());
        assert!(!m.is_resident(0) && !m.is_host_cached(0));
    }

    #[test]
    fn pinned_models_are_never_evicted() {
        let mut m = ResidencyManager::new();
        m.note_resident(7, ExecPlan::new(2, 1), 5 << 30, 0.0);
        m.pin(7);
        assert!(m.is_pinned(7));
        assert!(m.evict(7).is_none(), "pinned eviction must be refused");
        assert!(m.is_resident(7) && !m.is_host_cached(7));
        assert_eq!(m.lru_candidate(), None, "pinned models are not LRU candidates");
        m.unpin(7);
        assert!(m.evict(7).is_some());
    }

    #[test]
    fn swap_vs_wait_threshold() {
        // Waiting a little: keep waiting. Waiting much longer than the
        // swap round-trip: displace.
        assert!(!ResidencyManager::swap_vs_wait(10.0, 5.0));
        assert!(!ResidencyManager::swap_vs_wait(10.0, 20.0)); // boundary is strict
        assert!(ResidencyManager::swap_vs_wait(10.0, 20.1));
    }

    #[test]
    fn stats_absorb_accumulates() {
        let mut a = ResidencyStats {
            swaps_in: 1,
            swaps_out: 2,
            bytes_in: 10,
            bytes_out: 20,
            stall_seconds: 0.5,
            overlapped_seconds: 1.5,
        };
        let b = a;
        a.absorb(&b);
        assert_eq!(a.swaps_in, 2);
        assert_eq!(a.swaps_out, 4);
        assert_eq!(a.bytes_out, 40);
        assert!((a.stall_seconds - 1.0).abs() < 1e-12);
        assert!(a.any());
        assert!(!ResidencyStats::default().any());
    }

    #[test]
    fn overcommit_gate_uses_minimal_footprints() {
        let cluster = ClusterSpec::a100_node(2);
        let registry = Registry::paper();
        let mut graph = AppGraph::default();
        let a = graph.add_node("chatglm3-6b", "a", 256);
        let b = graph.add_node("mistral-7b-instruct", "b", 256);
        let c = graph.add_node("vicuna-13b-v1.5", "c", 256);
        // Two tp=1 models fill the node; a third ready model overcommits.
        let stage = Stage { entries: vec![entry(a, 1, 1), entry(b, 1, 1)] };
        assert!(!overcommitted(&stage, &[], &cluster, &registry, &graph));
        assert!(overcommitted(&stage, &[entry(c, 1, 1)], &cluster, &registry, &graph));
        // On a full 8-GPU node everything coexists at minimal plans.
        let big = ClusterSpec::a100_node(8);
        assert!(!overcommitted(&stage, &[entry(c, 1, 1)], &big, &registry, &graph));
    }
}
