//! BookSum / BOOOOKSCORE stand-in (§5.3, Fig. 10): documents for the
//! chain-summary application.
//!
//! Published statistics reproduced: chunk size 2048 tokens; document
//! lengths heavily skewed — at 100 sampled documents the median is 3
//! chunks and the maximum ~60; at 300 documents the maximum grows to ~201.

use crate::util::rng::Rng;

/// Tokens per chunk (the BOOOOKSCORE chunking configuration).
pub const CHUNK_TOKENS: u32 = 2048;

/// A sampled document: its id and number of 2048-token chunks.
#[derive(Debug, Clone)]
pub struct Document {
    /// Document id.
    pub id: u64,
    /// Number of 2048-token chunks.
    pub n_chunks: u32,
}

/// Sample `n` documents with the paper's skewed length profile.
///
/// Body: log-normal with median 3 chunks. Tail: a ~1% Pareto-ish tail so
/// the max grows with the sample count (60 @100 docs, ~200 @300 docs),
/// matching Fig. 10's "one extremely long document" observation.
pub fn documents(n: usize, seed: u64) -> Vec<Document> {
    let mut rng = Rng::new(seed ^ 0x626F_6F6B_7375);
    let mut docs: Vec<Document> = (0..n as u64)
        .map(|id| {
            let u = rng.uniform();
            let n_chunks = if u < 0.985 {
                // Log-normal body: median 3, sigma 0.85 -> most docs 1–10.
                let x = rng.lognormal((3.0f64).ln(), 0.85);
                (x.round() as u32).clamp(1, 40)
            } else {
                // Heavy tail: 40..~120 chunks.
                let t = rng.uniform();
                let x = 40.0 * (1.0 - t).powf(-0.45);
                (x.round() as u32).min(120)
            };
            Document { id, n_chunks }
        })
        .collect();
    // The paper's "one extremely long document": the deepest tail scales
    // with the sample size (max 60 chunks at 100 docs, ~201 at 300 docs).
    let mega = ((0.63 * n as f64).round() as u32).clamp(20, 220);
    let slot = rng.range_usize(0, n.max(1));
    docs[slot].n_chunks = docs[slot].n_chunks.max(mega);
    docs
}

/// Total chunks across documents (the summarizer's request count).
pub fn total_chunks(docs: &[Document]) -> u64 {
    docs.iter().map(|d| d.n_chunks as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn median(mut xs: Vec<u32>) -> u32 {
        xs.sort_unstable();
        xs[xs.len() / 2]
    }

    #[test]
    fn hundred_docs_match_fig10() {
        let docs = documents(100, 42);
        let lens: Vec<u32> = docs.iter().map(|d| d.n_chunks).collect();
        let med = median(lens.clone());
        let max = *lens.iter().max().unwrap();
        assert!((2..=5).contains(&med), "median={med} (paper: 3)");
        assert!((40..=220).contains(&max), "max={max} (paper: ~60)");
    }

    #[test]
    fn three_hundred_docs_have_longer_tail() {
        // More samples -> deeper tail (paper: max 201 at 300 docs vs 60 at
        // 100). Check the max grows and the median stays put.
        let m100: Vec<u32> = documents(100, 7).iter().map(|d| d.n_chunks).collect();
        let m300: Vec<u32> = documents(300, 7).iter().map(|d| d.n_chunks).collect();
        assert!(median(m300.clone()) <= 5);
        assert!(m300.iter().max() >= m100.iter().max());
    }

    #[test]
    fn skew_mean_far_above_median() {
        let docs = documents(500, 3);
        let lens: Vec<u32> = docs.iter().map(|d| d.n_chunks).collect();
        let mean = lens.iter().map(|&x| x as f64).sum::<f64>() / lens.len() as f64;
        let med = median(lens) as f64;
        assert!(mean > med, "skewed distributions have mean {mean} > median {med}");
    }

    #[test]
    fn deterministic() {
        let a = documents(50, 1);
        let b = documents(50, 1);
        assert!(a.iter().zip(&b).all(|(x, y)| x.n_chunks == y.n_chunks));
    }
}
