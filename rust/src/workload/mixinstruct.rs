//! MixInstruct stand-in (§5.1): inputs for the LLM-ensembling application.
//!
//! Published statistics reproduced: request input length 5–127, average 21.

use super::Category;
use crate::util::rng::Rng;

/// An ensembling input: just an id + prompt length (+ category for Fig. 2
/// style analyses). Output lengths are per-*model* and assigned when the
/// application scenario is built.
#[derive(Debug, Clone)]
pub struct MixInput {
    /// Request id.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Instruction category.
    pub category: Category,
}

/// Generate `n` MixInstruct-like inputs.
pub fn inputs(n: usize, seed: u64) -> Vec<MixInput> {
    let mut rng = Rng::new(seed ^ 0x6D69_7869_6E73);
    (0..n as u64)
        .map(|id| {
            // Log-normal-ish short prompts: median ~16, mean ~21, max 127.
            let x = rng.lognormal((16.0f64).ln(), 0.55);
            let input_len = (x.round() as u32).clamp(5, 127);
            MixInput { id, input_len, category: *rng.choice(&Category::ALL) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statistics_match_published() {
        let xs = inputs(10_000, 1);
        assert_eq!(xs.len(), 10_000);
        let min = xs.iter().map(|x| x.input_len).min().unwrap();
        let max = xs.iter().map(|x| x.input_len).max().unwrap();
        let mean = xs.iter().map(|x| x.input_len as f64).sum::<f64>() / xs.len() as f64;
        assert!(min >= 5);
        assert!(max <= 127);
        assert!((15.0..28.0).contains(&mean), "mean={mean} (paper: 21)");
    }

    #[test]
    fn deterministic() {
        let a = inputs(50, 9);
        let b = inputs(50, 9);
        assert!(a.iter().zip(&b).all(|(x, y)| x.input_len == y.input_len));
    }
}
