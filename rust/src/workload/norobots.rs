//! No Robots stand-in trace (§2, Fig. 2): the 10 000-request instruction
//! set used to build per-model output-length eCDFs offline.

use super::lengths::model_style;
use super::Category;
use crate::util::rng::Rng;

/// One trace record: what the paper collects by running an LLM over the
/// No Robots requests.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// Instruction category.
    pub category: Category,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Observed output length in tokens.
    pub output_len: u32,
}

/// Generate the eCDF-building trace for `model`: `n` requests across the
/// ten categories, input lengths 5–400 (instructions are short-ish), and
/// output lengths drawn from the model's true style — i.e. the trace is a
/// faithful but finite sample of reality, exactly like the paper's.
pub fn trace(model: &str, n: usize, seed: u64) -> Vec<TraceRecord> {
    let mut rng = Rng::new(seed ^ 0x6E6F_726F_626F_7473);
    let style = model_style(model);
    (0..n)
        .map(|_| {
            let category = *rng.choice(&Category::ALL);
            // Input length: log-uniform 5..400, category-independent.
            let lo = (5.0f64).ln();
            let hi = (400.0f64).ln();
            let input_len = rng.range_f64(lo, hi).exp().round() as u32;
            let output_len = style.sample(&mut rng);
            TraceRecord { category, input_len, output_len }
        })
        .collect()
}

/// Bucket a trace by input-length region (Fig. 2a): `[0,50) [50,100) ...`.
pub fn by_input_region(records: &[TraceRecord], edges: &[u32]) -> Vec<(String, Vec<u32>)> {
    let mut out = vec![];
    for w in edges.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let lens: Vec<u32> = records
            .iter()
            .filter(|r| r.input_len >= lo && r.input_len < hi)
            .map(|r| r.output_len)
            .collect();
        out.push((format!("[{lo},{hi})"), lens));
    }
    out
}

/// Bucket a trace by category (Fig. 2b).
pub fn by_category(records: &[TraceRecord]) -> Vec<(Category, Vec<u32>)> {
    Category::ALL
        .iter()
        .map(|&c| {
            (c, records.iter().filter(|r| r.category == c).map(|r| r.output_len).collect())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Ecdf;

    #[test]
    fn trace_has_requested_size_and_ranges() {
        let t = trace("vicuna-13b-v1.5", 5000, 7);
        assert_eq!(t.len(), 5000);
        for r in &t {
            assert!((5..=400).contains(&r.input_len));
            assert!((1..=1024).contains(&r.output_len));
        }
    }

    #[test]
    fn ecdfs_similar_across_categories() {
        // The Fig. 2 insight: output-length eCDFs barely depend on the
        // request category. KS distance between category eCDFs stays small.
        let t = trace("vicuna-13b-v1.5", 10_000, 9);
        let cats = by_category(&t);
        let first = Ecdf::from_samples(cats[0].1.clone());
        for (_, lens) in &cats[1..] {
            let e = Ecdf::from_samples(lens.clone());
            assert!(first.ks_distance(&e) < 0.08);
        }
    }

    #[test]
    fn ecdfs_similar_across_input_regions() {
        let t = trace("chatglm3-6b", 10_000, 11);
        let regions = by_input_region(&t, &[5, 50, 120, 250, 401]);
        let base = Ecdf::from_samples(regions[0].1.clone());
        for (_, lens) in &regions[1..] {
            assert!(!lens.is_empty());
            let e = Ecdf::from_samples(lens.clone());
            assert!(base.ks_distance(&e) < 0.08);
        }
    }

    #[test]
    fn trace_deterministic() {
        let a = trace("koala-13b", 100, 3);
        let b = trace("koala-13b", 100, 3);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.output_len == y.output_len));
    }
}
