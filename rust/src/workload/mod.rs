//! Synthetic workload generators reproducing the paper's dataset statistics.
//!
//! No proprietary datasets ship offline, so each generator reproduces the
//! *published statistics* of the dataset it stands in for (see DESIGN.md
//! substitution table):
//!
//! * [`norobots`] — the 10-category instruction trace used to build output
//!   length eCDFs (§2, Fig. 2).
//! * [`mixinstruct`] — LLM-ensembling inputs (§5.1): input 5–127, avg 21.
//! * [`routerbench`] — routing inputs (§5.2, Table 1): input 9–577 avg 310,
//!   output 3–1585 avg 199, with the published per-model routing counts.
//! * [`booksum`] — chain-summary documents (§5.3, Fig. 10): heavily skewed
//!   chunk counts (median 3, max 60 @100 docs, ~201 @300 docs).

pub mod booksum;
pub mod lengths;
pub mod mixinstruct;
pub mod norobots;
pub mod routerbench;

/// The ten No Robots instruction categories (Fig. 2b).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // the variants are the category names themselves
pub enum Category {
    Generation,
    OpenQa,
    Brainstorm,
    Chat,
    Rewrite,
    Summarize,
    Coding,
    Classify,
    ClosedQa,
    Extract,
}

impl Category {
    /// All ten categories, in Fig. 2b order.
    pub const ALL: [Category; 10] = [
        Category::Generation,
        Category::OpenQa,
        Category::Brainstorm,
        Category::Chat,
        Category::Rewrite,
        Category::Summarize,
        Category::Coding,
        Category::Classify,
        Category::ClosedQa,
        Category::Extract,
    ];

    /// Human-readable category name (Fig. 2b labels).
    pub fn name(&self) -> &'static str {
        match self {
            Category::Generation => "Generation",
            Category::OpenQa => "Open QA",
            Category::Brainstorm => "Brainstorm",
            Category::Chat => "Chat",
            Category::Rewrite => "Rewrite",
            Category::Summarize => "Summarize",
            Category::Coding => "Coding",
            Category::Classify => "Classify",
            Category::ClosedQa => "Closed QA",
            Category::Extract => "Extract",
        }
    }
}

/// One inference request as the scheduler sees it.
///
/// `true_output_len` is the hidden ground truth: only the running phase
/// (and "known output length" ablations) may read it. The planner must
/// sample lengths from the eCDF instead.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request id, unique within its node.
    pub id: u64,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Ground-truth output length (hidden from the planner).
    pub true_output_len: u32,
    /// Instruction category the request was drawn from.
    pub category: Category,
    /// Virtual time at which the request becomes available (0 for offline
    /// requests; set by the communicator for dependent models).
    pub ready_time: f64,
    /// Free-form grouping tag (document id for chain summary, etc.).
    pub tag: u64,
}

impl Request {
    /// An offline request: ready at time 0, no grouping tag.
    pub fn offline(id: u64, input_len: u32, true_output_len: u32, category: Category) -> Self {
        Request { id, input_len, true_output_len, category, ready_time: 0.0, tag: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_categories() {
        assert_eq!(Category::ALL.len(), 10);
        let names: std::collections::HashSet<_> = Category::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), 10);
    }
}
