//! RouterBench stand-in (§5.2, Table 1): routing inputs with known
//! best-model assignments and (optionally) known response lengths.
//!
//! Published statistics reproduced:
//! * per-model routing counts (Table 1): llama-70b 408, mixtral 1267,
//!   wizardlm 2068, codellama 456, mistral 2657 — total 6856;
//! * input length 9–577, average 310;
//! * output length 3–1585, average 199.

use super::Category;
use crate::util::rng::Rng;

/// Table 1 of the paper: (model, request count).
pub const TABLE1: [(&str, usize); 5] = [
    ("llama-2-70b-chat", 408),
    ("mixtral-8x7b-instruct", 1267),
    ("wizardlm-13b-v1.2", 2068),
    ("codellama-34b-instruct", 456),
    ("mistral-7b-instruct", 2657),
];

/// One routed request; `output_len` is the *known* response length the
/// dataset ships (used by the "known output lengths" experiment of Fig. 8).
#[derive(Debug, Clone)]
pub struct RoutedRequest {
    /// Request id.
    pub id: u64,
    /// The model the router sends this request to.
    pub model: &'static str,
    /// Prompt length in tokens.
    pub input_len: u32,
    /// Known response length the dataset ships.
    pub output_len: u32,
    /// Instruction category.
    pub category: Category,
}

/// Generate the full routed dataset with Table 1's exact counts.
pub fn dataset(seed: u64) -> Vec<RoutedRequest> {
    let mut rng = Rng::new(seed ^ 0x726F_7574_6572);
    let mut out = vec![];
    let mut id = 0u64;
    for (model, count) in TABLE1 {
        for _ in 0..count {
            // Inputs: log-normal centered to hit mean≈310 within [9,577].
            let input = rng.lognormal((290.0f64).ln(), 0.55);
            let input_len = (input.round() as u32).clamp(9, 577);
            // Outputs: mean≈199, range [3,1585].
            let output = rng.lognormal((150.0f64).ln(), 0.85);
            let output_len = (output.round() as u32).clamp(3, 1585);
            out.push(RoutedRequest {
                id,
                model,
                input_len,
                output_len,
                category: *rng.choice(&Category::ALL),
            });
            id += 1;
        }
    }
    // Interleave models (the dataset is not sorted by route target).
    rng.shuffle(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts_exact() {
        let d = dataset(3);
        assert_eq!(d.len(), 6856);
        for (model, count) in TABLE1 {
            let n = d.iter().filter(|r| r.model == model).count();
            assert_eq!(n, count, "{model}");
        }
    }

    #[test]
    fn length_statistics_match_published() {
        let d = dataset(5);
        let in_mean = d.iter().map(|r| r.input_len as f64).sum::<f64>() / d.len() as f64;
        let out_mean = d.iter().map(|r| r.output_len as f64).sum::<f64>() / d.len() as f64;
        assert!((250.0..370.0).contains(&in_mean), "input mean={in_mean} (paper: 310)");
        assert!((150.0..260.0).contains(&out_mean), "output mean={out_mean} (paper: 199)");
        assert!(d.iter().all(|r| (9..=577).contains(&r.input_len)));
        assert!(d.iter().all(|r| (3..=1585).contains(&r.output_len)));
    }

    #[test]
    fn ratios_match_table1() {
        // Ratio column of Table 1: 0.06 / 0.18 / 0.30 / 0.07 / 0.39.
        let d = dataset(1);
        let total = d.len() as f64;
        let want = [0.06, 0.18, 0.30, 0.07, 0.39];
        for ((model, _), w) in TABLE1.iter().zip(want) {
            let ratio = d.iter().filter(|r| r.model == *model).count() as f64 / total;
            assert!((ratio - w).abs() < 0.01, "{model}: {ratio} vs {w}");
        }
    }
}
