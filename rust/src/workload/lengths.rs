//! Output-length distributions — the single source of truth for both the
//! ground-truth request lengths and the eCDF-building trace.
//!
//! §2's insight: a model's output length follows a distribution that is
//! largely independent of the request (absent explicit length
//! instructions). We model each LLM's "style" as a log-normal with a
//! per-model location/scale derived deterministically from its name, so
//! the whole repo agrees on what every model's true distribution is.
//!
//! The *trace* (No Robots stand-in) draws from these same distributions —
//! the planner's eCDF is therefore a finite-sample estimate of the truth,
//! and applications may additionally apply a small per-app dataset shift
//! (MixInstruct answers are not No Robots answers), reproducing the
//! paper's estimation-error band.

use crate::util::rng::Rng;

/// A log-normal output-length distribution, truncated to `[1, cap]`.
#[derive(Debug, Clone, Copy)]
pub struct LengthDist {
    /// Location of the underlying normal (ln tokens).
    pub mu: f64,
    /// Scale of the underlying normal.
    pub sigma: f64,
    /// Hard cap (the model's practical maximum answer length).
    pub cap: u32,
}

impl LengthDist {
    /// Draw one capped log-normal length.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let x = rng.lognormal(self.mu, self.sigma);
        (x.round() as u32).clamp(1, self.cap)
    }

    /// Mean of the truncated distribution, estimated analytically from the
    /// untruncated log-normal (good enough for sanity checks).
    pub fn approx_mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp().min(self.cap as f64)
    }
}

fn name_hash(name: &str) -> u64 {
    // FNV-1a, stable across runs/platforms.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The true output-length style of a model. Centered near the published
/// MixInstruct statistics (avg ≈ 180, max ≈ 490 under a 512 cap) with a
/// deterministic per-model personality: chattier models answer longer.
pub fn model_style(model: &str) -> LengthDist {
    let h = name_hash(model);
    // Spread mu over ln(120)..ln(260) and sigma over 0.75..1.05.
    let u1 = (h & 0xffff) as f64 / 65535.0;
    let u2 = ((h >> 16) & 0xffff) as f64 / 65535.0;
    LengthDist {
        mu: (110.0f64).ln() + u1 * ((220.0f64).ln() - (110.0f64).ln()),
        sigma: 0.70 + 0.25 * u2,
        cap: 1024,
    }
}

/// Per-application dataset shift: the app's true answers come from a
/// slightly different distribution than the eCDF-building trace. `shift`
/// multiplies lengths by `exp(delta)` with `|delta| <= 0.25`.
pub fn dataset_shift(app_seed: u64) -> f64 {
    let mut rng = Rng::new(app_seed ^ 0xD1F7_5EED);
    rng.range_f64(-0.22, 0.22)
}

/// Draw one *true* output length for `(model, app)`, respecting the output
/// limit and the model's context window.
pub fn true_output_len(
    model: &str,
    app_shift: f64,
    input_len: u32,
    max_out: u32,
    max_seq: u32,
    rng: &mut Rng,
) -> u32 {
    let style = model_style(model);
    let shifted = LengthDist { mu: style.mu + app_shift, sigma: style.sigma, cap: style.cap };
    let x = shifted.sample(rng);
    let window = max_seq.saturating_sub(input_len).max(1);
    x.min(max_out).min(window).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn style_is_deterministic_per_model() {
        let a = model_style("vicuna-13b-v1.5");
        let b = model_style("vicuna-13b-v1.5");
        assert_eq!(a.mu, b.mu);
        let c = model_style("chatglm3-6b");
        assert_ne!(a.mu, c.mu);
    }

    #[test]
    fn sample_mean_near_mixinstruct_avg() {
        // Across the zoo the mean should land in the ~120–300 band the
        // MixInstruct statistics (avg 180) sit in.
        let mut rng = Rng::new(1);
        for m in crate::models::Registry::ensembling_models() {
            let d = model_style(m);
            let n = 4000;
            let mean: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
            assert!((80.0..350.0).contains(&mean), "{m}: mean={mean}");
        }
    }

    #[test]
    fn true_output_respects_limits() {
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let l = true_output_len("alpaca-13b", 0.1, 2000, 256, 2048, &mut rng);
            assert!(l >= 1 && l <= 48.min(256), "l={l}");
        }
    }

    #[test]
    fn dataset_shift_bounded_and_deterministic() {
        let s1 = dataset_shift(42);
        let s2 = dataset_shift(42);
        assert_eq!(s1, s2);
        assert!(s1.abs() <= 0.25);
        assert_ne!(dataset_shift(1), dataset_shift(2));
    }
}
