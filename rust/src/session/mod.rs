//! The [`SamuLlm`] session facade — the canonical entry point of the
//! library.
//!
//! A session owns everything `run_policy` used to re-assemble on every
//! call: the model [`crate::models::Registry`], the calibrated
//! [`crate::costmodel::CostModel`], the hardware ground truth and the
//! cluster description (bundled in a [`RunContext`]). Callers describe *what* to run with an
//! [`AppSpec`] and the session takes care of materialisation, policy
//! instantiation and execution:
//!
//! ```no_run
//! use samullm::prelude::*;
//!
//! let session = SamuLlm::builder()
//!     .cluster(ClusterSpec::a100_node(8))
//!     .policy("ours")
//!     .seed(42)
//!     .build()?;
//! let report = session.run(&AppSpec::ensembling(1000, 256))?;
//! println!("end-to-end: {:.1}s", report.end_to_end_time);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The builder validates the policy name against the
//! [`crate::policy`] registry at `build()` time, so misconfiguration
//! fails before any (expensive) planning starts.

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::cluster::ClusterSpec;
use crate::costmodel::online;
use crate::engine::AdmitPolicy;
use crate::exec::{self, pjrt::PjrtBackend, ExecBackend, SimBackend};
use crate::metrics::RunReport;
use crate::policy;
use crate::runner::{self, RunContext, RunOpts, Scenario};
use crate::spec::{AppSpec, TrafficSpec, WorkloadSpec};

/// Configured session: a cluster, a policy, a seed, an execution backend
/// and the shared cost-model wiring. Create one with [`SamuLlm::builder`].
pub struct SamuLlm {
    ctx: RunContext,
    policy: &'static str,
    backend: &'static str,
    artifacts: PathBuf,
    opts: RunOpts,
}

/// Builder for [`SamuLlm`]. Defaults: 8×A100 node, policy `"ours"`,
/// backend `"sim"`, seed 42, preemption on, sampled output lengths, 2%
/// ground-truth iteration jitter (the paper's §5 setup).
pub struct SamuLlmBuilder {
    cluster: ClusterSpec,
    /// A100-node GPU count requested via [`SamuLlmBuilder::gpus`];
    /// validated (and turned into a cluster) at `build()` time so bad
    /// counts error instead of panicking.
    gpus: Option<u32>,
    policy: String,
    backend: String,
    artifacts: Option<PathBuf>,
    seed: u64,
    no_preemption: bool,
    known_lengths: bool,
    noise_sigma: f64,
    threads: usize,
    sim_cache: bool,
    online_refinement: bool,
    replan_threshold: f64,
    online_weight: f64,
    admit: String,
    oversubscribe: bool,
    h2d_bw: Option<f64>,
    fast_step: bool,
    search_budget: Option<f64>,
    sequential_measured: bool,
}

impl SamuLlm {
    /// Start configuring a session (see [`SamuLlmBuilder`] defaults).
    pub fn builder() -> SamuLlmBuilder {
        SamuLlmBuilder {
            cluster: ClusterSpec::a100_node(8),
            gpus: None,
            policy: "ours".to_string(),
            backend: "sim".to_string(),
            artifacts: None,
            seed: 42,
            no_preemption: false,
            known_lengths: false,
            noise_sigma: 0.02,
            threads: 0,
            sim_cache: true,
            online_refinement: false,
            replan_threshold: online::DEFAULT_REPLAN_THRESHOLD,
            online_weight: online::DEFAULT_OBS_WEIGHT,
            admit: "fcfs".to_string(),
            oversubscribe: false,
            h2d_bw: None,
            fast_step: true,
            search_budget: None,
            sequential_measured: false,
        }
    }

    /// The session's canonical policy name.
    pub fn policy_name(&self) -> &'static str {
        self.policy
    }

    /// The session's canonical execution backend name.
    pub fn backend_name(&self) -> &'static str {
        self.backend
    }

    /// The cluster this session schedules onto.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.ctx.cluster
    }

    /// The session seed (workloads, calibration, planning).
    pub fn seed(&self) -> u64 {
        self.opts.seed
    }

    /// Materialise `spec` with the session seed and run it under the
    /// session policy. Spec-level run modes (e.g. routing's
    /// `known_lengths`) are honoured here.
    pub fn run(&self, spec: &AppSpec) -> Result<RunReport> {
        let scenario = spec.build(self.opts.seed)?;
        let mut opts = self.opts.clone();
        opts.known_lengths |= spec.wants_known_lengths();
        self.execute(self.policy, &scenario, &opts)
    }

    /// Run a pre-built [`Scenario`] under the session policy.
    pub fn run_scenario(&self, scenario: &Scenario) -> Result<RunReport> {
        self.execute(self.policy, scenario, &self.opts)
    }

    /// Materialise a multi-app [`WorkloadSpec`] with the session seed
    /// (per-entry overrides honoured) and run it jointly under the
    /// session policy: apps arriving at t = 0 are planned together, later
    /// arrivals enter through the replan path, and the report carries a
    /// per-app section ([`crate::metrics::WorkloadReport`]).
    pub fn run_workload(&self, workload: &WorkloadSpec) -> Result<RunReport> {
        let ws = workload.build(self.opts.seed)?;
        let mut opts = self.opts.clone();
        opts.known_lengths |= workload.wants_known_lengths();
        let mut policy = policy::create(self.policy)?;
        self.with_backend(|backend| {
            runner::run_workload_with_backend(policy.as_mut(), &ws, &self.ctx, &opts, backend)
        })
    }

    /// Materialise an open-loop [`TrafficSpec`] with the session seed and
    /// serve it under the session policy: per-app arrival processes feed a
    /// bounded admission queue, weighted fair-share admission turns
    /// per-entry `weight` into a real scheduling priority, and the report
    /// carries per-app serving metrics — TTFT, TPOT, latency percentiles
    /// and SLO attainment ([`crate::metrics::latency::TrafficReport`]).
    /// Traffic runs on the virtual-time substrate only; the `pjrt`
    /// backend is rejected.
    pub fn run_traffic(&self, traffic: &TrafficSpec) -> Result<RunReport> {
        let ts = traffic.build(self.opts.seed)?;
        let mut opts = self.opts.clone();
        opts.known_lengths |= traffic.wants_known_lengths();
        let mut policy = policy::create(self.policy)?;
        self.with_backend(|backend| {
            runner::run_traffic_with_backend(policy.as_mut(), &ts, &self.ctx, &opts, backend)
        })
    }

    /// Run the same spec under several policies (paper-style comparisons),
    /// reusing the session's scenario materialisation and wiring.
    pub fn compare(&self, spec: &AppSpec, policies: &[&str]) -> Result<Vec<RunReport>> {
        let scenario = spec.build(self.opts.seed)?;
        let mut opts = self.opts.clone();
        opts.known_lengths |= spec.wants_known_lengths();
        policies.iter().map(|p| self.execute(p, &scenario, &opts)).collect()
    }

    fn execute(&self, policy: &str, scenario: &Scenario, opts: &RunOpts) -> Result<RunReport> {
        let mut policy = policy::create(policy)?;
        self.with_backend(|backend| {
            runner::run_with_backend(policy.as_mut(), scenario, &self.ctx, opts, backend)
        })
    }

    /// Construct the session's execution backend and hand it to `f` — the
    /// one backend-dispatch point shared by [`SamuLlm::run`] /
    /// [`SamuLlm::run_scenario`] / [`SamuLlm::run_workload`] /
    /// [`SamuLlm::run_traffic`], so a new
    /// backend (or a change to the pjrt loading contract) is wired in one
    /// place.
    fn with_backend<T>(&self, f: impl FnOnce(&mut dyn ExecBackend) -> Result<T>) -> Result<T> {
        match self.backend {
            "pjrt" => {
                let mut backend = PjrtBackend::load(&self.artifacts)?;
                f(&mut backend)
            }
            _ => {
                let mut backend = SimBackend::new(&self.ctx.hw, self.ctx.cluster.mem_bytes);
                f(&mut backend)
            }
        }
    }
}

impl SamuLlmBuilder {
    /// The hardware to schedule on (default: `ClusterSpec::a100_node(8)`).
    pub fn cluster(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = cluster;
        self.gpus = None;
        self
    }

    /// Convenience: an `n`-GPU A100 node. `n` must be a power of two
    /// (checked at `build()`, which errors instead of panicking).
    pub fn gpus(mut self, n: u32) -> Self {
        self.gpus = Some(n);
        self
    }

    /// Scheduling policy by registry name or alias (default `"ours"`).
    pub fn policy(mut self, name: &str) -> Self {
        self.policy = name.to_string();
        self
    }

    /// Execution backend by registry name or alias (default `"sim"`):
    /// `"sim"` runs on the virtual-time substrate, `"pjrt"` on the real
    /// PJRT TinyGPT runtime (requires `make artifacts`; see
    /// [`SamuLlmBuilder::artifacts_dir`]).
    pub fn backend(mut self, name: &str) -> Self {
        self.backend = name.to_string();
        self
    }

    /// Artifacts directory for the `pjrt` backend (default:
    /// [`crate::runtime::default_artifacts_dir`]).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Seed for workload generation, cost-model calibration and planning.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Disable preemption (§5.5 ablation).
    pub fn no_preemption(mut self, on: bool) -> Self {
        self.no_preemption = on;
        self
    }

    /// Give every policy the true output lengths (§5.5 ablation).
    pub fn known_lengths(mut self, on: bool) -> Self {
        self.known_lengths = on;
        self
    }

    /// Ground-truth per-iteration jitter σ (default 0.02).
    pub fn noise_sigma(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// Planner candidate-evaluation worker threads (default `0` = auto,
    /// capped at 8). Plans are identical for every value — threads only
    /// change search wall-clock, never results.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Memoize planner simulations in the session's shared
    /// [`crate::planner::SimCache`] (default on). Hits are bit-identical
    /// to fresh simulations, so this too only affects search wall-clock.
    pub fn sim_cache(mut self, on: bool) -> Self {
        self.sim_cache = on;
        self
    }

    /// Runtime length-feedback loop (default off — results are then
    /// bit-identical to every pre-feedback release): observed completion
    /// lengths refine a per-model posterior, in-flight requests are
    /// re-estimated conditionally (`X | X > generated`), and the `ours`
    /// policy escalates from stage repair to a full re-plan of the
    /// remaining application when drift exceeds the replan threshold.
    pub fn online_refinement(mut self, on: bool) -> Self {
        self.online_refinement = on;
        self
    }

    /// Drift score above which the dynamic scheduler replans the
    /// remaining application (default
    /// [`online::DEFAULT_REPLAN_THRESHOLD`]; only meaningful with
    /// [`SamuLlmBuilder::online_refinement`]).
    pub fn replan_threshold(mut self, threshold: f64) -> Self {
        self.replan_threshold = threshold;
        self
    }

    /// Weight of one observed completion in offline-trace-sample
    /// equivalents when blending the online posterior (default
    /// [`online::DEFAULT_OBS_WEIGHT`]; only meaningful with
    /// [`SamuLlmBuilder::online_refinement`]).
    pub fn online_weight(mut self, weight: f64) -> Self {
        self.online_weight = weight;
        self
    }

    /// Engine admission policy by name (default `"fcfs"`, byte-identical
    /// to the pre-policy behaviour): one of
    /// `fcfs | spjf | multi-bin[:BINS] | skip-join[:QUEUES[:PROMOTE_S]]`.
    /// Validated at `build()` time. Non-FCFS policies order each engine's
    /// waiting queue by the planner's per-request length predictions
    /// (refined mid-run when [`SamuLlmBuilder::online_refinement`] is on).
    pub fn admit_policy(mut self, name: &str) -> Self {
        self.admit = name.to_string();
        self
    }

    /// Let plans oversubscribe the cluster (default off — bit-identical
    /// to the strict path): stages whose aggregate weight footprint
    /// exceeds HBM are lowered by the residency subsystem
    /// ([`crate::residency`]) into sub-stages that time-slice the GPUs,
    /// paying modeled weight-swap latency over the host link. Batch runs
    /// only; traffic runs reject it.
    pub fn oversubscribe(mut self, on: bool) -> Self {
        self.oversubscribe = on;
        self
    }

    /// Override the cluster's host-to-device copy bandwidth in bytes/s
    /// for swap-cost pricing (default: the cluster spec's own `h2d_bw`;
    /// the d2h side scales by the spec's d2h/h2d ratio). Must be positive
    /// — validated at `build()`.
    pub fn h2d_bw(mut self, bytes_per_sec: f64) -> Self {
        self.h2d_bw = Some(bytes_per_sec);
        self
    }

    /// Aggregated fast-step decode in every engine simulation (default
    /// on). Exact — outcomes, events and counters are bit-identical to
    /// per-token stepping, only simulation wall-clock changes — so `false`
    /// exists for verification and for measuring the speedup itself
    /// ([`crate::engine::sched::EngineConfig::fast_step`]).
    pub fn fast_step(mut self, on: bool) -> Self {
        self.fast_step = on;
        self
    }

    /// Anytime-search wall-clock budget in seconds for every Algorithm 1
    /// search the session runs (offline plans and mid-run re-plans;
    /// default: none — search to convergence). Must be positive
    /// (validated at `build()`; `f64::INFINITY` is accepted and
    /// equivalent to no budget). An expiring search returns best-so-far —
    /// always a complete, executable plan — and sets
    /// [`crate::planner::eval::EvalStats::budget_exhausted`] in the
    /// report.
    pub fn search_budget(mut self, seconds: f64) -> Self {
        self.search_budget = Some(seconds);
        self
    }

    /// Force the sequential measured lowering (default off). Measured
    /// stages normally interleave their nodes through the backend's
    /// stepping interface so the stage wall-clock is the max over nodes
    /// ([`crate::runner::ExecState::run_stage_concurrent`]); with this on
    /// they run one after another and measured times chain. Inert for
    /// virtual (`sim`) runs.
    pub fn sequential_measured(mut self, on: bool) -> Self {
        self.sequential_measured = on;
        self
    }

    /// Validate the configuration and assemble the session wiring. For
    /// the `pjrt` backend, the artifacts contract is checked here so
    /// misconfiguration fails before any (expensive) planning starts.
    pub fn build(self) -> Result<SamuLlm> {
        let policy = policy::canonical(&self.policy)?;
        let backend = exec::canonical(&self.backend)?;
        let admit = AdmitPolicy::parse(&self.admit)?;
        if let Some(bw) = self.h2d_bw {
            if !bw.is_finite() || bw <= 0.0 {
                return Err(anyhow!("h2d bandwidth must be positive, got {bw}"));
            }
        }
        if let Some(b) = self.search_budget {
            if b.is_nan() || b <= 0.0 {
                return Err(anyhow!("search budget must be positive seconds, got {b}"));
            }
        }
        let artifacts = self.artifacts.unwrap_or_else(crate::runtime::default_artifacts_dir);
        if backend == "pjrt" && !artifacts.join("model_meta.json").exists() {
            return Err(anyhow!(
                "backend \"pjrt\" needs TinyGPT artifacts in {} — run `make artifacts` \
                 first (or point artifacts_dir at them)",
                artifacts.display()
            ));
        }
        let cluster = match self.gpus {
            Some(n) => {
                if n == 0 || !n.is_power_of_two() {
                    return Err(anyhow!("gpu count must be a power of two, got {n}"));
                }
                ClusterSpec::a100_node(n)
            }
            None => self.cluster,
        };
        if cluster.n_gpus == 0 || !cluster.n_gpus.is_power_of_two() {
            return Err(anyhow!(
                "cluster gpu count must be a power of two, got {}",
                cluster.n_gpus
            ));
        }
        let opts = RunOpts {
            seed: self.seed,
            no_preemption: self.no_preemption,
            known_lengths: self.known_lengths,
            noise_sigma: self.noise_sigma,
            threads: self.threads,
            sim_cache: self.sim_cache,
            online_refinement: self.online_refinement,
            replan_threshold: self.replan_threshold,
            online_weight: self.online_weight,
            admit,
            oversubscribe: self.oversubscribe,
            h2d_bw: self.h2d_bw,
            fast_step: self.fast_step,
            search_budget: self.search_budget,
            sequential_measured: self.sequential_measured,
        };
        Ok(SamuLlm {
            ctx: RunContext::new(&cluster, self.seed),
            policy,
            backend,
            artifacts,
            opts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_policy_name() {
        assert!(SamuLlm::builder().policy("nope").build().is_err());
        let s = SamuLlm::builder().policy("samullm").build().unwrap();
        assert_eq!(s.policy_name(), "ours");
        assert_eq!(s.backend_name(), "sim");
        assert_eq!(s.seed(), 42);
    }

    #[test]
    fn builder_validates_backend_name_and_artifacts() {
        assert!(SamuLlm::builder().backend("cuda").build().is_err());
        let s = SamuLlm::builder().backend("virtual").build().unwrap();
        assert_eq!(s.backend_name(), "sim");
        // pjrt without artifacts fails up-front with a pointer to `make
        // artifacts` (the CI container never has them).
        let missing = std::path::Path::new("/definitely/not/here");
        let err = SamuLlm::builder()
            .backend("pjrt")
            .artifacts_dir(missing)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn explicit_sim_backend_is_the_default_path() {
        // backend("sim") and the default must be the same code path with
        // bit-identical results.
        let spec = AppSpec::ensembling(50, 128);
        let a = SamuLlm::builder().gpus(8).seed(9).build().unwrap().run(&spec).unwrap();
        let b = SamuLlm::builder()
            .gpus(8)
            .seed(9)
            .backend("sim")
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(a.backend, "sim");
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(a.n_stages, b.n_stages);
        assert!(a.measured.is_none());
        // The unified event stream reaches the report for the sim backend.
        assert!(a.timeline.iter().map(|s| s.events.completions).sum::<u64>() > 0);
    }

    #[test]
    fn builder_validates_gpu_count_without_panicking() {
        assert!(SamuLlm::builder().gpus(6).build().is_err());
        assert!(SamuLlm::builder().gpus(0).build().is_err());
        let s = SamuLlm::builder().gpus(4).build().unwrap();
        assert_eq!(s.cluster().n_gpus, 4);
    }

    #[test]
    fn session_runs_a_small_spec() {
        let session = SamuLlm::builder().gpus(8).policy("min").seed(3).build().unwrap();
        let spec = AppSpec::ensembling(60, 128);
        let r = session.run(&spec).unwrap();
        assert_eq!(r.policy, "min-heuristic");
        assert!(r.inference_time > 0.0);
        assert!(r.n_stages >= 1);
    }

    #[test]
    fn planner_knobs_do_not_change_results() {
        // threads / sim_cache steer search wall-clock only: virtual-time
        // results must be bit-identical across every configuration.
        let spec = AppSpec::ensembling(60, 128);
        let run = |threads: usize, cache: bool| {
            SamuLlm::builder()
                .gpus(8)
                .seed(3)
                .threads(threads)
                .sim_cache(cache)
                .build()
                .unwrap()
                .run(&spec)
                .unwrap()
        };
        let base = run(1, false);
        for (threads, cache) in [(2, false), (4, true), (0, true)] {
            let r = run(threads, cache);
            assert_eq!(r.inference_time.to_bits(), base.inference_time.to_bits());
            assert_eq!(
                r.estimated_inference_time.to_bits(),
                base.estimated_inference_time.to_bits()
            );
            assert_eq!(r.n_stages, base.n_stages);
        }
    }

    #[test]
    fn session_sim_cache_reuses_planning_across_runs() {
        // One session, same spec twice: the second search must be served
        // entirely from the shared cache (and change nothing).
        let session = SamuLlm::builder().gpus(8).policy("ours").seed(3).build().unwrap();
        let spec = AppSpec::ensembling(60, 128);
        let r1 = session.run(&spec).unwrap();
        let r2 = session.run(&spec).unwrap();
        assert_eq!(r1.inference_time.to_bits(), r2.inference_time.to_bits());
        assert!(r1.planner.cache_misses > 0);
        assert_eq!(r2.planner.cache_misses, 0, "{:?}", r2.planner);
        assert!(r2.planner.cache_hits > 0);
    }

    #[test]
    fn online_refinement_off_is_the_frozen_path_bit_for_bit() {
        // The feedback loop is opt-in: an explicit `false` (the default)
        // must leave every number untouched, and the report must carry no
        // online section.
        let spec = AppSpec::ensembling(60, 128);
        let a = SamuLlm::builder().gpus(8).seed(3).build().unwrap().run(&spec).unwrap();
        let b = SamuLlm::builder()
            .gpus(8)
            .seed(3)
            .online_refinement(false)
            .replan_threshold(0.01)
            .online_weight(1000.0)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(a.n_stages, b.n_stages);
        assert!(a.online.is_none() && b.online.is_none());
    }

    #[test]
    fn online_refinement_runs_are_deterministic_and_reported() {
        let spec = AppSpec::ensembling(60, 128);
        let run = || {
            SamuLlm::builder()
                .gpus(8)
                .seed(3)
                .online_refinement(true)
                .build()
                .unwrap()
                .run(&spec)
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(a.n_stages, b.n_stages);
        let (oa, ob) = (a.online.expect("online stats"), b.online.expect("online stats"));
        assert_eq!(oa.replans, ob.replans);
        assert_eq!(oa.drift.to_bits(), ob.drift.to_bits());
        assert!(oa.pre_est_total > 0.0);
        // The JSON contract carries the section.
        assert!(a.to_json().contains("\"online\":{"), "{}", a.to_json());
    }

    #[test]
    fn builder_validates_admit_policy_name() {
        assert!(SamuLlm::builder().admit_policy("nope").build().is_err());
        assert!(SamuLlm::builder().admit_policy("multi-bin:0").build().is_err());
        for good in ["fcfs", "spjf", "multi-bin:3", "skip-join:2:10"] {
            assert!(SamuLlm::builder().admit_policy(good).build().is_ok(), "{good}");
        }
    }

    #[test]
    fn explicit_fcfs_admission_is_the_default_path_bit_for_bit() {
        // The admission layer is opt-in: an explicit "fcfs" must leave
        // every virtual-time number untouched and report zero counters.
        let spec = AppSpec::ensembling(60, 128);
        let a = SamuLlm::builder().gpus(8).seed(3).build().unwrap().run(&spec).unwrap();
        let b = SamuLlm::builder()
            .gpus(8)
            .seed(3)
            .admit_policy("fcfs")
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(
            a.estimated_inference_time.to_bits(),
            b.estimated_inference_time.to_bits()
        );
        assert_eq!(a.n_stages, b.n_stages);
        assert_eq!(a.admit_policy, "fcfs");
        assert_eq!(a.admission, b.admission);
        assert_eq!(a.admission.queue_jumps, 0);
        assert!(a.to_json().contains("\"admission\":{"), "{}", a.to_json());
    }

    #[test]
    fn non_fcfs_admission_completes_and_reports_counters() {
        let spec = AppSpec::ensembling(60, 128);
        for admit in ["spjf", "multi-bin:4", "skip-join:4:5"] {
            let r = SamuLlm::builder()
                .gpus(8)
                .seed(3)
                .admit_policy(admit)
                .build()
                .unwrap()
                .run(&spec)
                .unwrap();
            assert!(r.inference_time > 0.0, "{admit}");
            assert!(r.admit_policy.starts_with(admit.split(':').next().unwrap()), "{admit}");
            // Every request still completes — admission only reorders.
            assert!(
                r.timeline.iter().map(|s| s.events.completions).sum::<u64>() >= 60,
                "{admit}"
            );
        }
    }

    #[test]
    fn builder_validates_h2d_bandwidth() {
        assert!(SamuLlm::builder().h2d_bw(0.0).build().is_err());
        assert!(SamuLlm::builder().h2d_bw(-1.0).build().is_err());
        assert!(SamuLlm::builder().h2d_bw(25.0e9).build().is_ok());
    }

    #[test]
    fn builder_validates_search_budget() {
        assert!(SamuLlm::builder().search_budget(0.0).build().is_err());
        assert!(SamuLlm::builder().search_budget(-2.0).build().is_err());
        assert!(SamuLlm::builder().search_budget(f64::NAN).build().is_err());
        assert!(SamuLlm::builder().search_budget(0.25).build().is_ok());
        // Infinity is a valid spelling of "unbudgeted".
        assert!(SamuLlm::builder().search_budget(f64::INFINITY).build().is_ok());
    }

    #[test]
    fn fast_step_off_is_bit_identical() {
        // The aggregated decode path is exact: disabling it must change
        // no reported number, only simulation wall-clock.
        let spec = AppSpec::ensembling(60, 128);
        let a = SamuLlm::builder().gpus(8).seed(3).build().unwrap().run(&spec).unwrap();
        let b = SamuLlm::builder()
            .gpus(8)
            .seed(3)
            .fast_step(false)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(
            a.estimated_inference_time.to_bits(),
            b.estimated_inference_time.to_bits()
        );
        assert_eq!(a.n_stages, b.n_stages);
        for (sa, sb) in a.timeline.iter().zip(&b.timeline) {
            assert_eq!(sa.events, sb.events, "per-stage event summaries must agree");
        }
    }

    #[test]
    fn infinite_search_budget_is_bit_identical() {
        let spec = AppSpec::ensembling(60, 128);
        let a = SamuLlm::builder().gpus(8).seed(3).build().unwrap().run(&spec).unwrap();
        let b = SamuLlm::builder()
            .gpus(8)
            .seed(3)
            .search_budget(f64::INFINITY)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(
            a.estimated_inference_time.to_bits(),
            b.estimated_inference_time.to_bits()
        );
        assert_eq!(a.n_stages, b.n_stages);
        assert!(!b.planner.budget_exhausted);
    }

    #[test]
    fn tiny_search_budget_still_completes_the_run() {
        let spec = AppSpec::ensembling(60, 128);
        let r = SamuLlm::builder()
            .gpus(8)
            .seed(3)
            .search_budget(1e-9)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert!(r.planner.budget_exhausted, "{:?}", r.planner);
        assert!(r.inference_time > 0.0);
        // Everything drained through the best-so-far plan.
        assert!(r.timeline.iter().map(|s| s.events.completions).sum::<u64>() >= 60);
        assert!(r.to_json().contains("\"budget_exhausted\":true"), "{}", r.to_json());
    }

    #[test]
    fn oversubscribe_on_a_fitting_workload_is_bit_identical() {
        // The switch is consulted only when a stage overcommits HBM; a
        // workload that fits must stay untouched, counters all zero.
        let spec = AppSpec::ensembling(60, 128);
        let a = SamuLlm::builder().gpus(8).seed(3).build().unwrap().run(&spec).unwrap();
        let b = SamuLlm::builder()
            .gpus(8)
            .seed(3)
            .oversubscribe(true)
            .build()
            .unwrap()
            .run(&spec)
            .unwrap();
        assert_eq!(a.inference_time.to_bits(), b.inference_time.to_bits());
        assert_eq!(
            a.estimated_inference_time.to_bits(),
            b.estimated_inference_time.to_bits()
        );
        assert_eq!(a.n_stages, b.n_stages);
        assert_eq!(b.residency.swaps_in, 0);
        assert_eq!(b.residency.swaps_out, 0);
        assert!(a.to_json().contains("\"residency\":{"), "{}", a.to_json());
    }

    #[test]
    fn session_runs_a_two_app_workload() {
        use crate::spec::WorkloadEntry;
        let session = SamuLlm::builder().gpus(8).seed(4).build().unwrap();
        let wl = WorkloadSpec::new(vec![
            WorkloadEntry::new(AppSpec::chain_summary(6, 1, 200)),
            WorkloadEntry::new(AppSpec::ensembling(30, 96)),
        ]);
        let r = session.run_workload(&wl).unwrap();
        assert_eq!(r.scenario, "workload-2apps");
        assert!(r.inference_time > 0.0);
        let w = r.workload.expect("workload runs carry the per-app section");
        assert_eq!(w.per_app.len(), 2);
        assert_eq!(w.arrivals, 0, "both apps present at start");
        for a in &w.per_app {
            assert_eq!(a.completed, a.n_requests, "run completed everything");
            assert!(a.makespan > 0.0);
            assert!(a.finish <= r.inference_time + 1e-9);
        }
        // Node id ranges are disjoint between the app instances.
        assert!(w.per_app[0].nodes.iter().all(|n| !w.per_app[1].nodes.contains(n)));
        // The JSON contract carries the section.
        assert!(r.to_json().contains("\"workload\":{"), "{}", r.to_json());
    }

    #[test]
    fn session_runs_open_loop_traffic() {
        let session = SamuLlm::builder().gpus(8).seed(7).build().unwrap();
        let spec = crate::harness::poisson_pair_traffic(1.0, 1.0, 2.0, 10.0);
        let r = session.run_traffic(&spec).unwrap();
        assert!(r.scenario.starts_with("poisson-pair"));
        let t = r.traffic.expect("traffic runs carry the serving section");
        assert_eq!(t.per_app.len(), 2);
        assert_eq!(t.offered, t.admitted + t.rejected);
        assert!(r.to_json().contains("\"traffic\":{"), "{}", r.to_json());
        // Batch runs stay traffic-free.
        let plain = session.run(&AppSpec::ensembling(30, 96)).unwrap();
        assert!(plain.traffic.is_none());
        assert!(plain.to_json().contains("\"traffic\":null"));
    }

    #[test]
    fn compare_runs_each_policy_once() {
        let session = SamuLlm::builder().seed(5).build().unwrap();
        let spec = AppSpec::ensembling(50, 128);
        let reports = session.compare(&spec, &policy::PAPER).unwrap();
        assert_eq!(reports.len(), 3);
        let names: Vec<&str> = reports.iter().map(|r| r.policy.as_str()).collect();
        assert_eq!(names, vec!["ours", "max-heuristic", "min-heuristic"]);
    }
}
