//! GPU placement with the §4.3 minimum-reload rule.
//!
//! Tensor-parallel groups occupy *aligned* power-of-two GPU blocks so that
//! tp=2 groups always coincide with NVLink pairs (the paper's example: a
//! tp=2 model may load on GPUs 0–1 or 2–3, never 1–2). When a new stage
//! starts, replicas that keep their `(owner, tp)` shape stay where they
//! are; everything else is (re)loaded into free blocks, and the stage pays
//! the loading time of the slowest newly-loaded replica (loads proceed in
//! parallel on disjoint GPUs).
//!
//! Owners are opaque ids (application *nodes*, not model names — the same
//! LLM may appear at two different nodes and must be two instances).

use super::ClusterSpec;

/// One replica pinned to the aligned GPU block `[start, start+tp)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Group {
    /// Owning application node.
    pub owner: u64,
    /// Tensor-parallel degree (= block width in GPUs).
    pub tp: u32,
    /// First GPU of the aligned block.
    pub start: u32,
}

impl Group {
    /// The GPU ids this group occupies.
    pub fn gpus(&self) -> impl Iterator<Item = u32> + '_ {
        self.start..self.start + self.tp
    }
}

/// Assignment of replicas to GPU blocks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Placement {
    /// Cluster GPU count.
    pub n_gpus: u32,
    /// Placed replica groups.
    pub groups: Vec<Group>,
}

/// Outcome of a stage transition: the new placement, which replicas must be
/// (re)loaded, and the wall-clock loading cost per owner.
#[derive(Debug, Clone)]
pub struct ReloadPlan {
    /// The placement after the transition.
    pub placement: Placement,
    /// Replicas that had to be (re)loaded.
    pub new_groups: Vec<Group>,
    /// Max load time across newly loaded replicas (loads are parallel).
    pub load_time: f64,
    /// Per-owner load time (0 for owners whose replicas were all kept).
    pub load_time_by_owner: std::collections::HashMap<u64, f64>,
}

impl Placement {
    /// A placement with every GPU free.
    pub fn empty(n_gpus: u32) -> Self {
        Placement { n_gpus, groups: vec![] }
    }

    /// Per-GPU occupancy bitmap.
    pub fn occupied(&self) -> Vec<bool> {
        let mut m = vec![false; self.n_gpus as usize];
        for g in &self.groups {
            for gpu in g.gpus() {
                m[gpu as usize] = true;
            }
        }
        m
    }

    /// GPUs currently occupied by some replica.
    pub fn gpus_used(&self) -> u32 {
        self.groups.iter().map(|g| g.tp).sum()
    }

    /// All placements must keep groups on aligned blocks inside the node.
    pub fn is_valid(&self, cluster: &ClusterSpec) -> bool {
        if self.n_gpus != cluster.n_gpus {
            return false;
        }
        let mut occ = vec![false; self.n_gpus as usize];
        for g in &self.groups {
            if !g.tp.is_power_of_two() || g.start % g.tp != 0 || g.start + g.tp > self.n_gpus {
                return false;
            }
            for gpu in g.gpus() {
                if occ[gpu as usize] {
                    return false; // overlap
                }
                occ[gpu as usize] = true;
            }
        }
        true
    }

    /// Find the lowest free aligned block of size `tp`, if any.
    fn find_block(occ: &[bool], tp: u32) -> Option<u32> {
        let n = occ.len() as u32;
        let mut start = 0;
        while start + tp <= n {
            if (start..start + tp).all(|g| !occ[g as usize]) {
                return Some(start);
            }
            start += tp; // aligned scan
        }
        None
    }

    /// Transition to a stage requiring `needs` = [(owner, dp, tp)], with
    /// `load_time(owner, tp)` giving the profiled loading cost.
    ///
    /// Returns `None` only if the request cannot fit the node at all.
    /// Minimum-reload policy: keep every replica whose `(owner, tp)`
    /// matches the previous placement, then first-fit the rest; if
    /// fragmentation from kept groups blocks allocation, retry from an
    /// empty node (full reload) before giving up.
    pub fn transition(
        prev: &Placement,
        needs: &[(u64, u32, u32)],
        cluster: &ClusterSpec,
        load_time: &dyn Fn(u64, u32) -> f64,
    ) -> Option<ReloadPlan> {
        let total: u32 = needs.iter().map(|(_, dp, tp)| dp * tp).sum();
        if total > cluster.n_gpus {
            return None;
        }
        Self::transition_keeping(prev, needs, cluster, load_time).or_else(|| {
            Self::transition_keeping(&Placement::empty(cluster.n_gpus), needs, cluster, load_time)
        })
    }

    fn transition_keeping(
        prev: &Placement,
        needs: &[(u64, u32, u32)],
        cluster: &ClusterSpec,
        load_time: &dyn Fn(u64, u32) -> f64,
    ) -> Option<ReloadPlan> {
        let mut kept: Vec<Group> = vec![];
        let mut pending: Vec<(u64, u32)> = vec![];
        let mut available: Vec<Group> = prev.groups.clone();

        for (owner, dp, tp) in needs {
            for _ in 0..*dp {
                if let Some(i) =
                    available.iter().position(|g| g.owner == *owner && g.tp == *tp)
                {
                    kept.push(available.remove(i));
                } else {
                    pending.push((*owner, *tp));
                }
            }
        }

        let mut placement = Placement { n_gpus: cluster.n_gpus, groups: kept };
        let mut occ = placement.occupied();
        pending.sort_by(|a, b| b.1.cmp(&a.1)); // largest groups first
        let mut new_groups = vec![];
        for (owner, tp) in pending {
            let start = Self::find_block(&occ, tp)?;
            let g = Group { owner, tp, start };
            for gpu in g.gpus() {
                occ[gpu as usize] = true;
            }
            new_groups.push(g);
            placement.groups.push(g);
        }

        let mut by_owner = std::collections::HashMap::new();
        let mut max_load = 0.0f64;
        for g in &new_groups {
            let t = load_time(g.owner, g.tp);
            let e = by_owner.entry(g.owner).or_insert(0.0f64);
            *e = e.max(t);
            max_load = max_load.max(t);
        }
        debug_assert!(placement.is_valid(cluster));
        Some(ReloadPlan {
            placement,
            new_groups,
            load_time: max_load,
            load_time_by_owner: by_owner,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Registry;

    fn setup() -> ClusterSpec {
        ClusterSpec::a100_node(8)
    }

    fn loader() -> impl Fn(u64, u32) -> f64 {
        let reg = Registry::paper();
        move |owner, tp| {
            let names =
                ["chatglm3-6b", "vicuna-13b-v1.5", "llama-2-70b-chat", "mistral-7b-instruct"];
            reg.get(names[(owner as usize) % names.len()]).unwrap().load_time(tp)
        }
    }

    #[test]
    fn fresh_allocation_loads_everything() {
        let c = setup();
        let lt = loader();
        let plan = Placement::transition(
            &Placement::empty(8),
            &[(0, 2, 1), (1, 1, 2)],
            &c,
            &lt,
        )
        .unwrap();
        assert_eq!(plan.new_groups.len(), 3);
        assert!(plan.load_time > 0.0);
        assert!(plan.placement.is_valid(&c));
        assert_eq!(plan.placement.gpus_used(), 4);
        assert_eq!(plan.load_time_by_owner.len(), 2);
    }

    #[test]
    fn unchanged_replicas_are_kept_free() {
        let c = setup();
        let lt = loader();
        let first = Placement::transition(&Placement::empty(8), &[(0, 4, 2)], &c, &lt).unwrap();
        let second = Placement::transition(&first.placement, &[(0, 4, 2)], &c, &lt).unwrap();
        assert!(second.new_groups.is_empty());
        assert_eq!(second.load_time, 0.0);
        assert_eq!(second.placement, first.placement);
    }

    #[test]
    fn tp2_groups_sit_on_nvlink_pairs() {
        let c = setup();
        let lt = loader();
        let plan = Placement::transition(&Placement::empty(8), &[(1, 4, 2)], &c, &lt).unwrap();
        for g in &plan.placement.groups {
            assert_eq!(g.start % 2, 0, "tp=2 must start on an even GPU");
            let gpus: Vec<u32> = g.gpus().collect();
            assert!(c.nvlinked(gpus[0], gpus[1]));
        }
    }

    #[test]
    fn overflow_is_rejected() {
        let c = setup();
        let lt = loader();
        assert!(Placement::transition(&Placement::empty(8), &[(0, 9, 1)], &c, &lt).is_none());
    }

    #[test]
    fn fragmentation_falls_back_to_full_reload() {
        let c = setup();
        let lt = loader();
        let a = Placement::transition(&Placement::empty(8), &[(0, 6, 1)], &c, &lt).unwrap();
        let b = Placement::transition(&a.placement, &[(2, 1, 8)], &c, &lt).unwrap();
        assert_eq!(b.placement.groups.len(), 1);
        assert_eq!(b.placement.groups[0].tp, 8);
    }

    #[test]
    fn partial_keep_counts_only_new_loads() {
        let c = setup();
        let lt = loader();
        let a = Placement::transition(&Placement::empty(8), &[(0, 2, 1)], &c, &lt).unwrap();
        let b = Placement::transition(&a.placement, &[(0, 2, 1), (3, 1, 2)], &c, &lt).unwrap();
        assert_eq!(b.new_groups.len(), 1);
        assert_eq!(b.new_groups[0].owner, 3);
        assert_eq!(b.load_time_by_owner.get(&0), None);
        assert!(b.load_time_by_owner[&3] > 0.0);
    }

    #[test]
    fn same_model_two_nodes_are_distinct_instances() {
        // Owner 0 and owner 4 may run the same LLM; keeping owner 0's
        // replica must not satisfy owner 4's need.
        let c = setup();
        let lt = loader();
        let a = Placement::transition(&Placement::empty(8), &[(0, 1, 1)], &c, &lt).unwrap();
        let b = Placement::transition(&a.placement, &[(4, 1, 1)], &c, &lt).unwrap();
        assert_eq!(b.new_groups.len(), 1);
        assert_eq!(b.new_groups[0].owner, 4);
    }
}
