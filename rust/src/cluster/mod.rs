//! Simulated single-node multi-GPU cluster (the paper's 8×A100-80G testbed).
//!
//! The scheduling problem consumes only: GPU count, per-GPU memory, which
//! GPU sets may form a tensor-parallel group (NVLink constraint), and the
//! interconnect bandwidths that feed the cost model. This module provides
//! that inventory plus the §4.3 minimum-reload placement solver.

pub mod placement;

pub use placement::{Placement, ReloadPlan};

/// Hardware description of the node.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Number of GPUs on the node (a power of two).
    pub n_gpus: u32,
    /// Usable HBM per GPU in bytes (80 GB minus runtime reserve).
    pub mem_bytes: u64,
    /// HBM bandwidth per GPU (bytes/s).
    pub hbm_bw: f64,
    /// Dense bf16/fp16 peak per GPU (FLOP/s).
    pub peak_flops: f64,
    /// NVLink bandwidth within a linked pair (bytes/s, per direction).
    pub nvlink_bw: f64,
    /// PCIe bandwidth between unlinked GPUs (bytes/s).
    pub pcie_bw: f64,
    /// Host-to-device weight-transfer bandwidth (bytes/s): what a warm
    /// (host-cached) model swap-in pays per GPU. Effective PCIe gen4
    /// throughput, below the link peak.
    pub h2d_bw: f64,
    /// Device-to-host offload bandwidth (bytes/s): what a proactive
    /// weight evict pays per GPU. Slightly below `h2d_bw` on A100 hosts.
    pub d2h_bw: f64,
}

impl ClusterSpec {
    /// The paper's testbed: `n` A100-80G GPUs, NVLink in adjacent pairs
    /// (GPU 0–1, 2–3, …), PCIe across pairs.
    pub fn a100_node(n: u32) -> Self {
        assert!(n.is_power_of_two() && n >= 1, "gpu count must be a power of two");
        ClusterSpec {
            n_gpus: n,
            mem_bytes: (80u64 << 30) - (6u64 << 30), // 6 GB runtime reserve
            hbm_bw: 2.0e12,
            peak_flops: 312.0e12,
            nvlink_bw: 300.0e9,
            pcie_bw: 32.0e9,
            h2d_bw: 26.0e9,
            d2h_bw: 22.0e9,
        }
    }

    /// Whether two GPUs share an NVLink (adjacent even/odd pair).
    pub fn nvlinked(&self, a: u32, b: u32) -> bool {
        a / 2 == b / 2 && a != b
    }

    /// Effective all-reduce bandwidth for a TP group of size `tp` rooted at
    /// an aligned block. `tp<=2` stays inside an NVLink pair; larger groups
    /// bottleneck on PCIe hops across pairs.
    pub fn tp_group_bw(&self, tp: u32) -> f64 {
        match tp {
            0 | 1 => f64::INFINITY,
            2 => self.nvlink_bw,
            _ => self.pcie_bw,
        }
    }

    /// Valid tensor-parallel degrees on this node. TP groups are aligned
    /// power-of-two blocks so tp=2 groups always coincide with NVLink pairs
    /// (the paper's placement rule, §4.3).
    pub fn valid_tp(&self) -> Vec<u32> {
        let mut v = vec![];
        let mut tp = 1;
        while tp <= self.n_gpus {
            v.push(tp);
            tp *= 2;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_node_shape() {
        let c = ClusterSpec::a100_node(8);
        assert_eq!(c.n_gpus, 8);
        assert_eq!(c.valid_tp(), vec![1, 2, 4, 8]);
        assert!(c.mem_bytes > 70 << 30);
    }

    #[test]
    fn nvlink_pairs_are_adjacent() {
        let c = ClusterSpec::a100_node(8);
        assert!(c.nvlinked(0, 1));
        assert!(c.nvlinked(3, 2));
        assert!(!c.nvlinked(1, 2));
        assert!(!c.nvlinked(0, 0));
        assert!(!c.nvlinked(0, 7));
    }

    #[test]
    fn host_link_bandwidths_are_ordered() {
        // Swap economics only make sense when host links are far slower
        // than HBM and d2h is no faster than h2d.
        let c = ClusterSpec::a100_node(8);
        assert!(c.h2d_bw > 0.0 && c.d2h_bw > 0.0);
        assert!(c.d2h_bw <= c.h2d_bw);
        assert!(c.h2d_bw < c.hbm_bw / 10.0);
    }

    #[test]
    fn tp_bandwidth_tiers() {
        let c = ClusterSpec::a100_node(8);
        assert!(c.tp_group_bw(1).is_infinite());
        assert_eq!(c.tp_group_bw(2), c.nvlink_bw);
        assert_eq!(c.tp_group_bw(4), c.pcie_bw);
        assert_eq!(c.tp_group_bw(8), c.pcie_bw);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        ClusterSpec::a100_node(6);
    }
}
