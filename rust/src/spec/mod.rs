//! Declarative scenario descriptions ([`AppSpec`]) and the app-builder
//! registry — the single place where application names become runnable
//! [`Scenario`]s.
//!
//! An `AppSpec` fully describes *what* to run: either one of the paper's
//! four applications with its parameters, or a user-defined computation
//! graph (`Custom`) whose nodes carry their own workload generators. It
//! serialises via [`crate::util::json`] so arbitrary applications can be
//! replayed from a small JSON file (`samullm config app.json`), and it
//! materialises into a [`Scenario`] with [`AppSpec::build`] — the one
//! match block in the codebase that constructs application graphs.
//!
//! The CLI goes through [`from_cli`], which looks the app name up in the
//! [`builders`] registry; each [`AppBuilder`] applies its own defaults and
//! *rejects* knobs that don't apply to it (no silently-dropped flags).
//!
//! Two composite specs sit on top of `AppSpec`: [`WorkloadSpec`] (a fixed
//! batch of N application instances, jointly planned) and [`TrafficSpec`]
//! (open-loop serving: per-app arrival processes feeding a bounded
//! admission queue — see [`crate::traffic`]).

pub mod traffic;
pub mod workload;

pub use traffic::{ArrivalSpec, TrafficEntry, TrafficSpec};
pub use workload::{WorkloadEntry, WorkloadSpec};

use anyhow::{anyhow, Result};

use crate::apps::{chain_summary, ensembling, mixed, routing};
use crate::graph::AppGraph;
use crate::models::Registry;
use crate::runner::{AppRequest, Scenario};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::lengths;

/// A declarative description of a multi-LLM application scenario.
///
/// The four builtin variants mirror the paper's §5 applications and
/// delegate to the exact seed builders, so a spec plus a seed reproduces
/// the published workloads bit-for-bit. `Custom` opens the framework to
/// arbitrary graphs: any registry models, any edges, per-node workload
/// generators.
#[derive(Debug, Clone, PartialEq)]
pub enum AppSpec {
    /// §5.1: every model answers every request.
    Ensembling {
        /// Number of ensembling requests.
        n_requests: usize,
        /// Output-length limit.
        max_out: u32,
    },
    /// §5.2: each request goes to its best model (Table 1 ratios). The
    /// `known_lengths` flag turns on the §5.5 known-output-length mode
    /// for the whole run (honoured by [`crate::session::SamuLlm::run`]).
    Routing {
        /// Output-length limit.
        max_out: u32,
        /// Run with true output lengths (§5.5 mode for the whole run).
        known_lengths: bool,
    },
    /// §5.3: chunked document summarization + summary evaluation.
    ChainSummary {
        /// Number of documents to summarize.
        n_docs: usize,
        /// Evaluations per document summary.
        eval_times: u32,
        /// Summarizer output-length limit.
        max_out: u32,
    },
    /// §5.4: chain summary + ensembling run as one application. A compat
    /// alias over the workload layer: materialises as the 2-entry
    /// [`crate::apps::mixed::workload_spec`] composition (bit-identical
    /// to the seed's hand-merged graph).
    Mixed {
        /// Number of chain-summary documents.
        n_docs: usize,
        /// Number of ensembling requests.
        n_ensemble_requests: usize,
        /// Summarizer output-length limit.
        summary_max_out: u32,
        /// Ensembling output-length limit.
        ensemble_max_out: u32,
        /// Evaluations per document summary.
        eval_times: u32,
    },
    /// A user-defined computation graph: nodes with per-node workload
    /// generators plus data-flow edges (producer, consumer).
    Custom {
        /// Scenario name (defaults to "custom" when empty).
        name: String,
        /// The graph's LLM nodes.
        nodes: Vec<NodeSpec>,
        /// Data-flow edges (producer index, consumer index).
        edges: Vec<(usize, usize)>,
    },
}

/// One node of a [`AppSpec::Custom`] graph.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    /// Registry name of the LLM this node runs (see [`Registry::paper`]).
    pub model: String,
    /// Human-readable role label.
    pub label: String,
    /// Output-length limit applied to this node's requests.
    pub max_out: u32,
    /// How this node's requests are produced.
    pub workload: WorkloadGen,
}

/// Per-node workload generator for custom graphs.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadGen {
    /// Explicit request list (replayed traces); ids are assigned by
    /// position. Output lengths are clamped to the node's `max_out` and
    /// the model's context window.
    Explicit {
        /// The requests, in submission order.
        requests: Vec<RequestSpec>,
    },
    /// `n_requests` synthetic requests: input lengths uniform in
    /// `[input_min, input_max]`, true output lengths drawn from the
    /// model's No-Robots-style length distribution capped at `max_out`.
    Synthetic {
        /// Number of requests to generate.
        n_requests: usize,
        /// Minimum input length (inclusive).
        input_min: u32,
        /// Maximum input length (inclusive).
        input_max: u32,
    },
}

/// One explicit request of [`WorkloadGen::Explicit`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpec {
    /// Prompt length in tokens (clamped to ≥ 1).
    pub input_len: u32,
    /// Ground-truth output length (clamped to the node's `max_out` and
    /// the model's context window).
    pub output_len: u32,
}

// ---------------------------------------------------------------------------
// Convenience constructors (the harness and examples build specs with these).
// ---------------------------------------------------------------------------

impl AppSpec {
    /// The §5.1 ensembling app: every model answers every request.
    pub fn ensembling(n_requests: usize, max_out: u32) -> AppSpec {
        AppSpec::Ensembling { n_requests, max_out }
    }

    /// The §5.2 routing app over the fixed RouterBench dataset.
    pub fn routing(max_out: u32, known_lengths: bool) -> AppSpec {
        AppSpec::Routing { max_out, known_lengths }
    }

    /// The §5.3 chain-summary app (summarize chunks, then evaluate).
    pub fn chain_summary(n_docs: usize, eval_times: u32, max_out: u32) -> AppSpec {
        AppSpec::ChainSummary { n_docs, eval_times, max_out }
    }

    /// The §5.4 mixed app: chain summary + ensembling as one graph.
    pub fn mixed(
        n_docs: usize,
        n_ensemble_requests: usize,
        summary_max_out: u32,
        ensemble_max_out: u32,
        eval_times: u32,
    ) -> AppSpec {
        AppSpec::Mixed {
            n_docs,
            n_ensemble_requests,
            summary_max_out,
            ensemble_max_out,
            eval_times,
        }
    }

    /// The spec's kind name as the CLI registry spells it. Note the JSON
    /// `kind` field canonically uses `chain_summary` (underscore) for
    /// [`AppSpec::ChainSummary`]; [`AppSpec::from_json`] accepts both
    /// spellings.
    pub fn kind(&self) -> &'static str {
        match self {
            AppSpec::Ensembling { .. } => "ensembling",
            AppSpec::Routing { .. } => "routing",
            AppSpec::ChainSummary { .. } => "chain-summary",
            AppSpec::Mixed { .. } => "mixed",
            AppSpec::Custom { .. } => "custom",
        }
    }

    /// Whether this spec asks for the known-output-lengths ablation mode.
    pub fn wants_known_lengths(&self) -> bool {
        matches!(self, AppSpec::Routing { known_lengths: true, .. })
    }
}

// ---------------------------------------------------------------------------
// Materialisation: AppSpec -> Scenario.
// ---------------------------------------------------------------------------

impl AppSpec {
    /// Materialise the spec into a runnable [`Scenario`]. The builtin
    /// variants call the seed app builders verbatim, so results are
    /// bit-identical to the pre-spec code paths for the same seed.
    pub fn build(&self, seed: u64) -> Result<Scenario> {
        Ok(match self {
            AppSpec::Ensembling { n_requests, max_out } => {
                ensembling::build(*n_requests, *max_out, seed)
            }
            AppSpec::Routing { max_out, .. } => routing::build(*max_out, seed),
            AppSpec::ChainSummary { n_docs, eval_times, max_out } => {
                chain_summary::build(*n_docs, *eval_times, *max_out, seed)
            }
            AppSpec::Mixed {
                n_docs,
                n_ensemble_requests,
                summary_max_out,
                ensemble_max_out,
                eval_times,
            } => mixed::build(
                *n_docs,
                *n_ensemble_requests,
                *summary_max_out,
                *ensemble_max_out,
                *eval_times,
                seed,
            ),
            AppSpec::Custom { name, nodes, edges } => build_custom(name, nodes, edges, seed)?,
        })
    }
}

/// Materialise a custom graph spec (validated; never panics on bad input).
fn build_custom(
    name: &str,
    nodes: &[NodeSpec],
    edges: &[(usize, usize)],
    seed: u64,
) -> Result<Scenario> {
    if nodes.is_empty() {
        return Err(anyhow!("custom spec needs at least one node"));
    }
    let registry = Registry::paper();
    for &(f, t) in edges {
        if f >= nodes.len() || t >= nodes.len() {
            return Err(anyhow!("edge ({f},{t}) out of range for {} nodes", nodes.len()));
        }
        if f == t {
            return Err(anyhow!(
                "self-loop edge ({f},{f}): fuse self-loops into request chains instead"
            ));
        }
    }
    let mut graph = AppGraph::default();
    let mut workloads: Vec<Vec<AppRequest>> = vec![];
    let shift = lengths::dataset_shift(seed ^ 0xC057);
    for (i, node) in nodes.iter().enumerate() {
        let spec = registry.get(&node.model).ok_or_else(|| {
            anyhow!(
                "node {i}: unknown model {:?} (known: {})",
                node.model,
                registry.names().join(", ")
            )
        })?;
        if node.max_out == 0 {
            return Err(anyhow!("node {i}: max_out must be positive"));
        }
        graph.add_node(&node.model, &node.label, node.max_out);
        let mut rng = Rng::new(seed ^ 0xC057_0000 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let window = |input_len: u32| spec.max_seq.saturating_sub(input_len).max(1);
        let reqs: Vec<AppRequest> = match &node.workload {
            WorkloadGen::Explicit { requests } => {
                if requests.is_empty() {
                    return Err(anyhow!("node {i}: explicit workload has no requests"));
                }
                requests
                    .iter()
                    .enumerate()
                    .map(|(id, r)| {
                        let input_len = r.input_len.max(1);
                        let out = r.output_len.min(node.max_out).min(window(input_len)).max(1);
                        AppRequest::simple(id as u64, input_len, out)
                    })
                    .collect()
            }
            WorkloadGen::Synthetic { n_requests, input_min, input_max } => {
                if *n_requests == 0 {
                    return Err(anyhow!("node {i}: synthetic workload needs n_requests > 0"));
                }
                let lo = (*input_min).max(1);
                let hi = (*input_max).max(lo);
                if hi >= spec.max_seq {
                    return Err(anyhow!(
                        "node {i}: input_max {hi} exceeds {}'s context window {}",
                        node.model,
                        spec.max_seq
                    ));
                }
                (0..*n_requests as u64)
                    .map(|id| {
                        let input_len = rng.range_u64(lo as u64, hi as u64 + 1) as u32;
                        let out = lengths::true_output_len(
                            &node.model,
                            shift,
                            input_len,
                            node.max_out,
                            spec.max_seq,
                            &mut rng,
                        );
                        AppRequest::simple(id, input_len, out)
                    })
                    .collect()
            }
        };
        workloads.push(reqs);
    }
    for &(f, t) in edges {
        graph.add_edge(f, t);
    }
    if !graph.is_acyclic() {
        return Err(anyhow!("custom graph has a cycle"));
    }
    let name = if name.is_empty() { "custom".to_string() } else { name.to_string() };
    Ok(Scenario { name, graph, workloads })
}

// ---------------------------------------------------------------------------
// CLI builder registry.
// ---------------------------------------------------------------------------

/// Optional knobs collected from the CLI. Builders apply their own
/// defaults and reject knobs that don't apply to their app, so no flag is
/// ever silently dropped.
#[derive(Debug, Clone, Default)]
pub struct AppParams {
    /// `--n-requests` (ensembling/mixed).
    pub n_requests: Option<usize>,
    /// `--max-out` output-length limit.
    pub max_out: Option<u32>,
    /// `--n-docs` (chain-summary/mixed).
    pub n_docs: Option<usize>,
    /// `--eval-times` (chain-summary/mixed).
    pub eval_times: Option<u32>,
    /// `--known-lengths` (§5.5 ablation; a spec-level mode for routing).
    pub known_lengths: bool,
}

/// A named app builder: CLI params -> [`AppSpec`].
pub struct AppBuilder {
    /// CLI app name.
    pub name: &'static str,
    /// One-line description for `--app ?` help.
    pub about: &'static str,
    /// Build the spec, rejecting inapplicable params.
    pub build: fn(&AppParams) -> Result<AppSpec>,
}

/// All registered app builders, in CLI help order.
pub fn builders() -> &'static [AppBuilder] {
    static BUILDERS: &[AppBuilder] = &[
        AppBuilder {
            name: "ensembling",
            about: "9-model LLM ensembling over MixInstruct-like inputs (§5.1)",
            build: cli_ensembling,
        },
        AppBuilder {
            name: "routing",
            about: "RouterBench routing, Table-1 skew, fixed 6856-request dataset (§5.2)",
            build: cli_routing,
        },
        AppBuilder {
            name: "chain-summary",
            about: "chunked document summarization + evaluation pipeline (§5.3)",
            build: cli_chain_summary,
        },
        AppBuilder {
            name: "mixed",
            about: "chain summary + ensembling as one computation graph (§5.4)",
            build: cli_mixed,
        },
    ];
    BUILDERS
}

/// Registered app names, in help order.
pub fn app_names() -> Vec<&'static str> {
    builders().iter().map(|b| b.name).collect()
}

/// Build a spec for a named app from CLI params (registry lookup — the
/// CLI never matches on app names itself).
pub fn from_cli(app: &str, params: &AppParams) -> Result<AppSpec> {
    let builder = builders()
        .iter()
        .find(|b| b.name == app)
        .ok_or_else(|| anyhow!("unknown app {app} (known: {})", app_names().join("|")))?;
    (builder.build)(params)
}

fn reject(given: bool, app: &str, flag: &str, why: &str) -> Result<()> {
    if given {
        Err(anyhow!("{app} does not accept {flag}: {why}"))
    } else {
        Ok(())
    }
}

fn cli_ensembling(p: &AppParams) -> Result<AppSpec> {
    reject(p.n_docs.is_some(), "ensembling", "--n-docs", "it has no documents")?;
    reject(p.eval_times.is_some(), "ensembling", "--eval-times", "it has no evaluator")?;
    Ok(AppSpec::ensembling(p.n_requests.unwrap_or(1000), p.max_out.unwrap_or(256)))
}

fn cli_routing(p: &AppParams) -> Result<AppSpec> {
    reject(
        p.n_requests.is_some(),
        "routing",
        "--n-requests",
        "it replays the fixed 6856-request RouterBench dataset",
    )?;
    reject(p.n_docs.is_some(), "routing", "--n-docs", "it has no documents")?;
    reject(p.eval_times.is_some(), "routing", "--eval-times", "it has no evaluator")?;
    // An explicit --max-out is honoured as given; the seed CLI silently
    // clamped values below 512 up to 512.
    Ok(AppSpec::routing(p.max_out.unwrap_or(512), p.known_lengths))
}

fn cli_chain_summary(p: &AppParams) -> Result<AppSpec> {
    reject(
        p.n_requests.is_some(),
        "chain-summary",
        "--n-requests",
        "its request count follows from --n-docs and --eval-times",
    )?;
    // An explicit --max-out is honoured as given; the seed CLI silently
    // clamped values below 100 up to 100.
    Ok(AppSpec::chain_summary(
        p.n_docs.unwrap_or(100),
        p.eval_times.unwrap_or(2),
        p.max_out.unwrap_or(256),
    ))
}

fn cli_mixed(p: &AppParams) -> Result<AppSpec> {
    Ok(AppSpec::mixed(
        p.n_docs.unwrap_or(100),
        p.n_requests.unwrap_or(1000),
        900,
        p.max_out.unwrap_or(256),
        p.eval_times.unwrap_or(4),
    ))
}

// ---------------------------------------------------------------------------
// JSON (de)serialisation via util::json.
// ---------------------------------------------------------------------------

impl AppSpec {
    /// Serialize to a [`Json`] value (round-trips via [`AppSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        match self {
            AppSpec::Ensembling { n_requests, max_out } => Json::obj(vec![
                ("kind", Json::Str("ensembling".into())),
                ("n_requests", Json::Num(*n_requests as f64)),
                ("max_out", Json::Num(*max_out as f64)),
            ]),
            AppSpec::Routing { max_out, known_lengths } => Json::obj(vec![
                ("kind", Json::Str("routing".into())),
                ("max_out", Json::Num(*max_out as f64)),
                ("known_lengths", Json::Bool(*known_lengths)),
            ]),
            AppSpec::ChainSummary { n_docs, eval_times, max_out } => Json::obj(vec![
                ("kind", Json::Str("chain_summary".into())),
                ("n_docs", Json::Num(*n_docs as f64)),
                ("eval_times", Json::Num(*eval_times as f64)),
                ("max_out", Json::Num(*max_out as f64)),
            ]),
            AppSpec::Mixed {
                n_docs,
                n_ensemble_requests,
                summary_max_out,
                ensemble_max_out,
                eval_times,
            } => Json::obj(vec![
                ("kind", Json::Str("mixed".into())),
                ("n_docs", Json::Num(*n_docs as f64)),
                ("n_ensemble_requests", Json::Num(*n_ensemble_requests as f64)),
                ("summary_max_out", Json::Num(*summary_max_out as f64)),
                ("ensemble_max_out", Json::Num(*ensemble_max_out as f64)),
                ("eval_times", Json::Num(*eval_times as f64)),
            ]),
            AppSpec::Custom { name, nodes, edges } => Json::obj(vec![
                ("kind", Json::Str("custom".into())),
                ("name", Json::Str(name.clone())),
                ("nodes", Json::Arr(nodes.iter().map(node_to_json).collect())),
                (
                    "edges",
                    Json::Arr(
                        edges
                            .iter()
                            .map(|&(f, t)| {
                                Json::Arr(vec![Json::Num(f as f64), Json::Num(t as f64)])
                            })
                            .collect(),
                    ),
                ),
            ]),
        }
    }

    /// Parse a spec from a JSON value. Builtin kinds keep the seed config
    /// defaults for missing fields; custom graphs are fully explicit.
    pub fn from_json(v: &Json) -> Result<Self> {
        let kind =
            v.get("kind").and_then(|k| k.as_str()).ok_or_else(|| anyhow!("app.kind missing"))?;
        let num = |k: &str, d: u64| v.get(k).and_then(|x| x.as_u64()).unwrap_or(d);
        Ok(match kind {
            "ensembling" => AppSpec::Ensembling {
                n_requests: num("n_requests", 1000) as usize,
                max_out: num("max_out", 256) as u32,
            },
            "routing" => AppSpec::Routing {
                max_out: num("max_out", 4096) as u32,
                known_lengths: v.get("known_lengths").and_then(|x| x.as_bool()).unwrap_or(false),
            },
            "chain_summary" | "chain-summary" => AppSpec::ChainSummary {
                n_docs: num("n_docs", 100) as usize,
                eval_times: num("eval_times", 1) as u32,
                max_out: num("max_out", 500) as u32,
            },
            "mixed" => AppSpec::Mixed {
                n_docs: num("n_docs", 100) as usize,
                n_ensemble_requests: num("n_ensemble_requests", 5000) as usize,
                summary_max_out: num("summary_max_out", 900) as u32,
                ensemble_max_out: num("ensemble_max_out", 256) as u32,
                eval_times: num("eval_times", 4) as u32,
            },
            "custom" => {
                let nodes = v
                    .get("nodes")
                    .and_then(|n| n.as_arr())
                    .ok_or_else(|| anyhow!("custom spec needs a nodes array"))?
                    .iter()
                    .map(node_from_json)
                    .collect::<Result<Vec<_>>>()?;
                let edges = match v.get("edges").and_then(|e| e.as_arr()) {
                    None => vec![],
                    Some(arr) => arr
                        .iter()
                        .map(|e| {
                            let pair = e.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                                anyhow!("edges must be [from, to] pairs, got {}", e.to_string())
                            })?;
                            let f = pair[0].as_usize().ok_or_else(|| anyhow!("bad edge from"))?;
                            let t = pair[1].as_usize().ok_or_else(|| anyhow!("bad edge to"))?;
                            Ok((f, t))
                        })
                        .collect::<Result<Vec<_>>>()?,
                };
                AppSpec::Custom {
                    name: v
                        .get("name")
                        .and_then(|n| n.as_str())
                        .unwrap_or("custom")
                        .to_string(),
                    nodes,
                    edges,
                }
            }
            other => return Err(anyhow!("unknown app kind {other}")),
        })
    }

    /// Serialize to a compact JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a spec from a JSON document string.
    pub fn parse(s: &str) -> Result<Self> {
        let v = Json::parse(s).map_err(|e| anyhow!("bad spec json: {e}"))?;
        Self::from_json(&v)
    }
}

fn node_to_json(n: &NodeSpec) -> Json {
    let workload = match &n.workload {
        WorkloadGen::Explicit { requests } => Json::obj(vec![
            ("kind", Json::Str("explicit".into())),
            (
                "requests",
                Json::Arr(
                    requests
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("input_len", Json::Num(r.input_len as f64)),
                                ("output_len", Json::Num(r.output_len as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        WorkloadGen::Synthetic { n_requests, input_min, input_max } => Json::obj(vec![
            ("kind", Json::Str("synthetic".into())),
            ("n_requests", Json::Num(*n_requests as f64)),
            ("input_min", Json::Num(*input_min as f64)),
            ("input_max", Json::Num(*input_max as f64)),
        ]),
    };
    Json::obj(vec![
        ("model", Json::Str(n.model.clone())),
        ("label", Json::Str(n.label.clone())),
        ("max_out", Json::Num(n.max_out as f64)),
        ("workload", workload),
    ])
}

fn node_from_json(v: &Json) -> Result<NodeSpec> {
    let model = v
        .get("model")
        .and_then(|m| m.as_str())
        .ok_or_else(|| anyhow!("node.model missing"))?
        .to_string();
    let label = v.get("label").and_then(|l| l.as_str()).unwrap_or(model.as_str()).to_string();
    let max_out = v
        .get("max_out")
        .and_then(|m| m.as_u64())
        .ok_or_else(|| anyhow!("node.max_out missing"))? as u32;
    let w = v.get("workload").ok_or_else(|| anyhow!("node.workload missing"))?;
    let kind = w
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or_else(|| anyhow!("workload.kind missing"))?;
    let workload = match kind {
        "explicit" => WorkloadGen::Explicit {
            requests: w
                .get("requests")
                .and_then(|r| r.as_arr())
                .ok_or_else(|| anyhow!("explicit workload needs a requests array"))?
                .iter()
                .map(|r| {
                    Ok(RequestSpec {
                        input_len: r
                            .get("input_len")
                            .and_then(|x| x.as_u64())
                            .ok_or_else(|| anyhow!("request.input_len missing"))?
                            as u32,
                        output_len: r
                            .get("output_len")
                            .and_then(|x| x.as_u64())
                            .ok_or_else(|| anyhow!("request.output_len missing"))?
                            as u32,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        },
        "synthetic" => WorkloadGen::Synthetic {
            n_requests: w
                .get("n_requests")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("synthetic workload needs n_requests"))?,
            input_min: w.get("input_min").and_then(|x| x.as_u64()).unwrap_or(5) as u32,
            input_max: w.get("input_max").and_then(|x| x.as_u64()).unwrap_or(127) as u32,
        },
        other => return Err(anyhow!("unknown workload kind {other}")),
    };
    Ok(NodeSpec { model, label, max_out, workload })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_custom() -> AppSpec {
        AppSpec::Custom {
            name: "two-stage".into(),
            nodes: vec![
                NodeSpec {
                    model: "vicuna-13b-v1.5".into(),
                    label: "draft".into(),
                    max_out: 300,
                    workload: WorkloadGen::Synthetic {
                        n_requests: 40,
                        input_min: 10,
                        input_max: 120,
                    },
                },
                NodeSpec {
                    model: "mistral-7b-instruct".into(),
                    label: "refine".into(),
                    max_out: 128,
                    workload: WorkloadGen::Explicit {
                        requests: vec![
                            RequestSpec { input_len: 30, output_len: 64 },
                            RequestSpec { input_len: 45, output_len: 9000 },
                        ],
                    },
                },
            ],
            edges: vec![(0, 1)],
        }
    }

    #[test]
    fn json_roundtrip_all_variants() {
        for spec in [
            AppSpec::ensembling(1000, 256),
            AppSpec::routing(4096, true),
            AppSpec::chain_summary(100, 4, 900),
            AppSpec::mixed(400, 5000, 900, 256, 4),
            sample_custom(),
        ] {
            let back = AppSpec::parse(&spec.to_json_string()).unwrap();
            assert_eq!(back, spec);
            // Stable: a second round-trip serialises identically.
            assert_eq!(back.to_json_string(), spec.to_json_string());
        }
    }

    #[test]
    fn builtin_specs_match_seed_builders() {
        // The spec path must be bit-identical to calling the app builders
        // directly (the pre-spec code path).
        let spec = AppSpec::ensembling(200, 256);
        let via_spec = spec.build(42).unwrap();
        let direct = crate::apps::ensembling::build(200, 256, 42);
        assert_eq!(via_spec.name, direct.name);
        assert_eq!(via_spec.graph.n_nodes(), direct.graph.n_nodes());
        for (a, b) in via_spec.workloads.iter().zip(&direct.workloads) {
            assert_eq!(a.len(), b.len());
            assert!(a
                .iter()
                .zip(b)
                .all(|(x, y)| x.input_len == y.input_len
                    && x.true_output_len == y.true_output_len));
        }
    }

    #[test]
    fn cli_defaults_match_seed_cli() {
        // Seed CLI: ensembling(1000, 256), routing(512), chain(100, 2, 256),
        // mixed(100, 1000, 900, 256, 4).
        let p = AppParams::default();
        assert_eq!(from_cli("ensembling", &p).unwrap(), AppSpec::ensembling(1000, 256));
        assert_eq!(from_cli("routing", &p).unwrap(), AppSpec::routing(512, false));
        assert_eq!(
            from_cli("chain-summary", &p).unwrap(),
            AppSpec::chain_summary(100, 2, 256)
        );
        assert_eq!(from_cli("mixed", &p).unwrap(), AppSpec::mixed(100, 1000, 900, 256, 4));
    }

    #[test]
    fn cli_rejects_inapplicable_flags() {
        let p = AppParams { n_requests: Some(5000), ..Default::default() };
        let err = from_cli("routing", &p).unwrap_err().to_string();
        assert!(err.contains("RouterBench"), "{err}");
        let p = AppParams { n_docs: Some(10), ..Default::default() };
        assert!(from_cli("ensembling", &p).is_err());
        assert!(from_cli("nonsense", &AppParams::default()).is_err());
    }

    #[test]
    fn custom_spec_builds_valid_scenario() {
        let spec = sample_custom();
        let sc = spec.build(7).unwrap();
        assert_eq!(sc.graph.n_nodes(), 2);
        assert_eq!(sc.graph.edges, vec![(0, 1)]);
        assert_eq!(sc.workloads[0].len(), 40);
        assert_eq!(sc.workloads[1].len(), 2);
        // Synthetic lengths respect bounds; explicit outputs are clamped.
        for r in &sc.workloads[0] {
            assert!((10..=120).contains(&r.input_len));
            assert!(r.true_output_len >= 1 && r.true_output_len <= 300);
        }
        assert!(sc.workloads[1][1].true_output_len <= 128);
        // Deterministic per seed.
        let again = spec.build(7).unwrap();
        assert!(sc.workloads[0]
            .iter()
            .zip(&again.workloads[0])
            .all(|(a, b)| a.true_output_len == b.true_output_len));
    }

    #[test]
    fn custom_spec_rejects_bad_graphs() {
        let mut bad = sample_custom();
        if let AppSpec::Custom { edges, .. } = &mut bad {
            edges.push((1, 0)); // cycle 0 -> 1 -> 0
        }
        assert!(bad.build(1).is_err());
        let mut oob = sample_custom();
        if let AppSpec::Custom { edges, .. } = &mut oob {
            *edges = vec![(0, 5)];
        }
        assert!(oob.build(1).is_err());
        let unknown = AppSpec::Custom {
            name: "x".into(),
            nodes: vec![NodeSpec {
                model: "gpt-17".into(),
                label: "x".into(),
                max_out: 64,
                workload: WorkloadGen::Synthetic { n_requests: 5, input_min: 5, input_max: 10 },
            }],
            edges: vec![],
        };
        assert!(unknown.build(1).is_err());
    }
}
