//! Declarative multi-application workloads: a [`WorkloadSpec`] is a list
//! of `{app, arrival, weight, seed}` entries that materialises into one
//! jointly planned, jointly executed
//! [`WorkloadScenario`](crate::runner::workload::WorkloadScenario).
//!
//! Each entry wraps a plain [`AppSpec`] — anything a single-app run
//! accepts, the paper's four applications or a custom graph — plus
//! workload-level metadata: the virtual arrival time (apps with
//! `arrival > 0` enter the run through the replan path), a priority
//! weight, and an optional per-app seed override (the default derivation
//! gives entry 0 the session seed and decorrelates later entries).
//!
//! Serialises via [`crate::util::json`] (the `workload` key of
//! [`crate::config::ExperimentConfig`]) and parses the CLI's
//! `--app name:key=value:...` descriptors (`samullm workload`).

use anyhow::{anyhow, Result};

use crate::runner::workload::WorkloadScenario;
use crate::spec::{from_cli, AppParams, AppSpec};
use crate::util::json::Json;

/// One application instance of a declarative workload.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadEntry {
    /// What to run — any single-app spec.
    pub app: AppSpec,
    /// Virtual arrival time in seconds (default 0 = present at start;
    /// later arrivals are absorbed at the first stage boundary at or
    /// after this time via a forced replan).
    pub arrival: f64,
    /// Relative priority weight (default 1; recorded in the per-app
    /// report).
    pub weight: f64,
    /// Per-app workload seed override. `None` derives a seed from the
    /// session seed and the entry index (entry 0 gets the session seed
    /// itself).
    pub seed: Option<u64>,
}

impl WorkloadEntry {
    /// An entry with default metadata: arrival 0, weight 1, derived seed.
    pub fn new(app: AppSpec) -> Self {
        WorkloadEntry { app, arrival: 0.0, weight: 1.0, seed: None }
    }

    /// Parse a CLI descriptor: `name[:key=value]...` where `name` is an
    /// app-builder registry name and keys are the app's own CLI knobs
    /// (`n-requests`, `max-out`, `n-docs`, `eval-times`, `known-lengths`)
    /// plus the workload-level `arrival`, `weight` and `seed`. Underscore
    /// spellings are accepted. Examples:
    ///
    /// ```text
    /// ensembling:n-requests=2000:max-out=256
    /// chain-summary:n-docs=100:arrival=30
    /// ```
    pub fn parse_cli(desc: &str) -> Result<Self> {
        let mut parts = desc.split(':');
        let name = parts.next().filter(|n| !n.is_empty()).ok_or_else(|| {
            anyhow!("empty --app descriptor (expected name[:key=value]...)")
        })?;
        let mut params = AppParams::default();
        let mut arrival = 0.0f64;
        let mut weight = 1.0f64;
        let mut seed = None;
        for kv in parts {
            let (key, value) = match kv.split_once('=') {
                Some((k, v)) => (k, v),
                // A bare key is a boolean switch (known-lengths).
                None => (kv, "true"),
            };
            let key = key.replace('_', "-");
            let bad = |e: &dyn std::fmt::Display| {
                anyhow!("--app {name}: invalid value {value:?} for {key}: {e}")
            };
            match key.as_str() {
                "n-requests" => params.n_requests = Some(value.parse().map_err(|e| bad(&e))?),
                "max-out" => params.max_out = Some(value.parse().map_err(|e| bad(&e))?),
                "n-docs" => params.n_docs = Some(value.parse().map_err(|e| bad(&e))?),
                "eval-times" => params.eval_times = Some(value.parse().map_err(|e| bad(&e))?),
                "known-lengths" => {
                    params.known_lengths = value.parse().map_err(|e| bad(&e))?
                }
                "arrival" => arrival = value.parse().map_err(|e| bad(&e))?,
                "weight" => weight = value.parse().map_err(|e| bad(&e))?,
                "seed" => seed = Some(value.parse().map_err(|e| bad(&e))?),
                other => {
                    return Err(anyhow!(
                        "--app {name}: unknown key {other:?} (known: n-requests, max-out, \
                         n-docs, eval-times, known-lengths, arrival, weight, seed)"
                    ))
                }
            }
        }
        Ok(WorkloadEntry { app: from_cli(name, &params)?, arrival, weight, seed })
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("app", self.app.to_json()),
            ("arrival", Json::Num(self.arrival)),
            ("weight", Json::Num(self.weight)),
        ];
        if let Some(s) = self.seed {
            fields.push(("seed", Json::Num(s as f64)));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let app = v.get("app").ok_or_else(|| anyhow!("workload entry: app missing"))?;
        let app = AppSpec::from_json(app)?;
        Ok(WorkloadEntry {
            app,
            arrival: v.get("arrival").and_then(|x| x.as_f64()).unwrap_or(0.0),
            weight: v.get("weight").and_then(|x| x.as_f64()).unwrap_or(1.0),
            seed: v.get("seed").and_then(|x| x.as_u64()),
        })
    }
}

/// A declarative multi-app workload: entries in app-id order.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Workload name (empty = derived: `workload-<n>apps`).
    pub name: String,
    /// The application entries; index = app id (composition order).
    pub entries: Vec<WorkloadEntry>,
}

impl WorkloadSpec {
    /// A workload from entries with a derived name.
    pub fn new(entries: Vec<WorkloadEntry>) -> Self {
        WorkloadSpec { name: String::new(), entries }
    }

    /// The workload's display name (derived from the entry count when
    /// unset).
    pub fn display_name(&self) -> String {
        if self.name.is_empty() {
            format!("workload-{}apps", self.entries.len())
        } else {
            self.name.clone()
        }
    }

    /// Whether any entry asks for the known-output-lengths mode (applied
    /// to the whole run, like the single-app path does).
    pub fn wants_known_lengths(&self) -> bool {
        self.entries.iter().any(|e| e.app.wants_known_lengths())
    }

    /// The seed entry `i` materialises with: its override, or a
    /// session-seed derivation (entry 0 = the session seed itself, later
    /// entries decorrelated by a golden-ratio mix).
    pub fn entry_seed(&self, i: usize, session_seed: u64) -> u64 {
        self.entries[i]
            .seed
            .unwrap_or_else(|| session_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Materialise the workload: build every entry's scenario with its
    /// resolved seed and compose them (validated; rejects empty
    /// workloads, non-finite/negative arrivals and non-positive weights).
    pub fn build(&self, session_seed: u64) -> Result<WorkloadScenario> {
        if self.entries.is_empty() {
            return Err(anyhow!("workload needs at least one app entry"));
        }
        let mut parts = vec![];
        for (i, e) in self.entries.iter().enumerate() {
            if !e.arrival.is_finite() || e.arrival < 0.0 {
                return Err(anyhow!("entry {i}: arrival must be finite and >= 0"));
            }
            if !e.weight.is_finite() || e.weight <= 0.0 {
                return Err(anyhow!("entry {i}: weight must be finite and > 0"));
            }
            let scenario = e.app.build(self.entry_seed(i, session_seed))?;
            parts.push((scenario, e.arrival, e.weight));
        }
        Ok(WorkloadScenario::compose(parts, &self.display_name()))
    }

    /// Serialize to a [`Json`] value (round-trips via
    /// [`WorkloadSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("entries", Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())),
        ])
    }

    /// Parse from JSON: either `{"name": ..., "entries": [...]}` or a
    /// bare entry array (the config file's `workload: [...]` shorthand).
    pub fn from_json(v: &Json) -> Result<Self> {
        let (name, arr) = match v.as_arr() {
            Some(arr) => (String::new(), arr),
            None => (
                v.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                v.get("entries")
                    .and_then(|e| e.as_arr())
                    .ok_or_else(|| anyhow!("workload needs an entries array"))?,
            ),
        };
        let entries =
            arr.iter().map(WorkloadEntry::from_json).collect::<Result<Vec<_>>>()?;
        Ok(WorkloadSpec { name, entries })
    }

    /// Serialize to a compact JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a workload from a JSON document string.
    pub fn parse(s: &str) -> Result<Self> {
        let v = Json::parse(s).map_err(|e| anyhow!("bad workload json: {e}"))?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WorkloadSpec {
        WorkloadSpec {
            name: "pair".into(),
            entries: vec![
                WorkloadEntry::new(AppSpec::chain_summary(20, 2, 300)),
                WorkloadEntry {
                    app: AppSpec::ensembling(200, 128),
                    arrival: 45.0,
                    weight: 2.0,
                    seed: Some(9),
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_object_and_array_forms() {
        let wl = sample();
        let back = WorkloadSpec::parse(&wl.to_json_string()).unwrap();
        assert_eq!(back, wl);
        assert_eq!(back.to_json_string(), wl.to_json_string());
        // Bare-array shorthand: entries only, derived name.
        let arr = r#"[{"app":{"kind":"ensembling","n_requests":50,"max_out":64}},
                      {"app":{"kind":"chain_summary"},"arrival":30,"weight":0.5}]"#;
        let wl = WorkloadSpec::parse(arr).unwrap();
        assert_eq!(wl.entries.len(), 2);
        assert_eq!(wl.display_name(), "workload-2apps");
        assert_eq!(wl.entries[0].arrival, 0.0);
        assert_eq!(wl.entries[1].arrival, 30.0);
        assert_eq!(wl.entries[1].weight, 0.5);
        assert_eq!(wl.entries[0].weight, 1.0);
    }

    #[test]
    fn entry_seed_defaults_and_overrides() {
        let wl = sample();
        assert_eq!(wl.entry_seed(0, 42), 42, "entry 0 inherits the session seed");
        assert_eq!(wl.entry_seed(1, 42), 9, "explicit override wins");
        let no_override = WorkloadSpec::new(vec![
            WorkloadEntry::new(AppSpec::ensembling(10, 64)),
            WorkloadEntry::new(AppSpec::ensembling(10, 64)),
        ]);
        assert_ne!(no_override.entry_seed(1, 42), 42, "later entries decorrelate");
    }

    #[test]
    fn build_composes_and_validates() {
        let wl = sample();
        let ws = wl.build(7).unwrap();
        assert_eq!(ws.name, "pair");
        assert_eq!(ws.apps.len(), 2);
        assert_eq!(ws.apps[1].arrival, 45.0);
        assert_eq!(
            ws.scenario.graph.n_nodes(),
            ws.apps.iter().map(|a| a.nodes.len()).sum::<usize>()
        );
        assert!(WorkloadSpec::new(vec![]).build(1).is_err());
        let mut bad = sample();
        bad.entries[1].arrival = -1.0;
        assert!(bad.build(1).is_err());
        let mut bad = sample();
        bad.entries[0].weight = 0.0;
        assert!(bad.build(1).is_err());
    }

    #[test]
    fn cli_descriptor_parses_knobs_and_rejects_unknown_keys() {
        let e = WorkloadEntry::parse_cli("ensembling:n-requests=200:max-out=64:arrival=30")
            .unwrap();
        assert_eq!(e.app, AppSpec::ensembling(200, 64));
        assert_eq!(e.arrival, 30.0);
        assert_eq!(e.weight, 1.0);
        let e = WorkloadEntry::parse_cli("chain-summary:n_docs=5:weight=2.5:seed=11").unwrap();
        assert_eq!(e.app, AppSpec::chain_summary(5, 2, 256));
        assert_eq!(e.weight, 2.5);
        assert_eq!(e.seed, Some(11));
        // Inapplicable app knobs are rejected by the app builder itself.
        assert!(WorkloadEntry::parse_cli("ensembling:n-docs=5").is_err());
        // Unknown keys and bad values error, never silently default.
        assert!(WorkloadEntry::parse_cli("ensembling:bogus=1").is_err());
        assert!(WorkloadEntry::parse_cli("ensembling:arrival=soon").is_err());
        assert!(WorkloadEntry::parse_cli("").is_err());
        assert!(WorkloadEntry::parse_cli("nonsense-app").is_err());
    }
}
