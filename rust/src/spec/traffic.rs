//! Declarative open-loop traffic: a [`TrafficSpec`] generalises the batch
//! [`WorkloadSpec`](crate::spec::WorkloadSpec) from a fixed request set to
//! *streams* — each entry wraps an [`AppSpec`] plus an arrival process
//! ([`ArrivalSpec`]: deterministic-seeded Poisson, bursty Markov-modulated
//! on-off, or trace replay from a timestamp file), a fair-share weight
//! that is a real admission priority, and an optional per-request latency
//! SLO.
//!
//! `build()` materialises the spec into a
//! [`TrafficScenario`](crate::traffic::TrafficScenario): the composed
//! graph, per-app request-template pools, and pre-generated arrival
//! timestamps over the `warmup + duration` horizon, all derived from the
//! session seed (same seed → bit-identical streams).
//!
//! Serialises via [`crate::util::json`] (the `traffic` key of
//! [`crate::config::ExperimentConfig`]) and parses the CLI's
//! `--app name:rate=5:weight=2` descriptors (`samullm traffic`).

use anyhow::{anyhow, Result};

use crate::runner::workload::compose_scenarios;
use crate::spec::{from_cli, AppParams, AppSpec};
use crate::traffic::queue::QueuePolicy;
use crate::traffic::{arrivals, TrafficApp, TrafficCfg, TrafficScenario};
use crate::util::json::Json;

/// XOR salt decorrelating an entry's arrival stream from its workload
/// materialisation (both derive from the same entry seed).
pub const ARRIVAL_SEED_SALT: u64 = 0x5452_4146; // "TRAF"

/// An open-loop arrival process (all processes are deterministic given a
/// seed — same seed, same stream).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Homogeneous Poisson arrivals: i.i.d. exponential inter-arrival
    /// gaps with mean `1/rate`.
    Poisson {
        /// Mean arrival rate in requests per second (> 0).
        rate: f64,
    },
    /// Bursty Markov-modulated on-off arrivals: a two-state background
    /// chain with exponential dwell times; arrivals are Poisson at
    /// `rate_on` while "on" and `rate_off` while "off" (`rate_off = 0`
    /// gives pure bursts separated by silence).
    OnOff {
        /// Arrival rate during on-phases (> 0).
        rate_on: f64,
        /// Arrival rate during off-phases (≥ 0).
        rate_off: f64,
        /// Mean on-phase dwell time in seconds (> 0).
        mean_on: f64,
        /// Mean off-phase dwell time in seconds (> 0).
        mean_off: f64,
    },
    /// Replay arrival timestamps from a text file: one ascending
    /// timestamp (seconds) per line; blank lines and `#` comments are
    /// skipped; timestamps at or past the horizon are clipped.
    Trace {
        /// Path to the timestamp file.
        path: String,
    },
}

impl ArrivalSpec {
    /// The process's JSON/CLI kind name.
    pub fn kind(&self) -> &'static str {
        match self {
            ArrivalSpec::Poisson { .. } => "poisson",
            ArrivalSpec::OnOff { .. } => "on_off",
            ArrivalSpec::Trace { .. } => "trace",
        }
    }

    /// Validate the process parameters (finite, correctly signed).
    pub fn validate(&self) -> Result<()> {
        let pos = |x: f64, what: &str| -> Result<()> {
            if !x.is_finite() || x <= 0.0 {
                return Err(anyhow!("{what} must be finite and > 0, got {x}"));
            }
            Ok(())
        };
        match self {
            ArrivalSpec::Poisson { rate } => pos(*rate, "poisson rate"),
            ArrivalSpec::OnOff { rate_on, rate_off, mean_on, mean_off } => {
                pos(*rate_on, "on-off rate_on")?;
                if !rate_off.is_finite() || *rate_off < 0.0 {
                    return Err(anyhow!(
                        "on-off rate_off must be finite and >= 0, got {rate_off}"
                    ));
                }
                pos(*mean_on, "on-off mean_on")?;
                pos(*mean_off, "on-off mean_off")
            }
            ArrivalSpec::Trace { path } => {
                if path.is_empty() {
                    return Err(anyhow!("trace process needs a file path"));
                }
                Ok(())
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            ArrivalSpec::Poisson { rate } => Json::obj(vec![
                ("kind", Json::Str("poisson".into())),
                ("rate", Json::Num(*rate)),
            ]),
            ArrivalSpec::OnOff { rate_on, rate_off, mean_on, mean_off } => Json::obj(vec![
                ("kind", Json::Str("on_off".into())),
                ("rate_on", Json::Num(*rate_on)),
                ("rate_off", Json::Num(*rate_off)),
                ("mean_on", Json::Num(*mean_on)),
                ("mean_off", Json::Num(*mean_off)),
            ]),
            ArrivalSpec::Trace { path } => Json::obj(vec![
                ("kind", Json::Str("trace".into())),
                ("path", Json::Str(path.clone())),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow!("arrival process needs a kind"))?;
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| anyhow!("{kind} process: missing numeric {key}"))
        };
        match kind {
            "poisson" => Ok(ArrivalSpec::Poisson { rate: num("rate")? }),
            "on_off" => Ok(ArrivalSpec::OnOff {
                rate_on: num("rate_on")?,
                rate_off: v.get("rate_off").and_then(|x| x.as_f64()).unwrap_or(0.0),
                mean_on: num("mean_on")?,
                mean_off: num("mean_off")?,
            }),
            "trace" => Ok(ArrivalSpec::Trace {
                path: v
                    .get("path")
                    .and_then(|p| p.as_str())
                    .ok_or_else(|| anyhow!("trace process: missing path"))?
                    .to_string(),
            }),
            other => Err(anyhow!(
                "unknown arrival process {other:?} (known: poisson, on_off, trace)"
            )),
        }
    }
}

/// One application stream of an open-loop traffic mix.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficEntry {
    /// What each arriving request runs — any single-app spec; the app's
    /// materialised per-node requests become the entry's request-template
    /// pool (arrival *k* replays template *k mod pool-size* on each
    /// node).
    pub app: AppSpec,
    /// The arrival process generating this app's request stream.
    pub process: ArrivalSpec,
    /// Weighted-fair-share admission weight (default 1): under backlog an
    /// app is admitted in proportion to its weight (virtual-time weighted
    /// round-robin across app queues) — a real scheduling priority, not
    /// just reporting metadata.
    pub weight: f64,
    /// Optional per-request latency SLO in seconds (arrival → completion)
    /// for the report's SLO-attainment metric.
    pub slo: Option<f64>,
    /// Per-app seed override. `None` derives a seed from the session seed
    /// and the entry index (entry 0 gets the session seed itself).
    pub seed: Option<u64>,
}

impl TrafficEntry {
    /// A Poisson entry with default metadata: weight 1, no SLO, derived
    /// seed.
    pub fn poisson(app: AppSpec, rate: f64) -> Self {
        TrafficEntry {
            app,
            process: ArrivalSpec::Poisson { rate },
            weight: 1.0,
            slo: None,
            seed: None,
        }
    }

    /// Parse a CLI descriptor: `name[:key=value]...` where `name` is an
    /// app-builder registry name and keys are the app's own CLI knobs
    /// (`n-requests`, `max-out`, `n-docs`, `eval-times`, `known-lengths`)
    /// plus the traffic-level `rate`, `process`, `rate-on`, `rate-off`,
    /// `mean-on`, `mean-off`, `trace`, `weight`, `slo` and `seed`.
    /// Underscore spellings are accepted. Examples:
    ///
    /// ```text
    /// ensembling:rate=5:weight=2
    /// chain-summary:n-docs=40:process=on-off:rate-on=8:rate-off=0:mean-on=10:mean-off=30
    /// routing:trace=arrivals.txt:slo=30
    /// ```
    pub fn parse_cli(desc: &str) -> Result<Self> {
        let mut parts = desc.split(':');
        let name = parts.next().filter(|n| !n.is_empty()).ok_or_else(|| {
            anyhow!("empty --app descriptor (expected name[:key=value]...)")
        })?;
        let mut params = AppParams::default();
        let mut process: Option<String> = None;
        let mut rate = None;
        let (mut rate_on, mut rate_off) = (None, None);
        let (mut mean_on, mut mean_off) = (None, None);
        let mut trace: Option<String> = None;
        let mut weight = 1.0f64;
        let mut slo = None;
        let mut seed = None;
        for kv in parts {
            let (key, value) = match kv.split_once('=') {
                Some((k, v)) => (k, v),
                // A bare key is a boolean switch (known-lengths).
                None => (kv, "true"),
            };
            let key = key.replace('_', "-");
            let bad = |e: &dyn std::fmt::Display| {
                anyhow!("--app {name}: invalid value {value:?} for {key}: {e}")
            };
            match key.as_str() {
                "n-requests" => params.n_requests = Some(value.parse().map_err(|e| bad(&e))?),
                "max-out" => params.max_out = Some(value.parse().map_err(|e| bad(&e))?),
                "n-docs" => params.n_docs = Some(value.parse().map_err(|e| bad(&e))?),
                "eval-times" => params.eval_times = Some(value.parse().map_err(|e| bad(&e))?),
                "known-lengths" => {
                    params.known_lengths = value.parse().map_err(|e| bad(&e))?
                }
                "process" => process = Some(value.replace('-', "_")),
                "rate" => rate = Some(value.parse().map_err(|e| bad(&e))?),
                "rate-on" => rate_on = Some(value.parse().map_err(|e| bad(&e))?),
                "rate-off" => rate_off = Some(value.parse().map_err(|e| bad(&e))?),
                "mean-on" => mean_on = Some(value.parse().map_err(|e| bad(&e))?),
                "mean-off" => mean_off = Some(value.parse().map_err(|e| bad(&e))?),
                "trace" => trace = Some(value.to_string()),
                "weight" => weight = value.parse().map_err(|e| bad(&e))?,
                "slo" => slo = Some(value.parse().map_err(|e| bad(&e))?),
                "seed" => seed = Some(value.parse().map_err(|e| bad(&e))?),
                other => {
                    return Err(anyhow!(
                        "--app {name}: unknown key {other:?} (known: n-requests, max-out, \
                         n-docs, eval-times, known-lengths, process, rate, rate-on, \
                         rate-off, mean-on, mean-off, trace, weight, slo, seed)"
                    ))
                }
            }
        }
        // The process kind is explicit (`process=`) or inferred from the
        // knobs that were given; missing required knobs are errors.
        let kind = match process.as_deref() {
            Some(k) => k.to_string(),
            None if trace.is_some() => "trace".into(),
            None if rate_on.is_some() || mean_on.is_some() => "on_off".into(),
            None => "poisson".into(),
        };
        let process = match kind.as_str() {
            "poisson" => ArrivalSpec::Poisson {
                rate: rate
                    .ok_or_else(|| anyhow!("--app {name}: poisson process needs rate="))?,
            },
            "on_off" => ArrivalSpec::OnOff {
                rate_on: rate_on.or(rate).ok_or_else(|| {
                    anyhow!("--app {name}: on-off process needs rate-on= (or rate=)")
                })?,
                rate_off: rate_off.unwrap_or(0.0),
                mean_on: mean_on
                    .ok_or_else(|| anyhow!("--app {name}: on-off process needs mean-on="))?,
                mean_off: mean_off
                    .ok_or_else(|| anyhow!("--app {name}: on-off process needs mean-off="))?,
            },
            "trace" => ArrivalSpec::Trace {
                path: trace
                    .ok_or_else(|| anyhow!("--app {name}: trace process needs trace=PATH"))?,
            },
            other => {
                return Err(anyhow!(
                    "--app {name}: unknown process {other:?} (known: poisson, on-off, trace)"
                ))
            }
        };
        Ok(TrafficEntry { app: from_cli(name, &params)?, process, weight, slo, seed })
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("app", self.app.to_json()),
            ("process", self.process.to_json()),
            ("weight", Json::Num(self.weight)),
        ];
        if let Some(s) = self.slo {
            fields.push(("slo", Json::Num(s)));
        }
        if let Some(s) = self.seed {
            fields.push(("seed", Json::Num(s as f64)));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let app = v.get("app").ok_or_else(|| anyhow!("traffic entry: app missing"))?;
        let app = AppSpec::from_json(app)?;
        let process = v
            .get("process")
            .ok_or_else(|| anyhow!("traffic entry: process missing"))?;
        Ok(TrafficEntry {
            app,
            process: ArrivalSpec::from_json(process)?,
            weight: v.get("weight").and_then(|x| x.as_f64()).unwrap_or(1.0),
            slo: v.get("slo").and_then(|x| x.as_f64()),
            seed: v.get("seed").and_then(|x| x.as_u64()),
        })
    }
}

/// A declarative open-loop traffic mix: app streams plus the run window
/// and admission-queue configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    /// Traffic-mix name (empty = derived: `traffic-<n>apps`).
    pub name: String,
    /// The application streams; index = app id (composition order).
    pub entries: Vec<TrafficEntry>,
    /// Measurement-window length in seconds: requests arriving inside
    /// `[warmup, warmup + duration)` are the measured population.
    pub duration: f64,
    /// Warmup seconds before the measurement window opens (arrivals are
    /// generated and served, but excluded from the latency metrics).
    pub warmup: f64,
    /// Per-app bounded admission-queue capacity (≥ 1).
    pub queue_capacity: usize,
    /// What happens to an arrival that finds its app queue full.
    pub queue_policy: QueuePolicy,
    /// Maximum jobs admitted per stage boundary across all apps (the
    /// weighted-fair-share quantum); `0` = `queue_capacity`.
    pub admit_quantum: usize,
}

impl TrafficSpec {
    /// A traffic mix from entries with the default window and queue
    /// configuration (120 s window, no warmup, capacity 64, reject).
    pub fn new(entries: Vec<TrafficEntry>) -> Self {
        TrafficSpec {
            name: String::new(),
            entries,
            duration: 120.0,
            warmup: 0.0,
            queue_capacity: 64,
            queue_policy: QueuePolicy::Reject,
            admit_quantum: 0,
        }
    }

    /// The mix's display name (derived from the entry count when unset).
    pub fn display_name(&self) -> String {
        if self.name.is_empty() {
            format!("traffic-{}apps", self.entries.len())
        } else {
            self.name.clone()
        }
    }

    /// Whether any entry asks for the known-output-lengths mode (applied
    /// to the whole run, like the workload path does).
    pub fn wants_known_lengths(&self) -> bool {
        self.entries.iter().any(|e| e.app.wants_known_lengths())
    }

    /// Arrival-generation horizon: `warmup + duration` (no arrivals are
    /// generated past it; the run then drains).
    pub fn horizon(&self) -> f64 {
        self.warmup + self.duration
    }

    /// The seed entry `i` materialises with: its override, or a
    /// session-seed derivation (entry 0 = the session seed itself, later
    /// entries decorrelated by a golden-ratio mix) — the same rule
    /// [`crate::spec::WorkloadSpec::entry_seed`] uses.
    pub fn entry_seed(&self, i: usize, session_seed: u64) -> u64 {
        self.entries[i]
            .seed
            .unwrap_or_else(|| session_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Materialise the mix into a runnable
    /// [`TrafficScenario`](crate::traffic::TrafficScenario): validate,
    /// build every entry's template scenario with its resolved seed,
    /// compose the joint graph, and pre-generate each entry's arrival
    /// stream over the horizon (deterministic from the seeds — the same
    /// spec and seed always produce bit-identical streams).
    pub fn build(&self, session_seed: u64) -> Result<TrafficScenario> {
        if self.entries.is_empty() {
            return Err(anyhow!("traffic needs at least one app entry"));
        }
        if !self.duration.is_finite() || self.duration <= 0.0 {
            return Err(anyhow!("traffic duration must be finite and > 0"));
        }
        if !self.warmup.is_finite() || self.warmup < 0.0 {
            return Err(anyhow!("traffic warmup must be finite and >= 0"));
        }
        if self.queue_capacity == 0 {
            return Err(anyhow!("traffic queue_capacity must be >= 1"));
        }
        let horizon = self.horizon();
        let mut parts = vec![];
        let mut streams = vec![];
        for (i, e) in self.entries.iter().enumerate() {
            if !e.weight.is_finite() || e.weight <= 0.0 {
                return Err(anyhow!("entry {i}: weight must be finite and > 0"));
            }
            if let Some(slo) = e.slo {
                if !slo.is_finite() || slo <= 0.0 {
                    return Err(anyhow!("entry {i}: slo must be finite and > 0"));
                }
            }
            e.process.validate().map_err(|err| anyhow!("entry {i}: {err}"))?;
            let seed = self.entry_seed(i, session_seed);
            let scenario = e.app.build(seed)?;
            if scenario.workloads.iter().all(|w| w.is_empty()) {
                return Err(anyhow!("entry {i}: app has an empty template pool"));
            }
            streams.push(arrivals::generate(&e.process, seed ^ ARRIVAL_SEED_SALT, horizon)?);
            parts.push(scenario);
        }
        let refs: Vec<&crate::runner::Scenario> = parts.iter().collect();
        let mut scenario = compose_scenarios(&refs, &self.display_name());
        let by_app = scenario.graph.nodes_by_app();
        let apps = parts
            .iter()
            .enumerate()
            .map(|(app_id, part)| TrafficApp {
                app_id,
                name: part.name.clone(),
                weight: self.entries[app_id].weight,
                slo: self.entries[app_id].slo,
                nodes: by_app[app_id].clone(),
                // Template pools: each arriving job replays one template
                // per node (traffic requests are independent — chain and
                // cross-node dependency structure is not replayed per
                // arrival; use the batch workload path for
                // dependency-faithful runs).
                pools: part
                    .workloads
                    .iter()
                    .map(|w| {
                        w.iter()
                            .map(|r| {
                                crate::runner::AppRequest::simple(
                                    r.id,
                                    r.input_len,
                                    r.true_output_len,
                                )
                            })
                            .collect()
                    })
                    .collect(),
                arrivals: streams[app_id].clone(),
            })
            .collect();
        // The open-loop run starts empty: requests enter only through the
        // admission queue.
        for w in scenario.workloads.iter_mut() {
            w.clear();
        }
        Ok(TrafficScenario {
            name: self.display_name(),
            scenario,
            apps,
            cfg: TrafficCfg {
                duration: self.duration,
                warmup: self.warmup,
                queue_capacity: self.queue_capacity,
                queue_policy: self.queue_policy,
                admit_quantum: if self.admit_quantum == 0 {
                    self.queue_capacity
                } else {
                    self.admit_quantum
                },
            },
        })
    }

    /// Serialize to a [`Json`] value (round-trips via
    /// [`TrafficSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("duration", Json::Num(self.duration)),
            ("warmup", Json::Num(self.warmup)),
            ("queue_capacity", Json::Num(self.queue_capacity as f64)),
            ("queue_policy", Json::Str(self.queue_policy.name().to_string())),
            ("admit_quantum", Json::Num(self.admit_quantum as f64)),
            ("entries", Json::Arr(self.entries.iter().map(|e| e.to_json()).collect())),
        ])
    }

    /// Parse from JSON: either the full object form or a bare entry array
    /// (the config file's `traffic: [...]` shorthand, default window and
    /// queue configuration).
    pub fn from_json(v: &Json) -> Result<Self> {
        let defaults = TrafficSpec::new(vec![]);
        let (name, arr, v) = match v.as_arr() {
            Some(arr) => (String::new(), arr, None),
            None => (
                v.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                v.get("entries")
                    .and_then(|e| e.as_arr())
                    .ok_or_else(|| anyhow!("traffic needs an entries array"))?,
                Some(v),
            ),
        };
        let entries =
            arr.iter().map(TrafficEntry::from_json).collect::<Result<Vec<_>>>()?;
        let get_f = |key: &str, default: f64| -> f64 {
            v.and_then(|v| v.get(key)).and_then(|x| x.as_f64()).unwrap_or(default)
        };
        let queue_policy = match v.and_then(|v| v.get("queue_policy")).and_then(|x| x.as_str())
        {
            None => defaults.queue_policy,
            Some(s) => QueuePolicy::parse(s)?,
        };
        Ok(TrafficSpec {
            name,
            entries,
            duration: get_f("duration", defaults.duration),
            warmup: get_f("warmup", defaults.warmup),
            queue_capacity: v
                .and_then(|v| v.get("queue_capacity"))
                .and_then(|x| x.as_usize())
                .unwrap_or(defaults.queue_capacity),
            queue_policy,
            admit_quantum: v
                .and_then(|v| v.get("admit_quantum"))
                .and_then(|x| x.as_usize())
                .unwrap_or(defaults.admit_quantum),
        })
    }

    /// Serialize to a compact JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse a traffic mix from a JSON document string.
    pub fn parse(s: &str) -> Result<Self> {
        let v = Json::parse(s).map_err(|e| anyhow!("bad traffic json: {e}"))?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrafficSpec {
        TrafficSpec {
            name: "pair".into(),
            entries: vec![
                TrafficEntry::poisson(AppSpec::ensembling(50, 96), 2.0),
                TrafficEntry {
                    app: AppSpec::ensembling(50, 96),
                    process: ArrivalSpec::OnOff {
                        rate_on: 8.0,
                        rate_off: 0.0,
                        mean_on: 5.0,
                        mean_off: 15.0,
                    },
                    weight: 2.0,
                    slo: Some(45.0),
                    seed: Some(9),
                },
            ],
            duration: 60.0,
            warmup: 10.0,
            queue_capacity: 16,
            queue_policy: QueuePolicy::Defer,
            admit_quantum: 4,
        }
    }

    #[test]
    fn json_roundtrip_object_and_array_forms() {
        let ts = sample();
        let back = TrafficSpec::parse(&ts.to_json_string()).unwrap();
        assert_eq!(back, ts);
        assert_eq!(back.to_json_string(), ts.to_json_string());
        // Bare-array shorthand: entries only, default window/queue knobs.
        let arr = r#"[{"app":{"kind":"ensembling"},"process":{"kind":"poisson","rate":5}},
                      {"app":{"kind":"chain_summary"},
                       "process":{"kind":"trace","path":"arr.txt"},"weight":0.5}]"#;
        let ts = TrafficSpec::parse(arr).unwrap();
        assert_eq!(ts.entries.len(), 2);
        assert_eq!(ts.display_name(), "traffic-2apps");
        assert_eq!(ts.duration, 120.0);
        assert_eq!(ts.queue_capacity, 64);
        assert_eq!(ts.queue_policy, QueuePolicy::Reject);
        assert_eq!(ts.entries[0].process, ArrivalSpec::Poisson { rate: 5.0 });
        assert_eq!(ts.entries[1].weight, 0.5);
        assert_eq!(ts.entries[1].process, ArrivalSpec::Trace { path: "arr.txt".into() });
        assert_eq!(ts.entries[0].slo, None);
    }

    #[test]
    fn entry_seed_defaults_and_overrides() {
        let ts = sample();
        assert_eq!(ts.entry_seed(0, 42), 42, "entry 0 inherits the session seed");
        assert_eq!(ts.entry_seed(1, 42), 9, "explicit override wins");
    }

    #[test]
    fn build_materialises_streams_and_validates() {
        let ts = sample();
        let sc = ts.build(7).unwrap();
        assert_eq!(sc.name, "pair");
        assert_eq!(sc.apps.len(), 2);
        assert_eq!(sc.cfg.duration, 60.0);
        assert_eq!(sc.cfg.warmup, 10.0);
        assert_eq!(sc.cfg.admit_quantum, 4);
        // The open-loop run starts empty; templates live in the pools.
        assert!(sc.scenario.workloads.iter().all(|w| w.is_empty()));
        for app in &sc.apps {
            assert_eq!(app.pools.len(), app.nodes.len());
            assert!(app.pools.iter().all(|p| !p.is_empty()));
            // Arrivals are sorted and inside the horizon.
            assert!(app.arrivals.windows(2).all(|w| w[0] <= w[1]));
            assert!(app.arrivals.iter().all(|&t| (0.0..ts.horizon()).contains(&t)));
        }
        // Poisson at 2/s over 70 s generates a non-trivial stream.
        assert!(sc.apps[0].arrivals.len() > 30, "{}", sc.apps[0].arrivals.len());
        // Same seed → bit-identical streams; different seed → different.
        let again = ts.build(7).unwrap();
        assert_eq!(sc.apps[0].arrivals, again.apps[0].arrivals);
        let other = ts.build(8).unwrap();
        assert_ne!(sc.apps[0].arrivals, other.apps[0].arrivals);

        assert!(TrafficSpec::new(vec![]).build(1).is_err());
        let mut bad = sample();
        bad.duration = 0.0;
        assert!(bad.build(1).is_err());
        let mut bad = sample();
        bad.entries[0].weight = -1.0;
        assert!(bad.build(1).is_err());
        let mut bad = sample();
        bad.entries[0].process = ArrivalSpec::Poisson { rate: 0.0 };
        assert!(bad.build(1).is_err());
        let mut bad = sample();
        bad.queue_capacity = 0;
        assert!(bad.build(1).is_err());
    }

    #[test]
    fn cli_descriptor_parses_knobs_and_rejects_unknown_keys() {
        let e = TrafficEntry::parse_cli("ensembling:rate=5:weight=2").unwrap();
        assert_eq!(e.app, AppSpec::ensembling(1000, 256));
        assert_eq!(e.process, ArrivalSpec::Poisson { rate: 5.0 });
        assert_eq!(e.weight, 2.0);
        assert_eq!(e.slo, None);
        let e = TrafficEntry::parse_cli(
            "chain-summary:n-docs=40:process=on-off:rate-on=8:mean-on=10:mean-off=30:slo=60",
        )
        .unwrap();
        assert_eq!(e.app, AppSpec::chain_summary(40, 2, 256));
        assert_eq!(
            e.process,
            ArrivalSpec::OnOff { rate_on: 8.0, rate_off: 0.0, mean_on: 10.0, mean_off: 30.0 }
        );
        assert_eq!(e.slo, Some(60.0));
        // trace= implies the trace process; rate-on implies on-off.
        let e = TrafficEntry::parse_cli("ensembling:trace=a.txt:seed=3").unwrap();
        assert_eq!(e.process, ArrivalSpec::Trace { path: "a.txt".into() });
        assert_eq!(e.seed, Some(3));
        let e = TrafficEntry::parse_cli(
            "ensembling:rate_on=4:mean_on=5:mean_off=5:rate_off=1",
        )
        .unwrap();
        assert!(matches!(e.process, ArrivalSpec::OnOff { rate_off, .. } if rate_off == 1.0));
        // Missing required knobs, unknown keys and bad values error.
        assert!(TrafficEntry::parse_cli("ensembling").is_err(), "poisson needs rate=");
        assert!(TrafficEntry::parse_cli("ensembling:process=on-off:rate-on=4").is_err());
        assert!(TrafficEntry::parse_cli("ensembling:process=uniform:rate=1").is_err());
        assert!(TrafficEntry::parse_cli("ensembling:rate=fast").is_err());
        assert!(TrafficEntry::parse_cli("ensembling:bogus=1").is_err());
        assert!(TrafficEntry::parse_cli("").is_err());
        // Inapplicable app knobs are rejected by the app builder itself.
        assert!(TrafficEntry::parse_cli("ensembling:n-docs=5:rate=1").is_err());
    }
}
