//! Application computation graphs (§3, Fig. 5).
//!
//! Nodes are LLMs, edges are data flows. Self-loops (chain summary's
//! update-the-summary loop) are handled by *fusing*: the node keeps its
//! identity and its requests form in-engine chains instead (§4.2 "we
//! heuristically fuse the nodes ... with self-loops into one node").

use std::collections::HashSet;

/// One LLM node in the application graph.
#[derive(Debug, Clone)]
pub struct AppNode {
    /// Node id (index into [`AppGraph::nodes`]).
    pub id: usize,
    /// Registry name of the LLM this node runs.
    pub model: String,
    /// Human-readable role ("summarizer", "evaluator", …).
    pub label: String,
    /// Output-length limit applied to this node's requests.
    pub max_out: u32,
}

/// A multi-LLM application graph (acyclic after self-loop fusion).
#[derive(Debug, Clone, Default)]
pub struct AppGraph {
    /// The LLM nodes, indexed by id.
    pub nodes: Vec<AppNode>,
    /// Directed data-flow edges (producer, consumer). No self-edges after
    /// fusion.
    pub edges: Vec<(usize, usize)>,
}

impl AppGraph {
    /// Append an LLM node; returns its id.
    pub fn add_node(&mut self, model: &str, label: &str, max_out: u32) -> usize {
        let id = self.nodes.len();
        self.nodes.push(AppNode {
            id,
            model: model.to_string(),
            label: label.to_string(),
            max_out,
        });
        id
    }

    /// Add a data-flow edge `from -> to`. Panics on out-of-range ids or
    /// self-loops (fuse those into request chains instead).
    pub fn add_edge(&mut self, from: usize, to: usize) {
        assert!(from < self.nodes.len() && to < self.nodes.len());
        assert_ne!(from, to, "self-loops must be fused into chains, not edges");
        self.edges.push((from, to));
    }

    /// Number of nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Producers feeding `node`.
    pub fn inputs_of(&self, node: usize) -> Vec<usize> {
        self.edges.iter().filter(|&&(_, t)| t == node).map(|&(f, _)| f).collect()
    }

    /// The §3 readiness rule: a node may run in a stage iff each input
    /// node is finished, or is itself selected in the same stage
    /// (model-level pipeline parallelism).
    pub fn is_ready(
        &self,
        node: usize,
        finished: &HashSet<usize>,
        in_stage: &HashSet<usize>,
    ) -> bool {
        self.inputs_of(node)
            .iter()
            .all(|i| finished.contains(i) || in_stage.contains(i))
    }

    /// Nodes eligible for a new stage given finished/co-scheduled sets.
    pub fn ready_nodes(&self, finished: &HashSet<usize>, in_stage: &HashSet<usize>) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|n| !finished.contains(n))
            .filter(|&n| self.is_ready(n, finished, in_stage))
            .collect()
    }

    /// Topological order of `subset` (falls back to id order inside
    /// independent groups). Panics on cycles — graphs are acyclic by
    /// construction.
    pub fn topo_order(&self, subset: &[usize]) -> Vec<usize> {
        let set: HashSet<usize> = subset.iter().copied().collect();
        let mut indeg: std::collections::HashMap<usize, usize> =
            subset.iter().map(|&n| (n, 0)).collect();
        for &(f, t) in &self.edges {
            if set.contains(&f) && set.contains(&t) {
                *indeg.get_mut(&t).unwrap() += 1;
            }
        }
        let mut queue: Vec<usize> = subset.iter().copied().filter(|n| indeg[n] == 0).collect();
        queue.sort_unstable();
        let mut out = vec![];
        while let Some(n) = queue.pop() {
            out.push(n);
            for &(f, t) in &self.edges {
                if f == n && set.contains(&t) {
                    let d = indeg.get_mut(&t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        queue.push(t);
                    }
                }
            }
            queue.sort_unstable();
            queue.reverse(); // pop smallest id first
        }
        assert_eq!(out.len(), subset.len(), "cycle in application graph");
        out
    }

    /// Check acyclicity of the whole graph.
    pub fn is_acyclic(&self) -> bool {
        let all: Vec<usize> = (0..self.nodes.len()).collect();
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.topo_order(&all))).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> AppGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        let mut g = AppGraph::default();
        for i in 0..4 {
            g.add_node("chatglm3-6b", &format!("n{i}"), 256);
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn readiness_follows_edges() {
        let g = diamond();
        let none = HashSet::new();
        assert_eq!(g.ready_nodes(&none, &none), vec![0]);
        let fin: HashSet<usize> = [0].into();
        let ready = g.ready_nodes(&fin, &none);
        assert_eq!(ready, vec![1, 2]);
    }

    #[test]
    fn pipeline_readiness_with_costage() {
        // Node 1 is ready if node 0 is in the same stage (pipeline).
        let g = diamond();
        let fin = HashSet::new();
        let stage: HashSet<usize> = [0].into();
        assert!(g.is_ready(1, &fin, &stage));
        assert!(!g.is_ready(3, &fin, &stage));
    }

    #[test]
    fn topo_respects_edges() {
        let g = diamond();
        let order = g.topo_order(&[0, 1, 2, 3]);
        let pos = |n: usize| order.iter().position(|&x| x == n).unwrap();
        assert!(pos(0) < pos(1));
        assert!(pos(0) < pos(2));
        assert!(pos(1) < pos(3));
        assert!(pos(2) < pos(3));
    }

    #[test]
    fn independent_nodes_all_ready() {
        let mut g = AppGraph::default();
        for i in 0..6 {
            g.add_node("alpaca-13b", &format!("m{i}"), 256);
        }
        let none = HashSet::new();
        assert_eq!(g.ready_nodes(&none, &none).len(), 6);
        assert!(g.is_acyclic());
    }

    #[test]
    #[should_panic]
    fn self_edges_rejected() {
        let mut g = AppGraph::default();
        let n = g.add_node("alpaca-13b", "x", 256);
        g.add_edge(n, n);
    }
}
