//! Application computation graphs (§3, Fig. 5).
//!
//! Nodes are LLMs, edges are data flows. Self-loops (chain summary's
//! update-the-summary loop) are handled by *fusing*: the node keeps its
//! identity and its requests form in-engine chains instead (§4.2 "we
//! heuristically fuse the nodes ... with self-loops into one node").

use std::collections::HashSet;

/// One LLM node in the application graph.
#[derive(Debug, Clone)]
pub struct AppNode {
    /// Node id (index into [`AppGraph::nodes`]).
    pub id: usize,
    /// Registry name of the LLM this node runs.
    pub model: String,
    /// Human-readable role ("summarizer", "evaluator", …).
    pub label: String,
    /// Output-length limit applied to this node's requests.
    pub max_out: u32,
    /// Workload provenance: which application instance of a composed
    /// multi-app graph this node belongs to (`0` for single-app graphs).
    /// Two nodes running the same LLM for two different apps keep two
    /// distinct `(app, local_id)` identities — placement owners are node
    /// ids, so they stay two model instances.
    pub app: usize,
    /// Workload provenance: the node's id inside its app's own graph
    /// (`== id` for single-app graphs).
    pub local_id: usize,
}

/// A multi-LLM application graph (acyclic after self-loop fusion).
#[derive(Debug, Clone, Default)]
pub struct AppGraph {
    /// The LLM nodes, indexed by id.
    pub nodes: Vec<AppNode>,
    /// Directed data-flow edges (producer, consumer). No self-edges after
    /// fusion.
    pub edges: Vec<(usize, usize)>,
}

impl AppGraph {
    /// Append an LLM node; returns its id. Provenance defaults to app 0 /
    /// `local_id == id` (a single-app graph); [`AppGraph::compose`]
    /// rewrites it for multi-app compositions.
    pub fn add_node(&mut self, model: &str, label: &str, max_out: u32) -> usize {
        let id = self.nodes.len();
        self.nodes.push(AppNode {
            id,
            model: model.to_string(),
            label: label.to_string(),
            max_out,
            app: 0,
            local_id: id,
        });
        id
    }

    /// Disjoint union of `parts` into one multi-app graph: part `i`'s
    /// nodes are appended in order with provenance `(app = i, local_id =
    /// their id inside part i)` and its edges are offset accordingly. The
    /// same LLM appearing in two parts yields two distinct nodes (hence
    /// two model instances at placement time). Node/edge order is exactly
    /// "all of part 0, then part 1, …", which keeps the legacy
    /// [`crate::apps::mixed::merge`] composition bit-identical.
    ///
    /// Composing already-composed graphs flattens provenance: every node
    /// of part `i` is re-stamped `app = i` regardless of its prior `app`.
    pub fn compose(parts: &[&AppGraph]) -> AppGraph {
        let mut g = AppGraph::default();
        for (app_id, part) in parts.iter().enumerate() {
            let offset = g.nodes.len();
            for n in &part.nodes {
                let id = g.add_node(&n.model, &n.label, n.max_out);
                g.nodes[id].app = app_id;
                g.nodes[id].local_id = n.id;
            }
            for &(f, t) in &part.edges {
                g.add_edge(f + offset, t + offset);
            }
        }
        g
    }

    /// Global node ids belonging to each app of a composed graph,
    /// grouped by `app` (index = app id). Single-app graphs return one
    /// group holding every node.
    pub fn nodes_by_app(&self) -> Vec<Vec<usize>> {
        let n_apps = self.nodes.iter().map(|n| n.app + 1).max().unwrap_or(0);
        let mut out = vec![vec![]; n_apps];
        for n in &self.nodes {
            out[n.app].push(n.id);
        }
        out
    }

    /// Add a data-flow edge `from -> to`. Panics on out-of-range ids or
    /// self-loops (fuse those into request chains instead).
    pub fn add_edge(&mut self, from: usize, to: usize) {
        assert!(from < self.nodes.len() && to < self.nodes.len());
        assert_ne!(from, to, "self-loops must be fused into chains, not edges");
        self.edges.push((from, to));
    }

    /// Number of nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Producers feeding `node`.
    pub fn inputs_of(&self, node: usize) -> Vec<usize> {
        self.edges.iter().filter(|&&(_, t)| t == node).map(|&(f, _)| f).collect()
    }

    /// The §3 readiness rule: a node may run in a stage iff each input
    /// node is finished, or is itself selected in the same stage
    /// (model-level pipeline parallelism).
    pub fn is_ready(
        &self,
        node: usize,
        finished: &HashSet<usize>,
        in_stage: &HashSet<usize>,
    ) -> bool {
        self.inputs_of(node)
            .iter()
            .all(|i| finished.contains(i) || in_stage.contains(i))
    }

    /// Nodes eligible for a new stage given finished/co-scheduled sets.
    pub fn ready_nodes(&self, finished: &HashSet<usize>, in_stage: &HashSet<usize>) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|n| !finished.contains(n))
            .filter(|&n| self.is_ready(n, finished, in_stage))
            .collect()
    }

    /// Topological order of `subset` (falls back to id order inside
    /// independent groups). Panics on cycles — graphs are acyclic by
    /// construction.
    pub fn topo_order(&self, subset: &[usize]) -> Vec<usize> {
        let set: HashSet<usize> = subset.iter().copied().collect();
        let mut indeg: std::collections::HashMap<usize, usize> =
            subset.iter().map(|&n| (n, 0)).collect();
        for &(f, t) in &self.edges {
            if set.contains(&f) && set.contains(&t) {
                *indeg.get_mut(&t).unwrap() += 1;
            }
        }
        let mut queue: Vec<usize> = subset.iter().copied().filter(|n| indeg[n] == 0).collect();
        queue.sort_unstable();
        let mut out = vec![];
        while let Some(n) = queue.pop() {
            out.push(n);
            for &(f, t) in &self.edges {
                if f == n && set.contains(&t) {
                    let d = indeg.get_mut(&t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        queue.push(t);
                    }
                }
            }
            queue.sort_unstable();
            queue.reverse(); // pop smallest id first
        }
        assert_eq!(out.len(), subset.len(), "cycle in application graph");
        out
    }

    /// Check acyclicity of the whole graph.
    pub fn is_acyclic(&self) -> bool {
        let all: Vec<usize> = (0..self.nodes.len()).collect();
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.topo_order(&all))).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> AppGraph {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        let mut g = AppGraph::default();
        for i in 0..4 {
            g.add_node("chatglm3-6b", &format!("n{i}"), 256);
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn readiness_follows_edges() {
        let g = diamond();
        let none = HashSet::new();
        assert_eq!(g.ready_nodes(&none, &none), vec![0]);
        let fin: HashSet<usize> = [0].into();
        let ready = g.ready_nodes(&fin, &none);
        assert_eq!(ready, vec![1, 2]);
    }

    #[test]
    fn pipeline_readiness_with_costage() {
        // Node 1 is ready if node 0 is in the same stage (pipeline).
        let g = diamond();
        let fin = HashSet::new();
        let stage: HashSet<usize> = [0].into();
        assert!(g.is_ready(1, &fin, &stage));
        assert!(!g.is_ready(3, &fin, &stage));
    }

    #[test]
    fn topo_respects_edges() {
        let g = diamond();
        let order = g.topo_order(&[0, 1, 2, 3]);
        let pos = |n: usize| order.iter().position(|&x| x == n).unwrap();
        assert!(pos(0) < pos(1));
        assert!(pos(0) < pos(2));
        assert!(pos(1) < pos(3));
        assert!(pos(2) < pos(3));
    }

    #[test]
    fn independent_nodes_all_ready() {
        let mut g = AppGraph::default();
        for i in 0..6 {
            g.add_node("alpaca-13b", &format!("m{i}"), 256);
        }
        let none = HashSet::new();
        assert_eq!(g.ready_nodes(&none, &none).len(), 6);
        assert!(g.is_acyclic());
    }

    #[test]
    #[should_panic]
    fn self_edges_rejected() {
        let mut g = AppGraph::default();
        let n = g.add_node("alpaca-13b", "x", 256);
        g.add_edge(n, n);
    }

    #[test]
    fn compose_offsets_nodes_edges_and_stamps_provenance() {
        let a = diamond();
        let mut b = AppGraph::default();
        b.add_node("alpaca-13b", "solo0", 128);
        b.add_node("alpaca-13b", "solo1", 128);
        b.add_edge(0, 1);
        let g = AppGraph::compose(&[&a, &b]);
        assert_eq!(g.n_nodes(), 6);
        assert_eq!(g.edges.len(), a.edges.len() + 1);
        // Part order is preserved: a's edges first, then b's offset by 4.
        assert_eq!(&g.edges[..a.edges.len()], &a.edges[..]);
        assert_eq!(g.edges[a.edges.len()], (4, 5));
        assert!(g.is_acyclic());
        // Provenance round-trips: (app, local_id) recovers the part node.
        for n in &g.nodes {
            let part = if n.app == 0 { &a } else { &b };
            let local = &part.nodes[n.local_id];
            assert_eq!(n.model, local.model);
            assert_eq!(n.label, local.label);
            assert_eq!(n.max_out, local.max_out);
        }
        assert_eq!(g.nodes_by_app(), vec![vec![0, 1, 2, 3], vec![4, 5]]);
        // The same LLM in both parts stays two instances (distinct ids).
        assert_ne!(g.nodes[1].id, g.nodes[4].id);
    }

    #[test]
    fn single_app_graphs_default_provenance() {
        let g = diamond();
        for n in &g.nodes {
            assert_eq!(n.app, 0);
            assert_eq!(n.local_id, n.id);
        }
        assert_eq!(g.nodes_by_app().len(), 1);
        assert!(AppGraph::default().nodes_by_app().is_empty());
    }
}
