//! The planning phase: greedy application-plan search (§4.2, Algorithm 1).
//!
//! [`greedy`] runs the stage-by-stage search; [`eval`] scores candidate
//! stages concurrently with a deterministic reduction; [`simcache`]
//! memoizes the underlying single-node simulations so unchanged
//! candidates are never re-simulated — across greedy iterations, and
//! across whole searches when they share one
//! [`crate::runner::RunContext::sim_cache`] (a session re-running or
//! comparing scenarios plans against a warm cache).

pub mod eval;
pub mod greedy;
pub mod simcache;

pub use eval::{EvalStats, Evaluator};
pub use greedy::{GreedyPlanner, PlannedApp};
pub use simcache::{SimCache, SimCacheStats, SimKey};
