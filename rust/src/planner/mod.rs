//! The planning phase: greedy application-plan search (§4.2, Algorithm 1).

pub mod greedy;

pub use greedy::{GreedyPlanner, PlannedApp};
