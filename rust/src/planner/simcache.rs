//! Memoized simulation outcomes for planner candidate scoring.
//!
//! Algorithm 1 re-scores the same `(model, plan)` candidates over and
//! over: across greedy iterations only the committed node's workload
//! changes, and when one [`crate::runner::RunContext`] plans several
//! searches (repeated or compared runs of a session) whole workloads
//! recur verbatim. [`SimCache`] memoizes the fast single-node simulation
//! behind a key that captures *everything* the outcome depends on —
//! model, plan, and a fingerprint of the node's remaining workload
//! (request ready state included) — so a hit is guaranteed to return
//! exactly what a fresh simulation would.
//!
//! Exactness matters: the planner's parity guarantee (parallel + cached
//! search ≡ sequential search) holds because cached values are
//! bit-identical to recomputed ones. Simulations are priced in *relative*
//! virtual time (see [`crate::runner::state::ExecState::simulate_node_fast`]),
//! so an outcome computed at clock `t` is valid verbatim at any other
//! clock.
//!
//! ## Delta keys and incremental re-simulation
//!
//! The same mechanism makes mid-run re-planning *incremental*. A replan
//! ([`crate::planner::GreedyPlanner::plan_from_state`]) prices the
//! remaining application from a state that differs from the previous
//! search only where execution made progress: most nodes' remaining
//! workloads — the unchanged suffix of the run — hash to the exact
//! fingerprints the previous search already priced. Those [`SimKey`]s
//! act as **delta keys**: an equal key proves nothing the outcome
//! depends on changed, so the node *resumes* from its memoized outcome
//! ([`crate::runner::state::ExecState::simulate_node_from`]) instead of
//! re-simulating; only nodes whose requests progressed, whose
//! predictions were refreshed, or whose candidate plan/loading differs
//! miss and re-price. Sharing one cache across a run's searches (the
//! [`crate::runner::RunContext::sim_cache`] wiring) is what turns
//! repeated replans from full re-simulations into delta work.
//!
//! A `SimCache` is scoped to one cost model + cluster (one
//! [`crate::runner::RunContext`]); sharing it across differently
//! calibrated contexts would alias keys to different truths.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::engine::sim::SimOutcome;
use crate::plan::ExecPlan;

/// Incremental FNV-1a hasher over 64-bit words (deterministic across
/// runs and platforms, unlike `DefaultHasher` state).
#[derive(Debug, Clone, Copy)]
pub struct Fnv(u64);

impl Fnv {
    /// Start a fresh hash with the FNV-1a offset basis.
    pub fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    /// Mix one word into the hash.
    pub fn push(&mut self, word: u64) {
        for byte in word.to_le_bytes() {
            self.0 ^= byte as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// Final hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// Cache key: everything a fast single-node candidate simulation depends
/// on besides the (fixed per cache) cost model, cluster memory and
/// registry.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SimKey {
    /// Registry name of the candidate's model.
    pub model: String,
    /// Candidate execution plan `(dp, tp)`.
    pub plan: ExecPlan,
    /// Fingerprint of the node's remaining workload as the estimator sees
    /// it (request ids, lengths, progress, chain/block structure and
    /// ready state; see
    /// [`crate::runner::state::ExecState::node_workload_fingerprint`]).
    pub workload_fp: u64,
    /// Exact bit pattern of the model-loading delay ahead of the
    /// simulation (`0.0` when the plan is kept resident). Bits, not a
    /// rounded value: a hit must reproduce a fresh run exactly.
    pub load_bits: u64,
}

impl SimKey {
    /// Build a key from the estimator's inputs.
    pub fn new(model: &str, plan: ExecPlan, workload_fp: u64, load_delay: f64) -> Self {
        SimKey { model: model.to_string(), plan, workload_fp, load_bits: load_delay.to_bits() }
    }
}

/// Point-in-time counters of a [`SimCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimCacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to run a fresh simulation.
    pub misses: u64,
    /// Distinct keys currently stored.
    pub entries: usize,
}

/// Thread-safe memo table of single-node simulation outcomes.
///
/// Interior mutability (a mutex around the map, atomics for counters)
/// lets one cache hang off a shared `&`[`crate::runner::RunContext`] and
/// serve concurrent evaluator threads. The mutex is never held while a
/// simulation runs, so parallel misses proceed without serializing; two
/// threads racing on the same key both compute the same value and the
/// insert is idempotent.
#[derive(Debug, Default)]
pub struct SimCache {
    map: Mutex<HashMap<SimKey, SimOutcome>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SimCache {
    /// An empty cache.
    pub fn new() -> Self {
        SimCache::default()
    }

    /// Whether `key` is present, without touching the hit/miss counters
    /// (used by the evaluator to decide if spawning workers is worth it).
    pub fn contains(&self, key: &SimKey) -> bool {
        self.map.lock().unwrap().contains_key(key)
    }

    /// Look `key` up, counting the hit or miss.
    pub fn lookup(&self, key: &SimKey) -> Option<SimOutcome> {
        let found = self.map.lock().unwrap().get(key).cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Store an outcome (idempotent for racing equal computations).
    pub fn insert(&self, key: SimKey, outcome: SimOutcome) {
        self.map.lock().unwrap().insert(key, outcome);
    }

    /// Return the cached outcome for `key`, or run `compute` (outside the
    /// lock) and memoize its result.
    pub fn get_or_compute(
        &self,
        key: SimKey,
        compute: impl FnOnce() -> SimOutcome,
    ) -> SimOutcome {
        if let Some(hit) = self.lookup(&key) {
            return hit;
        }
        let outcome = compute();
        self.insert(key, outcome.clone());
        outcome
    }

    /// Lookups answered from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that missed so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct keys stored.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the counters.
    pub fn stats(&self) -> SimCacheStats {
        SimCacheStats { hits: self.hits(), misses: self.misses(), entries: self.len() }
    }

    /// Drop all entries and reset the counters.
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::costmodel::CostModel;
    use crate::models::Registry;
    use crate::runner::state::{AppRequest, ExecState};

    fn fixture() -> (ExecState, Registry, CostModel, ClusterSpec) {
        let cluster = ClusterSpec::a100_node(8);
        let cost = CostModel::calibrated(&cluster, 11);
        let w: Vec<Vec<AppRequest>> = vec![
            (0..80).map(|i| AppRequest::simple(i, 25, 60 + (i % 40) as u32)).collect(),
        ];
        let st = ExecState::init(&w, |_, r| r.true_output_len);
        (st, Registry::paper(), cost, cluster)
    }

    fn graph() -> crate::graph::AppGraph {
        let mut g = crate::graph::AppGraph::default();
        g.add_node("chatglm3-6b", "a", 256);
        g
    }

    #[test]
    fn hit_returns_the_same_outcome_as_a_fresh_simulation() {
        let (st, reg, cost, cluster) = fixture();
        let g = graph();
        let plan = ExecPlan::new(2, 1);
        let fresh = st.simulate_node_fast(
            0,
            plan,
            &g,
            &reg,
            &cost.iter_model,
            cluster.mem_bytes,
            0.0,
        );
        let cache = SimCache::new();
        let key = SimKey::new("chatglm3-6b", plan, st.node_workload_fingerprint(0), 0.0);
        let first = cache.get_or_compute(key.clone(), || {
            st.simulate_node_fast(0, plan, &g, &reg, &cost.iter_model, cluster.mem_bytes, 0.0)
        });
        // Second lookup must be served from the cache...
        let second = cache.get_or_compute(key.clone(), || panic!("expected a cache hit"));
        // ...and both must equal a from-scratch simulation, bit for bit.
        assert_eq!(first, fresh);
        assert_eq!(second, fresh);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn workload_changes_invalidate_the_key() {
        let (st, _, _, _) = fixture();
        let fp0 = st.node_workload_fingerprint(0);
        // Progress on a single request must change the fingerprint.
        let mut progressed = st.clone();
        progressed.nodes[0][3].generated += 1;
        assert_ne!(progressed.node_workload_fingerprint(0), fp0);
        // Completing a request (it drops out of the remaining set) too.
        let mut completed = st.clone();
        completed.nodes[0][0].generated = completed.nodes[0][0].output_len;
        assert_ne!(completed.node_workload_fingerprint(0), fp0);
        // An untouched clone keeps the exact fingerprint.
        assert_eq!(st.clone().node_workload_fingerprint(0), fp0);
        // And distinct keys are distinct cache entries, not overwrites.
        let cache = SimCache::new();
        let plan = ExecPlan::new(1, 1);
        let k0 = SimKey::new("chatglm3-6b", plan, fp0, 0.0);
        let k1 = SimKey::new("chatglm3-6b", plan, progressed.node_workload_fingerprint(0), 0.0);
        cache.insert(k0.clone(), SimOutcome { clock: 1.0, ..Default::default() });
        cache.insert(k1.clone(), SimOutcome { clock: 2.0, ..Default::default() });
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.lookup(&k0).unwrap().clock, 1.0);
        assert_eq!(cache.lookup(&k1).unwrap().clock, 2.0);
    }

    #[test]
    fn load_delay_and_plan_are_part_of_the_key() {
        let (st, _, _, _) = fixture();
        let fp = st.node_workload_fingerprint(0);
        let a = SimKey::new("chatglm3-6b", ExecPlan::new(2, 1), fp, 0.0);
        let b = SimKey::new("chatglm3-6b", ExecPlan::new(2, 1), fp, 11.5);
        let c = SimKey::new("chatglm3-6b", ExecPlan::new(4, 1), fp, 0.0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        let cache = SimCache::new();
        cache.insert(a, SimOutcome::default());
        assert!(cache.lookup(&b).is_none());
        assert!(cache.lookup(&c).is_none());
        assert_eq!(cache.stats(), SimCacheStats { hits: 0, misses: 2, entries: 1 });
    }

    #[test]
    fn clear_resets_counters_and_entries() {
        let cache = SimCache::new();
        let key = SimKey::new("m", ExecPlan::new(1, 1), 7, 0.0);
        cache.get_or_compute(key.clone(), SimOutcome::default);
        cache.get_or_compute(key, || panic!("hit expected"));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!((cache.hits(), cache.misses()), (0, 0));
    }
}
